"""Quickstart: the paper's Fig.-1 pipeline end to end on one GEMM.

    python examples/quickstart.py

Traces a Python kernel (the SYCL role), lowers TensorIR -> LoopIR,
applies the paper's two schedules plus the TPU-native one, prints the
IR after every stage, the TABLE-I-style cycle/resource reports, and
validates every backend against numpy.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.core.frontend as fe
from repro.core import compile_gemm, run_pipeline, spec, trace


def main():
    # ---- 1. frontend: write the kernel in the host language ----
    def kernel(a, b, bias):
        return fe.relu(fe.matmul(a, b) + bias)

    graph = trace(kernel, [spec((64, 32)), spec((32, 16)), spec((16,))])
    print("== TensorIR (MLIR role) ==")
    print(graph, "\n")

    # ---- 2. run the declarative pass pipeline, dumping each stage ----
    result = run_pipeline(
        graph,
        "lower{tile_m=16,tile_n=16,tile_k=16},fuse-epilogue,grid{vars=3},"
        "emit-pallas",
        dump=True)
    for stage in result.trace[1:]:
        print(stage[:800], "\n")

    # ---- 3. validate: pallas kernel vs numpy (paper §II.B) ----
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 32)).astype(np.float32)
    b = rng.standard_normal((32, 16)).astype(np.float32)
    bias = rng.standard_normal((16,)).astype(np.float32)
    out = np.asarray(result.artifact(a, b, bias))
    want = np.maximum(a @ b + bias, 0)
    print("pallas vs numpy max err:", np.abs(out - want).max())
    assert np.allclose(out, want, atol=1e-4)

    # ---- 4. the paper's schedule study (TABLE I / Fig. 3) ----
    print("\n== schedule study, 32x32 GEMM ==")
    for sched in ("nested", "inner_flattened", "tpu_mxu_kgrid"):
        ck = compile_gemm(32, 32, 32, schedule=sched,
                          want_jax=False, want_pallas=False)
        print(f"{sched:18s} {ck.cycles}  {ck.resources}")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
