"""Extensibility demo — the paper's "reusable and extensible" claim.

Registers (1) a NEW TensorIR op, (2) a NEW scheduling pass, and (3) a
NEW canonicalization rewrite pattern from *outside* the core package,
then compiles a kernel using all three through the standard pipeline
string.  No core files are modified.

    python examples/extend_pipeline.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.core.frontend as fe
from repro.core import register_op, register_pass, run_pipeline, spec, trace
from repro.core.loop_ir import LoopKind
from repro.core.tensor_ir import TensorType


# ---- 1. a third-party op: leaky_relu -------------------------------------

def _infer_leaky(in_types, attrs):
    return in_types[0]


if "leaky_relu" not in __import__("repro.core.tensor_ir",
                                  fromlist=["OP_REGISTRY"]).OP_REGISTRY:
    register_op("leaky_relu", _infer_leaky,
                lambda a, **at: np.where(a > 0, a, at.get("alpha", 0.1) * a))


# ---- 2. a third-party pass: unroll-all-innermost ---------------------------

@register_pass("unroll-innermost-all", "loop",
               "flatten every innermost loop (third-party demo pass)")
def _unroll_all(kernel):
    for loop in kernel.loops():
        if not any(hasattr(s, "body") for s in loop.body):
            loop.kind = LoopKind.UNROLLED
    kernel.verify()
    return kernel


# ---- 3. a third-party canonicalization pattern ------------------------------
# fold the no-op neg(neg(x)) chain: the canonicalize pass picks the rule
# up at tensor level and reports its hits like any built-in pattern
# (dead-op-elim then collects the orphaned inner neg).

from repro.core import CANONICAL_PATTERNS, Pattern, register_canonical_pattern
from repro.core.rewrite import replace_value_uses


class FoldDoubleNeg(Pattern):
    """Fold ``neg(neg(x))`` to ``x`` (third-party demo pattern)."""

    name = "fold-double-neg"

    def match_and_rewrite(self, parent, siblings, i, root):
        op = siblings[i]
        if getattr(op, "opname", None) != "neg":
            return None
        prod = op.inputs[0].producer
        if prod is None or prod.opname != "neg":
            return None
        replace_value_uses(root, op.result, prod.inputs[0])
        return (1, [])


if not any(p.name == "fold-double-neg" for p in CANONICAL_PATTERNS["tensor"]):
    register_canonical_pattern("tensor")(FoldDoubleNeg)


def main():
    def f(a, b):
        return fe.matmul(a, b)

    g = trace(f, [spec((16, 16)), spec((16, 16))])
    res = run_pipeline(
        g, "lower{tile_m=4,tile_n=4,tile_k=4},unroll-innermost-all,"
           "emit-jax", dump=True)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    b = rng.standard_normal((16, 16)).astype(np.float32)
    out = np.asarray(res.artifact(a, b)[0])
    assert np.allclose(out, a @ b, atol=1e-4)
    print("third-party pass + op compiled and validated OK")

    # the new op works through the same tracer too
    g2 = trace(lambda x: x._emit("leaky_relu", alpha=0.05), [spec((8,))])
    (res2,) = g2.eval_np(np.asarray([-1.0, 2.0, -3.0, 4.0, 0.0, -0.5, 1.0,
                                     -2.0], np.float32))
    print("leaky_relu oracle:", res2)

    # the third-party canonicalization pattern fires through the standard
    # canonicalize pass, hit-counted like any built-in
    g3 = trace(lambda x: -(-x), [spec((4,))])
    res3 = run_pipeline(g3, "canonicalize")
    assert res3.records[0].pattern_stats.get("fold-double-neg") == 1
    assert not res3.artifact.ops, "neg(neg(x)) folds to the input"
    print("third-party canonicalization pattern fired:",
          res3.records[0].pattern_stats)


if __name__ == "__main__":
    main()
