"""End-to-end training driver: train a small LM for a few hundred steps
on the synthetic pipeline, with checkpointing and resume.

    python examples/train_lm.py                 # ~2M-param model, 200 steps
    python examples/train_lm.py --steps 50      # quicker
    python examples/train_lm.py --arch mamba2-130m   # SSM family

The same launcher scales to the full configs on real hardware via
``python -m repro.launch.train`` (see src/repro/launch/train.py).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import Model, RunConfig
from repro.optim import schedule as sched
from repro.optim.optimizer import adamw
from repro.train.step import TrainConfig, init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), layers=args.layers,
                  d_model=args.d_model, vocab=512)
    model = Model(cfg, RunConfig(max_seq=args.seq_len))
    print(f"arch family: {cfg.name}  params: {model.param_count():,}")

    opt = adamw(sched.make("wsd", peak=3e-3,
                           warmup_steps=max(args.steps // 20, 1),
                           total_steps=args.steps), weight_decay=0.01)
    step = jax.jit(make_train_step(model, opt, TrainConfig(microbatches=2)),
                   donate_argnums=(0,))
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size,
                               seq_len=args.seq_len,
                               global_batch=args.batch, seed=0))
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                      checkpoint_dir=args.ckpt, log_every=20),
        step, pipe)
    trainer.install_preemption_handler()
    state = init_state(model, opt, jax.random.PRNGKey(0))
    state = trainer.run(state)

    losses = [m["loss"] for m in trainer.metrics_history]
    if losses:
        k = min(10, max(1, len(losses) // 5))
        print(f"\nloss: {sum(losses[:k])/k:.4f} -> "
              f"{sum(losses[-k:])/k:.4f} over {len(losses)} steps "
              f"(straggler events: {trainer.straggler_events})")


if __name__ == "__main__":
    main()
