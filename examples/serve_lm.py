"""Batched serving example: prefill + decode with KV caches.

    python examples/serve_lm.py
    python examples/serve_lm.py --arch recurrentgemma-2b   # recurrent cache
    python examples/serve_lm.py --arch deepseek-v2-236b    # MLA compressed cache
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.model import Model, RunConfig
from repro.serve.engine import Engine, EngineConfig, throughput_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    max_len = args.prompt_len + args.gen + 1
    model = Model(cfg, RunConfig(max_seq=max_len))
    params = model.init(jax.random.PRNGKey(0))
    print(f"family: {cfg.name}  params: {model.param_count():,}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    eng = Engine(model, params,
                 EngineConfig(max_len=max_len,
                              temperature=args.temperature))
    stats = throughput_stats(eng, prompts, args.gen)
    out = eng.generate(prompts, args.gen)
    print(f"generated batch {out.shape}; "
          f"{stats['tok_per_s']:.1f} tok/s on this host")
    print("sample row:", out[0, :24], "...")


if __name__ == "__main__":
    main()
