"""Mamba-2 SSD (state-space duality) chunked scan — pallas kernel.

This realises the paper's future-work item (3): extending the GEMM-
centric compiler story to "tensor operations for machine learning".  The
SSD decomposition rewrites a linear recurrence as chunked *matmuls*
(MXU-friendly) plus a tiny inter-chunk state recurrence — i.e. the same
time-multiplexed-GEMM schedule the paper studies, applied to an SSM.

Math (per head h, chunk of length L, state dim N, head dim P):
    s_t   = cumsum(dt_t * A)                       (log-decay within chunk)
    y_t   = exp(s_t) * (C_t · h_in)                      [inter-chunk]
          + sum_{u<=t} exp(s_t - s_u) dt_u (C_t·B_u) x_u [intra, matmuls]
    h_out = exp(s_L) h_in + Σ_u exp(s_L - s_u) dt_u B_u x_u^T

Grid = (H, n_chunks); the chunk dimension iterates innermost and carries
the (P, N) state in VMEM scratch — constant on-chip footprint in S.
Validated in interpret mode against ``ref.ssd_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    c_id = pl.program_id(1)

    @pl.when(c_id == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[:, 0, :].astype(jnp.float32)       # (L, P)
    dt = dt_ref[:, 0].astype(jnp.float32)        # (L,)
    A = a_ref[0].astype(jnp.float32)             # scalar decay (negative)
    B = b_ref[...].astype(jnp.float32)           # (L, N)
    C = c_ref[...].astype(jnp.float32)           # (L, N)

    s = jnp.cumsum(dt * A)                       # (L,) log decay to t (incl.)
    seg = s[:, None] - s[None, :]                # s_t - s_u
    L_idx = jax.lax.iota(jnp.int32, chunk)
    causal = L_idx[:, None] >= L_idx[None, :]
    M = jnp.where(causal, jnp.exp(seg), 0.0)     # (L, L)

    h_in = state_ref[...]                        # (P, N)
    # inter-chunk contribution: exp(s_t) * C_t h_in
    y_inter = jnp.exp(s)[:, None] * jnp.dot(C, h_in.T,
                                            preferred_element_type=jnp.float32)
    # intra-chunk: (M ⊙ (C B^T)) @ (dt ⊙ x)
    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (L, L)
    y_intra = jnp.dot(M * CB, dt[:, None] * x,
                      preferred_element_type=jnp.float32)       # (L, P)
    y_ref[:, 0, :] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: h_out = exp(s_L) h_in + Σ_u exp(s_L - s_u) dt_u x_u B_u^T
    w = jnp.exp(s[-1] - s) * dt                   # (L,)
    h_new = jnp.exp(s[-1]) * h_in + jnp.dot(
        (w[:, None] * x).T, B, preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, D: jax.Array | None = None, *, chunk: int = 64,
             interpret: bool = True) -> jax.Array:
    """x: (S, H, P), dt: (S, H), A: (H,), B/C: (S, N) -> (S, H, P)."""
    S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} must divide chunk={chunk}")
    grid = (H, S // chunk)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, 1, P), lambda h, c: (c, h, 0)),
            pl.BlockSpec((chunk, 1), lambda h, c: (c, h)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((chunk, N), lambda h, c: (c, 0)),
            pl.BlockSpec((chunk, N), lambda h, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, 1, P), lambda h, c: (c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H, P), x.dtype),
        scratch_shapes=[_VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    if D is not None:
        y = y + (D[None, :, None] * x.astype(jnp.float32)).astype(y.dtype)
    return y


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array | None = None,
                chunk: int = 64) -> jax.Array:
    """Same chunked algorithm in pure jnp (XLA path used by the mamba2
    model on any backend; the dry-run/roofline path).  x: (S, H, P)."""
    S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xc = x.reshape(nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(nc, chunk, H).astype(jnp.float32)
    Bc = B.reshape(nc, chunk, N).astype(jnp.float32)
    Cc = C.reshape(nc, chunk, N).astype(jnp.float32)
    A32 = A.astype(jnp.float32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def step(h, inputs):                        # h: (H, P, N)
        xk, dtk, Bk, Ck = inputs                # (L,H,P), (L,H), (L,N), (L,N)
        s = jnp.cumsum(dtk * A32[None, :], axis=0)          # (L, H)
        M = jnp.where(causal[:, :, None], jnp.exp(s[:, None] - s[None, :]), 0.0)
        CB = Ck @ Bk.T                                        # (L, L)
        y_intra = jnp.einsum("tuh,tu,uhp->thp", M, CB, dtk[:, :, None] * xk)
        y_inter = jnp.exp(s)[:, :, None] * jnp.einsum("tn,hpn->thp", Ck, h)
        w = jnp.exp(s[-1][None, :] - s) * dtk                 # (L, H)
        h_new = (jnp.exp(s[-1])[:, None, None] * h
                 + jnp.einsum("uhp,un->hpn", w[:, :, None] * xk, Bk))
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = ys.reshape(S, H, P)
    if D is not None:
        y = y + D[None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)
