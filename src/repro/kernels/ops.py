"""Jit'd public wrappers for the kernels package.

``backend`` selects the execution path everywhere:
  * "xla"              — pure jnp (runs on any device; the dry-run path)
  * "pallas"           — pallas kernels in interpret mode (exact on CPU)
  * "pallas_hw"        — pallas lowered through Mosaic (real TPU)
Models take this as config so the same architecture definition runs in
smoke tests, dry-runs, and on hardware.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .gemm import pallas_gemm
from .ssd_scan import ssd_chunked, ssd_scan

BACKENDS = ("xla", "pallas", "pallas_hw", "pallas_auto")


def matmul(a: jax.Array, b: jax.Array, backend: str = "xla",
           schedule: str = "tpu_mxu_kgrid") -> jax.Array:
    if backend == "xla":
        return ref.gemm_ref(a, b)
    if backend == "pallas_auto":
        # cost-model-selected schedule+tiles (core/autotune.py)
        from repro.core.autotune import compile_gemm_autotuned
        m, k = a.shape
        n = b.shape[1]
        ck = compile_gemm_autotuned(m, n, k, dtype=str(a.dtype)
                                    if str(a.dtype) in ("float32", "bfloat16")
                                    else "float32")
        return ck.run_pallas(a, b)
    return pallas_gemm(a, b, schedule=schedule,
                       interpret=(backend != "pallas_hw"))


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None, backend: str = "xla",
              block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Batched multi-head attention.  q: (..., Sq, D), k/v: (..., Sk, D)."""
    if backend == "xla":
        fn = functools.partial(ref.attention_ref, causal=causal,
                               window=window, scale=scale)
        for _ in range(q.ndim - 2):
            fn = jax.vmap(fn)
        return fn(q, k, v)
    lead = q.shape[:-2]
    qf = q.reshape((-1,) + q.shape[-2:])
    kf = k.reshape((-1,) + k.shape[-2:])
    vf = v.reshape((-1,) + v.shape[-2:])
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          scale=scale, block_q=block_q, block_k=block_k,
                          interpret=(backend != "pallas_hw"))
    return out.reshape(lead + out.shape[-2:])


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, D: Optional[jax.Array] = None, *, chunk: int = 64,
        backend: str = "xla") -> jax.Array:
    """SSD scan.  x: (..., S, H, P); dt: (..., S, H); B/C: (..., S, N)."""
    if backend == "xla":
        fn = functools.partial(ssd_chunked, chunk=chunk)
    else:
        fn = functools.partial(ssd_scan, chunk=chunk,
                               interpret=(backend != "pallas_hw"))
    call = (lambda xx, dd, bb, cc: fn(xx, dd, A, bb, cc, D))
    for _ in range(x.ndim - 3):
        call = jax.vmap(call)
    return call(x, dt, B, C)
