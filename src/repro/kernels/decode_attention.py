"""Single-token (decode) attention over a partially-filled KV cache.

The serving hot spot: one query token per sequence attends a (Smax)-deep
cache of which only ``valid`` entries are live.  Blocked over the cache
with online softmax; GQA query groups ride along the sublane dimension
so the (rep x hd) tile feeds the MXU per KV block.

Layout: q (B, KV, rep, hd); k/v (B, KV, Smax, hd) — cache pre-transposed
to head-major, which is also the HBM-friendly layout for decode (each
(b, g) stream is contiguous).  ``valid`` (B,) int32.
Grid = (B, KV, nkv); statistics in VMEM scratch across the kv dimension.
Validated in interpret mode against the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

_NEG = -1e30


def _kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_k: int):
    ikv = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (rep, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (rep, bk)

    valid = valid_ref[0]
    kpos = ikv * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
    s = jnp.where(kpos < valid, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ikv == nkv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, block_k: int = 256,
                     interpret: bool = True) -> jax.Array:
    """q: (B, KV, rep, hd); k/v: (B, KV, Smax, hd); valid: (B,) int32.
    Returns (B, KV, rep, hd)."""
    B, KV, rep, hd = q.shape
    Smax = k.shape[2]
    block_k = min(block_k, Smax)
    if Smax % block_k:
        raise ValueError(f"Smax={Smax} % block_k={block_k}")
    scale = float(1.0 / np.sqrt(hd))
    grid = (B, KV, Smax // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, i: (b,)),
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, g, i: (b, g, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, g, i: (b, g, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, i: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, hd), q.dtype),
        scratch_shapes=[_VMEM((rep, hd), jnp.float32),
                        _VMEM((rep, 1), jnp.float32),
                        _VMEM((rep, 1), jnp.float32)],
        interpret=interpret,
    )(valid.astype(jnp.int32), q, k, v)


def decode_attention_ref(q, k, v, valid):
    """Oracle: per-(b, kv-group) masked softmax attention."""
    B, KV, rep, hd = q.shape
    Smax = k.shape[2]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bgrh,bgsh->bgrs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kpos = jnp.arange(Smax)[None, None, None, :]
    s = jnp.where(kpos < valid[:, None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrs,bgsh->bgrh", p,
                      v.astype(jnp.float32)).astype(q.dtype)
