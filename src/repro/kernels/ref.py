"""Pure-jnp oracles for every kernel in this package.

Each pallas kernel in ``kernels/`` is validated against the function of
the same name here (tests sweep shapes/dtypes and assert allclose) — the
same discipline the paper applies by checking "accurate output matrices"
from the generated RTL.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """Softmax attention oracle.

    q: (Sq, D), k/v: (Sk, D).  ``window`` limits attention to the last
    ``window`` positions (local attention); positions are aligned so that
    query i attends keys [i - window + 1, i] (with the causal offset
    Sk - Sq applied when lengths differ).
    """
    Sq, D = q.shape
    Sk = k.shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return (probs @ v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, D: jax.Array | None = None) -> jax.Array:
    """Mamba-2 SSD (state-space dual) recurrence, naive sequential oracle.

    x : (S, H, P)   per-head inputs
    dt: (S, H)      softplus-activated step sizes (already positive)
    A : (H,)        negative decay rates (A < 0)
    B : (S, N)      input projections (single group)
    C : (S, N)      output projections
    D : (H,) or None  skip connection
    returns (S, H, P)
    """
    S, H, P = x.shape
    N = B.shape[-1]

    def step(h, inputs):
        x_t, dt_t, B_t, C_t = inputs            # (H,P), (H,), (N,), (N,)
        decay = jnp.exp(dt_t * A)               # (H,)
        # dB_t x_t^T : outer product per head -> (H, P, N)
        dBx = dt_t[:, None, None] * x_t[:, :, None] * B_t[None, None, :]
        h = h * decay[:, None, None] + dBx      # (H, P, N)
        y_t = jnp.einsum("hpn,n->hp", h, C_t)   # (H, P)
        return h, y_t

    h0 = jnp.zeros((H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.astype(jnp.float32), dt.astype(jnp.float32),
                                    B.astype(jnp.float32), C.astype(jnp.float32)))
    if D is not None:
        ys = ys + D[None, :, None] * x.astype(jnp.float32)
    return ys.astype(x.dtype)


def rglru_ref(x: jax.Array, a_gate: jax.Array, i_gate: jax.Array,
              a_param: jax.Array, c: float = 8.0) -> jax.Array:
    """RG-LRU (RecurrentGemma) oracle.

    x, a_gate, i_gate: (S, D) — inputs and pre-sigmoid gates;
    a_param: (D,) — the learnable recurrence parameter (pre-softplus).
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    with log a_t = -c * softplus(a_param) * sigmoid(a_gate_t).
    """
    log_a = -c * jax.nn.softplus(a_param)[None, :] * jax.nn.sigmoid(
        a_gate.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i_gate.astype(jnp.float32)) * x.astype(jnp.float32)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))

    def step(h, inp):
        a_t, gx_t, m_t = inp
        h = a_t * h + m_t * gx_t
        return h, h

    h0 = jnp.zeros((x.shape[1],), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a, gated, mult))
    return hs.astype(x.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)
