"""Blocked (flash) attention — pallas kernel with explicit VMEM tiling.

TPU-native adaptation of the contraction-heavy hot spot of every
attention arch in the assigned pool.  The schedule is the
"time-multiplexed" one the paper's nested loop embodies, applied at MXU
granularity: KV blocks stream through one resident accumulator/statistics
set (grid revisiting), so VMEM stays constant in sequence length — the
profitable version of datapath reuse on TPU.

Layout: q (BH, Sq, D), k/v (BH, Sk, D); grid = (BH, nq, nkv) with the kv
dimension innermost (sequential revisits of the same q/out block).
Supports causal masking and local windows (gemma3 / recurrentgemma).
Validated in interpret mode against ``ref.attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; available in interpret mode too
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int | None,
                 sq: int, sk: int, block_q: int, block_k: int):
    ikv = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0].astype(jnp.float32)            # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(1)
    qpos = iq * block_q + jax.lax.iota(jnp.int32, block_q)[:, None] + (sk - sq)
    kpos = ikv * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                       # (bq, bk)
    corr = jnp.exp(m_prev - m_new)               # (bq, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ikv == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Sk, D) -> (BH, Sq, D)."""
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq lens ({sq},{sk}) must divide blocks "
                         f"({block_q},{block_k})")
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(d))
    grid = (bh, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        sq=sq, sk=sk, block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ikv: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ikv: (b, ikv, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ikv: (b, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ikv: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _VMEM((block_q, d), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
