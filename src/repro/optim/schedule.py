"""LR schedules: linear warmup + {cosine, WSD}.

WSD (warmup-stable-decay) is the schedule minicpm-2b trains with
[arXiv:2404.06395]: linear warmup, long stable plateau, then a sharp
decay tail — implemented exactly so the minicpm config is faithful.
"""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine(step, *, peak: float, warmup_steps: int, total_steps: int,
           final_frac: float = 0.1):
    warm = linear_warmup(step, warmup_steps, peak)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, peak * cos)


def wsd(step, *, peak: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> stable plateau -> sharp (exponential) decay tail."""
    warm = linear_warmup(step, warmup_steps, peak)
    decay_start = total_steps * (1.0 - decay_frac)
    t = jnp.clip((step - decay_start) / max(total_steps - decay_start, 1),
                 0.0, 1.0)
    decayed = peak * jnp.exp(jnp.log(final_frac) * t)
    out = jnp.where(step < warmup_steps, warm,
                    jnp.where(step < decay_start, peak, decayed))
    return out


SCHEDULES = {"cosine": cosine, "wsd": wsd}


def make(name: str, **kw):
    fn = SCHEDULES[name]
    return lambda step: fn(step, **kw)
