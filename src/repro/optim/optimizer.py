"""Optimizers built from scratch in JAX: AdamW and a factored variant.

``factored=True`` replaces the full second moment of every rank>=2
parameter with row/column statistics (Adafactor-style) — this is what
makes optimizer state for the 1T-param kimi-k2 config fit the v5e HBM
budget (see EXPERIMENTS.md §Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params                 # full v, or {"row": ..., "col": ...} if factored


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, OptState, Params], Tuple[Params, OptState,
                                                       Dict[str, jax.Array]]]


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def _is_factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def adamw(lr_fn: Callable[[jax.Array], jax.Array], *, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0, factored: bool = False,
          state_dtype=jnp.float32) -> Optimizer:

    def init(params: Params) -> OptState:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)
        if factored:
            def vinit(p):
                if _is_factorable(p.shape):
                    return {"row": jnp.zeros(p.shape[:-1], state_dtype),
                            "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                             state_dtype)}
                return {"full": jnp.zeros(p.shape, state_dtype)}
            v = jax.tree.map(vinit, params)
        else:
            v = jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)
        return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v)

    def update(grads: Params, state: OptState, params: Params):
        metrics: Dict[str, jax.Array] = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        lr = lr_fn(step)
        metrics["lr"] = lr
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda m, g: (b1 * m + (1 - b1) * g.astype(state_dtype)),
            state.m, grads)

        if factored:
            def vupd(v, g):
                g2 = jnp.square(g.astype(jnp.float32))
                if "full" in v:
                    return {"full": b2 * v["full"] + (1 - b2) *
                            g2.astype(state_dtype)}
                return {"row": b2 * v["row"] + (1 - b2) *
                        g2.mean(-1).astype(state_dtype),
                        "col": b2 * v["col"] + (1 - b2) *
                        g2.mean(-2).astype(state_dtype)}
            new_v = jax.tree.map(vupd, state.v, grads,
                                 is_leaf=lambda x: isinstance(x, dict)
                                 and ("full" in x or "row" in x))

            def vhat(v):
                if "full" in v:
                    return v["full"].astype(jnp.float32) / bc2
                row = v["row"].astype(jnp.float32) / bc2
                col = v["col"].astype(jnp.float32) / bc2
                denom = jnp.maximum(row.mean(-1, keepdims=True), 1e-30)
                return row[..., None] * col[..., None, :] / denom[..., None]
            vhats = jax.tree.map(vhat, new_v,
                                 is_leaf=lambda x: isinstance(x, dict)
                                 and ("full" in x or "row" in x))
        else:
            new_v = jax.tree.map(
                lambda v, g: b2 * v + (1 - b2) *
                jnp.square(g.astype(state_dtype)), state.v, grads)
            vhats = jax.tree.map(lambda v: v.astype(jnp.float32) / bc2, new_v)

        def pupd(p, m, vh):
            mhat = m.astype(jnp.float32) / bc1
            upd = mhat / (jnp.sqrt(vh) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(pupd, params, new_m, vhats)
        return new_params, OptState(step, new_m, new_v), metrics

    return Optimizer(init=init, update=update)


def sgd(lr_fn, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(jnp.zeros_like, params), v=())

    def update(grads, state, params):
        step = state.step + 1
        lr = lr_fn(step)
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state.m, grads)
        new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
        return new_p, OptState(step, new_m, ()), {"lr": lr}

    return Optimizer(init=init, update=update)
