"""Serving latency metrics: streaming histograms + per-request records.

``StreamingHistogram`` keeps log-spaced buckets (2% growth) so p50/p90/
p99 are recovered within ~2% relative error at O(1) memory regardless of
request count — the structure every serving system uses for tail
latency.  ``ServeMetrics`` ties the histograms to the request lifecycle
(arrival -> admit -> first token -> per-token -> finish), tracks queue
depth and slot occupancy per engine step, and snapshots everything into
the JSON dict ``BENCH_serve.json`` entries embed.

Time comes from a :class:`Clock`: ``WallClock`` for real measurements,
``VirtualClock`` for deterministic transcripts (docs, CI smoke) where
each engine step advances time by a fixed cost instead of wall time.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional

import numpy as np


# --------------------------------------------------------------------------
# clocks
# --------------------------------------------------------------------------


class Clock:
    def now(self) -> float:
        raise NotImplementedError

    def advance(self, dt: float) -> None:
        """Engine hooks call this per step; real clocks ignore it."""

    kind = "abstract"


class WallClock(Clock):
    kind = "wall"

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0


class VirtualClock(Clock):
    """Deterministic clock: time only moves when ``advance`` is called."""

    kind = "virtual"

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self._t += float(dt)


# --------------------------------------------------------------------------
# streaming histogram
# --------------------------------------------------------------------------


class StreamingHistogram:
    """Log-spaced bucket histogram over (0, +inf) with ~``growth``-1
    relative resolution; exact count/sum/min/max."""

    def __init__(self, lo: float = 1e-7, hi: float = 1e5,
                 growth: float = 1.02):
        self.lo, self.hi, self.growth = lo, hi, growth
        self._lg = math.log(growth)
        self.nbuckets = int(math.ceil(math.log(hi / lo) / self._lg)) + 2
        self.counts = np.zeros(self.nbuckets, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, v: float) -> int:
        if v < self.lo:
            return 0
        i = 1 + int(math.log(v / self.lo) / self._lg)
        return min(i, self.nbuckets - 1)

    def _edge(self, i: int) -> float:
        """Lower edge of bucket i (bucket 0 is the underflow bucket)."""
        return 0.0 if i == 0 else self.lo * self.growth ** (i - 1)

    def record(self, v: float) -> None:
        v = float(v)
        self.counts[self._index(v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100]; midpoint-of-bucket estimate, clamped to the
        exact observed min/max so p0/p100 are exact."""
        if not self.count:
            return 0.0
        if q <= 0:
            return float(self.min)
        if q >= 100:
            return float(self.max)
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += int(c)
            if cum >= target and c:
                lo = max(self._edge(i), self.min)
                hi = min(self._edge(i + 1), self.max)
                mid = math.sqrt(lo * hi) if lo > 0 else (lo + hi) / 2.0
                return float(min(max(mid, self.min), self.max))
        return float(self.max)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "min": 0.0 if self.count == 0 else self.min,
                "max": 0.0 if self.count == 0 else self.max,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


# --------------------------------------------------------------------------
# request lifecycle metrics
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _ReqState:
    arrival: float
    admit: Optional[float] = None
    first_token: Optional[float] = None
    last_token: Optional[float] = None
    tokens: int = 0


class ServeMetrics:
    """Lifecycle recorder for one serving run.

    TTFT  = first sampled token time - arrival (includes queueing).
    TPOT  = gap between consecutive decode tokens of one request.
    e2e   = finish - arrival.
    Queue depth and active slots are sampled once per engine step.
    """

    def __init__(self, clock: Optional[Clock] = None, slots: int = 0):
        self.clock = clock or WallClock()
        self.slots = slots
        self.ttft = StreamingHistogram()
        self.tpot = StreamingHistogram()
        self.e2e = StreamingHistogram()
        self.queue_depth = StreamingHistogram(lo=0.5, hi=1e6, growth=1.05)
        self._req: Dict[int, _ReqState] = {}
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self._steps = 0
        self._occupancy = 0
        self._t_start = self.clock.now()

    # ---- lifecycle hooks (engine calls these) -----------------------------

    def on_submit(self, rid: int, arrival: Optional[float] = None) -> None:
        self.submitted += 1
        t = self.clock.now() if arrival is None else float(arrival)
        self._req[rid] = _ReqState(arrival=t)

    def on_reject(self, rid: int) -> None:
        self.rejected += 1

    def on_admit(self, rid: int, prompt_len: int) -> None:
        st = self._req.setdefault(rid, _ReqState(arrival=self.clock.now()))
        st.admit = self.clock.now()
        self.prefill_tokens += int(prompt_len)

    def on_token(self, rid: int) -> None:
        now = self.clock.now()
        st = self._req.setdefault(rid, _ReqState(arrival=now))
        st.tokens += 1
        self.decode_tokens += 1
        if st.first_token is None:
            st.first_token = now
            self.ttft.record(max(now - st.arrival, 0.0))
        elif st.last_token is not None:
            self.tpot.record(max(now - st.last_token, 0.0))
        st.last_token = now

    def on_finish(self, rid: int) -> None:
        st = self._req.get(rid)
        if st is None:
            return
        self.completed += 1
        self.e2e.record(max(self.clock.now() - st.arrival, 0.0))

    def on_step(self, queue_depth: int, active_slots: int) -> None:
        self._steps += 1
        self._occupancy += int(active_slots)
        if queue_depth > 0:
            self.queue_depth.record(queue_depth)
        else:
            self.queue_depth.count += 1      # depth 0 still counts

    # ---- snapshot ---------------------------------------------------------

    @property
    def duration(self) -> float:
        return max(self.clock.now() - self._t_start, 1e-12)

    def slot_utilization(self) -> float:
        if not self._steps or not self.slots:
            return 0.0
        return self._occupancy / (self._steps * self.slots)

    def snapshot(self) -> Dict:
        """JSON-able summary — the per-run payload of BENCH_serve.json."""
        dur = self.duration
        toks = self.decode_tokens
        return {
            "schema": "serve_metrics/v1",
            "clock": self.clock.kind,
            "duration": dur,
            "requests": {"submitted": self.submitted,
                         "completed": self.completed,
                         "backpressure_events": self.rejected},
            "tokens": {"prefill": self.prefill_tokens, "decode": toks},
            "tokens_per_s": toks / dur,
            "ttft": self.ttft.summary(),
            "tpot": self.tpot.summary(),
            "e2e": self.e2e.summary(),
            "queue_depth": {"mean": (self.queue_depth.sum
                                     / max(self.queue_depth.count, 1)),
                            "max": (0.0 if self.queue_depth.max < 0
                                    else self.queue_depth.max)},
            "steps": self._steps,
            "slot_utilization": self.slot_utilization(),
        }
