"""Deterministic serving workload generator.

Produces replayable request streams for the continuous-batching engine:
arrival times from a Poisson / bursty (Markov-modulated Poisson) /
uniform process, prompt and output lengths from configurable
distributions, token ids from the same seeded generator.  The whole
stream is a pure function of :class:`LoadConfig` — identical config
(including ``seed``) always yields the identical stream, so every
``BENCH_serve.json`` entry names the workload it was measured under and
any run can be replayed bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Integer length distribution clamped to [lo, hi].

    kind: "fixed" (always ``lo``), "uniform" (inclusive [lo, hi]), or
    "lognormal" (exp(N(mu, sigma)) clamped — the long-tail shape real
    prompt/output lengths follow).
    """

    kind: str = "uniform"
    lo: int = 4
    hi: int = 32
    mu: float = 2.0
    sigma: float = 0.8

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "fixed":
            out = np.full(n, self.lo, np.int64)
        elif self.kind == "uniform":
            out = rng.integers(self.lo, self.hi + 1, n)
        elif self.kind == "lognormal":
            out = np.rint(rng.lognormal(self.mu, self.sigma, n)).astype(np.int64)
        else:
            raise ValueError(f"unknown length distribution {self.kind!r}")
        return np.clip(out, self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """One serving workload, fully determined by its fields."""

    num_requests: int = 32
    vocab_size: int = 256
    seed: int = 0
    # arrival process: "poisson" | "bursty" | "uniform"
    process: str = "poisson"
    rate: float = 8.0                 # mean arrivals per time unit
    burst_rate: float = 32.0          # bursty: rate inside a burst
    burst_fraction: float = 0.25      # bursty: fraction of time in burst state
    burst_len: float = 1.0            # bursty: mean burst duration (time units)
    prompt: LengthDist = LengthDist("uniform", 4, 16)
    output: LengthDist = LengthDist("uniform", 2, 12)

    def describe(self) -> Dict:
        d = dataclasses.asdict(self)
        d["prompt"] = dataclasses.asdict(self.prompt)
        d["output"] = dataclasses.asdict(self.output)
        return d


@dataclasses.dataclass(frozen=True)
class GenRequest:
    """One generated request of the stream."""

    rid: int
    arrival: float                    # time units since stream start
    prompt: np.ndarray                # (P,) int32 token ids
    max_new: int                      # tokens to generate (incl. first)


def _interarrival(cfg: LoadConfig, rng: np.random.Generator) -> np.ndarray:
    n = cfg.num_requests
    if cfg.process == "uniform":
        return np.full(n, 1.0 / cfg.rate)
    if cfg.process == "poisson":
        return rng.exponential(1.0 / cfg.rate, n)
    if cfg.process == "bursty":
        # two-state MMPP: "calm" at ``rate``, "burst" at ``burst_rate``;
        # state flips are sampled per-arrival with mean sojourns chosen so
        # ``burst_fraction`` of time is spent bursting.
        gaps = np.empty(n)
        in_burst = False
        t_left = rng.exponential(cfg.burst_len / max(cfg.burst_fraction, 1e-9))
        for i in range(n):
            r = cfg.burst_rate if in_burst else cfg.rate
            gap = rng.exponential(1.0 / r)
            t_left -= gap
            if t_left <= 0.0:
                in_burst = not in_burst
                mean = (cfg.burst_len if in_burst
                        else cfg.burst_len * (1.0 - cfg.burst_fraction)
                        / max(cfg.burst_fraction, 1e-9))
                t_left = rng.exponential(mean)
            gaps[i] = gap
        return gaps
    raise ValueError(f"unknown arrival process {cfg.process!r}")


def generate_stream(cfg: LoadConfig) -> List[GenRequest]:
    """The full request stream for ``cfg`` — deterministic in ``cfg``."""
    rng = np.random.default_rng(cfg.seed)
    gaps = _interarrival(cfg, rng)
    arrivals = np.cumsum(gaps)
    plens = cfg.prompt.sample(rng, cfg.num_requests)
    olens = cfg.output.sample(rng, cfg.num_requests)
    out: List[GenRequest] = []
    for i in range(cfg.num_requests):
        toks = rng.integers(0, cfg.vocab_size, plens[i]).astype(np.int32)
        out.append(GenRequest(rid=i, arrival=float(arrivals[i]),
                              prompt=toks, max_new=int(olens[i])))
    return out


def stream_digest(stream: List[GenRequest]) -> Tuple[int, int, int, float]:
    """Cheap replayability fingerprint: (n, prompt tokens, output tokens,
    last arrival) — equal streams have equal digests."""
    return (len(stream),
            int(sum(len(r.prompt) for r in stream)),
            int(sum(r.max_new for r in stream)),
            float(stream[-1].arrival) if stream else 0.0)
