"""Batched serving engine: prefill + decode with jit'd steps.

``make_prefill_step`` / ``make_decode_step`` are the exact functions the
inference dry-run cells lower (prefill_32k lowers prefill; decode_32k and
long_500k lower decode against a full cache).  ``Engine`` drives them for
real generation (greedy or temperature sampling) with continuous batch
slots.

Continuous batching lives next door: the production engine is
:class:`repro.serve.continuous.ContinuousEngine` (ONE vmap-batched jit'd
decode step across all occupied slots, async admission queue,
backpressure); :class:`SerialSlotEngine` below is the original per-slot
B=1 decode loop, kept as the bit-exact differential reference.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill(params, cache, tokens, extra_embeds=None):
        logits, cache, _ = model.apply(params, tokens,
                                       extra_embeds=extra_embeds,
                                       cache=cache)
        return logits[:, -1], cache
    return prefill


def make_decode_step(model: Model) -> Callable:
    def decode(params, cache, token):
        logits, cache, _ = model.apply(params, token, cache=cache)
        return logits[:, -1], cache
    return decode


@dataclasses.dataclass
class EngineConfig:
    max_len: int = 256
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: EngineConfig = EngineConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.prefill = jax.jit(make_prefill_step(model))
        self.decode = jax.jit(make_decode_step(model))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        from repro.models.model import mask_padded_vocab
        logits = mask_padded_vocab(logits.astype(jnp.float32),
                                   self.model.cfg.vocab_size)
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        probs = jax.nn.softmax(logits / self.cfg.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9),
                                      axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, steps: int,
                 extra_embeds=None, eos_id: Optional[int] = None
                 ) -> np.ndarray:
        """prompts: (B, P) int32 -> (B, P+steps) generated continuation.

        Rows that have emitted ``eos_id`` are frozen: every subsequent
        position is ``eos_id`` (not whatever the decoder keeps sampling
        into a finished row), so outputs are stable however long the
        other rows keep the batch alive.
        """
        B, P = prompts.shape
        cache = self.model.cache_init(B, self.cfg.max_len)
        key = jax.random.PRNGKey(self.cfg.seed)
        logits, cache = self.prefill(self.params, cache,
                                     jnp.asarray(prompts), extra_embeds)
        out = [jnp.asarray(prompts)]
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)[:, None]
        done = jnp.zeros((B,), bool)
        for _ in range(steps):
            if eos_id is not None:
                tok = jnp.where(done[:, None], jnp.int32(eos_id), tok)
            out.append(tok)
            if eos_id is not None:
                done = done | (tok[:, 0] == eos_id)
                if bool(done.all()):
                    break
            logits, cache = self.decode(self.params, cache, tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)[:, None]
        return np.asarray(jnp.concatenate(out, axis=1))


def real_token_count(out: np.ndarray, prompt_len: int,
                     eos_id: Optional[int] = None) -> int:
    """Generated tokens actually produced: everything after the prompt,
    counting each finished row only up to (and including) its first
    ``eos_id`` — the post-eos padding the engine emits is not work."""
    gen = out[:, prompt_len:]
    if eos_id is None:
        return int(gen.size)
    total = 0
    for row in gen:
        hits = np.flatnonzero(row == eos_id)
        total += int(hits[0]) + 1 if hits.size else row.size
    return total


def throughput_stats(engine: Engine, prompts: np.ndarray, steps: int,
                     eos_id: Optional[int] = None) -> Dict[str, float]:
    import time
    t0 = time.perf_counter()
    out = engine.generate(prompts, steps, eos_id=eos_id)
    dt = time.perf_counter() - t0
    new_tokens = real_token_count(out, prompts.shape[1], eos_id)
    return {"wall_s": dt, "tokens": new_tokens,
            "tok_per_s": new_tokens / dt}


# --------------------------------------------------------------------------
# continuous batching — serial reference implementation
# --------------------------------------------------------------------------

from repro.serve.continuous import ContinuousEngine, Request  # noqa: E402


class SerialSlotEngine:
    """Per-slot continuous batching: the original implementation, kept
    as the differential reference for :class:`ContinuousEngine`.

    A fixed decode batch of ``slots`` where finished/empty slots are
    immediately refilled from the queue; every slot decodes with its own
    B=1 jit'd step (``slots`` XLA dispatches per generated token — the
    batched engine replaces this loop with one vmap'd step and must
    produce bit-identical greedy token streams).
    """

    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cfg = EngineConfig(max_len=max_len, temperature=temperature,
                                seed=seed)
        self.decode = jax.jit(make_decode_step(model))
        self._prefill_one = jax.jit(self._prefill_into_slot)
        self.key = jax.random.PRNGKey(seed)

    def _prefill_into_slot(self, params, cache1, tokens1):
        logits, cache1, _ = self.model.apply(params, tokens1, cache=cache1)
        return logits[:, -1], cache1

    def serve(self, requests) -> Dict[int, np.ndarray]:
        """Run all requests to completion; returns rid -> generated ids."""
        queue = list(requests)
        results: Dict[int, np.ndarray] = {}
        # per-slot caches are allocated inside admit(); slots start empty
        slot_cache: list = [None] * self.slots
        slot_req: list = [None] * self.slots
        slot_tok = jnp.zeros((self.slots, 1), jnp.int32)
        slot_left = np.zeros(self.slots, np.int64)
        slot_hist: list = [[] for _ in range(self.slots)]

        def _finish(s):
            req = slot_req[s]
            results[req.rid] = np.asarray(slot_hist[s], np.int32)
            slot_req[s] = None

        def admit(s):
            nonlocal slot_tok
            while queue:
                req = queue.pop(0)
                cache = self.model.cache_init(1, self.max_len)
                logits, cache = self._prefill_one(
                    self.params, cache, jnp.asarray(req.prompt[None, :]))
                self.key, sub = jax.random.split(self.key)
                tok = self._sample(logits, sub)
                if req.max_new <= 1:
                    # the prefill sampled this request's only token; a
                    # decode pass would emit a second one (max_new=1
                    # off-by-one) — finish here instead
                    results[req.rid] = np.asarray([int(tok[0])], np.int32)
                    continue
                slot_cache[s] = cache
                slot_req[s] = req
                slot_hist[s] = [int(tok[0])]
                slot_left[s] = req.max_new - 1
                slot_tok = slot_tok.at[s, 0].set(tok[0])
                return True
            return False

        for s in range(self.slots):
            admit(s)
        while any(r is not None for r in slot_req) or queue:
            # per-slot decode (caches are independent pytrees)
            for s in range(self.slots):
                if slot_req[s] is None:
                    if not admit(s):
                        continue
                    continue
                logits, slot_cache[s] = self.decode(
                    self.params, slot_cache[s], slot_tok[s:s + 1])
                self.key, sub = jax.random.split(self.key)
                tok = self._sample(logits, sub)
                slot_tok = slot_tok.at[s, 0].set(tok[0])
                slot_hist[s].append(int(tok[0]))
                slot_left[s] -= 1
                if slot_left[s] <= 0 or \
                        int(slot_cache[s]["len"]) >= self.max_len - 1:
                    _finish(s)
        return results

    def _sample(self, logits, key):
        from repro.models.model import mask_padded_vocab
        logits = mask_padded_vocab(logits.astype(jnp.float32),
                                   self.model.cfg.vocab_size)
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)
