"""Compiler bridge: serve with pipeline-compiled, autotuned kernels.

Closes the loop from PR 6/7 (serving kernels and raised model blocks
compile through the PassManager stack) into the runtime: for one model
config, every raisable forward-pass block is compiled with
``pipeline.compile_traced`` under a schedule chosen by the autotuner
(``autotune.best_schedule`` on the block's dominant matmul shape, with
legality-driven fallbacks down to the nested schedule) and validated
against the traced reference on real inputs.  Blocks that do not raise,
do not lower, or do not validate fall back to plain jit — explicitly,
with the reason recorded, so a ``BENCH_serve.json`` entry always states
exactly which blocks of the serving model ran through the compiler and
which were XLA fallbacks.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional

import numpy as np

from repro.core.machine_model import TPU_V5E, MachineModel

_VALIDATE_RTOL = 1e-4


@dataclasses.dataclass
class BlockChoice:
    """Per-block outcome of the compile plan."""

    block: str
    status: str                       # "compiled" | "fallback"
    schedule: Optional[str] = None    # pipeline/schedule label
    cycles: Optional[int] = None      # machine-model cycles of the HwIR
    pallas: bool = False              # general pallas emitter succeeded
    reason: str = ""                  # validation note or fallback cause

    def row(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServeCompilePlan:
    """Which blocks of one serving model run through the compiler."""

    config: str
    choices: List[BlockChoice]
    machine: str = "tpu_v5e"

    @property
    def compiled(self) -> List[BlockChoice]:
        return [c for c in self.choices if c.status == "compiled"]

    @property
    def fallbacks(self) -> List[BlockChoice]:
        return [c for c in self.choices if c.status != "compiled"]

    def summary_rows(self) -> List[Dict]:
        return [c.row() for c in self.choices]

    def describe(self) -> str:
        lines = [f"// serve compile plan for {self.config} "
                 f"({len(self.compiled)}/{len(self.choices)} blocks "
                 f"compiled, machine={self.machine})"]
        for c in self.choices:
            if c.status == "compiled":
                lines.append(
                    f"//   {c.block}: COMPILED schedule={c.schedule} "
                    f"cycles={c.cycles} pallas={c.pallas} — {c.reason}")
            else:
                lines.append(f"//   {c.block}: FALLBACK plain jit — "
                             f"{c.reason}")
        return "\n".join(lines)


def _first_matmul_shape(graph) -> Optional[tuple]:
    for op in graph.ops:
        if op.opname == "matmul":
            m, k = op.inputs[0].type.shape
            _, n = op.inputs[1].type.shape
            return (m, n, k)
    return None


def _schedule_candidates(graph):
    """Ordered (schedule, tile) attempts: autotuned first, then the
    canned families, then the always-legal nested schedule."""
    cands = []
    mnk = _first_matmul_shape(graph)
    if mnk is not None:
        from repro.core import autotune
        sched, (tm, tn, tk) = autotune.best_schedule(*mnk)
        cands.append((f"autotuned:{sched}",
                      dict(schedule=sched,
                           tile={"m": tm, "n": tn, "k": tk})))
    cands.append(("tpu_mxu", dict(schedule="tpu_mxu")))
    cands.append(("nested", dict(schedule="nested")))
    return cands


def plan_blocks(config_name: str, *, seq: int = 8, seed: int = 0,
                machine: MachineModel = TPU_V5E,
                validate: bool = True) -> ServeCompilePlan:
    """Build the per-block compile plan for one registry config."""
    raising = importlib.import_module("repro.core.raise")
    reports = raising.raise_model_blocks(config_name, seq=seq, seed=seed)
    choices: List[BlockChoice] = []
    for rep in reports:
        if not rep.ok:
            first = (rep.error or "unraisable").splitlines()[0]
            choices.append(BlockChoice(rep.block, "fallback", reason=first))
            continue
        rg = rep.raised
        if not rg.lowerable:
            choices.append(BlockChoice(
                rep.block, "fallback",
                reason=f"unlowerable ops: {', '.join(rg.unlowerable_ops)}"))
            continue
        choice = None
        last_err = "no schedule candidate"
        for label, kw in _schedule_candidates(rg.graph):
            try:
                ck = rg.compile(machine=machine, **kw)
            except Exception as e:                      # legality/lowering
                last_err = f"{label}: {str(e).splitlines()[0]}"
                continue
            note = "not validated"
            if validate:
                try:
                    want = rg.run_ref(*rep.example_inputs)
                    got = rg.run_compiled(ck, *rep.example_inputs,
                                          backend="jax")
                    for w, g in zip(want, got):
                        np.testing.assert_allclose(
                            g, w, rtol=_VALIDATE_RTOL, atol=1e-5)
                    note = (f"validated jax backend vs reference at "
                            f"rtol={_VALIDATE_RTOL}")
                except Exception as e:
                    last_err = f"{label}: validation failed: " \
                               f"{str(e).splitlines()[0]}"
                    continue
            choice = BlockChoice(
                rep.block, "compiled", schedule=label,
                cycles=int(ck.cycles.total),
                pallas=ck.run_pallas is not None, reason=note)
            break
        if choice is None:
            choice = BlockChoice(rep.block, "fallback", reason=last_err)
        choices.append(choice)
    return ServeCompilePlan(config=config_name, choices=choices,
                            machine=machine.name)
