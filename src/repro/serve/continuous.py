"""Truly batched continuous batching: one jit'd decode step for all slots.

The engine keeps ``slots`` independent KV caches *stacked* along a
leading slot axis (each slot is the exact ``cache_init(1, max_len)``
pytree, so per-slot ``len`` scalars become a ``(slots,)`` vector) and
decodes every occupied slot in ONE ``jax.vmap``-batched, jit'd step —
instead of the per-slot B=1 Python loop of
:class:`repro.serve.engine.SerialSlotEngine`, which dispatches ``slots``
separate XLA computations per generated token.

Admission is decoupled from decode through a bounded pending queue
(``submit`` returns ``False`` when the queue is full — backpressure the
load generator must absorb).  Admitting a request runs the same B=1
prefill the serial engine uses and writes the prefilled cache into the
slot's rows of the stacked pytree, so engine state after admission is
bit-identical to the serial engine's; greedy decode token streams are
therefore bit-identical too (differential-tested in
``tests/test_continuous_batching.py``).

Per-slot sampling keys are derived by ``fold_in(base_key, rid)`` so the
token stream of one request never depends on which slot it landed in or
on what else is resident — unlike the serial engine's single sequential
key stream, whose sampled (temperature > 0) outputs depend on
scheduling order.  Greedy decoding is unaffected.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, mask_padded_vocab
from repro.serve.metrics import ServeMetrics

# prefill / decode step costs for deterministic VirtualClock runs (time
# units; WallClock.advance ignores them)
VIRTUAL_STEP_COST = 1.0
VIRTUAL_PREFILL_COST = 1.0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int
    out: Optional[np.ndarray] = None


class ContinuousEngine:
    """Slot-based continuous batching with a single batched decode step.

    API:
      ``submit(req)``   enqueue; ``False`` = queue full (backpressure).
      ``step()``        admit into free slots, then one batched decode
                        step across all occupied slots; returns the
                        number of tokens emitted.
      ``serve(reqs)``   run a request list to completion (differential-
                        test convenience; bypasses the queue limit).
      ``results``       rid -> generated ids (np.int32) of finished
                        requests.
    """

    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 seed: int = 0, queue_limit: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None,
                 plan=None):
        self.model = model
        self.params = params
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.temperature = float(temperature)
        self.queue_limit = queue_limit
        self.metrics = metrics
        self.plan = plan                       # ServeCompilePlan or None
        self.base_key = jax.random.PRNGKey(seed)

        self.pending: Deque[Request] = collections.deque()
        self.results: Dict[int, np.ndarray] = {}
        self._slot_req: List[Optional[Request]] = [None] * self.slots
        self._slot_hist: List[List[int]] = [[] for _ in range(self.slots)]
        self._slot_left = np.zeros(self.slots, np.int64)
        self._slot_len = np.zeros(self.slots, np.int64)

        one = model.cache_init(1, self.max_len)
        self._stacked = jax.tree.map(
            lambda l: jnp.zeros((self.slots,) + l.shape, l.dtype), one)
        self._tok = jnp.zeros((self.slots, 1), jnp.int32)
        self._keys = jnp.stack([jax.random.fold_in(self.base_key, s)
                                for s in range(self.slots)])

        self._prefill_one = jax.jit(self._prefill)
        self._write_slot = jax.jit(self._write, donate_argnums=(0, 1, 2))
        self._decode_all = jax.jit(self._batched_step, donate_argnums=(1,))

    # ---- jit'd pieces ------------------------------------------------------

    def _prefill(self, params, cache1, tokens1, key):
        logits, cache1, _ = self.model.apply(params, tokens1, cache=cache1)
        tok = self._sample(logits[:, -1], key)
        return tok, cache1

    def _write(self, stacked, tok_all, keys_all, cache1, tok0, key, s):
        """Write one prefilled B=1 cache into slot ``s``'s rows."""
        new = jax.tree.map(
            lambda big, one: jax.lax.dynamic_update_index_in_dim(
                big, one.astype(big.dtype), s, 0), stacked, cache1)
        tok = jax.lax.dynamic_update_index_in_dim(
            tok_all, tok0.astype(jnp.int32), s, 0)
        keys = jax.lax.dynamic_update_index_in_dim(keys_all, key, s, 0)
        return new, tok, keys

    def _batched_step(self, params, stacked, tok, active, keys):
        """ONE decode step for all slots: vmap over the stacked caches.

        Each slot runs the exact B=1 decode computation (own scalar
        ``len`` inside the vmap), so slots stay fully independent; the
        active mask freezes ``len`` (and zeroes the sampled token) for
        empty slots, whose garbage rows the next admission overwrites.
        """
        def one(cache, tok1, key):
            logits, new_cache, _ = self.model.apply(params, tok1[None, :],
                                                    cache=cache)
            key, sub = jax.random.split(key)
            nxt = self._sample(logits[:, -1], sub)
            return nxt[0], new_cache, key

        nxt, new_stacked, new_keys = jax.vmap(one)(stacked, tok, keys)
        new_stacked = dict(new_stacked)
        new_stacked["len"] = jnp.where(active, new_stacked["len"],
                                       stacked["len"])
        nxt = jnp.where(active, nxt, 0)
        return nxt[:, None], new_stacked, new_keys

    def _sample(self, logits, key):
        logits = mask_padded_vocab(logits.astype(jnp.float32),
                                   self.model.cfg.vocab_size)
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1).astype(jnp.int32)

    # ---- queue / admission -------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self.pending)

    @property
    def active_slots(self) -> int:
        return sum(r is not None for r in self._slot_req)

    @property
    def busy(self) -> bool:
        return bool(self.pending) or self.active_slots > 0

    def submit(self, req: Request, arrival: Optional[float] = None) -> bool:
        """Enqueue; ``False`` (and no enqueue) when the admission queue
        is at ``queue_limit`` — backpressure for the load generator."""
        if self.queue_limit is not None and \
                len(self.pending) >= self.queue_limit:
            if self.metrics:
                self.metrics.on_reject(req.rid)
            return False
        if self.metrics:
            self.metrics.on_submit(req.rid, arrival)
        self.pending.append(req)
        return True

    def _admit(self, s: int) -> bool:
        """Prefill the next pending request into free slot ``s``."""
        while self.pending:
            req = self.pending.popleft()
            cache = self.model.cache_init(1, self.max_len)
            key = jax.random.fold_in(self.base_key, req.rid)
            key, sub = jax.random.split(key)
            tok0, cache = self._prefill_one(
                self.params, cache, jnp.asarray(req.prompt[None, :]), sub)
            if self.metrics:
                self.metrics.clock.advance(VIRTUAL_PREFILL_COST)
                self.metrics.on_admit(req.rid, len(req.prompt))
                self.metrics.on_token(req.rid)
            first = int(tok0[0])
            if req.max_new <= 1:
                # the prefill already sampled the request's only token —
                # finish without occupying a slot (max_new=1 regression)
                self.results[req.rid] = np.asarray([first], np.int32)
                if self.metrics:
                    self.metrics.on_finish(req.rid)
                continue
            self._stacked, self._tok, self._keys = self._write_slot(
                self._stacked, self._tok, self._keys, cache, tok0, key,
                jnp.int32(s))
            self._slot_req[s] = req
            self._slot_hist[s] = [first]
            self._slot_left[s] = req.max_new - 1
            self._slot_len[s] = len(req.prompt)
            return True
        return False

    def _finish(self, s: int) -> None:
        req = self._slot_req[s]
        self.results[req.rid] = np.asarray(self._slot_hist[s], np.int32)
        self._slot_req[s] = None
        if self.metrics:
            self.metrics.on_finish(req.rid)

    # ---- the serving loop --------------------------------------------------

    def step(self) -> int:
        """Admissions + one batched decode step; returns tokens emitted."""
        for s in range(self.slots):
            if self._slot_req[s] is None:
                self._admit(s)
        active = np.asarray([r is not None for r in self._slot_req])
        if self.metrics:
            self.metrics.on_step(len(self.pending), int(active.sum()))
        if not active.any():
            return 0
        self._tok, self._stacked, self._keys = self._decode_all(
            self.params, self._stacked, self._tok, jnp.asarray(active),
            self._keys)
        if self.metrics:
            self.metrics.clock.advance(VIRTUAL_STEP_COST)
        toks = np.asarray(self._tok[:, 0])
        emitted = 0
        for s in range(self.slots):
            if self._slot_req[s] is None:
                continue
            self._slot_hist[s].append(int(toks[s]))
            if self.metrics:
                self.metrics.on_token(self._slot_req[s].rid)
            emitted += 1
            self._slot_left[s] -= 1
            self._slot_len[s] += 1
            if self._slot_left[s] <= 0 or \
                    self._slot_len[s] >= self.max_len - 1:
                self._finish(s)
        return emitted

    def drain(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Step until queue and slots are empty (or ``max_steps``)."""
        steps = 0
        while self.busy and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.results

    def serve(self, requests) -> Dict[int, np.ndarray]:
        """Run ``requests`` to completion; rid -> generated ids."""
        self.pending.extend(requests)        # bypass the queue limit
        if self.metrics:
            for r in requests:
                self.metrics.on_submit(r.rid)
        return self.drain()
