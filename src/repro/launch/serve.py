"""Serving launcher: batched generation with prefill/decode steps.

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --batch 4 --prompt-len 16 --gen 32

``--continuous`` serves a deterministic load-generator stream through
the batched continuous engine instead (one vmap'd decode step across
all slots; see ``repro.serve.continuous``) and prints the latency
metrics snapshot:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --continuous --slots 4 --requests 16 --rate 4
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.model import Model, RunConfig
from repro.serve.engine import Engine, EngineConfig, throughput_stats


def _serve_continuous(cfg, model, params, args) -> None:
    from repro.serve import loadgen
    from repro.serve.continuous import ContinuousEngine, Request
    from repro.serve.metrics import ServeMetrics, WallClock

    load = loadgen.LoadConfig(
        num_requests=args.requests, vocab_size=cfg.vocab_size,
        seed=args.seed, rate=args.rate,
        prompt=loadgen.LengthDist("uniform", 4, args.prompt_len),
        output=loadgen.LengthDist("uniform", 2, args.gen))
    metrics = ServeMetrics(WallClock(), slots=args.slots)
    engine = ContinuousEngine(model, params, slots=args.slots,
                              max_len=args.prompt_len + args.gen + 1,
                              temperature=args.temperature, seed=args.seed,
                              queue_limit=args.queue_limit, metrics=metrics)
    for r in loadgen.generate_stream(load):
        while not engine.submit(Request(r.rid, r.prompt, r.max_new)):
            engine.step()                    # backpressure: drain a step
    engine.drain()
    snap = metrics.snapshot()
    print(f"[serve] continuous: {snap['requests']['completed']} requests, "
          f"{snap['tokens']['decode']} tokens, "
          f"{snap['tokens_per_s']:.1f} tok/s, "
          f"ttft p50={snap['ttft']['p50']*1e3:.1f}ms "
          f"p99={snap['ttft']['p99']*1e3:.1f}ms")
    print(json.dumps(snap, indent=2, sort_keys=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a load-generator stream through the "
                         "batched continuous engine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--queue-limit", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    max_len = args.prompt_len + args.gen + 1
    model = Model(cfg, RunConfig(max_seq=max_len))
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] arch={cfg.name} params={model.param_count():,}")

    if args.continuous:
        _serve_continuous(cfg, model, params, args)
        return

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    ee = None
    if cfg.frontend == "image_patches":
        ee = 0.1 * np.ones((args.batch, cfg.frontend_len, cfg.d_model),
                           np.float32)
    if cfg.frontend == "audio_frames":
        ee = 0.1 * np.ones((args.batch, cfg.encoder.context,
                            cfg.encoder.d_model or cfg.d_model), np.float32)

    eng = Engine(model, params, EngineConfig(max_len=max_len,
                                             temperature=args.temperature,
                                             seed=args.seed))
    if ee is not None:
        out = eng.generate(prompts, args.gen, extra_embeds=jax.numpy.asarray(ee))
        print(f"[serve] generated {out.shape} tokens")
    else:
        stats = throughput_stats(eng, prompts, args.gen)
        print(f"[serve] {stats['tokens']} new tokens in {stats['wall_s']:.2f}s "
              f"= {stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
