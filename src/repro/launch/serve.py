"""Serving launcher: batched generation with prefill/decode steps.

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.model import Model, RunConfig
from repro.serve.engine import Engine, EngineConfig, throughput_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    max_len = args.prompt_len + args.gen + 1
    model = Model(cfg, RunConfig(max_seq=max_len))
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] arch={cfg.name} params={model.param_count():,}")

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    ee = None
    if cfg.frontend == "image_patches":
        ee = 0.1 * np.ones((args.batch, cfg.frontend_len, cfg.d_model),
                           np.float32)
    if cfg.frontend == "audio_frames":
        ee = 0.1 * np.ones((args.batch, cfg.encoder.context,
                            cfg.encoder.d_model or cfg.d_model), np.float32)

    eng = Engine(model, params, EngineConfig(max_len=max_len,
                                             temperature=args.temperature,
                                             seed=args.seed))
    if ee is not None:
        out = eng.generate(prompts, args.gen, extra_embeds=jax.numpy.asarray(ee))
        print(f"[serve] generated {out.shape} tokens")
    else:
        stats = throughput_stats(eng, prompts, args.gen)
        print(f"[serve] {stats['tokens']} new tokens in {stats['wall_s']:.2f}s "
              f"= {stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
