"""Post-partitioning HLO analysis: trip-count-correct FLOPs/bytes/
collectives + roofline terms.

Why not ``compiled.cost_analysis()`` alone: XLA's analysis counts each
``while`` body ONCE, ignoring trip count — a scan-over-layers model is
undercounted ~L-fold.  ``analyze_hlo_module`` below parses the optimized
(SPMD-partitioned) HLO text, walks the call graph assigning each
computation its execution multiplicity (ENTRY x1, while bodies x trip
count — recovered from the loop-condition constant — fusions at their
call-site multiplicity), and accounts:

  * FLOPs       — 2 x prod(result dims) x prod(contracted dims) per dot;
  * HBM bytes   — operands + result per non-trivial top-level op
                  (mirrors XLA's own bytes-accessed semantics, with
                  fusion internals excluded: register traffic);
  * collectives — on-the-wire bytes per device by replica-group size:
        ring all-reduce       2 (G-1)/G x result_bytes
        all-gather            (G-1)/G x result_bytes   (result = gathered)
        reduce-scatter        (G-1)   x result_bytes   (result = shard)
        all-to-all            (G-1)/G x result_bytes
        collective-permute    1       x result_bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,\s]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    by_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            size = sum(_shape_bytes(dt, dm)
                       for dt, dm in _SHAPE_RE.findall(tuple_body))
        else:
            size = _shape_bytes(dtype, dims)
        g = _group_size(line)
        if kind == "all-reduce":
            wire = 2.0 * (g - 1) / g * size
        elif kind == "all-gather":
            wire = (g - 1) / g * size
        elif kind == "reduce-scatter":
            wire = float(g - 1) * size
        elif kind == "all-to-all":
            wire = (g - 1) / g * size
        else:                                   # collective-permute
            wire = float(size)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0.0) + wire
    return CollectiveStats(counts, by_kind)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).replace(" ", "").split(",") if x]
        return max(len(ids), 1)
    return 2


# --------------------------------------------------------------------------
# full-module analyzer with while-trip-count multiplicities
# --------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
    r"([\w\-]+)\((.*)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_BC_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota",
                   # control ops whose traffic is accounted inside their
                   # called computations (bodies run in-place on the carry)
                   "while", "conditional", "call"}


@dataclasses.dataclass
class _Op:
    name: str
    dtype: Optional[str]
    dims: Optional[str]
    tuple_body: Optional[str]
    kind: str
    rest: str
    root: bool = False

    def result_bytes(self) -> int:
        if self.tuple_body is not None:
            return sum(_shape_bytes(dt, dm)
                       for dt, dm in _SHAPE_RE.findall(self.tuple_body))
        return _shape_bytes(self.dtype, self.dims)

    def operands(self) -> List[str]:
        return _OPERAND_RE.findall(self.rest.split(")")[0])


@dataclasses.dataclass
class ModuleStats:
    flops: float
    bytes: float
    collectives: CollectiveStats
    while_trips: Dict[str, int]


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[str] = None
    entry_marker = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry_marker = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, tup, dtype, dims, kind, rest = m.groups()
            comps[cur].append(_Op(name, dtype, dims, tup, kind, rest,
                                  root=line.lstrip().startswith("ROOT")))
    if entry_marker is not None:
        comps["__entry__"] = comps[entry_marker]
    return comps


def _dot_flops(op: _Op, env: Dict[str, Tuple[str, str]]) -> float:
    if op.dims is None:
        return 0.0
    res_elems = 1
    if op.dims.strip():
        for d in op.dims.split(","):
            res_elems *= int(d)
    operands = _OPERAND_RE.findall(op.rest)
    if not operands:
        return 0.0
    lhs = env.get(operands[0])
    if lhs is None:
        return 0.0
    _, lhs_dims = lhs
    lhs_shape = [int(d) for d in lhs_dims.split(",")] if lhs_dims.strip() \
        else []
    m = _CONTRACT_RE.search(op.rest)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_shape):
                contract *= lhs_shape[i]
    return 2.0 * res_elems * contract


def _coll_wire_bytes(op: _Op, line_rest: str) -> Tuple[str, float]:
    size = op.result_bytes()
    g = _group_size(line_rest)
    kind = op.kind.replace("-start", "")
    if kind == "all-reduce":
        return kind, 2.0 * (g - 1) / g * size
    if kind == "all-gather":
        return kind, (g - 1) / g * size
    if kind == "reduce-scatter":
        return kind, float(g - 1) * size
    if kind == "all-to-all":
        return kind, (g - 1) / g * size
    return kind, float(size)


def analyze_hlo_module(text: str) -> ModuleStats:
    comps = _parse_computations(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # shape environments per computation
    envs: Dict[int, Dict[str, Tuple[str, str]]] = {}

    def env_of(ops: List[_Op]) -> Dict[str, Tuple[str, str]]:
        key = id(ops)
        if key not in envs:
            envs[key] = {o.name: (o.dtype, o.dims) for o in ops
                         if o.dims is not None}
        return envs[key]

    def trip_count(cond_name: str) -> int:
        best = 1
        for o in comps.get(cond_name, []):
            if o.kind == "constant":
                # rest looks like "28), metadata=..." (the "constant(" was
                # consumed as the op kind by the parser)
                m = re.match(r"(\d+)\)", o.rest)
                if m:
                    best = max(best, int(m.group(1)))
            for m in _CONST_INT_RE.finditer(o.rest):
                best = max(best, int(m.group(1)))
        return best

    # multiplicity walk; fused computations contribute flops but not bytes
    mult: Dict[str, float] = {}
    fused_internal: Dict[str, bool] = {}
    while_trips: Dict[str, int] = {}

    def visit(name: str, m: float, fused: bool):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        fused_internal[name] = fused_internal.get(name, True) and fused
        for op in comps[name]:
            if op.kind == "while":
                mm = _WHILE_BC_RE.search(op.rest)
                if mm:
                    cond, body = mm.group(1), mm.group(2)
                    t = trip_count(cond)
                    while_trips[body] = t
                    visit(body, m * t, fused)
                    visit(cond, m * (t + 1), fused)
            elif op.kind in ("fusion",):
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    visit(cm.group(1), m, True)
            elif op.kind in ("call", "conditional", "custom-call",
                             "reduce", "sort", "map", "scatter",
                             "select-and-scatter", "reduce-window"):
                for cm in _CALLS_RE.finditer(op.rest):
                    visit(cm.group(1), m, True)
                # `to_apply=` style references
                for cm in re.finditer(r"to_apply=%?([\w\.\-]+)", op.rest):
                    visit(cm.group(1), m, True)

    # find the entry's real name to start
    entry_name = next(k for k, v in comps.items()
                      if v is entry and k != "__entry__")
    visit(entry_name, 1.0, False)

    flops = 0.0
    bytes_total = 0.0
    coll_counts: Dict[str, int] = {}
    coll_bytes: Dict[str, float] = {}

    def op_bytes(op: _Op, env) -> float:
        """XLA-HloCostAnalysis-style bytes for one op: slices charge the
        sliced region, in-place dynamic-update-slice charges the update,
        gathers/scatters charge moved rows — never whole backing buffers
        (those patterns dominate scan-over-layers models where per-layer
        slices are taken from stacked parameter/cache arrays)."""
        kind = op.kind
        if kind in ("dynamic-slice", "slice"):
            return 2.0 * op.result_bytes()
        if kind == "dynamic-update-slice":
            ons = op.operands()
            upd = env.get(ons[1]) if len(ons) > 1 else None
            ub = _shape_bytes(*upd) if upd else op.result_bytes()
            return 2.0 * ub
        if kind == "gather":
            return 2.0 * op.result_bytes()
        if kind == "scatter":
            ons = op.operands()
            upd = env.get(ons[-1]) if ons else None
            ub = _shape_bytes(*upd) if upd else op.result_bytes()
            return 2.0 * ub
        if kind == "fusion":
            cm = _CALLS_RE.search(op.rest)
            inner = comps.get(cm.group(1), []) if cm else []
            ienv = env_of(inner)
            by_name = {o.name: o for o in inner}
            params = {o.name: _shape_bytes(o.dtype, o.dims)
                      for o in inner if o.kind == "parameter"
                      and o.dims is not None}
            root = next((o for o in inner if o.root), None)
            total = 0.0
            dus_buffers = set()

            def charge_elem(o: Optional[_Op], fallback: float) -> float:
                """Output-element cost: in-place DUS writes its update."""
                if o is not None and o.kind == "dynamic-update-slice":
                    ons = o.operands()
                    if ons:
                        dus_buffers.add(ons[0])
                    upd = ienv.get(ons[1]) if len(ons) > 1 else None
                    return 2.0 * (_shape_bytes(*upd) if upd
                                  else o.result_bytes())
                return fallback

            if root is not None and root.kind == "dynamic-update-slice":
                total += charge_elem(root, root.result_bytes())
            elif root is not None and root.kind == "tuple":
                # multi-output fusion (scan ys stacking): charge each
                # element by its own rule, not the full tuple
                for on in root.operands():
                    o = by_name.get(on)
                    fb = (o.result_bytes() if o is not None and
                          o.dims is not None else 0.0)
                    total += charge_elem(o, fb)
            else:
                total += op.result_bytes()
            # parameters: sliced-only params charge their slices
            for pname, pbytes in params.items():
                if pname in dus_buffers:
                    continue                      # aliased in-place buffer
                uses = [o for o in inner if pname in o.operands()]
                if uses and all(u.kind in ("dynamic-slice", "slice",
                                           "gather") for u in uses):
                    total += sum(u.result_bytes() for u in uses)
                else:
                    total += pbytes
            return total
        b = op.result_bytes()
        for on in op.operands():
            sh = env.get(on)
            if sh is not None:
                b += _shape_bytes(sh[0], sh[1])
        return b

    for cname, m in mult.items():
        ops = comps[cname]
        env = env_of(ops)
        fused = fused_internal[cname]
        for op in ops:
            if op.kind in ("dot", "dot-general"):
                flops += m * _dot_flops(op, env)
            elif op.kind == "convolution":
                # not emitted by this framework; conservative: result-size
                flops += m * op.result_bytes()
            kind = op.kind.replace("-start", "")
            if kind in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                k, wire = _coll_wire_bytes(op, op.rest)
                coll_counts[k] = coll_counts.get(k, 0) + int(m)
                coll_bytes[k] = coll_bytes.get(k, 0.0) + m * wire
            if not fused and op.kind not in _SKIP_BYTES_OPS and \
                    not op.kind.endswith("-done"):
                bytes_total += m * op_bytes(op, env)

    return ModuleStats(flops=flops, bytes=bytes_total,
                       collectives=CollectiveStats(coll_counts, coll_bytes),
                       while_trips=while_trips)


# --------------------------------------------------------------------------
# roofline terms
# --------------------------------------------------------------------------

PEAK_FLOPS = 197e12            # bf16 / chip (v5e)
HBM_BW = 819e9                 # bytes/s / chip
ICI_BW = 50e9                  # bytes/s / link (~per direction)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   model_flops_total: float = 0.0,
                   n_devices: int = 1) -> Roofline:
    """All inputs are per-device quantities from the partitioned module,
    except model_flops_total (whole-model analytic 6ND)."""
    c = flops / PEAK_FLOPS
    m = hbm_bytes / HBM_BW
    k = coll_bytes / ICI_BW
    terms = {"compute": c, "memory": m, "collective": k}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops_total / (flops * n_devices)
              if flops and model_flops_total else 0.0)
    return Roofline(flops_per_device=flops, hbm_bytes_per_device=hbm_bytes,
                    coll_bytes_per_device=coll_bytes, compute_s=c,
                    memory_s=m, collective_s=k, bottleneck=bottleneck,
                    model_flops=model_flops_total, useful_ratio=useful)
