import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective analysis.

This is the scale proof for hardware we don't have: a successful
``.lower().compile()`` against the 256-chip single-pod mesh and the
512-chip 2-pod mesh demonstrates that every sharding in the system is
coherent (no mismatched pspecs, no unsupported collectives, no
compile-time OOM), and the compiled artifact yields the roofline terms
reported in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, get_config
from repro.distributed.sharding import axis_rules, sharding_for, tree_shardings
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models.model import (Model, RunConfig, SHAPES, cell_applicable,
                                input_specs)
from repro.optim import schedule as sched
from repro.optim.optimizer import adamw
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.step import (TrainConfig, make_train_step, state_axes,
                              state_shapes)

FACTORED_THRESHOLD = 5e10      # params above this use factored 2nd moment


def build_optimizer(cfg):
    factored = cfg.param_count() > FACTORED_THRESHOLD
    lr = sched.make("wsd" if cfg.name.startswith("minicpm") else "cosine",
                    peak=3e-4, warmup_steps=2000, total_steps=100_000)
    return adamw(lr, factored=factored,
                 state_dtype=jnp.bfloat16 if factored else jnp.float32)


def _shard_count(sharding, shape) -> int:
    n = 1
    spec = sharding.spec
    mesh = sharding.mesh
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            n *= mesh.shape[a]
    return n


def _bytes_per_device(shapes_tree, shardings_tree) -> int:
    total = 0
    for sds, sh in zip(jax.tree.leaves(shapes_tree),
                       jax.tree.leaves(shardings_tree,
                                       is_leaf=lambda x: hasattr(x, "spec"))):
        n = 1
        for d in sds.shape:
            n *= d
        total += n * sds.dtype.itemsize // max(_shard_count(sh, sds.shape), 1)
    return total


def dryrun_cell(arch: str, shape: str, multi_pod: bool,
                microbatches: int = 1, remat: str = "dots",
                extra_tag: str = "", moe_impl: str = "gspmd",
                attn_probs_dtype: str = "float32",
                block_q: int = 512, block_k: int = 1024,
                mla_absorbed: bool = True) -> Dict[str, Any]:
    from repro.models.layers import set_attention_options
    from repro.models.mla import set_mla_absorbed
    from repro.models.moe import set_moe_impl
    set_moe_impl(moe_impl)
    set_mla_absorbed(mla_absorbed)
    set_attention_options(probs_dtype=attn_probs_dtype, block_q=block_q,
                          block_k=block_k)
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": True, "reason": why}

    info = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    B, S = info["global_batch"], info["seq_len"]
    run = RunConfig(param_dtype="bfloat16", cache_dtype="bfloat16",
                    max_seq=S, remat=remat if info["kind"] == "train"
                    else "none")
    model = Model(cfg, run)
    kind = info["kind"]
    specs = input_specs(cfg, shape, dtype=jnp.bfloat16)

    t0 = time.perf_counter()
    with mesh, axis_rules(mesh):
        batch_shardings = {
            k: sharding_for(("batch",) + ("-",) * (len(v.shape) - 1),
                            v.shape, mesh)
            for k, v in specs.items()}

        if kind == "train":
            optimizer = build_optimizer(cfg)
            st_shapes = state_shapes(model, optimizer)
            st_axes = state_axes(model, optimizer)
            st_shardings = tree_shardings(st_axes, st_shapes, mesh)
            step_fn = make_train_step(model, optimizer,
                                      TrainConfig(microbatches=microbatches))
            jitted = jax.jit(step_fn,
                             in_shardings=(st_shardings, batch_shardings),
                             out_shardings=(st_shardings, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(st_shapes, specs)
            state_bytes = _bytes_per_device(st_shapes, st_shardings)
        else:
            pshapes = model.param_shapes()
            paxes = model.param_axes()
            pshardings = tree_shardings(paxes, pshapes, mesh)
            cshapes = model.cache_shapes(B, S)
            caxes = model.cache_axes(B, S)
            cshardings = tree_shardings(caxes, cshapes, mesh)
            state_bytes = (_bytes_per_device(pshapes, pshardings)
                           + _bytes_per_device(cshapes, cshardings))
            if kind == "prefill":
                fn = make_prefill_step(model)
                args = (pshapes, cshapes, specs["tokens"])
                in_sh = (pshardings, cshardings, batch_shardings["tokens"])
                if "extra_embeds" in specs:
                    args = args + (specs["extra_embeds"],)
                    in_sh = in_sh + (batch_shardings["extra_embeds"],)
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 out_shardings=(None, cshardings),
                                 donate_argnums=(1,))
                lowered = jitted.lower(*args)
            else:                                   # decode
                fn = make_decode_step(model)
                jitted = jax.jit(
                    fn,
                    in_shardings=(pshardings, cshardings,
                                  batch_shardings["tokens"]),
                    out_shardings=(None, cshardings),
                    donate_argnums=(1,))
                lowered = jitted.lower(pshapes, cshapes, specs["tokens"])
        t_lower = time.perf_counter() - t0

        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1

    # ---- analysis ----
    mem: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            if hasattr(ma, field):
                mem[field] = int(getattr(ma, field))
    except Exception as e:                          # pragma: no cover
        mem["error"] = str(e)
    print("memory_analysis:", mem or "n/a")

    cost: Dict[str, float] = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed"))}
    except Exception as e:                          # pragma: no cover
        cost = {"error": str(e)}
    print("cost_analysis:", {k: v for k, v in list(cost.items())[:4]})

    hlo = compiled.as_text()
    stats = H.analyze_hlo_module(hlo)        # trip-count-correct accounting
    coll = stats.collectives

    # analytic model flops
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = B * S
        model_flops = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = B * S
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = B
        model_flops = 2.0 * n_active * tokens

    flops_dev = stats.flops
    bytes_dev = stats.bytes
    roof = H.roofline_terms(flops_dev, bytes_dev, coll.total_bytes,
                            model_flops_total=model_flops, n_devices=n_dev)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "params": cfg.param_count(),
        "active_params": n_active,
        "tokens_per_step": tokens,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "state_bytes_per_device": int(state_bytes),
        "memory_analysis": mem,
        "cost_analysis_raw": cost,
        "hlo_stats": {"flops": stats.flops, "bytes": stats.bytes,
                      "while_trips": stats.while_trips},
        "collectives": {"counts": coll.counts,
                        "bytes_by_kind": coll.bytes_by_kind,
                        "total_bytes": coll.total_bytes},
        "roofline": roof.as_dict(),
        "tag": extra_tag,
    }
    return rec


def cell_list():
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = cell_applicable(cfg, shape)
            cells.append((arch, shape, ok))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="grad-accum microbatches for train cells (8 keeps "
                         "temp memory within v5e HBM at the baseline)")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--moe-impl", default="gspmd",
                    choices=["auto", "gspmd", "shardmap"])
    ap.add_argument("--attn-probs-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--block-q", type=int, default=512)
    ap.add_argument("--block-k", type=int, default=1024)
    ap.add_argument("--mla-absorbed", default="on", choices=["on", "off"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--timeout", type=int, default=1800,
                    help="per-cell subprocess timeout (driver mode)")
    args = ap.parse_args()

    if args.list:
        for arch, shape, ok in cell_list():
            print(f"{arch:22s} {shape:12s} {'run' if ok else 'SKIP'}")
        return

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        # driver mode: one subprocess per cell for isolation
        failures = []
        for arch, shape, ok in cell_list():
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                outfile = os.path.join(args.out, tag + ".json")
                if os.path.exists(outfile):
                    print(f"[skip existing] {tag}")
                    continue
                if not ok:
                    cfgrec = {"arch": arch, "shape": shape, "skipped": True,
                              "mesh": "2x16x16" if mp else "16x16",
                              "reason": cell_applicable(get_config(arch),
                                                        shape)[1]}
                    with open(outfile, "w") as f:
                        json.dump(cfgrec, f, indent=1)
                    print(f"[skip n/a] {tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", "multi" if mp else "single",
                       "--out", args.out,
                       "--microbatches", str(args.microbatches),
                       "--remat", args.remat, "--tag", args.tag,
                       "--moe-impl", args.moe_impl,
                       "--attn-probs-dtype", args.attn_probs_dtype,
                       "--block-q", str(args.block_q),
                       "--block-k", str(args.block_k)]
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.perf_counter()
                try:
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    dt = time.perf_counter() - t0
                    if r.returncode != 0:
                        failures.append(tag)
                        print(f"[FAIL {dt:.0f}s] {tag}\n{r.stdout[-2000:]}"
                              f"\n{r.stderr[-4000:]}")
                    else:
                        print(f"[ok {dt:.0f}s] {tag}")
                except subprocess.TimeoutExpired:
                    failures.append(tag)
                    print(f"[TIMEOUT] {tag}")
        print(f"\ndone; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    # single-cell mode
    assert args.arch and args.shape, "--arch and --shape required"
    for mp in meshes:
        rec = dryrun_cell(args.arch, args.shape, mp,
                          microbatches=args.microbatches, remat=args.remat,
                          extra_tag=args.tag, moe_impl=args.moe_impl,
                          attn_probs_dtype=args.attn_probs_dtype,
                          block_q=args.block_q, block_k=args.block_k,
                          mla_absorbed=(args.mla_absorbed == "on"))
        tag = f"{args.arch}__{args.shape}__{rec.get('mesh', 'na')}"
        if args.tag:
            tag += f"__{args.tag}"
        outfile = os.path.join(args.out, tag + ".json")
        with open(outfile, "w") as f:
            json.dump(rec, f, indent=1)
        if not rec.get("skipped"):
            r = rec["roofline"]
            print(f"[cell] {tag}: compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_ratio']:.3f} "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")


if __name__ == "__main__":
    main()
