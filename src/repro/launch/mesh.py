"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e-256 pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
``pod`` axis composes with ``data`` for batch/FSDP sharding so the DCN/
inter-pod boundary only ever carries data-parallel gradient traffic.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.
"""

from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devs)} are "
            f"visible; the dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh(model: Optional[int] = None):
    """Degenerate mesh over whatever devices exist (tests on 1-8 CPUs)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
