"""Training launcher.

Examples:
  # CPU-runnable reduced config (this container):
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 100 --seq-len 128 --global-batch 8 --checkpoint-dir /tmp/ck

  # full config on a real fleet (same code path; mesh axes picked up from
  # the runtime's device count):
  python -m repro.launch.train --arch qwen2-7b --seq-len 4096 \
      --global-batch 256 --steps 100000 --mesh auto
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed.sharding import axis_rules, sharding_for, tree_shardings
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model, RunConfig
from repro.optim import schedule as sched
from repro.optim.optimizer import adamw
from repro.train.step import (TrainConfig, init_state, make_train_step,
                              state_axes, state_shapes)
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default=None, choices=[None, "cosine", "wsd"])
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single", "multi"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    run = RunConfig(max_seq=args.seq_len, remat=args.remat)
    model = Model(cfg, run)

    sname = args.schedule or ("wsd" if cfg.name.startswith("minicpm")
                              else "cosine")
    lr = sched.make(sname, peak=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps)
    optimizer = adamw(lr, weight_decay=0.01)
    step_fn = make_train_step(model, optimizer,
                              TrainConfig(microbatches=args.microbatches))

    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size,
                               seq_len=args.seq_len,
                               global_batch=args.global_batch,
                               seed=args.seed))

    key = jax.random.PRNGKey(args.seed)
    print(f"[train] arch={cfg.name} params={model.param_count():,} "
          f"mesh={args.mesh} steps={args.steps}")

    if mesh is not None:
        with mesh, axis_rules(mesh):
            st_shapes = state_shapes(model, optimizer)
            st_axes = state_axes(model, optimizer)
            st_sh = tree_shardings(st_axes, st_shapes, mesh)
            jstep = jax.jit(step_fn, in_shardings=(st_sh, None),
                            out_shardings=(st_sh, None),
                            donate_argnums=(0,))
            state = jax.jit(lambda k: init_state(model, optimizer, k),
                            out_shardings=st_sh)(key)
            trainer = Trainer(TrainerConfig(
                total_steps=args.steps,
                checkpoint_every=args.checkpoint_every,
                checkpoint_dir=args.checkpoint_dir), jstep, pipe)
            trainer.install_preemption_handler()
            trainer.run(state)
    else:
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        state = init_state(model, optimizer, key)
        trainer = Trainer(TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir), jstep, pipe)
        trainer.install_preemption_handler()
        state = trainer.run(state)
        losses = [m["loss"] for m in trainer.metrics_history]
        if losses:
            print(f"[train] loss first->last: {losses[0]:.4f} -> "
                  f"{losses[-1]:.4f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
