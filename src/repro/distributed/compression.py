"""Gradient compression: int8 quantised all-reduce with error feedback.

Distributed-optimisation trick for scale: data-parallel gradient
all-reduces move ~4 bytes/param/step; per-tensor-scaled int8 cuts that
4x on the wire.  Error feedback (residual carried to the next step)
keeps SGD convergence unbiased in expectation.

Implemented as an explicit ``shard_map`` collective so the quantised
representation actually crosses the ICI (a plain with_sharding_constraint
would let XLA all-reduce in f32).  Opt-in via TrainConfig.grad_compress.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` of int8-quantised x (inside shard_map)."""
    q, scale = quantize_int8(x)
    # int8 payloads sum in int32 to avoid overflow across replicas;
    # scales are tiny and reduce in f32.
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # all replicas must agree on a scale: use the max scale
    smax = jax.lax.pmax(scale, axis_name)
    return s.astype(jnp.float32) * smax / n


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns f(local_grads_tree) -> mean-reduced tree, communicating
    int8.  Gradients must be replicated over the other mesh axes."""

    def reduce_tree(tree):
        def one(x):
            fn = shard_map(
                functools.partial(compressed_psum_mean, axis_name=axis),
                mesh=mesh, in_specs=P(*(axis,) + (None,) * (x.ndim - 1)),
                out_specs=P(*(axis,) + (None,) * (x.ndim - 1)),
                check_rep=False)
            return fn(x)
        return jax.tree.map(one, tree)

    return reduce_tree


def error_feedback_update(grads, residual):
    """g' = g + r;  r' = g' - Q(g') applied leaf-wise (int8)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g)
        deq = dequantize_int8(q, scale)
        return deq, g - deq
    pairs = jax.tree.map(one, grads, residual)
    new_grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_res
