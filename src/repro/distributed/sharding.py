"""Logical-axis sharding: DP/FSDP/TP/EP/SP on the production mesh.

Tensors (params and activations) are annotated with *logical* axis names;
rules map logical names to mesh axes.  The resolver enforces
divisibility: a mesh axis that does not divide the tensor dimension is
dropped (documented fallback — e.g. 10 attention heads on a 16-way
``model`` axis stay replicated while d_ff still shards).  This keeps
every (arch × shape × mesh) cell compilable; the roofline table then
exposes the cost of any fallback.

Logical axes used across the framework:
    batch      — global batch            -> ("pod", "data")
    kv_seq     — KV-cache sequence       -> sequence-sharding for long ctx
    heads      — attention query heads   -> "model" (Megatron TP)
    kv_heads   — KV heads                -> "model"
    ff         — MLP hidden              -> "model"
    vocab      — embedding/logits vocab  -> "model"
    experts    — MoE experts             -> "model" (expert parallelism)
    fsdp       — parameter dim for ZeRO-3-style sharding -> ("pod", "data")
    embed/None — replicated
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "kv_seq": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "fsdp": ("pod", "data"),
    "embed": (),
    "seq": (),
}

_ctx = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def current_rules() -> Rules:
    return getattr(_ctx, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    """Activate a mesh + rule set for ``shard()`` constraints within."""
    prev = (getattr(_ctx, "mesh", None), getattr(_ctx, "rules", DEFAULT_RULES))
    _ctx.mesh = mesh
    _ctx.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def _resolve_axis(logical: Optional[str], dim: int, mesh: Mesh,
                  rules: Rules, used: set) -> Optional[Union[str, Tuple[str, ...]]]:
    """Map one logical axis to mesh axes with divisibility fallback."""
    if logical is None or logical == "":
        return None
    mesh_axes = rules.get(logical)
    if mesh_axes is None:
        return None
    mesh_axes = tuple(a for a in mesh_axes
                      if a in mesh.shape and a not in used)
    # greedy prefix: keep the longest prefix whose product divides dim
    while mesh_axes:
        prod = 1
        for a in mesh_axes:
            prod *= mesh.shape[a]
        if prod and dim % prod == 0:
            break
        mesh_axes = mesh_axes[:-1]
    if not mesh_axes:
        return None
    used.update(mesh_axes)
    return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]


def pspec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
              mesh: Mesh, rules: Optional[Rules] = None) -> P:
    rules = dict(DEFAULT_RULES, **(rules or {}))
    used: set = set()
    parts = [_resolve_axis(a, d, mesh, rules, used)
             for a, d in zip(axes, shape)]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def parse_axes(axes: Union[str, Sequence[Optional[str]]]):
    """"fsdp ff" -> ("fsdp", "ff"); "-" entries mean replicated."""
    if isinstance(axes, str):
        return tuple(None if a in ("-", "_") else a for a in axes.split())
    return tuple(axes)


def sharding_for(axes: Union[str, Sequence[Optional[str]]],
                 shape: Sequence[int], mesh: Mesh,
                 rules: Optional[Rules] = None) -> NamedSharding:
    ax = parse_axes(axes)
    if len(ax) != len(shape):
        raise ValueError(f"axes {ax} rank != shape {tuple(shape)}")
    return NamedSharding(mesh, pspec_for(ax, shape, mesh, rules))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding under the active mesh (no-op
    when no mesh context is active, e.g. CPU smoke tests)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = pspec_for(axes, x.shape, mesh, current_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh,
                   rules: Optional[Rules] = None):
    """Zip an axes tree (string leaves) with a ShapeDtypeStruct tree into a
    NamedSharding tree (for jit in_shardings / checkpoint layouts)."""
    return jax.tree.map(
        lambda ax, sds: sharding_for(ax, sds.shape, mesh, rules),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, str))
