"""Failure-domain handling beyond checkpoint/restart.

``FailoverRunner`` wraps a train-step callable with restore-on-failure
semantics: any step that raises a recoverable error (device OOM, a
simulated chip loss, a collective timeout surfaced as RuntimeError) rolls
the state back to the last committed checkpoint and replays from there —
the in-process equivalent of a job restart, with the same guarantees
(stateless data pipeline keyed by step => no sample skew).

On a real fleet this sits under a cluster scheduler that also replaces
the failed host; the state machine here (checkpoint -> fail -> restore ->
replay) is identical, which is what the tests exercise by injection.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.checkpoint import checkpointer as ckpt

RECOVERABLE = (RuntimeError, ValueError, jax.errors.JaxRuntimeError)


@dataclasses.dataclass
class FailoverConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    max_failures: int = 5
    backoff_s: float = 0.0           # real fleets back off; tests don't


class FailoverRunner:
    def __init__(self, cfg: FailoverConfig, train_step: Callable,
                 batch_fn: Callable[[int], Dict],
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.log = log_fn
        self.failures = 0
        self.replayed_steps = 0

    def run(self, state, start_step: int, total_steps: int):
        step = start_step
        last_commit = start_step
        # resume if a previous incarnation left a checkpoint
        latest = ckpt.latest_step(self.cfg.checkpoint_dir)
        if latest is not None and latest > step:
            state, extra = ckpt.restore(self.cfg.checkpoint_dir,
                                        target=state)
            step = last_commit = extra["step"]
            self.log(f"[failover] resumed at step {step}")
        while step < total_steps:
            try:
                state, metrics = self.train_step(state, self.batch_fn(step))
                jax.block_until_ready(jax.tree.leaves(metrics)[0])
                step += 1
                if step % self.cfg.checkpoint_every == 0 or \
                        step == total_steps:
                    ckpt.save(self.cfg.checkpoint_dir, step, state)
                    last_commit = step
            except RECOVERABLE as e:
                self.failures += 1
                if self.failures > self.cfg.max_failures:
                    raise RuntimeError(
                        f"exceeded {self.cfg.max_failures} failures") from e
                self.log(f"[failover] step {step} failed ({type(e).__name__}:"
                         f" {e}); restoring step {last_commit}")
                if self.cfg.backoff_s:
                    time.sleep(self.cfg.backoff_s)
                if ckpt.latest_step(self.cfg.checkpoint_dir) is not None:
                    state, extra = ckpt.restore(self.cfg.checkpoint_dir,
                                                target=state)
                    self.replayed_steps += step - extra["step"]
                    step = extra["step"]
                else:
                    self.replayed_steps += step - start_step
                    step = start_step
        return state, step
