"""Fault-tolerant checkpointing: step-atomic, mesh-elastic, numpy-backed.

Design for 1000+-node operation:
  * **atomicity** — write to ``step_N.tmp/`` then ``os.rename``; a crash
    mid-write can never corrupt the latest checkpoint;
  * **elasticity** — arrays are stored with *logical* shapes only (no
    device layout); restore re-shards onto whatever mesh is active, so a
    job can come back on a different pod count after failures;
  * **data-pipeline state** — just the step counter (the pipeline is
    stateless by construction), stored in the manifest;
  * **GC** — keep-last-k, oldest removed only after the newest commit.

On a real multi-host fleet each host writes only its addressable shards
(``jax.experimental.multihost_utils``); this container is single-process,
so ``save`` gathers.  The manifest/restore format is identical in both
modes, which is what elasticity actually requires.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = SEP.join(_key_str(p) for p in path)
        out.append((key, leaf))
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten(tree)
    index = {}
    arrays: Dict[str, np.ndarray] = {}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"a{len(index)}"] = arr
        index[key] = {"id": f"a{len(index)}", "shape": list(arr.shape),
                      "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "index": index, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None, target=None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore a checkpoint.

    ``target``: optional pytree (of arrays or ShapeDtypeStructs) giving
    the structure to restore into; without it a nested dict is rebuilt
    from the flattened keys.  ``shardings``: matching tree of
    NamedShardings for elastic placement onto the active mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {key: data[meta["id"]] for key, meta in manifest["index"].items()}

    if target is not None:
        leaves = _flatten(target)
        rebuilt = []
        for key, leaf in leaves:
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = flat[key]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {want}")
            rebuilt.append(arr)
        treedef = jax.tree_util.tree_structure(target)
        tree = jax.tree_util.tree_unflatten(treedef, rebuilt)
    else:
        tree = _unflatten_keys(flat)

    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree, manifest["extra"] | {"step": manifest["step"]}


def _unflatten_keys(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, arr in flat.items():
        parts = key.split(SEP)
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return root
