"""Config system: architecture + run configuration.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro/configs/``; ``get_config(name)`` resolves them by id, and
``reduced(cfg)`` derives the CPU-smoke-test variant of the same family.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0
    first_dense_layers: int = 0
    dense_ff: int = 0                 # ff of the leading dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 0                   # 0 = full-rank q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_inner: int = 0                  # 0 -> 2 * d_model
    head_dim: int = 64
    state_dim: int = 128
    conv_width: int = 4
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int = 0                    # 0 -> d_model
    conv_width: int = 4
    c: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). The modality frontend is
    a stub per the assignment: inputs are precomputed frame embeddings."""
    num_layers: int
    context: int                      # e.g. 1500 audio frames
    d_model: int = 0                  # 0 -> same as decoder


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # per-layer block pattern, cycled over layers: entries from
    # {"attn", "moe", "rglru", "ssd"}
    pattern: Tuple[str, ...] = ("attn",)
    # per-layer local-attention window; None = global. For mixed
    # local:global archs (gemma3) use window_pattern, cycled per layer.
    window_pattern: Tuple[Optional[int], ...] = (None,)
    qkv_bias: bool = False
    mlp: str = "gated_silu"           # gated_silu | gated_gelu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None    # None | audio_frames | image_patches
    frontend_len: int = 0             # stub frames/patches prepended
    # whether the arch is sub-quadratic enough for the long_500k cell
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding/logits vocab dim shards over
        the model axis (x16) and the fsdp axes (x32) — padded logit
        columns are masked to -inf in the loss/sampler."""
        mult = 512
        return (self.vocab_size + mult - 1) // mult * mult

    def layer_kinds(self) -> List[str]:
        return [self.pattern[i % len(self.pattern)]
                for i in range(self.num_layers)]

    def layer_windows(self) -> List[Optional[int]]:
        return [self.window_pattern[i % len(self.window_pattern)]
                for i in range(self.num_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # lm head
        for i, kind in enumerate(self.layer_kinds()):
            n += 2 * d                                # 2 norms
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qdim = self.num_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    n += d * qdim
                    n += d * (m.kv_lora + m.qk_rope_dim)
                    n += m.kv_lora * self.num_heads * (m.qk_nope_dim + m.v_dim)
                    n += self.num_heads * m.v_dim * d
                else:
                    n += d * self.num_heads * hd
                    n += 2 * d * self.num_kv_heads * hd
                    n += self.num_heads * hd * d
                n += self._mlp_params(i)
            elif kind == "moe":
                n += self._mlp_params(i)
            elif kind == "rglru":
                r = self.rglru or RGLRUConfig()
                w = r.width or d
                n += 2 * d * w + w * d               # in projs + out proj
                n += r.conv_width * w + 3 * w        # conv + a_param + gates
                n += 2 * w * w                       # gate linears
            elif kind == "ssd":
                s = self.ssm or SSMConfig()
                di = s.d_inner or 2 * d
                heads = di // s.head_dim
                n += d * (2 * di + 2 * s.state_dim + heads)  # in_proj
                n += s.conv_width * (di + 2 * s.state_dim)   # conv
                n += 2 * heads + di                          # A, D, norm
                n += di * d                                  # out_proj
        if self.encoder is not None:
            e = self.encoder
            ed = e.d_model or d
            per = 4 * ed * ed + 2 * ed * self.d_ff + 2 * ed  # self-attn + mlp
            n += e.num_layers * per
            # decoder cross-attention adds per-layer params
            n += self.num_layers * 4 * d * d
        return n

    def _mlp_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.moe is not None and layer_idx >= self.moe.first_dense_layers \
                and self.layer_kinds()[layer_idx] == "moe":
            m = self.moe
            n = d * m.num_experts                     # router
            gates = 3 if self.mlp.startswith("gated") else 2
            n += m.num_experts * gates * d * m.expert_ff
            n += m.num_shared * gates * d * m.expert_ff
            return n
        ff = self.d_ff
        if self.moe is not None and layer_idx < self.moe.first_dense_layers:
            ff = self.moe.dense_ff or self.d_ff
        if self.mlp.startswith("gated"):
            return 3 * d * ff
        return 2 * d * ff

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        m = self.moe
        gates = 3 if self.mlp.startswith("gated") else 2
        n_moe_layers = sum(1 for i, k in enumerate(self.layer_kinds())
                           if k == "moe" and i >= m.first_dense_layers)
        all_routed = n_moe_layers * m.num_experts * gates * self.d_model * m.expert_ff
        active_routed = n_moe_layers * m.top_k * gates * self.d_model * m.expert_ff
        return total - all_routed + active_routed


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

ARCHS = (
    "recurrentgemma_2b", "qwen1_5_32b", "gemma3_4b", "minicpm_2b",
    "qwen2_7b", "mamba2_130m", "deepseek_v2_236b", "kimi_k2_1t",
    "pixtral_12b", "whisper_base",
)

_ALIASES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen1.5-32b": "qwen1_5_32b",
    "gemma3-4b": "gemma3_4b",
    "minicpm-2b": "minicpm_2b",
    "qwen2-7b": "qwen2_7b",
    "mamba2-130m": "mamba2_130m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "kimi-k2-1t": "kimi_k2_1t",
    "pixtral-12b": "pixtral_12b",
    "whisper-base": "whisper_base",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)} "
                       f"(aliases: {sorted(_ALIASES)})")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    pat = len(cfg.pattern)
    layers = max(layers, pat)          # at least one full pattern
    heads = max(2, min(cfg.num_heads, 4))
    kv = 1 if cfg.num_kv_heads == 1 else max(1, min(cfg.num_kv_heads, heads))
    hd = max(8, d_model // heads)
    changes: Dict = dict(
        num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=kv, head_dim=hd, d_ff=d_model * 2,
        vocab_size=vocab, frontend_len=min(cfg.frontend_len, 8),
        window_pattern=tuple(None if w is None else min(w, 8)
                             for w in cfg.window_pattern),
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, expert_ff=d_model,
            dense_ff=d_model * 2)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora=16, qk_nope_dim=8, qk_rope_dim=8,
                                   v_dim=8)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_inner=2 * d_model, head_dim=16, state_dim=16, chunk=8)
    if cfg.rglru is not None:
        changes["rglru"] = dataclasses.replace(cfg.rglru, width=d_model)
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(num_layers=2, context=16,
                                           d_model=d_model)
    return dataclasses.replace(cfg, **changes)
