"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8,
head_dim=128), MoE: 384 routed experts top-8 + 1 shared, expert
d_ff=2048, first layer dense (d_ff=18432), vocab=163840.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,               # routed expert intermediate
    vocab_size=163_840,
    pattern=("moe",),
    mlp="gated_silu",
    moe=MoEConfig(num_experts=384, top_k=8, expert_ff=2048, num_shared=1,
                  first_dense_layers=1, dense_ff=18432,
                  capacity_factor=1.25),
    supports_long_context=False,
)
