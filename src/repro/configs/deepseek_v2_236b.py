"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf]  60L d_model=5120 128H, MLA kv_lora=512
(qk_nope=128, qk_rope=64, v=128), MoE: 160 routed experts top-6 +
2 shared, expert d_ff=1536, first layer dense (d_ff=12288),
vocab=102400.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,        # MLA: per-head KV reconstructed from the latent
    head_dim=128,
    d_ff=1536,               # routed expert intermediate
    vocab_size=102_400,
    pattern=("moe",),
    mlp="gated_silu",
    moe=MoEConfig(num_experts=160, top_k=6, expert_ff=1536, num_shared=2,
                  first_dense_layers=1, dense_ff=12288,
                  capacity_factor=1.25),
    mla=MLAConfig(kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    supports_long_context=False,
)
