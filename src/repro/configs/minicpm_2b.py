"""minicpm-2b — llama-like dense transformer trained with WSD schedule.

[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36) d_ff=5760
vocab=122753.  The WSD (warmup-stable-decay) schedule lives in
``repro.optim.schedule``; this config carries the architecture.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    mlp="gated_silu",
    tie_embeddings=True,
    supports_long_context=False,
)
