"""mamba2-130m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  24L d_model=768, d_inner=1536,
head_dim=64 (24 heads), ssm_state=128, vocab=50280.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,            # SSD heads = d_inner / head_dim
    num_kv_heads=24,
    d_ff=0,                  # attention-free, no separate MLP block
    vocab_size=50_280,
    pattern=("ssd",),
    tie_embeddings=True,
    ssm=SSMConfig(d_inner=1536, head_dim=64, state_dim=128, conv_width=4,
                  chunk=64),
    supports_long_context=True,   # linear-time recurrence
)
