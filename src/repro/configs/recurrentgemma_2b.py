"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, local-attention window 2048, pattern (rglru, rglru, attn).
"""

from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern=("rglru", "rglru", "attn"),
    window_pattern=(2048,),
    mlp="gated_gelu",
    tie_embeddings=True,
    rglru=RGLRUConfig(width=2560, conv_width=4, c=8.0),
    supports_long_context=True,      # recurrence + windowed attention
)
