"""pixtral-12b — VLM: pixtral-ViT frontend (STUB) + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H
(GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.

Per the assignment the vision frontend is a stub: ``input_specs()``
provides precomputed patch embeddings (B, frontend_len, d_model) which
replace the first ``frontend_len`` token embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    mlp="gated_silu",
    frontend="image_patches",
    frontend_len=1024,       # one 1024-patch image per sequence (stub)
    supports_long_context=False,
)
