"""gemma3-4b — dense transformer, 5 local : 1 global attention, 128k ctx.

[hf:google/gemma-3-4b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, local window 1024.

Local/global layers share one block structure; the window is a per-layer
scalar threaded through the layer scan, so the stack still compiles as a
single homogeneous scan (no HLO branch duplication).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    pattern=("attn",),
    window_pattern=(1024, 1024, 1024, 1024, 1024, None),   # 5 local : 1 global
    mlp="gated_gelu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    supports_long_context=True,      # dominated by windowed layers
)
