"""whisper-base — encoder-decoder speech model, conv frontend STUB.

[arXiv:2212.04356; unverified]  6L encoder + 6L decoder, d_model=512,
8H (kv=8, head_dim=64), d_ff=2048 (plain GELU MLP), vocab=51865,
encoder context 1500 frames.

Per the assignment the conv/mel frontend is a stub: ``input_specs()``
provides precomputed frame embeddings (B, 1500, 512).  Decode shapes run
against the decoder with cross-attention over the (fixed) encoder output.
"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,            # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    mlp="gelu",
    rope_theta=0.0,          # whisper uses learned absolute positions
    encoder=EncoderConfig(num_layers=6, context=1500, d_model=512),
    frontend="audio_frames",
    frontend_len=1500,
    supports_long_context=False,
)
