"""qwen1.5-32b — dense transformer with QKV bias.

[hf:Qwen/Qwen1.5-32B; hf]  64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152_064,
    qkv_bias=True,
    mlp="gated_silu",
    supports_long_context=False,     # pure full attention -> skip long_500k
)
