"""Training loop with fault tolerance: checkpoint/restart, preemption
handling, straggler detection, elastic resume.

Failure model on a 1000+-node fleet:
  * **node loss / preemption** — SIGTERM triggers a final checkpoint;
    the next incarnation of the job auto-resumes from the latest commit
    (``Trainer.run`` is re-entrant by construction).
  * **elastic rescale** — checkpoints are logical (see checkpointer);
    restoring under a different mesh re-shards automatically.
  * **stragglers** — per-step wall time is tracked with an EMA; steps
    slower than ``straggler_factor``x the EMA are logged and counted.
    On a real fleet this signal feeds the scheduler (hot-spare swap);
    here it is surfaced in metrics and tested by injection.
  * **data skew** — the pipeline is stateless; the step counter in the
    manifest is the only data-state, so no replica can drift.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import Pipeline
from repro.train.step import TrainState


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_ema: float = 0.9


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 pipeline: Pipeline,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.train_step = train_step
        self.pipeline = pipeline
        self.log = log_fn
        self._preempted = False
        self.metrics_history: List[Dict[str, float]] = []
        self.straggler_events = 0

    # ---- fault-tolerance hooks ----------------------------------------------

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def _maybe_restore(self, state: TrainState):
        d = self.cfg.checkpoint_dir
        if not d:
            return state, 0
        step = ckpt.latest_step(d)
        if step is None:
            return state, 0
        state, extra = ckpt.restore(d, step=step, target=state)
        self.log(f"[trainer] resumed from step {extra['step']}")
        return state, extra["step"]

    def _save(self, state: TrainState, step: int):
        if self.cfg.checkpoint_dir:
            path = ckpt.save(self.cfg.checkpoint_dir, step, state,
                             extra={"data_step": step},
                             keep=self.cfg.keep_checkpoints)
            self.log(f"[trainer] checkpointed step {step} -> {path}")

    # ---- loop ----------------------------------------------------------------

    def run(self, state: TrainState, start_step: int = 0,
            sharding=None) -> TrainState:
        state, resumed = self._maybe_restore(state)
        step = max(start_step, resumed)
        ema = None
        first_step = True
        while step < self.cfg.total_steps and not self._preempted:
            batch = self.pipeline.jax_batch(step, sharding)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler detection (the first step carries jit compilation
            # and is excluded from the EMA)
            if ema is not None and dt > self.cfg.straggler_factor * ema:
                self.straggler_events += 1
                self.log(f"[trainer] straggler step {step}: {dt:.3f}s "
                         f"(ema {ema:.3f}s)")
            if first_step:
                first_step = False
            else:
                ema = dt if ema is None else (self.cfg.straggler_ema * ema
                                              + (1 - self.cfg.straggler_ema)
                                              * dt)
            step += 1
            scalars = {k: float(np.asarray(v)) for k, v in metrics.items()}
            scalars["step_time_s"] = dt
            self.metrics_history.append(scalars)
            if step % self.cfg.log_every == 0 or step == 1:
                self.log(f"[trainer] step {step}: loss={scalars['loss']:.4f} "
                         f"lr={scalars.get('lr', 0):.2e} {dt*1e3:.0f}ms")
            if step % self.cfg.checkpoint_every == 0:
                self._save(state, step)
        if self._preempted:
            self.log(f"[trainer] preempted at step {step}; checkpointing")
            self._save(state, step)
        elif self.cfg.checkpoint_dir:
            self._save(state, step)
        return state
