"""Train-step factory: grad accumulation, remat, donation, sharding.

``make_train_step`` builds the jit-able (state, batch) -> (state, metrics)
function used by the trainer, the launcher and the dry-run.  Microbatch
gradient accumulation runs as a ``lax.scan`` over microbatches — on real
hardware this is also what overlaps the data-parallel gradient
reduce-scatter of microbatch i with the compute of microbatch i+1 (XLA
latency-hides collectives across scan iterations).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.optimizer import Optimizer, OptState


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    grad_compress: bool = False       # int8 error-feedback DP reduction


def init_state(model: Model, optimizer: Optimizer,
               key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      rng=jax.random.fold_in(key, 1))


def state_shapes(model: Model, optimizer: Optimizer) -> TrainState:
    """Abstract TrainState (ShapeDtypeStructs) — dry-run path, no alloc."""
    pshapes = model.param_shapes()
    opt = jax.eval_shape(optimizer.init, pshapes)
    return TrainState(params=pshapes, opt=opt,
                      rng=jax.ShapeDtypeStruct((2,), jnp.uint32))


def state_axes(model: Model, optimizer: Optimizer) -> TrainState:
    """Logical-axes TrainState matching ``state_shapes`` structure.

    Optimizer ``m`` mirrors the params tree (same axes).  A factored ``v``
    stores {"row","col"} (or {"full"}) per param; row drops the last
    logical axis, col drops the second-to-last.
    """
    paxes = model.param_axes()
    pshapes = model.param_shapes()
    opt_shapes = jax.eval_shape(optimizer.init, pshapes)

    def v_leaf_axes(p_ax: str, v_leaf) -> Any:
        ax = p_ax.split()
        if isinstance(v_leaf, dict):
            out = {}
            if "full" in v_leaf:
                out["full"] = p_ax
            if "row" in v_leaf:
                out["row"] = " ".join(ax[:-1]) or "-"
            if "col" in v_leaf:
                out["col"] = " ".join(ax[:-2] + ax[-1:]) or "-"
            return out
        return p_ax

    def walk(p_ax, v_sub):
        if isinstance(v_sub, dict) and ("full" in v_sub or "row" in v_sub):
            return v_leaf_axes(p_ax, v_sub)
        if isinstance(v_sub, dict):
            return {k: walk(p_ax[k], v_sub[k]) for k in v_sub}
        return p_ax

    v_shapes = opt_shapes.v
    v_axes = () if isinstance(v_shapes, tuple) and v_shapes == () \
        else walk(paxes, v_shapes)
    return TrainState(params=paxes,
                      opt=OptState(step="", m=paxes, v=v_axes),
                      rng="-")


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        return model.loss(params, batch)
    return loss_fn


def make_train_step(model: Model, optimizer: Optimizer,
                    tcfg: TrainConfig = TrainConfig()
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        if tcfg.microbatches > 1:
            def reshape(x):
                b = x.shape[0]
                assert b % tcfg.microbatches == 0, (b, tcfg.microbatches)
                return x.reshape((tcfg.microbatches, b // tcfg.microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(reshape, batch)

            def body(carry, micro):
                acc = carry
                grads, metrics = single(params, micro)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, metrics_all = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        else:
            grads, metrics = single(params, batch)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, params)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(new_params, new_opt,
                          jax.random.fold_in(state.rng, 0)), metrics

    return train_step
