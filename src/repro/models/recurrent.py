"""Recurrent blocks: RG-LRU (recurrentgemma/griffin) and SSD (mamba2).

TPU adaptation notes:
  * training-time RG-LRU uses ``jax.lax.associative_scan`` (log-depth,
    VPU-friendly) instead of a sequential loop;
  * training-time SSD uses the chunked matmul decomposition
    (``kernels.ssd_scan``) so the MXU does the work — the paper's
    "GEMM-ification of tensor ops" future-work item;
  * decode is a single recurrence step on cached state (constant memory —
    these are the archs that make the 500k-context cell feasible).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ops as kops
from repro.models.layers import Maker, Params, rmsnorm


def causal_conv1d(x: jax.Array, w: jax.Array,
                  state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: (B, S, C), w: (W, C).
    ``state``: (B, W-1, C) previous inputs (decode/prefill continuation).
    Returns (y, new_state)."""
    B, S, C = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)          # (B, S+W-1, C)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):
        y = y + ext[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = ext[:, -(W - 1):] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# RG-LRU (griffin recurrent block)
# --------------------------------------------------------------------------


def init_rglru(cfg, mk: Maker) -> Params:
    d = cfg.d_model
    r = cfg.rglru
    w = r.width or d
    return {
        "norm": mk((d,), "embed", init="zeros"),
        "w_x": mk((d, w), "fsdp ff"),
        "w_y": mk((d, w), "fsdp ff"),          # gate branch
        "conv": mk((r.conv_width, w), "- ff"),
        "w_a_gate": mk((w, w), "fsdp ff"),
        "w_i_gate": mk((w, w), "fsdp ff"),
        "a_param": mk((w,), "ff", init="normal", scale=0.5),
        "w_out": mk((w, d), "ff fsdp"),
    }


def _rglru_scan(u: jax.Array, ag: jax.Array, ig: jax.Array,
                a_param: jax.Array, c: float,
                h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """u/ag/ig: (B, S, W). Returns (h_seq, h_last)."""
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32)) * \
        jax.nn.sigmoid(ag.astype(jnp.float32))
    a = jnp.exp(log_a)                                  # (B, S, W)
    gated = jax.nn.sigmoid(ig.astype(jnp.float32)) * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * gated
    if h0 is not None:
        # fold the initial state in as a virtual step at t=-1
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def apply_rglru(p: Params, x: jax.Array, cfg,
                cache: Optional[Params] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    B, S, d = x.shape
    r = cfg.rglru
    h_in = rmsnorm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("bsd,dw->bsw", h_in, p["w_x"])
    u = shard(u, "batch", None, "ff")
    ygate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h_in, p["w_y"]))

    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = causal_conv1d(u, p["conv"], conv_state)
    ag = jnp.einsum("bsw,wv->bsv", u, p["w_a_gate"])
    ig = jnp.einsum("bsw,wv->bsv", u, p["w_i_gate"])
    h0 = cache["h"] if cache is not None else None
    h, h_last = _rglru_scan(u, ag, ig, p["a_param"], r.c, h0)
    out = jnp.einsum("bsw,wd->bsd", (h * ygate.astype(h.dtype)).astype(x.dtype),
                     p["w_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_last.astype(cache["h"].dtype), "conv": new_conv}
    return x + shard(out, "batch", None, None), new_cache


def rglru_cache_spec(cfg, batch: int, dtype) -> dict:
    r = cfg.rglru
    w = r.width or cfg.d_model
    return {"h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, r.conv_width - 1, w), dtype)}


# --------------------------------------------------------------------------
# SSD / mamba2 block
# --------------------------------------------------------------------------


def init_ssd(cfg, mk: Maker) -> Params:
    d = cfg.d_model
    s = cfg.ssm
    di = s.d_inner or 2 * d
    H = di // s.head_dim
    N = s.state_dim
    return {
        "norm": mk((d,), "embed", init="zeros"),
        "in_proj": mk((d, 2 * di + 2 * N + H), "fsdp ff"),
        "conv": mk((s.conv_width, di + 2 * N), "- ff"),
        "A_log": mk((H,), "-", init="zeros"),
        "D": mk((H,), "-", init="ones"),
        "dt_bias": mk((H,), "-", init="zeros"),
        "out_norm": mk((di,), "ff", init="zeros"),
        "out_proj": mk((di, d), "ff fsdp"),
    }


def _split_ssd(proj: jax.Array, di: int, N: int, H: int):
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * N]
    dt = proj[..., -H:]
    return z, xbc, dt


def apply_ssd(p: Params, x: jax.Array, cfg,
              cache: Optional[Params] = None, backend: str = "xla"
              ) -> Tuple[jax.Array, Optional[Params]]:
    B, S, d = x.shape
    s = cfg.ssm
    di = s.d_inner or 2 * d
    H = di // s.head_dim
    P, N = s.head_dim, s.state_dim

    h_in = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", h_in, p["in_proj"])
    z, xbc, dt = _split_ssd(proj, di, N, H)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = causal_conv1d(xbc, p["conv"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bmat = xbc[..., di:di + N]
    Cmat = xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h0 = cache["state"] if cache is not None else None
    if S == 1 and cache is not None:
        # single-token recurrence (decode)
        decay = jnp.exp(dt[:, 0] * A[None, :])                    # (B, H)
        dBx = (dt[:, 0, :, None, None] * xs[:, 0, :, :, None]
               * Bmat[:, 0, None, None, :])                       # (B,H,P,N)
        h_new = h0 * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h_new, Cmat[:, 0].astype(jnp.float32)) \
            + p["D"].astype(jnp.float32)[None, :, None] * xs[:, 0]
        y = y[:, None].reshape(B, S, di).astype(x.dtype)
        new_state = h_new
    else:
        y, new_state = _ssd_with_state(xs, dt, A, Bmat, Cmat, p["D"],
                                       h0, s.chunk, backend)
        y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state.astype(cache["state"].dtype),
                     "conv": new_conv}
    return x + shard(out, "batch", None, None), new_cache


def _ssd_with_state(xs, dt, A, Bmat, Cmat, D, h0, chunk, backend):
    """Batched chunked SSD that threads an initial/final state.
    xs: (B,S,H,P), dt: (B,S,H), Bmat/Cmat: (B,S,N)."""
    B_, S, H, P = xs.shape
    N = Bmat.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def per_batch(xb, dtb, Bb, Cb, h0b):
        xc = xb.reshape(nc, chunk, H, P).astype(jnp.float32)
        dtc = dtb.reshape(nc, chunk, H).astype(jnp.float32)
        Bc = Bb.reshape(nc, chunk, N).astype(jnp.float32)
        Cc = Cb.reshape(nc, chunk, N).astype(jnp.float32)

        def step(h, inp):
            xk, dtk, Bk, Ck = inp
            sl = jnp.cumsum(dtk * A[None, :], axis=0)             # (L, H)
            M = jnp.where(causal[:, :, None],
                          jnp.exp(sl[:, None] - sl[None, :]), 0.0)
            CB = Ck @ Bk.T
            y_intra = jnp.einsum("tuh,tu,uhp->thp", M, CB,
                                 dtk[:, :, None] * xk)
            y_inter = jnp.exp(sl)[:, :, None] * jnp.einsum("tn,hpn->thp", Ck, h)
            w = jnp.exp(sl[-1][None, :] - sl) * dtk
            h_new = (jnp.exp(sl[-1])[:, None, None] * h
                     + jnp.einsum("uhp,un->hpn", w[:, :, None] * xk, Bk))
            return h_new, y_intra + y_inter

        hh = (jnp.zeros((H, P, N), jnp.float32) if h0b is None
              else h0b.astype(jnp.float32))
        h_fin, ys = jax.lax.scan(step, hh, (xc, dtc, Bc, Cc))
        return ys.reshape(S, H, P), h_fin

    if h0 is None:
        f = lambda xb, dtb, Bb, Cb: per_batch(xb, dtb, Bb, Cb, None)
        y, h_fin = jax.vmap(f)(xs, dt, Bmat, Cmat)
    else:
        y, h_fin = jax.vmap(per_batch)(xs, dt, Bmat, Cmat, h0)
    y = y + D[None, None, :, None] * xs.astype(jnp.float32)
    return y.astype(xs.dtype), h_fin


def ssd_cache_spec(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    di = s.d_inner or 2 * cfg.d_model
    H = di // s.head_dim
    return {
        "state": jax.ShapeDtypeStruct((batch, H, s.head_dim, s.state_dim),
                                      jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1,
                                      di + 2 * s.state_dim), dtype),
    }
