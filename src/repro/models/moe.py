"""Mixture-of-Experts block (deepseek-v2 / kimi-k2 style).

Capacity-based dense dispatch (Mesh-TensorFlow style): routing becomes
one-hot einsum contractions that GSPMD partitions into all-to-alls when
the expert dimension is sharded over the ``model`` axis (expert
parallelism).  Deterministic, differentiable, and analyzable in the
dry-run roofline — at the price of the capacity-overflow approximation
(dropped tokens fall through the residual), which is the standard
trade-off in TPU MoE stacks.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, shard
from repro.models.layers import Maker, Params, rmsnorm

# MoE execution strategy: "auto" picks the shard_map expert-parallel path
# whenever a mesh with a dividing "model" axis is active (the optimized
# path found in §Perf); "gspmd" forces the baseline. Set via
# ``set_moe_impl`` (the dry-run exposes --moe-impl).
_MOE_IMPL = "auto"


def set_moe_impl(impl: str) -> None:
    global _MOE_IMPL
    assert impl in ("auto", "gspmd", "shardmap"), impl
    _MOE_IMPL = impl


def init_moe(cfg, mk: Maker) -> Params:
    d = cfg.d_model
    m = cfg.moe
    gated = cfg.mlp.startswith("gated")
    p = {
        "norm": mk((d,), "embed", init="zeros"),
        # router weights replicated over "model": every expert-parallel
        # rank computes identical routing locally, costing zero collective
        # traffic (§Perf iteration A2)
        "router": mk((d, m.num_experts), "fsdp -"),
        "w_up": mk((m.num_experts, d, m.expert_ff), "experts fsdp ff"),
        "w_down": mk((m.num_experts, m.expert_ff, d), "experts ff fsdp"),
    }
    if gated:
        p["w_gate"] = mk((m.num_experts, d, m.expert_ff), "experts fsdp ff")
    if m.num_shared:
        sf = m.expert_ff * m.num_shared
        p["shared_up"] = mk((d, sf), "fsdp ff")
        p["shared_down"] = mk((sf, d), "ff fsdp")
        if gated:
            p["shared_gate"] = mk((d, sf), "fsdp ff")
    return p


def _local_expert_ffn(h, top_idx, gates, w_gate, w_up, w_down, *,
                      n_experts: int, top_k: int, capacity: int,
                      act, gated: bool, axis: str = "model"):
    """Per-device body of the expert-parallel shard_map (§Perf A1).

    ``h`` (T_loc, d) is this data-shard's tokens, replicated across the
    ``model`` axis; w_* are the LOCAL expert slices (E_loc, d, f).  Each
    model rank serves the tokens routed to its own experts — tokens need
    no exchange at all (they are already resident) and the only
    collective is one psum of the combined output.
    """
    T, d = h.shape
    E_loc = w_up.shape[0]
    rank = jax.lax.axis_index(axis)
    lo = rank * E_loc

    e_flat = top_idx.reshape(-1)                  # (T*K,) global expert ids
    g_flat = gates.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), top_k)
    e_local = e_flat - lo
    mine = (e_local >= 0) & (e_local < E_loc)
    e_key = jnp.where(mine, e_local, E_loc)       # foreign tokens sort last
    order = jnp.argsort(e_key)
    e_sort = e_key[order]
    t_sort = t_flat[order]
    g_sort = g_flat[order]
    counts = jnp.zeros((E_loc + 1,), jnp.int32).at[e_key].add(1)
    seg_start = jnp.cumsum(counts) - counts
    rank_in_e = jnp.arange(T * top_k) - seg_start[e_sort]
    keep = (e_sort < E_loc) & (rank_in_e < capacity)
    slot = jnp.where(keep, e_sort * capacity + rank_in_e, E_loc * capacity)

    buf = jnp.zeros((E_loc * capacity + 1, d), h.dtype)
    buf = buf.at[slot].set(h[t_sort], mode="drop")
    xin = buf[:E_loc * capacity].reshape(E_loc, capacity, d)
    if gated:
        hid = act(jnp.einsum("ecd,edf->ecf", xin, w_gate)) * \
            jnp.einsum("ecd,edf->ecf", xin, w_up)
    else:
        hid = act(jnp.einsum("ecd,edf->ecf", xin, w_up))
    xout = jnp.einsum("ecf,efd->ecd", hid, w_down)
    xout = jnp.concatenate([xout.reshape(E_loc * capacity, d),
                            jnp.zeros((1, d), xout.dtype)], axis=0)
    contrib = xout[slot] * (g_sort * keep.astype(jnp.float32)
                            )[:, None].astype(xout.dtype)
    y = jnp.zeros((T, d), xout.dtype).at[t_sort].add(contrib)
    return jax.lax.psum(y, axis)


def _shardmap_moe(p, h, cfg, act, gated, top_idx, gates, mesh):
    m = cfg.moe
    T = h.shape[0]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    T_loc = T // n_batch
    E_loc = m.num_experts // mesh.shape["model"]
    capacity = max(1, min(
        int(math.ceil(T_loc * m.top_k * m.capacity_factor / m.num_experts)),
        T_loc))
    body = lambda hh, ti, gg, wg, wu, wd: _local_expert_ffn(
        hh, ti, gg, wg, wu, wd, n_experts=m.num_experts, top_k=m.top_k,
        capacity=capacity, act=act, gated=gated)
    tok_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    w_spec = P("model", None, None)
    wg = p["w_gate"] if gated else p["w_up"]
    return shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, w_spec, w_spec, w_spec),
        out_specs=tok_spec, check_rep=False,
    )(h, top_idx, gates, wg, p["w_up"], p["w_down"])


def _can_shardmap(cfg, T: int) -> bool:
    mesh = current_mesh()
    if _MOE_IMPL == "gspmd" or mesh is None or "model" not in mesh.shape:
        return False
    m = cfg.moe
    if m.num_experts % mesh.shape["model"]:
        return False
    n_batch = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n_batch *= mesh.shape[a]
    if T % n_batch:
        return False
    # Measured (§Perf B2): below ~1 routed token per expert the dispatch
    # overhead of the expert-parallel path exceeds its win — decode-sized
    # token counts stay on the dense GSPMD path under "auto".
    if _MOE_IMPL == "auto" and T * m.top_k < m.num_experts:
        return False
    return True


def apply_moe(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Sort-based dispatch: tokens are routed through an (E*C, d) expert
    buffer via scatter/gather with computed slots.  Memory is
    O(T*d + E*C*d) — never the O(T*E*C) of one-hot dispatch tensors,
    which is what keeps the 1M-token train_4k cells of deepseek/kimi
    compilable.  Capacity overflow drops through the residual (standard
    TPU-MoE approximation).

    Under an active mesh the dispatch runs as an explicit expert-parallel
    ``shard_map`` (§Perf iteration A1): GSPMD cannot partition the
    scatter/gather with computed indices and falls back to replicating
    token buffers (baseline: ~118 TB/device of all-reduce on
    kimi-k2 train_4k); the shard_map form needs a single output psum.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    capacity = max(1, int(math.ceil(T * K * m.capacity_factor / E)))
    capacity = min(capacity, T)
    gated = cfg.mlp.startswith("gated")
    act = jax.nn.silu if cfg.mlp == "gated_silu" else jax.nn.gelu

    h = rmsnorm(x, p["norm"], cfg.norm_eps).reshape(T, d)
    logits = jnp.einsum("td,de->te", h, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)

    top_vals, top_idx = jax.lax.top_k(probs, K)                   # (T, K)
    gates = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    sel_frac = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0) / (T * K)
    frac_probs = probs.mean(0)
    aux = E * jnp.sum(sel_frac * frac_probs) * m.router_aux_weight

    if _MOE_IMPL in ("auto", "shardmap") and _can_shardmap(cfg, T):
        y = _shardmap_moe(p, h, cfg, act, gated, top_idx,
                          gates.astype(jnp.float32), current_mesh())
        if m.num_shared:
            if gated:
                sh = act(h @ p["shared_gate"]) * (h @ p["shared_up"])
            else:
                sh = act(h @ p["shared_up"])
            y = y + sh @ p["shared_down"]
        y = y.reshape(B, S, d)
        return x + shard(y, "batch", None, None), aux

    # ---- sort-based slot assignment ----
    e_flat = top_idx.reshape(-1)                                  # (T*K,)
    t_flat = jnp.repeat(jnp.arange(T), K)                         # (T*K,)
    g_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat)                                   # stable
    e_sort = e_flat[order]
    t_sort = t_flat[order]
    g_sort = g_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    seg_start = jnp.cumsum(counts) - counts                       # (E,)
    rank = jnp.arange(T * K) - seg_start[e_sort]
    keep = rank < capacity
    slot = jnp.where(keep, e_sort * capacity + rank, E * capacity)

    # scatter tokens into the expert buffer; dropped tokens carry slot ==
    # E*capacity, one past the end, and fall out via mode="drop".  (An
    # earlier version kept a trash row inside the buffer and gathered
    # back through a (E*capacity+1)-row concatenate; GSPMD mispartitions
    # that odd-sized gather under a model-sharded mesh — the computed-
    # index gather read wrong rows and silently zeroed routed expert
    # contributions, the "gspmd vs shardmap divergence" tracked since
    # PR 1.  Keeping every array exactly E*capacity rows and masking
    # with ``keep`` is bit-exact under partitioning.)
    buf = jnp.zeros((E * capacity, d), h.dtype)
    buf = buf.at[slot].set(h[t_sort], mode="drop")
    xin = shard(buf.reshape(E, capacity, d), "experts", None, None)

    if gated:
        hid = act(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    else:
        hid = act(jnp.einsum("ecd,edf->ecf", xin, p["w_up"]))
    hid = shard(hid, "experts", None, "ff")
    xout = jnp.einsum("ecf,efd->ecd", hid, p["w_down"])           # (E, C, d)
    flat = xout.reshape(E * capacity, d)

    # gather back (clamped index + explicit keep mask, see above) and
    # combine with gates
    contrib = jnp.where(keep[:, None], flat[jnp.where(keep, slot, 0)], 0.0)
    contrib = contrib * (g_sort * keep.astype(jnp.float32)
                         )[:, None].astype(xout.dtype)
    y = jnp.zeros((T, d), xout.dtype).at[t_sort].add(contrib)

    if m.num_shared:
        if gated:
            sh = act(h @ p["shared_gate"]) * (h @ p["shared_up"])
        else:
            sh = act(h @ p["shared_up"])
        y = y + sh @ p["shared_down"]

    y = y.reshape(B, S, d)
    return x + shard(y, "batch", None, None), aux
