"""Architecture-generic transformer stack with pattern-group layer scan.

All ten assigned architectures compile through this module.  Layers are
grouped by the config's cyclic ``pattern``; the repeated groups run under
``jax.lax.scan`` (stacked params — O(1) HLO in depth, essential both for
compile time on huge configs and for remat ergonomics), with any
non-conforming prefix (e.g. deepseek's leading dense layer) or suffix
(recurrentgemma's trailing recurrent pair) unrolled around the scan.

Per-layer local-attention windows (gemma3's 5 local : 1 global) are
threaded through the scan as data, so mixed local/global stacks still
compile as one homogeneous scan without HLO branch duplication.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.layers import Maker, Params

GLOBAL_WINDOW = 1 << 30          # "no window" sentinel carried through scans


# --------------------------------------------------------------------------
# layer kinds
# --------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> List[str]:
    kinds = cfg.layer_kinds()
    if cfg.moe is not None:
        for i in range(min(cfg.moe.first_dense_layers, len(kinds))):
            kinds[i] = "dense_moe"
    if cfg.encoder is not None:
        kinds = ["xdec"] * cfg.num_layers
    return kinds


def _init_layer(cfg: ModelConfig, kind: str, mk: Maker) -> Params:
    if kind == "attn":
        return {"attn": L.init_attention(cfg, mk), "mlp": L.init_mlp(cfg, mk)}
    if kind == "dense_moe":
        att = (mla_mod.init_mla(cfg, mk) if cfg.mla is not None
               else L.init_attention(cfg, mk))
        return {"attn": att,
                "mlp": L.init_mlp(cfg, mk, ff=cfg.moe.dense_ff or cfg.d_ff)}
    if kind == "moe":
        att = (mla_mod.init_mla(cfg, mk) if cfg.mla is not None
               else L.init_attention(cfg, mk))
        return {"attn": att, "moe": moe_mod.init_moe(cfg, mk)}
    if kind == "rglru":
        return {"rec": rec_mod.init_rglru(cfg, mk),
                "mlp": L.init_mlp(cfg, mk)}
    if kind == "ssd":
        return {"ssd": rec_mod.init_ssd(cfg, mk)}
    if kind == "xdec":
        return {"attn": L.init_attention(cfg, mk),
                "cross": L.init_cross_attention(cfg, mk),
                "mlp": L.init_mlp(cfg, mk)}
    raise ValueError(f"unknown layer kind {kind!r}")


def _apply_layer(kind: str, p: Params, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, window, cache, kv_len,
                 backend: str, enc_kv=None):
    """Returns (x, new_cache, aux_loss)."""
    cache = cache if cache else None
    aux = jnp.float32(0.0)
    if kind in ("attn", "dense_moe", "moe", "xdec"):
        attn_cache = cache.get("attn") if cache else None
        if cfg.mla is not None and kind in ("moe", "dense_moe"):
            x, nc = mla_mod.apply_mla(p["attn"], x, cfg, positions,
                                      cache=attn_cache, kv_len=kv_len)
        else:
            x, nc = L.apply_attention(p["attn"], x, cfg, positions,
                                      window=window, cache=attn_cache,
                                      kv_len=kv_len, backend=backend)
        new_cache = {"attn": nc} if nc is not None else None
        if kind == "xdec":
            if enc_kv is not None:           # encoder ran this call (train/prefill)
                ekv = enc_kv(p["cross"])     # callable: builds k/v from enc
            else:                            # decode: use cached cross-KV
                ekv = (cache["xk"], cache["xv"])
            x = L.apply_cross_attention(p["cross"], x, cfg, ekv)
            if new_cache is not None:
                new_cache["xk"], new_cache["xv"] = ekv
        if kind == "moe":
            x, aux = moe_mod.apply_moe(p["moe"], x, cfg)
        else:
            x = L.apply_mlp(p["mlp"], x, cfg)
        return x, new_cache, aux
    if kind == "rglru":
        x, nc = rec_mod.apply_rglru(p["rec"], x, cfg,
                                    cache.get("rec") if cache else None)
        x = L.apply_mlp(p["mlp"], x, cfg)
        return x, ({"rec": nc} if nc is not None else None), aux
    if kind == "ssd":
        x, nc = rec_mod.apply_ssd(p["ssd"], x, cfg,
                                  cache.get("ssd") if cache else None,
                                  backend=backend)
        return x, ({"ssd": nc} if nc is not None else None), aux
    raise ValueError(kind)


def _layer_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype) -> Dict[str, Any]:
    hd, KV = cfg.resolved_head_dim, cfg.num_kv_heads
    if kind in ("attn", "xdec") or (kind in ("moe", "dense_moe")
                                    and cfg.mla is None):
        spec = {"attn": {
            "k": jax.ShapeDtypeStruct((batch, max_len, KV, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, max_len, KV, hd), dtype)}}
        if kind == "xdec":
            e = cfg.encoder
            H = cfg.num_heads
            spec["xk"] = jax.ShapeDtypeStruct((batch, e.context, H, hd), dtype)
            spec["xv"] = jax.ShapeDtypeStruct((batch, e.context, H, hd), dtype)
        return spec
    if kind in ("moe", "dense_moe"):         # MLA compressed cache
        a = cfg.mla
        return {"attn": {
            "ckv": jax.ShapeDtypeStruct((batch, max_len, a.kv_lora), dtype),
            "kr": jax.ShapeDtypeStruct((batch, max_len, a.qk_rope_dim), dtype)}}
    if kind == "rglru":
        return {"rec": rec_mod.rglru_cache_spec(cfg, batch, dtype)}
    if kind == "ssd":
        return {"ssd": rec_mod.ssd_cache_spec(cfg, batch, dtype)}
    raise ValueError(kind)


_CACHE_AXES = {"k": "batch kv_seq kv_heads -", "v": "batch kv_seq kv_heads -",
               "ckv": "batch kv_seq -", "kr": "batch kv_seq -",
               "xk": "batch - heads -", "xv": "batch - heads -",
               "h": "batch ff", "conv": "batch - ff",
               "state": "batch heads - -"}


def _cache_axes(spec) -> Any:
    def walk(d):
        return {k: (walk(v) if isinstance(v, dict) else _CACHE_AXES[k])
                for k, v in d.items()}
    return walk(spec)


# --------------------------------------------------------------------------
# layer grouping: prefix / scanned pattern groups / suffix
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackPlan:
    prefix: Tuple[int, ...]          # layer indices unrolled before the scan
    pattern: Tuple[str, ...]         # kinds of one scanned group
    groups: int                      # number of scanned groups
    suffix: Tuple[int, ...]          # layer indices unrolled after


def stack_plan(cfg: ModelConfig) -> StackPlan:
    kinds = layer_kinds(cfg)
    n = len(kinds)
    # prefix = leading layers not matching the cyclic pattern of the rest
    start = 0
    if cfg.moe is not None:
        start = min(cfg.moe.first_dense_layers, n)
    period_kinds = tuple(kinds[start:start + _period(cfg)])
    period = len(period_kinds)
    groups = (n - start) // period if period else 0
    used = start + groups * period
    return StackPlan(prefix=tuple(range(start)), pattern=period_kinds,
                     groups=groups, suffix=tuple(range(used, n)))


def _period(cfg: ModelConfig) -> int:
    if cfg.encoder is not None:
        return 1
    return len(cfg.pattern)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, mode: str = "shape",
                key: Optional[jax.Array] = None, dtype=jnp.float32,
                max_seq: int = 0) -> Params:
    """mode: "init" (arrays) | "shape" (ShapeDtypeStructs) | "axes"."""
    plan = stack_plan(cfg)
    kinds = layer_kinds(cfg)
    windows = cfg.layer_windows()
    if key is None and mode == "init":
        key = jax.random.PRNGKey(0)

    def mk_for(k):
        return Maker(mode, k, dtype)

    def split(k):
        if mode != "init":
            return None, None
        return jax.random.split(k)

    p: Params = {}
    key, sub = split(key) if mode == "init" else (None, None)
    p["embed"] = mk_for(sub)((cfg.padded_vocab, cfg.d_model), "vocab fsdp")
    if not cfg.rope_theta:
        pos_len = max(max_seq, 2048)
        key, sub = split(key) if mode == "init" else (None, None)
        p["pos_embed"] = mk_for(sub)((pos_len, cfg.d_model), "- fsdp")
    # prefix / suffix layers, unrolled
    for name, idxs in (("prefix", plan.prefix), ("suffix", plan.suffix)):
        if idxs:
            sub_p = {}
            for i in idxs:
                key, sub = split(key) if mode == "init" else (None, None)
                sub_p[str(i)] = _init_layer(cfg, kinds[i], mk_for(sub))
            p[name] = sub_p
    # scanned groups: stacked along a leading axis
    if plan.groups:
        scan_p = {}
        for pos, kind in enumerate(plan.pattern):
            if mode == "axes":
                one = _init_layer(cfg, kind, Maker("axes"))
                scan_p[f"pos{pos}"] = jax.tree.map(
                    lambda s: ("- " + s) if s else "-", one)
            elif mode == "shape":
                one = _init_layer(cfg, kind, Maker("shape", dtype=dtype))
                scan_p[f"pos{pos}"] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (plan.groups,) + s.shape, s.dtype), one)
            else:
                key, sub = split(key)
                keys = jax.random.split(sub, plan.groups)
                scan_p[f"pos{pos}"] = jax.vmap(
                    lambda kk: _init_layer(cfg, kind, Maker("init", kk,
                                                            dtype)))(keys)
        p["scan"] = scan_p
    key, sub = split(key) if mode == "init" else (None, None)
    p["final_norm"] = mk_for(sub)((cfg.d_model,), "embed", init="zeros")
    if not cfg.tie_embeddings:
        key, sub = split(key) if mode == "init" else (None, None)
        p["lm_head"] = mk_for(sub)((cfg.d_model, cfg.padded_vocab), "fsdp vocab")
    if cfg.encoder is not None:
        p["encoder"] = _init_encoder(cfg, mode, key, dtype)
    return p


def _init_encoder(cfg: ModelConfig, mode, key, dtype) -> Params:
    e = cfg.encoder
    ed = e.d_model or cfg.d_model
    enc_cfg = dataclasses.replace(
        cfg, d_model=ed, num_layers=e.num_layers, pattern=("attn",),
        rope_theta=0.0, moe=None, mla=None, encoder=None)
    p: Params = {}
    if mode == "init":
        key, k1, k2, k3 = jax.random.split(key, 4)
    else:
        k1 = k2 = k3 = None
    p["pos_embed"] = Maker(mode, k1, dtype)((e.context, ed), "- fsdp")
    if mode == "axes":
        one = _init_layer(enc_cfg, "attn", Maker("axes"))
        p["scan"] = jax.tree.map(lambda s: ("- " + s) if s else "-", one)
    elif mode == "shape":
        one = _init_layer(enc_cfg, "attn", Maker("shape", dtype=dtype))
        p["scan"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((e.num_layers,) + s.shape,
                                           s.dtype), one)
    else:
        keys = jax.random.split(k2, e.num_layers)
        p["scan"] = jax.vmap(
            lambda kk: _init_layer(enc_cfg, "attn", Maker("init", kk,
                                                          dtype)))(keys)
    p["final_norm"] = Maker(mode, k3, dtype)((ed,), "embed", init="zeros")
    return p


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _window_arrays(cfg: ModelConfig, plan: StackPlan) -> Tuple[jax.Array, ...]:
    windows = cfg.layer_windows()
    out = []
    start = len(plan.prefix)
    period = len(plan.pattern)
    for pos in range(period):
        vals = [windows[start + g * period + pos] for g in range(plan.groups)]
        out.append(jnp.asarray([GLOBAL_WINDOW if w is None else w
                                for w in vals], jnp.int32))
    return tuple(out)


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    e = cfg.encoder
    ed = e.d_model or cfg.d_model
    enc_cfg = dataclasses.replace(
        cfg, d_model=ed, num_layers=e.num_layers, pattern=("attn",),
        rope_theta=0.0, moe=None, mla=None, encoder=None)
    x = frames + params["encoder"]["pos_embed"][None, :frames.shape[1]]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                                 frames.shape[:2])

    def body(x, p_slice):
        h, _ = L.apply_attention(p_slice["attn"], x, enc_cfg, positions,
                                 window=None, causal=False)
        h = L.apply_mlp(p_slice["mlp"], h, enc_cfg)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["scan"])
    return L.rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            extra_embeds: Optional[jax.Array] = None,
            cache: Optional[Params] = None,
            backend: str = "xla",
            remat: str = "none") -> Tuple[jax.Array, Optional[Params],
                                          jax.Array]:
    """tokens: (B, S) -> (logits (B, S, V), new_cache, aux_loss).

    cache=None: training forward.  cache given: prefill (S>1, fresh cache)
    or decode (S==1).  ``extra_embeds``: patch embeddings (pixtral) or
    frame embeddings (whisper encoder input).
    """
    plan = stack_plan(cfg)
    kinds = layer_kinds(cfg)
    B, S = tokens.shape
    kv_len = cache["len"] if cache is not None else None

    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    if cfg.frontend == "image_patches" and extra_embeds is not None:
        pl_ = min(extra_embeds.shape[1], S)
        x = jax.lax.dynamic_update_slice(
            x, extra_embeds[:, :pl_].astype(x.dtype), (0, 0, 0))
    start = kv_len if kv_len is not None else 0
    positions = start + jnp.arange(S)[None, :] + jnp.zeros((B, 1), jnp.int32)
    if not cfg.rope_theta:
        pos_tab = params["pos_embed"]
        x = x + jnp.take(pos_tab, jnp.minimum(positions, pos_tab.shape[0] - 1),
                         axis=0).astype(x.dtype)
    x = shard(x, "batch", None, None)

    enc_kv_fn = None
    if cfg.encoder is not None and extra_embeds is not None:
        enc_out = encode(params, cfg, extra_embeds)
        enc_kv_fn = lambda pc: L.cross_kv(pc, cfg, enc_out)

    aux_total = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {} if cache is not None else None

    # ---- prefix layers (unrolled) ----
    for i in plan.prefix:
        c = cache["prefix"][str(i)] if cache is not None else None
        x, nc, aux = _apply_layer(kinds[i], params["prefix"][str(i)], x, cfg,
                                  positions, None, c, kv_len, backend,
                                  enc_kv_fn)
        aux_total += aux
        if cache is not None:
            new_cache.setdefault("prefix", {})[str(i)] = nc

    # ---- scanned pattern groups ----
    if plan.groups:
        windows = _window_arrays(cfg, plan)
        scan_params = tuple(params["scan"][f"pos{i}"]
                            for i in range(len(plan.pattern)))
        scan_cache = (tuple(cache["scan"][f"pos{i}"]
                            for i in range(len(plan.pattern)))
                      if cache is not None else
                      tuple({} for _ in plan.pattern))

        def body(x, xs):
            p_sl, c_sl, w_sl = xs
            ncs = []
            aux_g = jnp.float32(0.0)
            for i, kind in enumerate(plan.pattern):
                x, nc, aux = _apply_layer(kind, p_sl[i], x, cfg, positions,
                                          w_sl[i], c_sl[i], kv_len, backend,
                                          enc_kv_fn)
                ncs.append(nc if nc is not None else {})
                aux_g = aux_g + aux
            return x, (tuple(ncs), aux_g)

        if remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if remat == "dots" else None)
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=False)
        x, (scan_new_cache, auxs) = jax.lax.scan(
            body, x, (scan_params, scan_cache, windows))
        aux_total += jnp.sum(auxs)
        if cache is not None:
            new_cache["scan"] = {f"pos{i}": scan_new_cache[i]
                                 for i in range(len(plan.pattern))}

    # ---- suffix layers (unrolled) ----
    for i in plan.suffix:
        c = cache["suffix"][str(i)] if cache is not None else None
        x, nc, aux = _apply_layer(kinds[i], params["suffix"][str(i)], x, cfg,
                                  positions, None, c, kv_len, backend,
                                  enc_kv_fn)
        aux_total += aux
        if cache is not None:
            new_cache.setdefault("suffix", {})[str(i)] = nc

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = shard(logits, "batch", None, "vocab")
    if cache is not None:
        new_cache["len"] = (kv_len + S).astype(jnp.int32)
        if cfg.encoder is not None:
            new_cache["enc_done"] = jnp.int32(1)
    return logits, new_cache, aux_total


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32, mode: str = "shape") -> Params:
    """Cache pytree as ShapeDtypeStructs ("shape"), zeros ("init"), or
    logical-axes strings ("axes")."""
    plan = stack_plan(cfg)
    kinds = layer_kinds(cfg)
    spec: Dict[str, Any] = {}
    for name, idxs in (("prefix", plan.prefix), ("suffix", plan.suffix)):
        if idxs:
            spec[name] = {str(i): _layer_cache_spec(cfg, kinds[i], batch,
                                                    max_len, dtype)
                          for i in idxs}
    if plan.groups:
        sc = {}
        for pos, kind in enumerate(plan.pattern):
            one = _layer_cache_spec(cfg, kind, batch, max_len, dtype)
            sc[f"pos{pos}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((plan.groups,) + s.shape,
                                               s.dtype), one)
        spec["scan"] = sc
    spec["len"] = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.encoder is not None:
        spec["enc_done"] = jax.ShapeDtypeStruct((), jnp.int32)

    if mode == "shape":
        return spec
    if mode == "init":
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    if mode == "axes":
        def to_axes(path_leaf):
            return path_leaf
        def walk(d, under_scan=False):
            out = {}
            for k, v in d.items():
                if k == "len" or k == "enc_done":
                    out[k] = ""
                elif isinstance(v, dict):
                    out[k] = walk(v, under_scan or k == "scan")
                else:
                    ax = _CACHE_AXES[k]
                    out[k] = ("- " + ax) if under_scan else ax
            return out
        return walk(spec)
    raise ValueError(mode)
