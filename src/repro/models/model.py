"""Model facade: build, init, apply, cache, and input specs per arch."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models import transformer as T

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution configuration orthogonal to the architecture."""
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    backend: str = "xla"               # xla | pallas | pallas_hw
    remat: str = "none"                # none | full | dots
    max_seq: int = 4096                # position-table / cache upper bound
    cache_dtype: str = "float32"


class Model:
    """Thin, stateless wrapper tying a ModelConfig to the generic stack."""

    def __init__(self, cfg: ModelConfig, run: RunConfig = RunConfig()):
        self.cfg = cfg
        self.run = run
        self.pdtype = DTYPES[run.param_dtype]
        self.cdtype = DTYPES[run.cache_dtype]

    # ---- params ------------------------------------------------------------

    def init(self, key: jax.Array):
        return T.init_params(self.cfg, mode="init", key=key,
                             dtype=self.pdtype, max_seq=self.run.max_seq)

    def param_shapes(self):
        return T.init_params(self.cfg, mode="shape", dtype=self.pdtype,
                             max_seq=self.run.max_seq)

    def param_axes(self):
        return T.init_params(self.cfg, mode="axes", max_seq=self.run.max_seq)

    def param_count(self) -> int:
        shapes = self.param_shapes()
        return sum(int(jnp.prod(jnp.asarray(s.shape)))
                   for s in jax.tree.leaves(shapes))

    # ---- caches ------------------------------------------------------------

    def cache_shapes(self, batch: int, max_len: int):
        return T.cache_spec(self.cfg, batch, max_len, self.cdtype, "shape")

    def cache_init(self, batch: int, max_len: int):
        return T.cache_spec(self.cfg, batch, max_len, self.cdtype, "init")

    def cache_axes(self, batch: int, max_len: int):
        return T.cache_spec(self.cfg, batch, max_len, self.cdtype, "axes")

    # ---- compute -----------------------------------------------------------

    def apply(self, params, tokens, *, extra_embeds=None, cache=None):
        return T.forward(params, self.cfg, tokens,
                         extra_embeds=extra_embeds, cache=cache,
                         backend=self.run.backend, remat=self.run.remat)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: {"tokens", "labels", "mask"?, "extra_embeds"?}."""
        logits, _, aux = self.apply(params, batch["tokens"],
                                    extra_embeds=batch.get("extra_embeds"))
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logits = mask_padded_vocab(logits, self.cfg.vocab_size)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(nll)
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux,
                       "tokens": denom}


def mask_padded_vocab(logits: jax.Array, vocab_size: int) -> jax.Array:
    """-inf out padded logit columns so softmax normalisation is exact."""
    if logits.shape[-1] == vocab_size:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    return jnp.where(iota < vocab_size, logits, -1e30)


def build(arch: str, run: RunConfig = RunConfig()) -> Model:
    return Model(get_config(arch), run)


# --------------------------------------------------------------------------
# assigned input shapes (the 4 shape cells)
# --------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if it doesn't."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k-context decode is "
                       "skipped per assignment (sub-quadratic archs only)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: str,
                dtype=jnp.float32) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.
    No device allocation — feeds ``jit(...).lower()`` in the dry-run."""
    info = SHAPES[shape]
    B, S = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    specs: Dict[str, Any] = {}
    tok_len = S if kind != "decode" else 1
    specs["tokens"] = jax.ShapeDtypeStruct((B, tok_len), jnp.int32)
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["mask"] = jax.ShapeDtypeStruct((B, S), dtype)
    if cfg.frontend == "image_patches" and kind != "decode":
        specs["extra_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.d_model), dtype)
    if cfg.frontend == "audio_frames" and kind != "decode":
        ed = cfg.encoder.d_model or cfg.d_model
        specs["extra_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.context, ed), dtype)
    return specs
