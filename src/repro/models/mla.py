"""Multi-head Latent Attention (deepseek-v2).

The KV cache stores only the compressed latent ``c_kv`` (kv_lora wide)
plus the shared rope key — MLA's memory contribution.  This is the
*faithful* (non-absorbed) formulation: per-head K/V are reconstructed
from the latent at attention time.  The absorbed-matmul variant (folding
W_uk into the query projection) is a recorded beyond-paper optimisation
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import (Maker, Params, attention_core, rmsnorm,
                                 rope)

# §Perf B1 knob: absorbed (latent-MQA) attention for cached paths.
# True is the optimised default; False forces the baseline
# reconstruct-then-attend form (kept for A/B roofline measurement).
_ABSORBED = True


def set_mla_absorbed(on: bool) -> None:
    global _ABSORBED
    _ABSORBED = bool(on)


def init_mla(cfg, mk: Maker) -> Params:
    d = cfg.d_model
    a = cfg.mla
    H = cfg.num_heads
    qd = a.qk_nope_dim + a.qk_rope_dim
    return {
        "norm": mk((d,), "embed", init="zeros"),
        "wq": mk((d, H * qd), "fsdp heads"),
        "w_dkv": mk((d, a.kv_lora + a.qk_rope_dim), "fsdp embed"),
        "kv_norm": mk((a.kv_lora,), "embed", init="zeros"),
        "w_ukv": mk((a.kv_lora, H * (a.qk_nope_dim + a.v_dim)), "fsdp heads"),
        "wo": mk((H * a.v_dim, d), "heads fsdp"),
    }


def apply_mla(p: Params, x: jax.Array, cfg, positions: jax.Array,
              cache: Optional[Params] = None,
              kv_len: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Optional[Params]]:
    B, S, d = x.shape
    a = cfg.mla
    H = cfg.num_heads
    nope, rdim, vdim = a.qk_nope_dim, a.qk_rope_dim, a.v_dim

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"]).reshape(B, S, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dq->bsq", h, p["w_dkv"])
    c_kv = rmsnorm(dkv[..., :a.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(dkv[..., None, a.kv_lora:], positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if cache is not None:
        start = kv_len if kv_len is not None else jnp.int32(0)
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, start, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["kr"], k_rope.astype(cache["kr"].dtype), (0, start, 0))
        new_cache = {"ckv": cc, "kr": cr}
        c_kv, k_rope = cc, cr
        kpos = jnp.arange(c_kv.shape[1])[None, :]
        valid = start + S
    else:
        kpos = positions
        valid = None

    Sk = c_kv.shape[1]
    if cache is not None and _ABSORBED:
        # ---- absorbed MLA (§Perf iteration B1): attend in latent space.
        # Folding W_uk into the query turns MLA into MQA with kv_heads=1,
        # head_dim = kv_lora + qk_rope, v_dim = kv_lora — no per-head K/V
        # is ever reconstructed from the 32k-deep cache (the baseline
        # materialised (B, Sk, H, nope+v) per layer per step).
        w_ukv = p["w_ukv"].reshape(a.kv_lora, H, nope + vdim)
        w_uk = w_ukv[..., :nope]                        # (lora, H, nope)
        w_uv = w_ukv[..., nope:]                        # (lora, H, v)
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk)
        qq = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,S,H,lora+rope)
        k_eff = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
        v_eff = c_kv[:, :, None, :]                     # (B,Sk,1,lora)
        qq = shard(qq, "batch", None, "heads", None)
        # logits are identical to the non-absorbed form by associativity,
        # so the softmax scale must stay 1/sqrt(nope+rope), NOT the latent
        # width
        ctx = attention_core(qq, k_eff, v_eff, positions,
                             jnp.broadcast_to(kpos, (B, Sk)),
                             None if valid is None else jnp.asarray(valid),
                             causal=True, window=None,
                             scale=1.0 / float((nope + rdim) ** 0.5))
        out = jnp.einsum("bshl,lhv->bshv", ctx, w_uv)
    else:
        # non-absorbed (training): reconstruct per-head K/V once — cheaper
        # in FLOPs when the whole sequence attends anyway.
        kv = jnp.einsum("bsl,lq->bsq", c_kv, p["w_ukv"]).reshape(
            B, Sk, H, nope + vdim)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, Sk, H, rdim))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = shard(qq, "batch", None, "heads", None)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
        out = attention_core(qq, k, v, positions,
                             jnp.broadcast_to(kpos, (B, Sk)),
                             None if valid is None else jnp.asarray(valid),
                             causal=True, window=None)
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].reshape(H, vdim, d))
    return x + shard(out, "batch", None, None), new_cache
