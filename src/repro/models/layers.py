"""Shared model primitives: params maker, norms, rope, attention, MLP.

Every ``init_*`` function takes a ``Maker``; the same code path produces
real arrays (mode="init"), ShapeDtypeStructs (mode="shape", used by the
dry-run so no memory is ever allocated), or logical-axes strings
(mode="axes", consumed by the sharding resolver).  One definition, three
interpretations — no drift between init, sharding and checkpoint layouts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

Params = Dict[str, Any]


@dataclasses.dataclass
class Maker:
    mode: str                       # "init" | "shape" | "axes"
    key: Optional[jax.Array] = None
    dtype: Any = jnp.float32

    def __call__(self, shape: Tuple[int, ...], axes: str,
                 init: str = "normal", scale: float = 0.02):
        if self.mode == "axes":
            return axes
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        assert self.key is not None
        self.key, sub = jax.random.split(self.key)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "normal":
            fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
            s = min(scale, (1.0 / fan_in) ** 0.5) if len(shape) > 1 else scale
            return (jax.random.normal(sub, shape) * s).astype(self.dtype)
        raise ValueError(init)


# --------------------------------------------------------------------------
# norms / rope / positions
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, windows, caches)
# --------------------------------------------------------------------------


def init_attention(cfg, mk: Maker) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    p = {
        "norm": mk((d,), "embed", init="zeros"),
        "wq": mk((d, H * hd), "fsdp heads"),
        "wk": mk((d, KV * hd), "fsdp kv_heads"),
        "wv": mk((d, KV * hd), "fsdp kv_heads"),
        "wo": mk((H * hd, d), "heads fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = mk((H * hd,), "heads", init="zeros")
        p["bk"] = mk((KV * hd,), "kv_heads", init="zeros")
        p["bv"] = mk((KV * hd,), "kv_heads", init="zeros")
    return p


def init_cross_attention(cfg, mk: Maker) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H = cfg.num_heads
    ed = cfg.encoder.d_model or d
    return {
        "norm": mk((d,), "embed", init="zeros"),
        "wq": mk((d, H * hd), "fsdp heads"),
        "wk": mk((ed, H * hd), "fsdp heads"),
        "wv": mk((ed, H * hd), "fsdp heads"),
        "wo": mk((H * hd, d), "heads fsdp"),
    }


def _mask(qpos: jax.Array, kpos: jax.Array, kv_len: Optional[jax.Array],
          causal: bool, window) -> jax.Array:
    """(..., Sq, Sk) boolean mask.  ``window`` may be a traced scalar
    (per-layer local window; big value = global)."""
    m = jnp.ones(qpos.shape[:-1] + (qpos.shape[-1], kpos.shape[-1]), bool)
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    if kv_len is not None:
        m &= k < (kv_len[..., None, None] if kv_len.ndim else kv_len)
    return m


def attention_math(q: jax.Array, k: jax.Array, v: jax.Array,
                   mask: jax.Array, backend: str = "xla",
                   scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); mask: (B, Sq, Sk) or
    broadcastable.  GQA via head grouping (no KV materialised repeat)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = float(scale) if scale is not None else 1.0 / (hd ** 0.5)
    qg = q.reshape(B, Sq, KV, rep, hd).astype(jnp.float32)
    logits = jnp.einsum("bqgrh,bkgh->bgrqk", qg * scale,
                        k.astype(jnp.float32))
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# threshold above which attention switches to the blockwise (flash-style)
# XLA path: never materialise an (Sq, Sk) logits tensor past this size.
_DIRECT_LIMIT = 1 << 21

# Tunable execution options for the blockwise path (§Perf iteration C):
#   probs_dtype — dtype of the softmax weights entering the PV matmul.
#     Statistics (m, l) always stay f32; bf16 probs halve the dominant
#     HBM term of long-sequence attention at <1e-2 output error.
#   block_q/block_k — VMEM-tile analogue of the stagecc tile sizes.
ATTN_OPTIONS = {"probs_dtype": jnp.float32, "block_q": 512, "block_k": 1024}


def set_attention_options(probs_dtype=None, block_q=None, block_k=None):
    if probs_dtype is not None:
        ATTN_OPTIONS["probs_dtype"] = (
            jnp.bfloat16 if str(probs_dtype) in ("bf16", "bfloat16")
            else jnp.float32)
    if block_q is not None:
        ATTN_OPTIONS["block_q"] = int(block_q)
    if block_k is not None:
        ATTN_OPTIONS["block_k"] = int(block_k)


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   qpos: jax.Array, kpos: jax.Array,
                   valid: Optional[jax.Array], causal: bool, window,
                   block_q: Optional[int] = None,
                   block_k: Optional[int] = None,
                   scale: Optional[float] = None) -> jax.Array:
    """Position-based attention that never builds a full (Sq, Sk) mask.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); qpos: (B, Sq); kpos: (B, Sk);
    ``valid``: scalar count of valid cache entries (decode) or None;
    ``window`` may be a traced scalar (per-layer local window).

    Small problems take the direct path; large ones run a blockwise
    online-softmax (the flash algorithm expressed in XLA: a lax.scan over
    KV blocks nested in a scan over Q blocks), keeping live memory
    O(block_q x block_k) per head — this is what makes the 32k/500k
    cells compile with sane footprints on the dry-run, and mirrors the
    pallas kernel used on real TPU.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = float(scale) if scale is not None else 1.0 / (hd ** 0.5)
    block_q = block_q or ATTN_OPTIONS["block_q"]
    block_k = block_k or ATTN_OPTIONS["block_k"]
    pdt = ATTN_OPTIONS["probs_dtype"]

    def mask_for(qp, kp):                       # (B, sq) x (B, sk) -> bool
        m = jnp.ones((B, qp.shape[1], kp.shape[1]), bool)
        kk = kp[:, None, :]
        qq = qp[:, :, None]
        if causal:
            m &= kk <= qq
        if window is not None:
            m &= kk > qq - window
        if valid is not None:
            m &= kk < valid
        return m

    if Sq * Sk <= _DIRECT_LIMIT or Sq % min(block_q, Sq) or \
            Sk % min(block_k, Sk):
        return attention_math(q, k, v, mask_for(qpos, kpos), scale=scale)

    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    qg = q.reshape(B, nq, bq, KV, rep, hd)
    qpos_b = qpos.reshape(B, nq, bq)
    kb = k.reshape(B, nk, bk, KV, hd)
    vb = v.reshape(B, nk, bk, KV, v.shape[-1])
    kpos_b = kpos.reshape(B, nk, bk)

    def q_step(_, xs):
        qblk, qp = xs                            # (B,bq,KV,rep,hd), (B,bq)
        qblk = qblk.astype(jnp.float32) * scale

        def kv_step(carry, kxs):
            m_run, l_run, acc = carry
            kblk, vblk, kp = kxs
            s = jnp.einsum("bqgrh,bkgh->bgrqk", qblk,
                           kblk.astype(jnp.float32))
            msk = mask_for(qp, kp)[:, None, None]
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p.astype(pdt), vblk.astype(pdt),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, rep, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, bq, v.shape[-1]), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kpos_b.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out                        # (B,KV,rep,bq,hv)

    _, outs = jax.lax.scan(q_step, None,
                           (qg.transpose(1, 0, 2, 3, 4, 5),
                            qpos_b.transpose(1, 0, 2)))
    # outs: (nq, B, KV, rep, bq, hv) -> (B, Sq, H, hv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, v.shape[-1])
    return out.astype(q.dtype)


def apply_attention(p: Params, x: jax.Array, cfg, positions: jax.Array,
                    window=None, cache: Optional[Params] = None,
                    kv_len: Optional[jax.Array] = None,
                    backend: str = "xla",
                    causal: bool = True) -> Tuple[jax.Array, Optional[Params]]:
    """Pre-norm GQA attention block with optional KV cache.

    Training/prefill: x is (B, S, d), cache None/fresh. Decode: x is
    (B, 1, d) and ``cache`` holds (B, Smax, KV, hd) ring buffers with
    ``kv_len`` tokens valid before this call.
    """
    B, S, d = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", h, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(B, S, H, hd), "batch", None, "heads", None)
    k = shard(k.reshape(B, S, KV, hd), "batch", None, "kv_heads", None)
    v = shard(v.reshape(B, S, KV, hd), "batch", None, "kv_heads", None)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # insert at kv_len (scalar; same for all batch rows)
        start = kv_len if kv_len is not None else jnp.int32(0)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        kpos = jnp.arange(k.shape[1])[None, :]
        valid = start + S
    else:
        kpos = positions
        valid = None

    if (backend in ("pallas", "pallas_hw") and cache is not None and S == 1
            and window is None):
        # serving fast path: the pallas decode kernel attends the cache
        # with VMEM-resident statistics (kernels/decode_attention.py)
        from repro.kernels.decode_attention import decode_attention
        rep = H // KV
        qd = q.reshape(B, KV, rep, hd)
        kd = jnp.swapaxes(k, 1, 2)               # (B, KV, Smax, hd)
        vd = jnp.swapaxes(v, 1, 2)
        out = decode_attention(qd, kd, vd,
                               jnp.broadcast_to(jnp.asarray(valid), (B,)),
                               interpret=(backend != "pallas_hw"))
        out = out.reshape(B, S, H, hd)
    else:
        out = attention_core(q, k, v, positions,
                             jnp.broadcast_to(kpos, (B, k.shape[1])),
                             None if valid is None else jnp.asarray(valid),
                             causal=causal, window=window)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"].reshape(H, hd, d))
    return x + shard(out, "batch", None, None), new_cache


def apply_cross_attention(p: Params, x: jax.Array, cfg,
                          enc_kv: Tuple[jax.Array, jax.Array]
                          ) -> jax.Array:
    """Decoder cross-attention; enc_kv = (k, v): (B, Senc, H, hd)."""
    B, S, d = x.shape
    hd, H = cfg.resolved_head_dim, cfg.num_heads
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"]).reshape(B, S, H, hd)
    k, v = enc_kv
    m = jnp.ones((B, S, k.shape[1]), bool)
    out = attention_math(q, k, v, m)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"].reshape(H, hd, d))
    return x + out


def cross_kv(p: Params, cfg, enc_out: jax.Array):
    B, Se, ed = enc_out.shape
    hd, H = cfg.resolved_head_dim, cfg.num_heads
    k = jnp.einsum("bsd,dq->bsq", enc_out, p["wk"]).reshape(B, Se, H, hd)
    v = jnp.einsum("bsd,dq->bsq", enc_out, p["wv"]).reshape(B, Se, H, hd)
    return k, v


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def init_mlp(cfg, mk: Maker, ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = ff or cfg.d_ff
    p = {"norm": mk((d,), "embed", init="zeros")}
    if cfg.mlp.startswith("gated"):
        p["w_gate"] = mk((d, ff), "fsdp ff")
        p["w_up"] = mk((d, ff), "fsdp ff")
        p["w_down"] = mk((ff, d), "ff fsdp")
    else:
        p["w_up"] = mk((d, ff), "fsdp ff")
        p["w_down"] = mk((ff, d), "ff fsdp")
    return p


def apply_mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    if cfg.mlp.startswith("gated"):
        act = jax.nn.silu if cfg.mlp == "gated_silu" else jax.nn.gelu
        g = act(jnp.einsum("bsd,df->bsf", h, p["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", h, p["w_up"])
        hidden = shard(g * u, "batch", None, "ff")
    else:
        hidden = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["w_up"]))
        hidden = shard(hidden, "batch", None, "ff")
    out = jnp.einsum("bsf,fd->bsd", hidden, p["w_down"])
    return x + shard(out, "batch", None, None)
