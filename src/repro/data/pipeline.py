"""Deterministic, restart-safe data pipeline.

Stateless by construction: batch contents are a pure function of
(seed, step, host_shard), so checkpoint/restore and elastic re-sharding
need only the step counter — no iterator state to persist, no skew after
a failover.  Two sources:

  * ``synthetic`` — structured pseudo-text (Zipf-ish token stream with
    local repetition so a real LM can actually reduce loss on it);
  * ``memmap``    — a flat token file (np.memmap) sliced per step/shard,
    the production path for tokenised corpora.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    memmap_path: Optional[str] = None
    num_shards: int = 1                # data-parallel host shards
    shard_id: int = 0


class Pipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.num_shards:
            raise ValueError(f"global_batch {cfg.global_batch} not divisible "
                             f"by num_shards {cfg.num_shards}")
        self.local_batch = cfg.global_batch // cfg.num_shards
        self._mm = None
        if cfg.source == "memmap":
            if not cfg.memmap_path:
                raise ValueError("memmap source requires memmap_path")
            self._mm = np.memmap(cfg.memmap_path, dtype=np.int32, mode="r")

    # ---- synthetic ---------------------------------------------------------

    def _synthetic(self, step: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng(
            np.uint64(c.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(9176) + np.uint64(c.shard_id))
        B, S = self.local_batch, c.seq_len + 1
        # Zipf-distributed base stream
        ranks = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        toks = (ranks - 1) % c.vocab_size
        # inject local repetition: copy a window forward (learnable signal)
        for b in range(B):
            if S >= 8:
                w = rng.integers(2, max(3, S // 4))
                src = rng.integers(0, S - 2 * w)
                toks[b, src + w:src + 2 * w] = toks[b, src:src + w]
        return toks.astype(np.int32)

    def _memmap(self, step: int) -> np.ndarray:
        c = self.cfg
        B, S = self.local_batch, c.seq_len + 1
        n = self._mm.shape[0]
        per_step = c.global_batch * S
        base = (step * per_step + self.local_batch * S * c.shard_id) % max(
            n - B * S, 1)
        flat = np.asarray(self._mm[base:base + B * S])
        return (flat.reshape(B, S) % c.vocab_size).astype(np.int32)

    # ---- public -------------------------------------------------------------

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = (self._synthetic(step) if self.cfg.source == "synthetic"
                else self._memmap(step))
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones_like(toks[:, 1:], np.float32),
        }

    def jax_batch(self, step: int, sharding=None) -> Dict:
        b = self.batch(step)
        if sharding is None:
            return {k: jnp.asarray(v) for k, v in b.items()}
        return {k: jax.device_put(jnp.asarray(v), sharding) for k, v in
                b.items()}
