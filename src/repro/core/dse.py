"""DSE — hardware design-space exploration over schedule programs × HwIR.

The paper's flow leaves the *choice* of schedule manual: generate RTL
for a hand-picked transformation, simulate it in Vivado, read off
cycles and utilisation, repeat.  ``autotune.py`` automated a slice of
that (GEMM tile sweep under one cost model); this module generalizes it
into compiler infrastructure:

  * a **design point** (:class:`DsePoint`) is a *schedule program* — a
    real pass-pipeline spec over the LoopIR scheduling passes (tile
    choices via ``lower{...}``, ``split`` + ``unroll`` replication,
    ``interchange``, ``vectorize``, ``fuse-epilogue``, ``set-space``
    memory placement, ``grid``) plus an optional HwIR-level knob
    pipeline (``set-sequencer`` — ``@fsm`` ↔ ``@stream``
    double-buffering).  Every point is a string the ``reproc`` driver
    can replay verbatim;
  * each point lowers through the **real pipeline** (``PassManager`` →
    scheduled ``Kernel`` → ``hw_ir.lower_to_hw`` → ``HwModule``), is
    priced *structurally* by ``machine_model.cycles``/``resources``,
    checked against a :class:`ResourceBudget`, and folded onto a
    cycles × area **Pareto frontier**;
  * the top frontier points are then **validated** the way the paper
    validates in Vivado: ``hw_sim.cosim`` executes the module
    cycle-accurately against the numpy oracle and cross-checks observed
    vs modeled cycles.

Before pricing, design points are deduplicated by **canonical form**
(:func:`dedupe_points`): two schedule programs whose canonicalized
kernels print identically are spellings of one design (e.g.
``grid{vars=2}`` vs ``grid{vars=3}`` when the extra grid loop has
extent 1), so only the first is priced — and every elimination is
recorded on ``DseResult.deduped`` and logged in the result table.
Candidate pricing is memoized in a persistent on-disk cache keyed by
(kernel text, machine, schedule program), and uncached points evaluate
in parallel.  Entry points: :func:`explore` (library),
``CompiledKernel.explore()`` (artifact method), the ``dse`` pass
(pipelines), ``reproc --dse[=N] [--pareto-csv F]`` (CLI), and
``benchmarks/pareto.py`` (the paper-points frontier).

Legality is enforced, not assumed: ``vectorize`` candidates are only
generated for loops whose written tiles all depend on the loop variable
(SIMD lanes must write disjoint tiles; a reduction loop like GEMM's K
is *not* vectorizable, while it *is* unrollable — the paper's
flattening chains spatial MACs).

Beyond one kernel in isolation, :func:`explore_fleet` (implemented in
``core/fabric.py``, re-exported here) composes the per-kernel frontiers
this module computes into *fleet* candidates — which kernels get area,
how many copies of each — priced under crossbar contention against a
traffic mix and ranked on a throughput × total-area frontier.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from . import hw_ir, hw_sim, ir_text, machine_model
from .hw_ir import HwModule, HwStep
from .loop_ir import Kernel, Loop, MemSpace, _stmt_refs, _stmt_written_refs
from .machine_model import (TPU_V5E, CycleReport, MachineModel,
                            ResourceReport)
from .passes import PassError, PassManager
from .tensor_ir import Graph, dtype_bytes

# --------------------------------------------------------------------------
# area model — one scalar "hardware size" so the frontier is 2-D
# --------------------------------------------------------------------------

#: FF/LUT-equivalents per spatial datapath lane (a DSP slice + glue)
LANE_AREA = 64
#: BRAM bits are denser than register bits by roughly this factor
BRAM_BIT_DISCOUNT = 16
#: on-chip RAM is quantized in blocks (an 18Kb BRAM): a 4-byte
#: accumulator pushed to @vmem still burns a whole block
BRAM_BLOCK_BITS = 18 * 1024


def stream_dbuf_bytes(mod: HwModule) -> int:
    """Double-buffer RAM implied by ``@stream`` sequencers.

    The cycle model's overlap credit assumes the grid sequencer
    ping-pongs each step's off-chip tiles (the pallas double-buffered
    DMA); that storage is real hardware, so the area model charges two
    copies of every HBM-port tile touched under a stream loop.
    """
    total = 0
    for node, _, trail in mod.walk():
        if not any(l.kind == "stream" for l in trail):
            continue
        if isinstance(node, HwStep):
            operands = node.operands
        elif isinstance(node, hw_ir.HwInstance):
            operands = node.portmap     # the call's HBM traffic ping-pongs
        else:
            continue
        for o in operands:
            if mod.space_of(o.target) == MemSpace.HBM:
                total += 2 * o.elems * dtype_bytes(
                    mod.storage(o.target).dtype)
    # a sub-module definition is one hardware instance however many call
    # states reference it, so its double buffers are paid once
    return total + sum(stream_dbuf_bytes(s) for s in mod.submodules)


def _bram_area(mod: HwModule) -> int:
    a = 0
    for mm in mod.mems:
        blocks = math.ceil(8 * mm.bytes / BRAM_BLOCK_BITS)
        a += blocks * BRAM_BLOCK_BITS // BRAM_BIT_DISCOUNT
    return a + sum(_bram_area(s) for s in mod.submodules)


def area(mod: HwModule) -> int:
    """Composite spatial footprint of a module, in FF/LUT-equivalents.

    summed lanes × :data:`LANE_AREA` over every declared unit (the DSP
    column — *summed*, not peak, so sharing a unit across FSM states and
    outlining a repeated subcircuit into one definition both shrink it)
    + architectural/counter/state register bits (the FF column) +
    input-mux overhead of time-multiplexed units + block-quantized RAM
    bits (the BRAM column, discounted per bit) + stream double-buffer
    RAM.  Sub-module definitions count once, however many call sites
    instance them.
    """
    a = (mod.total_lanes() * LANE_AREA + mod.register_bits()
         + mod.mux_bits())
    a += _bram_area(mod)
    a += 8 * stream_dbuf_bytes(mod) // BRAM_BIT_DISCOUNT
    return a


@dataclasses.dataclass(frozen=True)
class ResourceBudget:
    """Feasibility envelope — the FPGA-size analogue of the search."""

    max_lanes: int
    max_vmem_bytes: int
    max_reg_bits: int

    @classmethod
    def from_machine(cls, m: MachineModel) -> "ResourceBudget":
        return cls(max_lanes=m.mxu_dim * m.mxu_dim,
                   max_vmem_bytes=m.vmem_capacity_bytes,
                   max_reg_bits=64 * 1024 * 1024)

    def admits(self, res: ResourceReport, dbuf_bytes: int = 0) -> bool:
        return (res.compute_lanes <= self.max_lanes
                and res.vmem_bytes + dbuf_bytes <= self.max_vmem_bytes
                and res.reg_bits <= self.max_reg_bits)


# --------------------------------------------------------------------------
# design points
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DsePoint:
    """One candidate schedule program.

    ``pipeline`` takes the TensorIR graph to a scheduled LoopIR kernel;
    ``hw_pipeline`` (optional) applies HwIR-level knobs after
    ``lower-to-hw``.  ``spec`` is the single replayable pipeline string.
    """

    family: str
    pipeline: str
    hw_pipeline: str = ""

    @property
    def spec(self) -> str:
        s = f"{self.pipeline},lower-to-hw"
        if self.hw_pipeline:
            s += f",{self.hw_pipeline}"
        return s


@dataclasses.dataclass
class DseCandidate:
    """A priced design point."""

    point: DsePoint
    cycles: CycleReport
    resources: ResourceReport
    area: int
    dbuf_bytes: int
    feasible: bool
    on_frontier: bool = False
    cached: bool = False

    @property
    def key(self) -> Tuple[int, int]:
        return (self.cycles.total, self.area)


@dataclasses.dataclass
class DseValidation:
    """One cosim validation of a frontier point (the Vivado-sim leg)."""

    point: DsePoint
    ok: bool
    observed_cycles: int
    modeled_cycles: int
    max_abs_err: float
    detail: str = ""

    @property
    def cycle_dev_pct(self) -> float:
        if self.modeled_cycles <= 0:
            return 0.0
        return 100.0 * abs(self.observed_cycles - self.modeled_cycles) \
            / self.modeled_cycles


# --------------------------------------------------------------------------
# candidate enumeration
# --------------------------------------------------------------------------

#: tile edges tried for the grid-mapped (tpu_mxu-family) points
DEFAULT_TILES = (128, 64, 32, 16, 8)
#: spatial replication factors tried for split+unroll points
DEFAULT_UNROLL_FACTORS = (2, 4, 8, 16)


def _innermost(kernel: Kernel) -> Optional[Loop]:
    """Deepest loop with no nested loops (flatten-inner's target)."""
    best, depth_of = None, -1
    for s, depth, _ in kernel.walk():
        if isinstance(s, Loop) and not any(isinstance(b, Loop)
                                           for b in s.body):
            if depth > depth_of:
                depth_of, best = depth, s
    return best


def _perfect_pair(kernel: Kernel) -> Optional[Tuple[Loop, Loop]]:
    """Topmost perfectly-nested (outer, inner) loop pair, if any."""
    for s, _, _ in kernel.walk():
        if isinstance(s, Loop) and len(s.body) == 1 \
                and isinstance(s.body[0], Loop):
            return s, s.body[0]
    return None


def vectorize_legal(kernel: Kernel, loop: Loop) -> bool:
    """A loop is SIMD-legal iff every tile written under it is indexed
    by the loop variable (lanes write disjoint tiles).  A reduction
    loop (GEMM's K: the accumulator index is K-invariant) is not, and
    neither is any loop threading a carry (a ``ReduceTile`` running
    statistic, a ``ScanTile`` state row) — ``_stmt_written_refs``
    surfaces the carry as a written ref, so those loops fail the
    disjointness test here and ``schedule.vectorize`` raises on them."""
    def written_depends(stmts) -> bool:
        for s in stmts:
            if isinstance(s, Loop):
                if not written_depends(s.body):
                    return False
            else:
                for ref in _stmt_written_refs(s):
                    used = {v for e in ref.index for v, _ in e.coeffs}
                    if loop.var.name not in used:
                        return False
        return True
    return written_depends(loop.body)


def _lower_nested(graph: Graph) -> Kernel:
    return PassManager.parse("lower").run(graph).artifact


def canonical_key(graph: Graph, point: DsePoint,
                  machine: MachineModel = TPU_V5E,
                  hw: Optional[HwModule] = None) -> Optional[str]:
    """Canonical-form dedupe key of a design point: the canonicalized
    textual form of the *hardware* the point lowers to (HwIR knobs
    applied).  Two schedule programs with the same key describe one
    design — e.g. ``grid{vars=2}`` vs ``grid{vars=3}`` at full-dim
    tiles, whose extra trip-1 stream sequencer collapses away.

    Pass ``hw`` to key an already-built module (``explore`` builds each
    point once and reuses it for pricing); the module itself is never
    mutated (``canonical_text`` canonicalizes a re-parsed copy).
    Returns ``None`` when the point's pipeline fails — such points are
    kept so the caller records the real error.
    """
    from . import rewrite

    try:
        if hw is None:
            _, hw = build_point(graph, point, machine)
        return rewrite.canonical_text(hw)
    except (PassError, ValueError, KeyError):
        return None


def _dedupe_by_key(points: Sequence[DsePoint],
                   keys: Sequence[Optional[str]]
                   ) -> Tuple[List[int], List[Tuple[DsePoint, DsePoint]]]:
    """The one dedupe policy (first point with a key wins; ``None`` keys
    — failed builds — are never deduped): index-level so both the
    public :func:`dedupe_points` and :func:`explore` share it exactly.
    Returns ``(kept_indices, dropped_pairs)``."""
    seen: Dict[str, int] = {}
    keep: List[int] = []
    dropped: List[Tuple[DsePoint, DsePoint]] = []
    for i, (pt, key) in enumerate(zip(points, keys)):
        if key is not None and key in seen:
            dropped.append((pt, points[seen[key]]))
            continue
        if key is not None:
            seen[key] = i
        keep.append(i)
    return keep, dropped


def dedupe_points(graph: Graph, points: Sequence[DsePoint],
                  machine: MachineModel = TPU_V5E
                  ) -> Tuple[List[DsePoint], List[Tuple[DsePoint, DsePoint]]]:
    """Drop design points whose canonical form duplicates an earlier
    point's.  Returns ``(kept, dropped)`` where each dropped entry pairs
    the eliminated point with the kept point it duplicates — the caller
    logs every elimination (no silent shrinkage of the search space).

    Convenience wrapper (keys computed serially, uncached) over the same
    :func:`_dedupe_by_key` policy ``explore`` uses; explore itself keys
    off artifacts it already built and cached."""
    keys = [canonical_key(graph, pt, machine) for pt in points]
    keep, dropped = _dedupe_by_key(points, keys)
    return [points[i] for i in keep], dropped


def enumerate_points(graph: Graph,
                     tiles: Sequence[int] = DEFAULT_TILES,
                     unroll_factors: Sequence[int] = DEFAULT_UNROLL_FACTORS,
                     ) -> List[DsePoint]:
    """The search space: schedule families instantiated against the
    *actual* lowered structure of ``graph`` (loop names, extents and
    scratch buffers are discovered from the real nested lowering, so
    every generated pipeline replays verbatim)."""
    k = _lower_nested(graph)
    pts: List[DsePoint] = []

    # -- the two paper points ------------------------------------------------
    pts.append(DsePoint("nested", "lower"))
    inner = _innermost(k)
    if inner is not None:
        pts.append(DsePoint("inner_flattened", "lower,flatten-inner"))

    # -- split+unroll: partial spatial replication (unit replication N) ------
    if inner is not None:
        for f in unroll_factors:
            if f < inner.var.extent and inner.var.extent % f == 0:
                v = inner.var.name
                pts.append(DsePoint(
                    "split_unroll",
                    f"lower,split{{var={v},factor={f}}},"
                    f"unroll{{var={v}_i}}"))

    # -- interchange (only where it changes the trip structure) --------------
    pair = _perfect_pair(k)
    if pair is not None and pair[0].var.extent != pair[1].var.extent:
        pts.append(DsePoint(
            "interchange",
            f"lower,interchange{{outer={pair[0].var.name},"
            f"inner={pair[1].var.name}}}"))

    # -- vectorize (SIMD) every legal loop -----------------------------------
    for loop in k.loops():
        if not any(isinstance(s, Loop) for s in loop.body) \
                and vectorize_legal(k, loop):
            pts.append(DsePoint(
                "simd", f"lower,vectorize{{var={loop.var.name}}}"))

    # -- epilogue fusion on the scalar nest ----------------------------------
    if sum(1 for s in k.body if isinstance(s, Loop)) > 1:
        pts.append(DsePoint("nested_fused", "lower,fuse-epilogue"))

    # -- memory-space placement: accumulator VREG -> VMEM --------------------
    for b in k.scratch:
        if b.space == MemSpace.VREG:
            pts.append(DsePoint(
                "vmem_acc", f"lower,set-space{{buffer={b.name},space=vmem}}"))
            break

    # -- HwIR knob: re-sequence the outer loop as @stream (double buffer) ----
    tops = [s for s in k.body if isinstance(s, Loop)]
    if tops:
        outer = tops[0].var.name
        pts.append(DsePoint(
            "stream_outer", "lower",
            hw_pipeline=f"set-sequencer{{counter={outer},kind=stream}}"))
        if inner is not None:
            pts.append(DsePoint(
                "flat_stream", "lower,flatten-inner",
                hw_pipeline=f"set-sequencer{{counter={outer},kind=stream}}"))

    # -- resource sharing: outline repeats, time-multiplex units -------------
    # "shared" trades nothing (bindings at serial=1 fold duplicate units
    # behind muxes); "serialized" additionally lets wide units run on
    # narrow hardware, trading cycles for the smallest area on the
    # frontier.
    pts.append(DsePoint("shared", "lower",
                        hw_pipeline="canonicalize,set-sharing{mode=share}"))
    if inner is not None:
        pts.append(DsePoint(
            "flat_serialized", "lower,flatten-inner",
            hw_pipeline="canonicalize,set-sharing{mode=serialize}"))

    # -- grid-mapped MXU tilings (the TPU-native families) -------------------
    dims = [b.type.shape for b in k.params]
    flat_dims = sorted({d for shape in dims for d in shape})
    for t in tiles:
        if not all(d % t == 0 for shape in dims for d in shape) or \
                t > min(flat_dims):
            continue
        lowered = f"lower{{tile_m={t},tile_n={t},tile_k={t}}},fuse-epilogue"
        pts.append(DsePoint("tpu_mxu", f"{lowered},grid{{vars=2}}"))
        pts.append(DsePoint("tpu_mxu_kgrid", f"{lowered},grid{{vars=3}}"))
    return pts


# --------------------------------------------------------------------------
# pricing (with the persistent candidate cache)
# --------------------------------------------------------------------------


def _default_cache_dir() -> str:
    return os.environ.get("STAGECC_DSE_CACHE") or os.path.join(
        os.path.expanduser("~"), ".cache", "stagecc", "dse")


def _cache_key(graph_text: str, machine: MachineModel,
               point: DsePoint, budget: ResourceBudget) -> str:
    blob = "\x1f".join(("dse-v2", graph_text, repr(machine), point.spec,
                        repr(budget)))
    return hashlib.sha256(blob.encode()).hexdigest()


def _cache_load(path: str, point: DsePoint
                ) -> Tuple[Optional[DseCandidate], Optional[str]]:
    """Load a cached pricing plus its canonical dedupe key (the key
    rides in the cache so a warm explore never recompiles a point).
    Deduped points cache a key-only entry: ``(None, key)``."""
    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, ValueError):
        return None, None               # corrupt/missing entry
    if not isinstance(d, dict):
        return None, None               # valid JSON, wrong shape
    key = d.get("canonical_key")
    try:
        cand = DseCandidate(
            point=point, cycles=CycleReport(**d["cycles"]),
            resources=ResourceReport(**d["resources"]), area=d["area"],
            dbuf_bytes=d["dbuf_bytes"], feasible=d["feasible"], cached=True)
    except (ValueError, KeyError, TypeError):
        cand = None                     # key-only entry (or stale format)
    return cand, key


def _cache_store(path: str, cand: Optional[DseCandidate],
                 canonical_key: Optional[str] = None,
                 point: Optional[DsePoint] = None) -> None:
    """Persist a pricing (or, with ``cand=None``, just a point's
    canonical key — enough for the next explore to dedupe it without
    recompiling)."""
    pt = cand.point if cand is not None else point
    entry = {"spec": pt.spec, "family": pt.family,
             "canonical_key": canonical_key}
    if cand is not None:
        entry.update({
            "cycles": dataclasses.asdict(cand.cycles),
            "resources": dataclasses.asdict(cand.resources),
            "area": cand.area, "dbuf_bytes": cand.dbuf_bytes,
            "feasible": cand.feasible})
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(entry, f)
        os.replace(tmp, path)
    except OSError:
        pass                            # cache is best-effort


def build_point(graph: Graph, point: DsePoint,
                machine: MachineModel = TPU_V5E
                ) -> Tuple[Kernel, HwModule]:
    """Replay a design point through the real pipeline: Graph →
    scheduled Kernel → HwModule (+ HwIR knob passes)."""
    kernel = PassManager.parse(point.pipeline).run(graph).artifact
    if not isinstance(kernel, Kernel):
        raise PassError(f"point {point.spec!r} did not produce a Kernel")
    hw = hw_ir.lower_to_hw(kernel, mxu_min_dim=machine.mxu_min_dim)
    if point.hw_pipeline:
        hw = PassManager.parse(point.hw_pipeline).run(hw).artifact
    return kernel, hw


def evaluate(graph: Graph, point: DsePoint, machine: MachineModel,
             budget: ResourceBudget,
             built: Optional[Tuple[Kernel, HwModule]] = None) -> DseCandidate:
    """Price one design point structurally (no execution).  ``built``
    reuses an already-lowered (kernel, hw) pair instead of recompiling
    (``explore`` builds each point exactly once)."""
    _, hw = built if built is not None else \
        build_point(graph, point, machine)
    cyc = machine_model.cycles(hw, machine)
    try:
        res = machine_model.resources(hw, machine)
        over_capacity = False
    except ResourceWarning:
        # RAM footprint exceeds the machine: reconstruct the report
        # structurally and mark the point infeasible
        res = ResourceReport(
            compute_lanes=hw.lane_count(), vmem_bytes=hw.mem_bytes(),
            vreg_tiles=0, fsm_states=hw.fsm_state_count(),
            reg_bits=hw.register_bits(), total_lanes=hw.total_lanes(),
            mux_bits=hw.mux_bits(), shared_units=hw.shared_unit_count())
        over_capacity = True
    dbuf = stream_dbuf_bytes(hw)
    return DseCandidate(
        point=point, cycles=cyc, resources=res, area=area(hw),
        dbuf_bytes=dbuf,
        feasible=not over_capacity and budget.admits(res, dbuf))


# --------------------------------------------------------------------------
# Pareto frontier
# --------------------------------------------------------------------------


def dominates(a: Tuple[int, int], b: Tuple[int, int]) -> bool:
    """Strict Pareto domination on (cycles, area): no worse on both,
    strictly better on at least one.  Equal points do not dominate."""
    return a[0] <= b[0] and a[1] <= b[1] and a != b


def pareto_frontier(cands: Sequence[DseCandidate]) -> List[DseCandidate]:
    """Non-dominated feasible candidates, fastest first."""
    feas = [c for c in cands if c.feasible]
    front = [c for c in feas
             if not any(dominates(o.key, c.key) for o in feas)]
    return sorted(front, key=lambda c: c.key)


# --------------------------------------------------------------------------
# the explorer
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DseResult:
    graph_name: str
    machine: MachineModel
    budget: ResourceBudget
    candidates: List[DseCandidate]
    errors: List[Tuple[DsePoint, str]]
    validations: List[DseValidation]
    #: (eliminated, kept) pairs from the canonical-form dedupe — every
    #: shrink of the explored space is recorded, never silent
    deduped: List[Tuple[DsePoint, DsePoint]] = \
        dataclasses.field(default_factory=list)

    @property
    def frontier(self) -> List[DseCandidate]:
        return sorted((c for c in self.candidates if c.on_frontier),
                      key=lambda c: c.key)

    def best(self) -> Optional[DseCandidate]:
        front = self.frontier
        return front[0] if front else None

    # ---- rendering ---------------------------------------------------------

    def table(self) -> str:
        rows = [f"// dse {self.graph_name} on {self.machine.name}: "
                f"{len(self.candidates)} candidates "
                f"({sum(c.cached for c in self.candidates)} cached, "
                f"{len(self.errors)} failed, "
                f"{len(self.deduped)} deduped), "
                f"{len(self.frontier)} on the Pareto frontier"]
        if self.deduped:
            total = len(self.candidates) + len(self.errors) \
                + len(self.deduped)
            rows.append(f"// canonical-form dedupe eliminated "
                        f"{len(self.deduped)} of {total} design points "
                        f"before pricing:")
            for gone, kept in self.deduped:
                rows.append(f"//   dedupe {gone.family}: {gone.spec}  ==  "
                            f"{kept.family}: {kept.spec}")
        hdr = (f"{'':2s}{'FAMILY':16s} {'CYCLES':>12s} {'AREA':>10s} "
               f"{'LANES':>6s} {'REGBITS':>8s} {'VMEM':>7s} {'FSM':>5s}  "
               f"SCHEDULE PROGRAM")
        rows.append(hdr)
        for c in sorted(self.candidates, key=lambda c: c.key):
            mark = "* " if c.on_frontier else ("  " if c.feasible else "! ")
            rows.append(
                f"{mark}{c.point.family:16s} {c.cycles.total:>12,} "
                f"{c.area:>10,} {c.resources.compute_lanes:>6,} "
                f"{c.resources.reg_bits:>8,} {c.resources.vmem_bytes:>7,} "
                f"{c.resources.fsm_states:>5,}  {c.point.spec}")
        rows.append("// '*' = Pareto frontier (cycles x area), "
                    "'!' = infeasible under the resource budget")
        for v in self.validations:
            status = "ok" if v.ok else "FAIL"
            rows.append(
                f"// cosim {v.point.family:16s} [{status}] "
                f"observed={v.observed_cycles:,} "
                f"modeled={v.modeled_cycles:,} "
                f"(dev {v.cycle_dev_pct:.2f}%) "
                f"max|err|={v.max_abs_err:.2e}"
                + (f"  {v.detail}" if v.detail else ""))
        for pt, msg in self.errors:
            rows.append(f"// error {pt.family}: {pt.spec}: {msg}")
        return "\n".join(rows)

    def to_csv(self) -> str:
        lines = ["family,spec,cycles,compute,memory,control,lanes,"
                 "reg_bits,vmem_bytes,fsm_states,area,dbuf_bytes,"
                 "feasible,on_frontier,validated,observed_cycles,"
                 "max_abs_err,total_lanes,mux_bits,shared_units"]
        vmap = {v.point.spec: v for v in self.validations}
        for c in sorted(self.candidates, key=lambda c: c.key):
            v = vmap.get(c.point.spec)
            lines.append(",".join(str(x) for x in (
                c.point.family, f'"{c.point.spec}"', c.cycles.total,
                c.cycles.compute, c.cycles.memory, c.cycles.control,
                c.resources.compute_lanes, c.resources.reg_bits,
                c.resources.vmem_bytes, c.resources.fsm_states, c.area,
                c.dbuf_bytes, int(c.feasible), int(c.on_frontier),
                int(v is not None and v.ok),
                v.observed_cycles if v else "",
                f"{v.max_abs_err:.3e}" if v else "",
                c.resources.total_lanes, c.resources.mux_bits,
                c.resources.shared_units)))
        return "\n".join(lines) + "\n"


def validate_point(graph: Graph, cand: DseCandidate,
                   machine: MachineModel, seed: int = 0,
                   atol: float = 1e-5,
                   cycle_tol_pct: float = 10.0) -> DseValidation:
    """Co-simulate one candidate against the numpy oracle (the Vivado
    simulation leg of the closed loop).

    ``ok`` requires *both* checks: outputs within ``atol`` of the
    oracle, and observed cycles within ``cycle_tol_pct`` percent of the
    structural model (the same gate the ``simulate`` pass applies — a
    frontier priced by a model the simulation contradicts is not a
    frontier).
    """
    kernel, hw = build_point(graph, cand.point, machine)
    inputs = hw_sim.random_inputs(hw, seed=seed)
    try:
        rep = hw_sim.cosim(hw, kernel, inputs, machine=machine,
                           modeled=cand.cycles.total, atol=atol)
    except hw_sim.SimError as e:
        return DseValidation(point=cand.point, ok=False,
                             observed_cycles=0,
                             modeled_cycles=cand.cycles.total,
                             max_abs_err=float("nan"), detail=str(e))
    v = DseValidation(point=cand.point, ok=True,
                      observed_cycles=rep.observed_cycles,
                      modeled_cycles=rep.modeled_cycles,
                      max_abs_err=rep.max_abs_err)
    if v.cycle_dev_pct > cycle_tol_pct:
        v.ok = False
        v.detail = (f"observed cycles deviate {v.cycle_dev_pct:.1f}% "
                    f"from modeled (> {cycle_tol_pct:g}%)")
    return v


def explore(graph: Graph, machine: MachineModel = TPU_V5E,
            budget: Optional[ResourceBudget] = None,
            tiles: Sequence[int] = DEFAULT_TILES,
            validate_top: int = 0,
            workers: Optional[int] = None,
            cache_dir: Optional[str] = None,
            use_cache: bool = True,
            seed: int = 0, atol: float = 1e-5,
            cycle_tol_pct: float = 10.0) -> DseResult:
    """Run the full DSE loop: enumerate → price (parallel, cached) →
    Pareto → validate the ``validate_top`` fastest frontier points.
    """
    graph.verify()
    budget = budget or ResourceBudget.from_machine(machine)
    points = enumerate_points(graph, tiles=tiles)
    gtext = ir_text.print_ir(graph)
    cdir = cache_dir or _default_cache_dir()
    nworkers = workers or min(8, os.cpu_count() or 1)

    def path_of(i: int) -> str:
        return os.path.join(cdir, _cache_key(gtext, machine, points[i],
                                             budget) + ".json")

    cands: List[Optional[DseCandidate]] = [None] * len(points)
    ckeys: List[Optional[str]] = [None] * len(points)
    errors: List[Tuple[DsePoint, str]] = []
    failed: set = set()
    built: Dict[int, Tuple[Kernel, HwModule]] = {}

    to_build: List[int] = []
    for i, pt in enumerate(points):
        if use_cache:
            cands[i], ckeys[i] = _cache_load(path_of(i), pt)
        if ckeys[i] is None:
            to_build.append(i)

    # build every uncached point exactly once (parallel); the lowered
    # artifacts feed both the canonical dedupe key and the pricing below
    def build(i: int) -> None:
        try:
            built[i] = build_point(graph, points[i], machine)
            ckeys[i] = canonical_key(graph, points[i], machine,
                                     hw=built[i][1])
        except (PassError, ValueError, KeyError) as e:
            errors.append((points[i], str(e)))
            failed.add(i)

    if to_build:
        with ThreadPoolExecutor(max_workers=nworkers) as ex:
            list(ex.map(build, to_build))

    # canonical-form dedupe *before* pricing — every elimination logged
    # (failed builds sit in `errors`, not in the dedupe or the pricing)
    kept_idx, deduped = _dedupe_by_key(
        points, [None if i in failed else k for i, k in enumerate(ckeys)])
    keep = [i for i in kept_idx if i not in failed]
    dropped_idx = set(range(len(points))) - set(kept_idx)
    if use_cache:
        for i in dropped_idx & set(to_build):
            # key-only entry: the next explore dedupes this point
            # straight from the cache, compiling nothing
            _cache_store(path_of(i), None, ckeys[i], point=points[i])

    def price(i: int) -> Optional[DseCandidate]:
        try:
            return evaluate(graph, points[i], machine, budget,
                            built=built.get(i))
        except (PassError, ValueError, KeyError) as e:
            errors.append((points[i], str(e)))
            return None

    to_price = [i for i in keep if cands[i] is None]
    if to_price:
        with ThreadPoolExecutor(max_workers=nworkers) as ex:
            for i, cand in zip(to_price, ex.map(price, to_price)):
                cands[i] = cand
                if cand is not None and use_cache:
                    _cache_store(path_of(i), cand, ckeys[i])
    if use_cache:
        # refresh pre-canonical-key cache entries so the next explore is
        # fully warm (no rebuild just to recover the dedupe key)
        for i in keep:
            if i in to_build and cands[i] is not None and cands[i].cached:
                _cache_store(path_of(i), cands[i], ckeys[i])

    priced = [cands[i] for i in keep if cands[i] is not None]
    for c in pareto_frontier(priced):
        c.on_frontier = True

    validations: List[DseValidation] = []
    if validate_top:
        front = pareto_frontier(priced)
        for cand in front[:validate_top]:
            validations.append(validate_point(
                graph, cand, machine, seed=seed, atol=atol,
                cycle_tol_pct=cycle_tol_pct))
    return DseResult(graph_name=graph.name, machine=machine, budget=budget,
                     candidates=priced, errors=errors,
                     validations=validations, deduped=deduped)


def explore_fleet(graphs, mix, **kwargs):
    """Fleet-level DSE: optimize N kernels sharing one crossbar against
    a traffic mix under a total :class:`ResourceBudget` — per-kernel
    frontiers from :func:`explore`, fleets priced by the fabric machine
    model under contention, ranked on requests/s × total area, top
    points validated by the fabric event simulator.  Implemented in
    :mod:`repro.core.fabric`; see
    :func:`repro.core.fabric.explore_fleet` for the parameters."""
    from .fabric import explore_fleet as _explore_fleet

    return _explore_fleet(graphs, mix, **kwargs)
