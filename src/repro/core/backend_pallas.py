"""Pallas backend: emit a ``pl.pallas_call`` TPU kernel from scheduled LoopIR.

This is the RTL-emission stage of the paper's pipeline (Calyx -> System
Verilog): the scheduled LoopIR's GRID loops become the pallas grid, tile
shapes become BlockSpecs (explicit VMEM tiling), and the statement body
becomes the kernel body executed per grid step by the Mosaic "synthesis"
layer.

Like Calyx, the emitter accepts a *structured subset* of the IR — the
shapes produced by ``lowering.py`` + ``schedule.py`` for contraction
kernels:

    Loop(g0 @grid) { Loop(g1 @grid) { [Loop(g2 @grid)]
        [ZeroTile(acc)]
        ( Loop(k @seq|@unrolled) { MatmulTile(acc, A, B) } | MatmulTile )
        [EwiseTile epilogue ...]*
        [EwiseTile copy -> HBM out]
    }}}

Two canonical layouts fall out of the schedules, mirroring the paper:

  * ``(i, j)`` grid, K inside the block  — the *inner-flattened* analogue:
    each grid step holds a full ``(tm, K)``/(``K, tn``) stripe in VMEM, so
    VMEM consumption grows with K (Fig. 3(b): resources ∝ size);
  * ``(i, j, k)`` grid                   — the *nested* analogue: one
    ``(tm, tk)`` tile per step, one output tile time-multiplexed across
    the k grid dimension (Fig. 3(a): constant resources, datapath reuse).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .loop_ir import (EwiseTile, FillTile, Kernel, Loop, LoopKind, MatmulTile,
                      MemSpace, ReduceTile, ScanTile, Stmt, TileRef, ZeroTile,
                      _stmt_refs, _stmt_written_refs)
from .backend_jax import _EWISE_JNP, _JNP_DTYPE


class EmitError(NotImplementedError):
    """Raised when a kernel is outside the emitter's structured subset."""


@dataclasses.dataclass
class _Plan:
    grid_vars: List[str]                 # outer -> inner
    grid: Tuple[int, ...]
    inner_body: List[Stmt]
    k_loop: Optional[Loop]               # reduction loop inside block, if any
    k_grid_var: Optional[str]            # reduction on the grid, if any
    in_buffers: List[str]
    out_buffer: str
    block_specs: Dict[str, Tuple[Tuple[int, ...], Tuple[object, ...]]]
    acc_name: Optional[str]
    matmul: Optional[MatmulTile] = None


def _analyze(kernel: Kernel) -> _Plan:
    kernel.verify()
    # 1. peel GRID loops
    grid_vars: List[str] = []
    grid: List[int] = []
    stmts = kernel.body
    if len(stmts) != 1 or not isinstance(stmts[0], Loop):
        raise EmitError(f"{kernel.name}: body must be a single loop nest")
    cur: Stmt = stmts[0]
    while isinstance(cur, Loop) and cur.kind == LoopKind.GRID:
        grid_vars.append(cur.var.name)
        grid.append(cur.var.extent)
        if len(cur.body) == 1 and isinstance(cur.body[0], Loop) \
                and cur.body[0].kind == LoopKind.GRID:
            cur = cur.body[0]
        else:
            inner = cur.body
            break
    else:
        raise EmitError(f"{kernel.name}: no GRID loops — run a schedule first")

    if not grid_vars:
        raise EmitError(f"{kernel.name}: no GRID loops")

    # 2. classify the inner statements
    acc_name = None
    k_loop = None
    k_grid_var = None
    matmul: Optional[MatmulTile] = None
    epilogue: List[EwiseTile] = []
    for s in inner:
        if isinstance(s, ZeroTile):
            if s.dst.buffer.space == MemSpace.VREG:
                acc_name = s.dst.buffer.name
            # Zero of the HBM out with a k grid var is implicit (pl.when)
        elif isinstance(s, Loop):
            if len(s.body) != 1 or not isinstance(s.body[0], MatmulTile):
                raise EmitError(f"{kernel.name}: reduction loop body must be "
                                f"a single MatmulTile")
            if s.kind == LoopKind.GRID:
                # reduction mapped onto the grid (time-multiplexed schedule):
                # hoist it as the innermost grid dimension; accumulation
                # becomes pl.when-guarded updates of the revisited out block.
                grid_vars.append(s.var.name)
                grid.append(s.var.extent)
                k_grid_var = s.var.name
                matmul = s.body[0]
                continue
            if k_loop is not None or s.kind not in (LoopKind.SEQUENTIAL,
                                                    LoopKind.UNROLLED):
                raise EmitError(f"{kernel.name}: unsupported inner loop {s.var}")
            k_loop = s
            matmul = s.body[0]
        elif isinstance(s, MatmulTile):
            matmul = s
            kvars = [v for e in (*s.lhs.index, *s.rhs.index)
                     for v, _ in e.coeffs if v in grid_vars[2:]]
            if kvars:
                k_grid_var = kvars[0]
        elif isinstance(s, EwiseTile):
            epilogue.append(s)
        else:
            raise EmitError(f"{kernel.name}: unsupported stmt {s}")
    if matmul is None:
        raise EmitError(f"{kernel.name}: no MatmulTile found")
    # a 3-long grid means k lives on the grid
    if len(grid_vars) == 3:
        k_grid_var = grid_vars[2]

    # HBM buffers *written* inside the block that are not the kernel
    # output are SSA temporaries left by fusion; the emitter forwards
    # their values through registers instead of materialising them
    # (the codegen equivalent of Calyx wiring cells directly).
    out_names_ = {b.name for b in kernel.outputs}
    written = set()
    for s in inner:
        if isinstance(s, (ZeroTile, MatmulTile, EwiseTile)) \
                and s.dst.buffer.space == MemSpace.HBM \
                and s.dst.buffer.name not in out_names_:
            written.add(s.dst.buffer.name)

    # 3. build block specs for every HBM buffer touched
    inner_vars = {} if k_loop is None else {k_loop.var.name: k_loop.var.extent}
    specs: Dict[str, Tuple[Tuple[int, ...], Tuple[object, ...]]] = {}

    def visit(ref: TileRef):
        if ref.buffer.space != MemSpace.HBM or ref.buffer.name in written:
            return
        block: List[int] = []
        imap: List[object] = []   # either a grid-var name or 0
        for d, e in enumerate(ref.index):
            t = ref.tile[d]
            if not e.coeffs:
                # constant index: block covers [const*t, const*t + t)
                if e.const != 0:
                    raise EmitError(f"{kernel.name}: non-zero const index")
                block.append(t)
                imap.append(0)
            elif len(e.coeffs) == 1:
                v, stride = e.coeffs[0]
                if stride != 1:
                    raise EmitError(f"{kernel.name}: strided index on {v}")
                if v in grid_vars:
                    block.append(t)
                    imap.append(v)
                elif v in inner_vars:
                    block.append(t * inner_vars[v])
                    imap.append(0)
                else:
                    raise EmitError(f"{kernel.name}: unbound index var {v}")
            else:
                raise EmitError(f"{kernel.name}: multi-var affine index "
                                f"(apply split+grid only)")
        prev = specs.get(ref.buffer.name)
        spec = (tuple(block), tuple(imap))
        if prev is not None and prev != spec:
            raise EmitError(f"{kernel.name}: inconsistent refs to "
                            f"{ref.buffer.name}: {prev} vs {spec}")
        specs[ref.buffer.name] = spec

    for s in inner:
        if isinstance(s, Loop):
            for b in s.body:
                if isinstance(b, MatmulTile):
                    visit(b.dst), visit(b.lhs), visit(b.rhs)
        elif isinstance(s, ZeroTile):
            visit(s.dst)
        elif isinstance(s, MatmulTile):
            visit(s.dst), visit(s.lhs), visit(s.rhs)
        elif isinstance(s, EwiseTile):
            visit(s.dst)
            for r in s.srcs:
                visit(r)

    out_names = [b.name for b in kernel.outputs]
    if len(out_names) != 1:
        raise EmitError(f"{kernel.name}: exactly one output supported")
    out = out_names[0]
    ins = [b.name for b in kernel.params
           if b.name in specs and b.name != out]
    return _Plan(grid_vars=grid_vars, grid=tuple(grid), inner_body=inner,
                 k_loop=k_loop, k_grid_var=k_grid_var, in_buffers=ins,
                 out_buffer=out, block_specs=specs, acc_name=acc_name,
                 matmul=matmul)


def emit(kernel: Kernel, interpret: bool = True) -> Callable[..., jax.Array]:
    """Emit ``f(*hbm_inputs) -> out`` for a scheduled kernel.

    Dispatch: the single-nest GEMM classifier (``_analyze``) first — it
    produces the tight BlockSpec'd pallas_call the contraction schedules
    want — and the general multi-nest emitter (``emit_general``) for
    everything else (the serving-kernel graphs: several chained nests
    with carried reductions and scans).

    ``interpret=True`` (default here) runs the kernel body in the pallas
    interpreter so it is exact on CPU; on real TPU pass ``interpret=False``
    to lower through Mosaic.
    """
    try:
        return _emit_gemm(kernel, interpret=interpret)
    except EmitError:
        return emit_general(kernel, interpret=interpret)


def _emit_gemm(kernel: Kernel,
               interpret: bool = True) -> Callable[..., jax.Array]:
    """The original single-nest contraction emitter (see module doc)."""
    plan = _analyze(kernel)
    buffers = {b.name: b for b in kernel.params + kernel.scratch}
    out_buf = buffers[plan.out_buffer]
    out_dtype = _JNP_DTYPE[out_buf.type.dtype]
    gpos = {v: i for i, v in enumerate(plan.grid_vars)}

    def mk_index_map(imap):
        def index_map(*gids):
            return tuple(gids[gpos[v]] if isinstance(v, str) else 0
                         for v in imap)
        return index_map

    in_specs = []
    for name in plan.in_buffers:
        block, imap = plan.block_specs[name]
        in_specs.append(pl.BlockSpec(block, mk_index_map(imap)))
    out_block, out_imap = plan.block_specs[plan.out_buffer]
    out_spec = pl.BlockSpec(out_block, mk_index_map(out_imap))

    mm = plan.matmul
    tm, tk = mm.lhs.tile[-2:]
    tn = mm.rhs.tile[-1]
    lhs_name, rhs_name = mm.lhs.buffer.name, mm.rhs.buffer.name
    k_on_grid = plan.k_grid_var is not None
    k_extent = plan.k_loop.var.extent if plan.k_loop is not None else 1
    k_unrolled = (plan.k_loop is not None
                  and plan.k_loop.kind == LoopKind.UNROLLED)
    # which dim of each operand block the k sub-tiling walks
    epilogue = [s for s in plan.inner_body if isinstance(s, EwiseTile)]

    def body(*refs):
        ref_of = dict(zip(plan.in_buffers + [plan.out_buffer], refs))
        a_ref, b_ref = ref_of[lhs_name], ref_of[rhs_name]
        o_ref = ref_of[plan.out_buffer]

        def dot_k(kk):
            a = a_ref[..., :, pl.dslice(kk * tk, tk)] if k_extent > 1 else a_ref[...]
            b = b_ref[pl.dslice(kk * tk, tk), :] if k_extent > 1 else b_ref[...]
            return jnp.dot(a, b, preferred_element_type=jnp.float32)

        if k_on_grid:
            k_id = pl.program_id(gpos[plan.k_grid_var])

            @pl.when(k_id == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] = o_ref[...] + dot_k(0).astype(out_dtype)
            last = pl.num_programs(gpos[plan.k_grid_var]) - 1
            if epilogue:
                @pl.when(k_id == last)
                def _epi():
                    o_ref[...] = _apply_epilogue(
                        epilogue, o_ref[...], ref_of, plan).astype(out_dtype)
        else:
            acc = jnp.zeros((tm, tn), jnp.float32)
            if k_unrolled or k_extent <= 4:
                for kk in range(k_extent):
                    acc = acc + dot_k(kk)
            else:
                acc = jax.lax.fori_loop(
                    0, k_extent, lambda kk, c: c + dot_k(kk), acc)
            acc = _apply_epilogue(epilogue, acc, ref_of, plan)
            o_ref[...] = acc.astype(out_dtype)

    fname = f"stagecc_pallas_{kernel.name}"
    call = pl.pallas_call(
        body,
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_buf.shape, out_dtype),
        interpret=interpret,
    )

    def fn(*inputs):
        by_name = dict(zip(plan.in_buffers, inputs))
        args = [jnp.asarray(by_name[n], _JNP_DTYPE[buffers[n].type.dtype])
                for n in plan.in_buffers]
        return call(*args)

    fn.__name__ = fname
    fn.plan = plan  # exposed for tests / resource introspection
    return fn


def _apply_epilogue(epilogue: Sequence[EwiseTile], acc, ref_of, plan: _Plan):
    """Apply fused elementwise tail ops to the accumulator value.

    HBM temporaries introduced by fusion are forwarded through a local
    SSA environment (``local``) and never materialised.
    """
    local: Dict[str, object] = {}
    if plan.acc_name is not None:
        local[plan.acc_name] = acc
    val = acc
    for s in epilogue:
        srcs = []
        for r in s.srcs:
            if r.buffer.name in local:
                srcs.append(local[r.buffer.name])
            elif r.buffer.name == plan.out_buffer:
                srcs.append(val)
            elif r.buffer.name in ref_of:
                srcs.append(ref_of[r.buffer.name][...])
            else:
                raise EmitError(f"epilogue src {r.buffer.name} not mapped")
        if len(srcs) == 2 and getattr(srcs[1], "ndim", 0) < srcs[0].ndim:
            srcs[1] = srcs[1][(None,) * (srcs[0].ndim - srcs[1].ndim)]
        v = srcs[0] if s.op == "copy" else _EWISE_JNP[s.op](*srcs)
        local[s.dst.buffer.name] = v
        val = v
    if plan.out_buffer in local:
        return local[plan.out_buffer]
    return val


# --------------------------------------------------------------------------
# general multi-nest emitter
# --------------------------------------------------------------------------
#
# The serving-kernel graphs lower to *several* top-level nests chained
# through HBM temporaries (matmul -> mask add -> carried max -> exp ->
# carried sum -> matmul -> div), which the single-nest classifier above
# cannot express.  The general emitter maps each top-level statement to
# its own ``pl.pallas_call``:
#
#   * the nest's leading @grid chain becomes the pallas grid; every HBM
#     buffer the stage touches is passed as a full-array block (constant
#     index map), and tile addressing happens *inside* the body with
#     ``pl.dslice`` — grid counters resolve to ``pl.program_id``, inner
#     @seq/@unrolled/@vector counters to python ints at trace time;
#   * VREG/VMEM scratch (accumulators, scan carries) become local jnp
#     values updated functionally — carried state threads through the
#     trace exactly as the sequential schedule orders it;
#   * stages communicate through a host-level environment: each stage's
#     written HBM buffers feed the next stage's inputs.
#
# Interior @grid loops (the k-on-grid revisit trick) stay exclusive to
# the GEMM path — in a multi-nest kernel they would need cross-stage
# revisit reasoning, so the general emitter refuses them.


def _stage_io(stmts: Sequence[Stmt]) -> Tuple[List[str], List[str]]:
    """(read, written) HBM buffer names under ``stmts``, in first-use
    order.  The carry of a ScanTile counts as read *and* written."""
    read: List[str] = []
    written: List[str] = []

    def go(ss):
        for s in ss:
            if isinstance(s, Loop):
                go(s.body)
                continue
            w = {r.buffer.name for r in _stmt_written_refs(s)}
            for r in _stmt_refs(s):
                if r.buffer.space != MemSpace.HBM:
                    continue
                tgt = written if r.buffer.name in w else read
                if r.buffer.name not in tgt:
                    tgt.append(r.buffer.name)
            if isinstance(s, (MatmulTile, ReduceTile)) and s.accumulate \
                    and s.dst.buffer.space == MemSpace.HBM:
                raise EmitError(
                    f"stage accumulates into HBM buffer "
                    f"{s.dst.buffer.name} (schedule an accumulator)")
    go(stmts)
    return read, written


def _emit_stage(kernel: Kernel, top: Stmt, buffers: Dict[str, "Buffer"],
                interpret: bool):
    """Build ``stage(env) -> None`` executing one top-level statement as
    a pallas_call over the host-level buffer environment."""
    # 1. peel the leading @grid chain
    grid_vars: List[str] = []
    grid: List[int] = []
    cur = top
    while isinstance(cur, Loop) and cur.kind == LoopKind.GRID:
        grid_vars.append(cur.var.name)
        grid.append(cur.var.extent)
        if len(cur.body) == 1 and isinstance(cur.body[0], Loop) \
                and cur.body[0].kind == LoopKind.GRID:
            cur = cur.body[0]
        else:
            break
    inner: List[Stmt] = list(cur.body) if isinstance(cur, Loop) \
        and cur.kind == LoopKind.GRID else [cur]
    for s in inner:
        for n in _walk_stmts([s]):
            if isinstance(n, Loop) and n.kind == LoopKind.GRID:
                raise EmitError(
                    f"{kernel.name}: interior @grid loop %{n.var.name} "
                    f"(k-on-grid is a single-nest schedule)")

    reads, writes = _stage_io([top])
    if not writes:
        raise EmitError(f"{kernel.name}: stage writes no HBM buffer")
    # the non-grid loops unroll at trace time: refuse schedules so
    # scalar the trace would blow up (tile-1 nested GEMM belongs to the
    # XLA backend, not pallas)
    traced = _traced_stmts(inner)
    if traced > 4096:
        raise EmitError(
            f"{kernel.name}: stage would trace {traced} statements "
            f"(grid-map or tile the schedule first)")
    scratch = [b for b in kernel.scratch
               if b.name in {r.buffer.name for s in _walk_stmts([top])
                             if not isinstance(s, Loop)
                             for r in _stmt_refs(s)}]

    def body(*refs):
        ref_of = dict(zip(reads + writes, refs))
        local: Dict[str, jax.Array] = {
            b.name: jnp.zeros(b.shape, _JNP_DTYPE[b.type.dtype])
            for b in scratch}

        def read(r: TileRef, env):
            starts = [e.evaluate(env) * t
                      for e, t in zip(r.index, r.tile)]
            if r.buffer.name in local:
                return jax.lax.dynamic_slice(local[r.buffer.name], starts,
                                             r.tile)
            ref = ref_of[r.buffer.name]
            return ref[tuple(pl.dslice(o, t)
                             for o, t in zip(starts, r.tile))]

        def write(r: TileRef, env, val):
            starts = [e.evaluate(env) * t
                      for e, t in zip(r.index, r.tile)]
            if r.buffer.name in local:
                local[r.buffer.name] = jax.lax.dynamic_update_slice(
                    local[r.buffer.name],
                    val.astype(local[r.buffer.name].dtype), starts)
                return
            ref = ref_of[r.buffer.name]
            idx = tuple(pl.dslice(o, t) for o, t in zip(starts, r.tile))
            ref[idx] = val.astype(ref.dtype)

        def exec_stmt(s: Stmt, env):
            if isinstance(s, ZeroTile):
                write(s.dst, env, jnp.zeros(s.dst.tile, jnp.float32))
            elif isinstance(s, FillTile):
                write(s.dst, env,
                      jnp.full(s.dst.tile, s.value, jnp.float32))
            elif isinstance(s, MatmulTile):
                c = jnp.dot(read(s.lhs, env), read(s.rhs, env),
                            preferred_element_type=jnp.float32)
                if s.accumulate:
                    c = read(s.dst, env).astype(jnp.float32) + c
                write(s.dst, env, c)
            elif isinstance(s, ReduceTile):
                r = (jnp.max if s.kind == "max" else jnp.sum)(
                    read(s.src, env), axis=-1, keepdims=True)
                if s.accumulate:
                    d = read(s.dst, env)
                    r = jnp.maximum(d, r) if s.kind == "max" else d + r
                write(s.dst, env, r)
            elif isinstance(s, ScanTile):
                srcs = [read(r, env) for r in s.srcs]

                def step(c, row):
                    if s.kind == "linear":
                        c = row[0] * c + row[1]
                    else:
                        c = c + row[0]
                    return c, c

                carry0 = read(s.carry, env)[0]
                last, out = jax.lax.scan(step, carry0, tuple(srcs))
                write(s.dst, env, out)
                write(s.carry, env, last[None])
            elif isinstance(s, EwiseTile):
                if s.op == "ones":
                    write(s.dst, env, jnp.ones(s.dst.tile, jnp.float32))
                    return
                srcs = [read(r, env) for r in s.srcs]
                if s.op == "copy1":
                    write(s.dst, env, srcs[0].reshape(s.dst.tile))
                    return
                if s.op == "cast":
                    write(s.dst, env, srcs[0])
                    return
                if len(srcs) == 2 and srcs[1].ndim < srcs[0].ndim:
                    srcs[1] = srcs[1][(None,) * (srcs[0].ndim
                                                 - srcs[1].ndim)]
                write(s.dst, env, _EWISE_JNP[s.op](*srcs))
            else:
                raise EmitError(
                    f"{kernel.name}: no pallas emission for "
                    f"{type(s).__name__}")

        def go(stmts, env):
            for s in stmts:
                if isinstance(s, Loop):
                    for t in range(s.var.extent):
                        go(s.body, {**env, s.var.name: t})
                else:
                    exec_stmt(s, env)

        env0 = {v: pl.program_id(i) for i, v in enumerate(grid_vars)}
        go(inner, env0)

    specs = {n: pl.BlockSpec(buffers[n].shape,
                             (lambda rank: lambda *g: (0,) * rank)(
                                 len(buffers[n].shape)))
             for n in reads + writes}
    call = pl.pallas_call(
        body,
        grid=tuple(grid) or (1,),
        in_specs=[specs[n] for n in reads],
        out_specs=[specs[n] for n in writes],
        out_shape=[jax.ShapeDtypeStruct(buffers[n].shape,
                                        _JNP_DTYPE[buffers[n].type.dtype])
                   for n in writes],
        interpret=interpret,
    )

    def stage(env: Dict[str, jax.Array]) -> None:
        outs = call(*[env[n] for n in reads])
        for n, a in zip(writes, outs):
            env[n] = a

    stage.reads, stage.writes = reads, writes
    return stage


def _walk_stmts(stmts):
    for s in stmts:
        yield s
        if isinstance(s, Loop):
            yield from _walk_stmts(s.body)


def _traced_stmts(stmts) -> int:
    """Leaf statements the stage body will trace (loop trips multiply)."""
    n = 0
    for s in stmts:
        if isinstance(s, Loop):
            n += s.var.extent * _traced_stmts(s.body)
        else:
            n += 1
    return n


def emit_general(kernel: Kernel,
                 interpret: bool = True) -> Callable[..., jax.Array]:
    """Emit a multi-nest kernel as a chain of per-nest pallas_calls."""
    kernel.verify()
    if len(kernel.outputs) != 1:
        raise EmitError(f"{kernel.name}: exactly one output supported")
    buffers = {b.name: b for b in kernel.params + kernel.scratch}
    stages = [_emit_stage(kernel, top, buffers, interpret)
              for top in kernel.body]
    out_name = kernel.outputs[0].name
    out_names = {b.name for b in kernel.outputs}
    in_params = [b for b in kernel.params if b.name not in out_names]

    def fn(*inputs):
        if len(inputs) > len(in_params):
            raise ValueError(
                f"{kernel.name}: expected <= {len(in_params)} inputs")
        env: Dict[str, jax.Array] = {}
        it = iter(inputs)
        for b in kernel.params:
            if b.name in out_names:
                env[b.name] = jnp.zeros(b.shape, _JNP_DTYPE[b.type.dtype])
                continue
            try:
                env[b.name] = jnp.asarray(next(it),
                                          _JNP_DTYPE[b.type.dtype])
            except StopIteration:
                env[b.name] = jnp.zeros(b.shape, _JNP_DTYPE[b.type.dtype])
        for stage in stages:
            stage(env)
        return env[out_name]

    fn.__name__ = f"stagecc_pallas_{kernel.name}"
    fn.plan = None                       # general path has no _Plan
    fn.stages = stages                   # introspection for tests
    return fn
