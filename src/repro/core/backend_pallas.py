"""Pallas backend: emit a ``pl.pallas_call`` TPU kernel from scheduled LoopIR.

This is the RTL-emission stage of the paper's pipeline (Calyx -> System
Verilog): the scheduled LoopIR's GRID loops become the pallas grid, tile
shapes become BlockSpecs (explicit VMEM tiling), and the statement body
becomes the kernel body executed per grid step by the Mosaic "synthesis"
layer.

Like Calyx, the emitter accepts a *structured subset* of the IR — the
shapes produced by ``lowering.py`` + ``schedule.py`` for contraction
kernels:

    Loop(g0 @grid) { Loop(g1 @grid) { [Loop(g2 @grid)]
        [ZeroTile(acc)]
        ( Loop(k @seq|@unrolled) { MatmulTile(acc, A, B) } | MatmulTile )
        [EwiseTile epilogue ...]*
        [EwiseTile copy -> HBM out]
    }}}

Two canonical layouts fall out of the schedules, mirroring the paper:

  * ``(i, j)`` grid, K inside the block  — the *inner-flattened* analogue:
    each grid step holds a full ``(tm, K)``/(``K, tn``) stripe in VMEM, so
    VMEM consumption grows with K (Fig. 3(b): resources ∝ size);
  * ``(i, j, k)`` grid                   — the *nested* analogue: one
    ``(tm, tk)`` tile per step, one output tile time-multiplexed across
    the k grid dimension (Fig. 3(a): constant resources, datapath reuse).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .loop_ir import (EwiseTile, Kernel, Loop, LoopKind, MatmulTile, MemSpace,
                      Stmt, TileRef, ZeroTile)
from .backend_jax import _EWISE_JNP, _JNP_DTYPE


class EmitError(NotImplementedError):
    """Raised when a kernel is outside the emitter's structured subset."""


@dataclasses.dataclass
class _Plan:
    grid_vars: List[str]                 # outer -> inner
    grid: Tuple[int, ...]
    inner_body: List[Stmt]
    k_loop: Optional[Loop]               # reduction loop inside block, if any
    k_grid_var: Optional[str]            # reduction on the grid, if any
    in_buffers: List[str]
    out_buffer: str
    block_specs: Dict[str, Tuple[Tuple[int, ...], Tuple[object, ...]]]
    acc_name: Optional[str]
    matmul: Optional[MatmulTile] = None


def _analyze(kernel: Kernel) -> _Plan:
    kernel.verify()
    # 1. peel GRID loops
    grid_vars: List[str] = []
    grid: List[int] = []
    stmts = kernel.body
    if len(stmts) != 1 or not isinstance(stmts[0], Loop):
        raise EmitError(f"{kernel.name}: body must be a single loop nest")
    cur: Stmt = stmts[0]
    while isinstance(cur, Loop) and cur.kind == LoopKind.GRID:
        grid_vars.append(cur.var.name)
        grid.append(cur.var.extent)
        if len(cur.body) == 1 and isinstance(cur.body[0], Loop) \
                and cur.body[0].kind == LoopKind.GRID:
            cur = cur.body[0]
        else:
            inner = cur.body
            break
    else:
        raise EmitError(f"{kernel.name}: no GRID loops — run a schedule first")

    if not grid_vars:
        raise EmitError(f"{kernel.name}: no GRID loops")

    # 2. classify the inner statements
    acc_name = None
    k_loop = None
    k_grid_var = None
    matmul: Optional[MatmulTile] = None
    epilogue: List[EwiseTile] = []
    for s in inner:
        if isinstance(s, ZeroTile):
            if s.dst.buffer.space == MemSpace.VREG:
                acc_name = s.dst.buffer.name
            # Zero of the HBM out with a k grid var is implicit (pl.when)
        elif isinstance(s, Loop):
            if len(s.body) != 1 or not isinstance(s.body[0], MatmulTile):
                raise EmitError(f"{kernel.name}: reduction loop body must be "
                                f"a single MatmulTile")
            if s.kind == LoopKind.GRID:
                # reduction mapped onto the grid (time-multiplexed schedule):
                # hoist it as the innermost grid dimension; accumulation
                # becomes pl.when-guarded updates of the revisited out block.
                grid_vars.append(s.var.name)
                grid.append(s.var.extent)
                k_grid_var = s.var.name
                matmul = s.body[0]
                continue
            if k_loop is not None or s.kind not in (LoopKind.SEQUENTIAL,
                                                    LoopKind.UNROLLED):
                raise EmitError(f"{kernel.name}: unsupported inner loop {s.var}")
            k_loop = s
            matmul = s.body[0]
        elif isinstance(s, MatmulTile):
            matmul = s
            kvars = [v for e in (*s.lhs.index, *s.rhs.index)
                     for v, _ in e.coeffs if v in grid_vars[2:]]
            if kvars:
                k_grid_var = kvars[0]
        elif isinstance(s, EwiseTile):
            epilogue.append(s)
        else:
            raise EmitError(f"{kernel.name}: unsupported stmt {s}")
    if matmul is None:
        raise EmitError(f"{kernel.name}: no MatmulTile found")
    # a 3-long grid means k lives on the grid
    if len(grid_vars) == 3:
        k_grid_var = grid_vars[2]

    # HBM buffers *written* inside the block that are not the kernel
    # output are SSA temporaries left by fusion; the emitter forwards
    # their values through registers instead of materialising them
    # (the codegen equivalent of Calyx wiring cells directly).
    out_names_ = {b.name for b in kernel.outputs}
    written = set()
    for s in inner:
        if isinstance(s, (ZeroTile, MatmulTile, EwiseTile)) \
                and s.dst.buffer.space == MemSpace.HBM \
                and s.dst.buffer.name not in out_names_:
            written.add(s.dst.buffer.name)

    # 3. build block specs for every HBM buffer touched
    inner_vars = {} if k_loop is None else {k_loop.var.name: k_loop.var.extent}
    specs: Dict[str, Tuple[Tuple[int, ...], Tuple[object, ...]]] = {}

    def visit(ref: TileRef):
        if ref.buffer.space != MemSpace.HBM or ref.buffer.name in written:
            return
        block: List[int] = []
        imap: List[object] = []   # either a grid-var name or 0
        for d, e in enumerate(ref.index):
            t = ref.tile[d]
            if not e.coeffs:
                # constant index: block covers [const*t, const*t + t)
                if e.const != 0:
                    raise EmitError(f"{kernel.name}: non-zero const index")
                block.append(t)
                imap.append(0)
            elif len(e.coeffs) == 1:
                v, stride = e.coeffs[0]
                if stride != 1:
                    raise EmitError(f"{kernel.name}: strided index on {v}")
                if v in grid_vars:
                    block.append(t)
                    imap.append(v)
                elif v in inner_vars:
                    block.append(t * inner_vars[v])
                    imap.append(0)
                else:
                    raise EmitError(f"{kernel.name}: unbound index var {v}")
            else:
                raise EmitError(f"{kernel.name}: multi-var affine index "
                                f"(apply split+grid only)")
        prev = specs.get(ref.buffer.name)
        spec = (tuple(block), tuple(imap))
        if prev is not None and prev != spec:
            raise EmitError(f"{kernel.name}: inconsistent refs to "
                            f"{ref.buffer.name}: {prev} vs {spec}")
        specs[ref.buffer.name] = spec

    for s in inner:
        if isinstance(s, Loop):
            for b in s.body:
                if isinstance(b, MatmulTile):
                    visit(b.dst), visit(b.lhs), visit(b.rhs)
        elif isinstance(s, ZeroTile):
            visit(s.dst)
        elif isinstance(s, MatmulTile):
            visit(s.dst), visit(s.lhs), visit(s.rhs)
        elif isinstance(s, EwiseTile):
            visit(s.dst)
            for r in s.srcs:
                visit(r)

    out_names = [b.name for b in kernel.outputs]
    if len(out_names) != 1:
        raise EmitError(f"{kernel.name}: exactly one output supported")
    out = out_names[0]
    ins = [b.name for b in kernel.params
           if b.name in specs and b.name != out]
    return _Plan(grid_vars=grid_vars, grid=tuple(grid), inner_body=inner,
                 k_loop=k_loop, k_grid_var=k_grid_var, in_buffers=ins,
                 out_buffer=out, block_specs=specs, acc_name=acc_name,
                 matmul=matmul)


def emit(kernel: Kernel, interpret: bool = True) -> Callable[..., jax.Array]:
    """Emit ``f(*hbm_inputs) -> out`` as a pallas_call.

    ``interpret=True`` (default here) runs the kernel body in the pallas
    interpreter so it is exact on CPU; on real TPU pass ``interpret=False``
    to lower through Mosaic.
    """
    plan = _analyze(kernel)
    buffers = {b.name: b for b in kernel.params + kernel.scratch}
    out_buf = buffers[plan.out_buffer]
    out_dtype = _JNP_DTYPE[out_buf.type.dtype]
    gpos = {v: i for i, v in enumerate(plan.grid_vars)}

    def mk_index_map(imap):
        def index_map(*gids):
            return tuple(gids[gpos[v]] if isinstance(v, str) else 0
                         for v in imap)
        return index_map

    in_specs = []
    for name in plan.in_buffers:
        block, imap = plan.block_specs[name]
        in_specs.append(pl.BlockSpec(block, mk_index_map(imap)))
    out_block, out_imap = plan.block_specs[plan.out_buffer]
    out_spec = pl.BlockSpec(out_block, mk_index_map(out_imap))

    mm = plan.matmul
    tm, tk = mm.lhs.tile[-2:]
    tn = mm.rhs.tile[-1]
    lhs_name, rhs_name = mm.lhs.buffer.name, mm.rhs.buffer.name
    k_on_grid = plan.k_grid_var is not None
    k_extent = plan.k_loop.var.extent if plan.k_loop is not None else 1
    k_unrolled = (plan.k_loop is not None
                  and plan.k_loop.kind == LoopKind.UNROLLED)
    # which dim of each operand block the k sub-tiling walks
    epilogue = [s for s in plan.inner_body if isinstance(s, EwiseTile)]

    def body(*refs):
        ref_of = dict(zip(plan.in_buffers + [plan.out_buffer], refs))
        a_ref, b_ref = ref_of[lhs_name], ref_of[rhs_name]
        o_ref = ref_of[plan.out_buffer]

        def dot_k(kk):
            a = a_ref[..., :, pl.dslice(kk * tk, tk)] if k_extent > 1 else a_ref[...]
            b = b_ref[pl.dslice(kk * tk, tk), :] if k_extent > 1 else b_ref[...]
            return jnp.dot(a, b, preferred_element_type=jnp.float32)

        if k_on_grid:
            k_id = pl.program_id(gpos[plan.k_grid_var])

            @pl.when(k_id == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] = o_ref[...] + dot_k(0).astype(out_dtype)
            last = pl.num_programs(gpos[plan.k_grid_var]) - 1
            if epilogue:
                @pl.when(k_id == last)
                def _epi():
                    o_ref[...] = _apply_epilogue(
                        epilogue, o_ref[...], ref_of, plan).astype(out_dtype)
        else:
            acc = jnp.zeros((tm, tn), jnp.float32)
            if k_unrolled or k_extent <= 4:
                for kk in range(k_extent):
                    acc = acc + dot_k(kk)
            else:
                acc = jax.lax.fori_loop(
                    0, k_extent, lambda kk, c: c + dot_k(kk), acc)
            acc = _apply_epilogue(epilogue, acc, ref_of, plan)
            o_ref[...] = acc.astype(out_dtype)

    fname = f"stagecc_pallas_{kernel.name}"
    call = pl.pallas_call(
        body,
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_buf.shape, out_dtype),
        interpret=interpret,
    )

    def fn(*inputs):
        by_name = dict(zip(plan.in_buffers, inputs))
        args = [jnp.asarray(by_name[n], _JNP_DTYPE[buffers[n].type.dtype])
                for n in plan.in_buffers]
        return call(*args)

    fn.__name__ = fname
    fn.plan = plan  # exposed for tests / resource introspection
    return fn


def _apply_epilogue(epilogue: Sequence[EwiseTile], acc, ref_of, plan: _Plan):
    """Apply fused elementwise tail ops to the accumulator value.

    HBM temporaries introduced by fusion are forwarded through a local
    SSA environment (``local``) and never materialised.
    """
    local: Dict[str, object] = {}
    if plan.acc_name is not None:
        local[plan.acc_name] = acc
    val = acc
    for s in epilogue:
        srcs = []
        for r in s.srcs:
            if r.buffer.name in local:
                srcs.append(local[r.buffer.name])
            elif r.buffer.name == plan.out_buffer:
                srcs.append(val)
            elif r.buffer.name in ref_of:
                srcs.append(ref_of[r.buffer.name][...])
            else:
                raise EmitError(f"epilogue src {r.buffer.name} not mapped")
        if len(srcs) == 2 and getattr(srcs[1], "ndim", 0) < srcs[0].ndim:
            srcs[1] = srcs[1][(None,) * (srcs[0].ndim - srcs[1].ndim)]
        v = srcs[0] if s.op == "copy" else _EWISE_JNP[s.op](*srcs)
        local[s.dst.buffer.name] = v
        val = v
    if plan.out_buffer in local:
        return local[plan.out_buffer]
    return val
