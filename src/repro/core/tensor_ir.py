"""TensorIR — level-1 (algorithmic) dialect of the stagecc compiler stack.

This is the MLIR-linalg analogue in the paper's pipeline (Fig. 1):
SYCL -> [DPC++] -> MLIR -> CIRCT/Calyx -> RTL
            here:  TensorIR -> LoopIR -> {ref | jax | pallas}

TensorIR is an SSA graph of whole-tensor operations with static shapes.
It is deliberately small: the ops below cover the contraction-plus-
epilogue family the paper's GEMM case study lives in, and the op set is
extensible through ``register_op`` (the paper's "reusable & extensible"
requirement).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Types and values
# --------------------------------------------------------------------------

_DTYPES = ("float32", "bfloat16", "float16", "int32", "int8")


@dataclasses.dataclass(frozen=True)
class TensorType:
    shape: Tuple[int, ...]
    dtype: str = "float32"

    def __post_init__(self):
        if self.dtype not in _DTYPES:
            raise TypeError(f"unsupported dtype {self.dtype!r}")
        if any((not isinstance(d, (int, np.integer))) or d <= 0 for d in self.shape):
            raise TypeError(f"bad shape {self.shape!r}")
        object.__setattr__(self, "shape", tuple(int(d) for d in self.shape))

    @property
    def rank(self) -> int:
        return len(self.shape)

    @property
    def nelems(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.nelems * dtype_bytes(self.dtype)

    def __str__(self):
        dims = "x".join(str(d) for d in self.shape)
        return f"tensor<{dims}x{self.dtype}>" if self.shape else f"tensor<{self.dtype}>"


def dtype_bytes(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4, "int8": 1}[dtype]


@dataclasses.dataclass(eq=False)
class Value:
    """SSA value. Identity-hashed; ``producer`` is set by the graph builder."""

    name: str
    type: TensorType
    producer: Optional["Op"] = dataclasses.field(default=None, repr=False)

    def __str__(self):
        return f"%{self.name}: {self.type}"


# --------------------------------------------------------------------------
# Op registry — the extensibility mechanism
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpDef:
    """Definition of a TensorIR op.

    ``infer`` maps (input types, attrs) -> result type and doubles as the
    verifier: it must raise on ill-typed operands.
    """

    name: str
    infer: Callable[[Sequence[TensorType], Dict[str, Any]], TensorType]
    # numpy semantics, used by the TensorIR-level interpreter (oracle).
    eval_np: Callable[..., np.ndarray]


OP_REGISTRY: Dict[str, OpDef] = {}


def register_op(name: str, infer, eval_np) -> OpDef:
    if name in OP_REGISTRY:
        raise ValueError(f"op {name!r} already registered")
    opdef = OpDef(name, infer, eval_np)
    OP_REGISTRY[name] = opdef
    return opdef


# ---- standard op definitions ----------------------------------------------


def _infer_matmul(in_types, attrs):
    a, b = in_types
    if a.rank != 2 or b.rank != 2:
        raise TypeError(f"matmul needs rank-2 operands, got {a} @ {b}")
    if a.shape[1] != b.shape[0]:
        raise TypeError(f"matmul contraction mismatch: {a} @ {b}")
    if a.dtype != b.dtype:
        raise TypeError(f"matmul dtype mismatch: {a} @ {b}")
    acc = attrs.get("acc_dtype", "float32")
    return TensorType((a.shape[0], b.shape[1]), acc)


def _infer_ewise_binary(in_types, attrs):
    a, b = in_types
    if a.shape != b.shape and b.shape != ():
        # numpy-style broadcast of the SECOND operand only, restricted to
        # size-1 dims (e.g. an (M, N) map against per-row (M, 1) statistics
        # — the online-softmax normalisation shape)
        ok = (b.rank == a.rank
              and all(db == da or db == 1
                      for da, db in zip(a.shape, b.shape)))
        if not ok:
            raise TypeError(f"elementwise shape mismatch: {a} vs {b}")
    if a.dtype != b.dtype:
        raise TypeError(f"elementwise dtype mismatch: {a} vs {b}")
    return a


def _infer_ewise_unary(in_types, attrs):
    (a,) = in_types
    return a


def _infer_bias_add(in_types, attrs):
    a, b = in_types
    if b.rank != 1 or b.shape[0] != a.shape[-1]:
        raise TypeError(f"bias_add: bias {b} does not match {a}")
    return a


def _infer_reduce_sum(in_types, attrs):
    (a,) = in_types
    axis = attrs["axis"]
    shape = tuple(d for i, d in enumerate(a.shape) if i != axis)
    return TensorType(shape, a.dtype)


#: reduction kinds with their combine semantics and identity element
REDUCE_KINDS = ("max", "sum")
#: scan kinds: ``linear`` is the carried recurrence h_t = a_t*h_{t-1}+x_t
#: (the SSD/Mamba state update); ``cumsum`` is the a_t == 1 special case
SCAN_KINDS = ("linear", "cumsum")
#: identity element of a max reduction (matches the hand-written kernels'
#: _NEG so masked attention rows behave identically through both paths)
REDUCE_NEG_INF = -1e30


def reduce_identity(kind: str) -> float:
    return REDUCE_NEG_INF if kind == "max" else 0.0


def _infer_reduce(in_types, attrs):
    (a,) = in_types
    kind = attrs.get("kind")
    if kind not in REDUCE_KINDS:
        raise TypeError(f"reduce: kind must be one of {REDUCE_KINDS}, "
                        f"got {kind!r}")
    axis = attrs.get("axis")
    if not isinstance(axis, (int, np.integer)) or not 0 <= axis < a.rank:
        raise TypeError(f"reduce: axis {axis!r} out of range for {a}")
    keepdims = attrs.get("keepdims", True)
    if keepdims:
        shape = tuple(1 if i == axis else d for i, d in enumerate(a.shape))
    else:
        shape = tuple(d for i, d in enumerate(a.shape) if i != axis)
    return TensorType(shape, a.dtype)


def _eval_reduce(a, **at):
    fn = np.max if at["kind"] == "max" else np.sum
    return fn(a, axis=at["axis"], keepdims=at.get("keepdims", True))


def _infer_scan(in_types, attrs):
    kind = attrs.get("kind")
    if kind not in SCAN_KINDS:
        raise TypeError(f"scan: kind must be one of {SCAN_KINDS}, "
                        f"got {kind!r}")
    if kind == "linear":
        if len(in_types) != 2:
            raise TypeError(f"scan<linear> takes (decay, update) operands, "
                            f"got {len(in_types)}")
        a, x = in_types
        if a.shape != x.shape or a.dtype != x.dtype:
            raise TypeError(f"scan: carry-shape mismatch: decay {a} vs "
                            f"update {x}")
    else:
        if len(in_types) != 1:
            raise TypeError(f"scan<cumsum> takes one operand, "
                            f"got {len(in_types)}")
        x = in_types[0]
    axis = attrs.get("axis")
    if not isinstance(axis, (int, np.integer)) or not 0 <= axis < x.rank:
        raise TypeError(f"scan: axis {axis!r} out of range for {x}")
    return x


def _eval_scan(*arrays, **at):
    axis = at["axis"]
    if at["kind"] == "cumsum":
        return np.cumsum(arrays[0], axis=axis)
    a, x = (np.moveaxis(np.asarray(v), axis, 0) for v in arrays)
    h = np.zeros_like(x)
    carry = np.zeros_like(x[0])
    for t in range(x.shape[0]):
        carry = a[t] * carry + x[t]
        h[t] = carry
    return np.moveaxis(h, 0, axis)


def _infer_transpose(in_types, attrs):
    (a,) = in_types
    perm = attrs["perm"]
    if sorted(perm) != list(range(a.rank)):
        raise TypeError(f"bad perm {perm} for {a}")
    return TensorType(tuple(a.shape[p] for p in perm), a.dtype)


def _infer_cast(in_types, attrs):
    (a,) = in_types
    return TensorType(a.shape, attrs["dtype"])


register_op("matmul", _infer_matmul, lambda a, b, **at: (
    np.asarray(a, np.float32) @ np.asarray(b, np.float32)))
register_op("add", _infer_ewise_binary, lambda a, b, **at: a + b)
register_op("sub", _infer_ewise_binary, lambda a, b, **at: a - b)
register_op("mul", _infer_ewise_binary, lambda a, b, **at: a * b)
register_op("maximum", _infer_ewise_binary, lambda a, b, **at: np.maximum(a, b))
register_op("div", _infer_ewise_binary, lambda a, b, **at: a / b)
register_op("relu", _infer_ewise_unary, lambda a, **at: np.maximum(a, 0))
register_op("gelu", _infer_ewise_unary, lambda a, **at: (
    0.5 * a * (1.0 + np.tanh(np.sqrt(2.0 / np.pi) * (a + 0.044715 * a**3)))))
register_op("exp", _infer_ewise_unary, lambda a, **at: np.exp(a))
register_op("neg", _infer_ewise_unary, lambda a, **at: -a)
register_op("tanh", _infer_ewise_unary, lambda a, **at: np.tanh(a))
register_op("sigmoid", _infer_ewise_unary,
            lambda a, **at: 1.0 / (1.0 + np.exp(-a)))
register_op("sqrt", _infer_ewise_unary, lambda a, **at: np.sqrt(a))
register_op("rsqrt", _infer_ewise_unary, lambda a, **at: 1.0 / np.sqrt(a))
register_op("log1p", _infer_ewise_unary, lambda a, **at: np.log1p(a))
register_op("abs", _infer_ewise_unary, lambda a, **at: np.abs(a))
register_op("bias_add", _infer_bias_add, lambda a, b, **at: a + b[None, :])
register_op("reduce_sum", _infer_reduce_sum,
            lambda a, **at: np.sum(a, axis=at["axis"]))
register_op("reduce", _infer_reduce, _eval_reduce)
register_op("scan", _infer_scan, _eval_scan)
register_op("transpose", _infer_transpose,
            lambda a, **at: np.transpose(a, at["perm"]))
register_op("cast", _infer_cast, lambda a, **at: a.astype(at["dtype"]
            if at["dtype"] != "bfloat16" else np.float32))


# --------------------------------------------------------------------------
# Ops and graphs
# --------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class Op:
    opname: str
    inputs: List[Value]
    attrs: Dict[str, Any]
    result: Value

    def __str__(self):
        from . import ir_text
        return ir_text.print_op(self)

    # ---- rewrite-core structural protocol (see core/rewrite.py) -----------

    def children(self) -> List["Op"]:
        return []

    def rebuild(self, children: Sequence["Op"]) -> "Op":
        assert not children
        return Op(self.opname, list(self.inputs), dict(self.attrs),
                  self.result)

    def is_equivalent(self, other) -> bool:
        from . import ir_text
        return isinstance(other, Op) and \
            ir_text.print_op(self) == ir_text.print_op(other)


class Graph:
    """A TensorIR function: ordered SSA ops over named inputs."""

    def __init__(self, name: str):
        self.name = name
        self.inputs: List[Value] = []
        self.ops: List[Op] = []
        self.outputs: List[Value] = []
        self._counter = 0

    # ---- builder API -------------------------------------------------------

    def add_input(self, name: str, type: TensorType) -> Value:
        v = Value(name, type)
        self.inputs.append(v)
        return v

    def fresh_name(self, hint: str = "v") -> str:
        self._counter += 1
        return f"{hint}{self._counter}"

    def emit(self, opname: str, inputs: Sequence[Value], **attrs) -> Value:
        if opname not in OP_REGISTRY:
            raise KeyError(f"unknown op {opname!r}; registered: {sorted(OP_REGISTRY)}")
        opdef = OP_REGISTRY[opname]
        rtype = opdef.infer([v.type for v in inputs], attrs)
        res = Value(self.fresh_name(opname), rtype)
        op = Op(opname, list(inputs), dict(attrs), res)
        res.producer = op
        self.ops.append(op)
        return res

    def set_outputs(self, *values: Value):
        self.outputs = list(values)

    # ---- rewrite-core structural protocol (see core/rewrite.py) -----------

    def children(self) -> List[Op]:
        """The graph's mutable op list (the rewrite driver splices it)."""
        return self.ops

    def rebuild(self, children: Sequence[Op]) -> "Graph":
        g = Graph(self.name)
        g.inputs = list(self.inputs)
        g.ops = list(children)
        g.outputs = list(self.outputs)
        g._counter = self._counter
        return g

    def is_equivalent(self, other) -> bool:
        """Structural equivalence: identical canonical textual form."""
        from . import ir_text
        return isinstance(other, Graph) and \
            ir_text.print_graph(self) == ir_text.print_graph(other)

    # ---- verification ------------------------------------------------------

    def verify(self) -> None:
        """SSA well-formedness: defs precede uses, types re-infer identically."""
        defined = {id(v) for v in self.inputs}
        for op in self.ops:
            for v in op.inputs:
                if id(v) not in defined:
                    raise ValueError(
                        f"use-before-def of %{v.name} in {op.opname} ({self.name})")
            opdef = OP_REGISTRY[op.opname]
            rtype = opdef.infer([v.type for v in op.inputs], op.attrs)
            if rtype != op.result.type:
                raise ValueError(
                    f"type mismatch on %{op.result.name}: stored {op.result.type}, "
                    f"inferred {rtype}")
            defined.add(id(op.result))
        for v in self.outputs:
            if id(v) not in defined:
                raise ValueError(f"output %{v.name} is not defined")

    # ---- oracle ------------------------------------------------------------

    def eval_np(self, *arrays: np.ndarray) -> List[np.ndarray]:
        """Reference interpretation with numpy — the top-level oracle."""
        if len(arrays) != len(self.inputs):
            raise ValueError(f"{self.name} expects {len(self.inputs)} inputs")
        env: Dict[int, np.ndarray] = {}
        for v, a in zip(self.inputs, arrays):
            if tuple(a.shape) != v.type.shape:
                raise ValueError(f"input %{v.name}: got shape {a.shape}, "
                                 f"expected {v.type.shape}")
            env[id(v)] = np.asarray(a)
        for op in self.ops:
            fn = OP_REGISTRY[op.opname].eval_np
            env[id(op.result)] = fn(*[env[id(v)] for v in op.inputs], **op.attrs)
        return [env[id(v)] for v in self.outputs]

    # ---- printing ----------------------------------------------------------

    def __str__(self):
        # canonical textual form lives in ir_text (it round-trips through
        # ir_text.parse_graph); delegate so str() and the parser can't drift.
        from . import ir_text
        return ir_text.print_graph(self)
