"""HwSim — cycle-accurate simulation of HwIR modules (the Vivado-sim role).

The paper validates its generated RTL two ways: numerically ("accurate
output matrices") and temporally (consumed clock cycles read off Vivado
simulation).  This module gives the reproduction's hardware level the
same property: an :class:`~repro.core.hw_ir.HwModule` *executes* against
real numpy inputs, and the run yields an **observed** cycle count that
can be cross-checked against the analytic ``machine_model.cycles``
prediction.

The interpreter walks the control tree exactly as the hardware would
sequence it:

  * ``@fsm`` / ``@stream`` loops step a counter register through their
    trips, paying the FSM state-transition chain each iteration;
  * ``@unroll`` / ``@simd`` bodies are spatially replicated — every copy
    executes (numerics are computed per replication index) but control
    is paid once, and ``@simd`` divides compute across VPU lanes;
  * each :class:`~repro.core.hw_ir.HwStep` invokes its datapath unit:
    the operand address generators (affine ``index`` over the enclosing
    counters) resolve to numpy slices of the port/mem/reg backing
    arrays, and the invocation is charged its unit latency.

Per-event latencies come from :func:`machine_model.step_cycles` — one
source of truth for unit timing, so model and simulation can only
diverge through *scheduling* effects (e.g. the double-buffered DMA
overlap of ``@stream`` loops, replayed here event-by-event), never
through inconsistent constants.  Fractional per-event cycles represent
pipelined initiation intervals; totals are rounded once at the end,
mirroring the analytic report.

``simulate`` runs a bare module; ``cosim`` additionally checks the
outputs against the LoopIR numpy oracle (``backend_ref``) and packages
observed-vs-modeled cycles.  The host-coupled transaction model (CSR +
crossbar DMA) lives in :mod:`repro.core.host_bridge`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import backend_ref, machine_model
from .backend_ref import _EWISE_NP, _np_dtype, reduce_tile_np, scan_tile_np
from .hw_ir import HwInstance, HwLoop, HwModule, HwOperand, HwStep
from .loop_ir import Kernel
from .machine_model import TPU_V5E, CycleReport, MachineModel


class SimError(RuntimeError):
    """Simulation could not run (bad inputs, inexecutable op, runaway)."""


class SimMismatch(SimError):
    """Co-simulation numeric mismatch against the reference backend."""


# --------------------------------------------------------------------------
# reports
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One retired event of the simulated schedule."""

    cycle: int                       # observed cycle at retirement
    kind: str                        # "step" | "loop" | "dma" | "call" | "done"
    label: str                       # state-ish label (unit.op / %counter)
    detail: str = ""
    env: Tuple[Tuple[str, int], ...] = ()   # counter bindings, sorted
    seq: int = 0                     # dynamic event ordinal

    def __str__(self):
        binds = " ".join(f"{c}={v}" for c, v in self.env)
        parts = [f"[{self.cycle:>10,}]", f"{self.kind:<4}", self.label]
        if binds:
            parts.append(f"({binds})")
        if self.detail:
            parts.append(f"  // {self.detail}")
        return " ".join(parts)


@dataclasses.dataclass
class SimReport:
    """Result of one module simulation: final storage state + observed
    cycle accounting + (optionally) the per-state event trace."""

    module: str
    storage: Dict[str, np.ndarray]   # final contents of every declaration
    out_ports: List[str]             # ports with direction out/inout
    cycles: CycleReport              # observed (event-accumulated)
    steps_retired: int
    fsm_transitions: int             # dynamic state transitions taken
    counters: List[str]              # sequenced-loop counter names
    trace: List[TraceEvent] = dataclasses.field(default_factory=list)
    trace_truncated: bool = False

    @property
    def outputs(self) -> List[np.ndarray]:
        """Contents of the write-channel ports, in port order."""
        return [self.storage[n] for n in self.out_ports]

    def summary(self) -> str:
        return (f"sim {self.module}: {self.cycles}, "
                f"steps={self.steps_retired:,}, "
                f"fsm_transitions={self.fsm_transitions:,}")

    def format_trace(self) -> str:
        lines = [f"// trace of {self.module}: {len(self.trace)} events"]
        lines += [str(ev) for ev in self.trace]
        if self.trace_truncated:
            lines.append("// ... trace truncated (max events reached)")
        return "\n".join(lines)

    def vcd(self) -> str:
        """VCD-style dump of the schedule: the dynamic step ordinal and
        every sequenced-loop counter, one timestamp per retired event.
        Toy-scale (readable in GTKWave), not a full four-state dump."""
        names = ["step"] + list(self.counters)
        sym = {n: chr(33 + i) for i, n in enumerate(names)}
        lines = [
            "$date stagecc hw_sim $end",
            "$timescale 1ns $end",
            f"$scope module {self.module} $end",
        ]
        for n in names:
            lines.append(f"$var wire 32 {sym[n]} {n} $end")
        lines.append("$upscope $end")
        lines.append("$enddefinitions $end")
        lines.append("#0")
        for n in names:
            lines.append(f"b0 {sym[n]}")
        # VCD requires strictly ascending timestamps; trace cycles can
        # step back when a @stream loop reclaims overlap credit at its
        # close, so clamp each emission to be monotone
        t = 0
        for ev in self.trace:
            if ev.kind not in ("step", "loop"):
                continue
            t = max(t + 1, ev.cycle)
            lines.append(f"#{t}")
            lines.append(f"b{ev.seq:b} {sym['step']}")
            for c, v in ev.env:
                if c in sym:
                    lines.append(f"b{v:b} {sym[c]}")
        lines.append(f"#{max(t + 1, self.cycles.total)}")
        return "\n".join(lines) + "\n"


@dataclasses.dataclass
class CoSimReport:
    """Observed-vs-modeled packaging of one co-simulation run."""

    sim: SimReport
    modeled_cycles: int
    observed_cycles: int
    checked: bool = False            # outputs compared against the oracle
    max_abs_err: float = float("nan")

    @property
    def outputs(self) -> List[np.ndarray]:
        return self.sim.outputs

    @property
    def cycle_ratio(self) -> float:
        return self.observed_cycles / max(1, self.modeled_cycles)

    def summary(self) -> str:
        s = (f"cosim {self.sim.module}: observed={self.observed_cycles:,} "
             f"cycles vs modeled={self.modeled_cycles:,} "
             f"(ratio {self.cycle_ratio:.4f}), "
             f"steps={self.sim.steps_retired:,}, "
             f"fsm_transitions={self.sim.fsm_transitions:,}")
        if self.checked:
            s += f", max|err|={self.max_abs_err:.1e} vs numpy oracle"
        return s


# --------------------------------------------------------------------------
# the interpreter
# --------------------------------------------------------------------------


class _Sim:
    def __init__(self, mod: HwModule, machine: MachineModel, trace: bool,
                 max_trace_events: int, max_steps: int):
        self.mod = mod
        self.m = machine
        self.want_trace = trace
        self.max_trace_events = max_trace_events
        self.max_steps = max_steps
        self.mem: Dict[str, np.ndarray] = {}
        self.clock = 0.0                 # observed cycle estimate
        self.steps = 0
        self.transitions = 0
        self.seq = 0
        self.trace: List[TraceEvent] = []
        self.trace_truncated = False

    # ---- storage ----------------------------------------------------------

    def bind(self, inputs: Sequence[np.ndarray]) -> None:
        inputs = list(inputs)
        it = iter(inputs)
        in_ports = [p for p in self.mod.ports if p.direction == "in"]
        if len(inputs) > len(in_ports):
            raise SimError(
                f"module {self.mod.name} has {len(in_ports)} input ports "
                f"but {len(inputs)} inputs were given")
        for p in self.mod.ports:
            dt = _np_dtype(p.dtype)
            if p.direction == "in":
                try:
                    a = np.asarray(next(it))
                except StopIteration:
                    # unbound input channel (HBM temporary): reads zeros
                    self.mem[p.name] = np.zeros(p.shape, dt)
                    continue
                if tuple(a.shape) != tuple(p.shape):
                    raise SimError(f"port {p.name}: input shape {a.shape} "
                                   f"!= {p.shape}")
                self.mem[p.name] = np.array(a, dtype=dt)
            else:
                # write channels start zeroed, like the oracle's outputs
                self.mem[p.name] = np.zeros(p.shape, dt)
        for r in self.mod.regs:
            self.mem[r.name] = np.zeros(r.shape, _np_dtype(r.dtype))
        for mm in self.mod.mems:
            self.mem[mm.name] = np.zeros(mm.shape, _np_dtype(mm.dtype))

    # ---- tracing ----------------------------------------------------------

    def _emit(self, kind: str, label: str, env: Dict[str, int],
              detail: str = "") -> None:
        if not self.want_trace:
            return
        if len(self.trace) >= self.max_trace_events:
            self.trace_truncated = True
            return
        self.seq += 1
        self.trace.append(TraceEvent(
            cycle=int(round(self.clock)), kind=kind, label=label,
            detail=detail, env=tuple(sorted(env.items())), seq=self.seq))

    # ---- execution --------------------------------------------------------

    def _slices(self, o: HwOperand, env: Dict[str, int]) -> Tuple[slice, ...]:
        shape = tuple(self.mod.storage(o.target).shape)
        return o.slices(shape, env)

    def _get(self, o: HwOperand, env: Dict[str, int]) -> np.ndarray:
        return self.mem[o.target][self._slices(o, env)]

    def _put(self, o: HwOperand, env: Dict[str, int], val) -> None:
        self.mem[o.target][self._slices(o, env)] = val

    def _exec_step(self, step: HwStep, env: Dict[str, int]) -> None:
        ops = step.operands
        if step.op == "zero":
            self._put(ops[0], env, 0.0)
        elif step.op == "ones":
            self._put(ops[0], env, 1.0)
        elif step.op == "fill_min":
            self._put(ops[0], env, -1e30)
        elif step.op in ("reduce_max", "reduce_sum"):
            dst, src = ops
            # shares the oracle's numpy expression so cosim is bitwise
            self._put(dst, env, reduce_tile_np(
                step.op[len("reduce_"):], self._get(dst, env),
                self._get(src, env), dst.role == "acc"))
        elif step.op in ("scan_linear", "scan_cumsum"):
            dst, carry = ops[0], ops[1]
            srcs = [self._get(o, env) for o in ops[2:]]
            out = scan_tile_np(step.op[len("scan_"):], srcs,
                               self._get(carry, env))
            self._put(dst, env, out)
            self._put(carry, env, out[-1:])
        elif step.op == "matmul":
            dst, lhs, rhs = ops
            c = (self._get(lhs, env).astype(np.float32)
                 @ self._get(rhs, env).astype(np.float32))
            if dst.role == "acc":
                c = self._get(dst, env) + c
            self._put(dst, env, c)
        else:
            dst, srcs = ops[0], [self._get(o, env) for o in ops[1:]]
            if step.op == "copy1":
                shape = self.mem[dst.target][self._slices(dst, env)].shape
                self._put(dst, env, srcs[0].reshape(shape))
            elif step.op == "cast":
                self._put(dst, env, srcs[0])   # numpy casts on assignment
            else:
                fn = _EWISE_NP.get(step.op)
                if fn is None:
                    raise SimError(f"step op {step.op!r} has no executable "
                                   f"semantics on unit {step.unit}")
                # broadcast rank-1 bias against rank-n tiles, as the
                # oracle does
                if len(srcs) == 2 and srcs[1].ndim < srcs[0].ndim:
                    srcs[1] = srcs[1][(None,) * (srcs[0].ndim - srcs[1].ndim)]
                self._put(dst, env, fn(*srcs))

    def run_block(self, nodes, env: Dict[str, int],
                  lanes: int) -> Dict[str, float]:
        acc = {"compute": 0.0, "memory": 0.0, "control": 0.0}
        for n in nodes:
            if isinstance(n, HwLoop):
                acc["control"] += self.m.loop_setup_cycles
                self.clock += self.m.loop_setup_cycles
                if n.kind in ("fsm", "stream"):
                    sub = {"compute": 0.0, "memory": 0.0, "control": 0.0}
                    for t in range(n.trips):
                        # the loop header state: test + counter increment
                        sub["control"] += self.m.seq_loop_overhead_cycles
                        self.clock += self.m.seq_loop_overhead_cycles
                        self.transitions += 1
                        self._emit("loop", f"%{n.counter}",
                                   {**env, n.counter: t},
                                   f"@{n.kind} trip {t}/{n.trips}")
                        body = self.run_block(n.body, {**env, n.counter: t},
                                              lanes)
                        for k in sub:
                            sub[k] += body[k]
                    if n.kind == "stream":
                        # double-buffered DMA: the grid sequencer overlaps
                        # the body's memory traffic with compute across
                        # steps; the engines run concurrently, so the
                        # loop's wall-clock is the busier of the two.
                        overlapped = max(sub["compute"], sub["memory"])
                        credit = (sub["compute"] + sub["memory"]
                                  - overlapped)
                        if credit > 0:
                            self.clock -= credit
                            self._emit("dma", f"%{n.counter}", env,
                                       f"stream overlap reclaimed "
                                       f"{credit:.1f} cycles")
                        sub = {"compute": overlapped, "memory": 0.0,
                               "control": sub["control"]}
                    for k in acc:
                        acc[k] += sub[k]
                else:
                    # unroll/simd: spatial replication — every copy
                    # computes (distinct replication index), control is
                    # paid once and no per-trip FSM transition exists
                    sub_lanes = lanes * n.trips if n.kind == "simd" else lanes
                    for t in range(n.trips):
                        self._emit("loop", f"%{n.counter}",
                                   {**env, n.counter: t},
                                   f"@{n.kind} copy {t}/{n.trips}")
                        body = self.run_block(n.body, {**env, n.counter: t},
                                              sub_lanes)
                        for k in acc:
                            acc[k] += body[k]
            elif isinstance(n, HwInstance):
                sub = self.mod.submodule(n.module)
                # port map: each submodule port becomes a numpy *view* of
                # the caller's storage slice, so writes land in place —
                # exactly one physical memory, accessed through the
                # instance's address map.  Local regs/mems reset per call.
                submem: Dict[str, np.ndarray] = {}
                for port, o in zip(sub.ports, n.portmap):
                    submem[port.name] = self.mem[o.target][
                        self._slices(o, env)]
                for r in sub.regs:
                    submem[r.name] = np.zeros(r.shape, _np_dtype(r.dtype))
                for mm in sub.mems:
                    submem[mm.name] = np.zeros(mm.shape, _np_dtype(mm.dtype))
                saved = (self.mod, self.mem)
                self.mod, self.mem = sub, submem
                try:
                    body = self.run_block(sub.ctrl, {}, lanes)
                finally:
                    self.mod, self.mem = saved
                for k in acc:
                    acc[k] += body[k]
                # start/done handshake of the call-site FSM state
                acc["control"] += self.m.call_overhead_cycles
                self.clock += self.m.call_overhead_cycles
                self.transitions += 1
                opnds = ",".join(o.target for o in n.portmap)
                self._emit("call", f"@{n.module}", env, f"({opnds})")
            else:
                self.steps += 1
                if self.steps > self.max_steps:
                    raise SimError(
                        f"simulation exceeded {self.max_steps:,} dynamic "
                        f"steps (runaway schedule?)")
                try:
                    self._exec_step(n, env)
                except IndexError as e:
                    # verify() bounds the whole iteration box, so this is
                    # a belt-and-braces escape hatch for hand-built
                    # modules that bypassed it
                    raise SimError(
                        f"address generator overran storage: {e}") from e
                c = machine_model.step_cycles(n, self.mod, self.m, lanes)
                acc["compute"] += c["compute"]
                acc["memory"] += c["memory"]
                # contention stall of a serialized shared-unit binding —
                # same formula the analytic model charges
                acc["control"] += c.get("control", 0.0)
                self.clock += (c["compute"] + c["memory"]
                               + c.get("control", 0.0))
                self.transitions += 1
                opnds = ",".join(o.target for o in n.operands)
                self._emit("step", f"{n.unit}.{n.op}", env, f"({opnds})")
        return acc


def simulate(mod: HwModule, inputs: Sequence[np.ndarray] = (),
             machine: MachineModel = TPU_V5E, trace: bool = False,
             max_trace_events: int = 65536,
             max_steps: int = 10_000_000) -> SimReport:
    """Execute ``mod`` cycle-accurately against ``inputs``.

    ``inputs`` bind the module's ``in``-direction ports in declaration
    order (missing trailing inputs read zeros — HBM temporaries); all
    write-channel ports, register banks and RAMs start zeroed.  Returns
    a :class:`SimReport` with the final storage state, the observed
    cycle accounting, and (when ``trace``) the retired-event trace.
    """
    mod.verify()
    sim = _Sim(mod, machine, trace, max_trace_events, max_steps)
    sim.bind(inputs)
    costs = sim.run_block(mod.ctrl, {}, 1)
    sim._emit("done", "S_IDLE", {}, "machine returned to idle")
    total = int(round(costs["compute"] + costs["memory"] + costs["control"]))
    report = CycleReport(total=total,
                         compute=int(round(costs["compute"])),
                         memory=int(round(costs["memory"])),
                         control=int(round(costs["control"])))
    return SimReport(
        module=mod.name, storage=sim.mem,
        out_ports=[p.name for p in mod.ports
                   if p.direction in ("out", "inout")],
        cycles=report, steps_retired=sim.steps,
        fsm_transitions=sim.transitions,
        counters=[l.counter for l in mod.loops()
                  if l.kind in ("fsm", "stream")],
        trace=sim.trace, trace_truncated=sim.trace_truncated)


# --------------------------------------------------------------------------
# co-simulation against the LoopIR oracle
# --------------------------------------------------------------------------


def random_inputs(mod: HwModule, seed: int = 0) -> List[np.ndarray]:
    """Deterministic random arrays for the module's input ports."""
    rng = np.random.default_rng(seed)
    out = []
    for p in mod.ports:
        if p.direction != "in":
            continue
        out.append(np.asarray(rng.standard_normal(p.shape),
                              dtype=_np_dtype(p.dtype)))
    return out


def cosim(mod: HwModule, kernel: Optional[Kernel],
          inputs: Sequence[np.ndarray], machine: MachineModel = TPU_V5E,
          modeled: Optional[int] = None, trace: bool = False,
          check: bool = True, atol: float = 1e-5) -> CoSimReport:
    """Simulate ``mod`` and cross-check it both ways:

    * numerically — final output-port contents against the LoopIR numpy
      oracle (``backend_ref.run(kernel, inputs)``), when a kernel is
      available;
    * temporally — observed cycles against the analytic
      ``machine_model.cycles`` prediction (``modeled`` overrides).

    Raises :class:`SimMismatch` when any output deviates beyond ``atol``.
    """
    rep = simulate(mod, inputs, machine=machine, trace=trace)
    if modeled is None:
        modeled = machine_model.cycles(mod, machine).total
    checked, max_err = False, float("nan")
    if check and kernel is not None:
        refs = backend_ref.run(kernel, inputs)
        max_err = 0.0
        for buf, want in zip(kernel.outputs, refs):
            got = rep.storage[buf.name]
            err = float(np.max(np.abs(np.asarray(got, dtype=np.float64)
                                      - np.asarray(want,
                                                   dtype=np.float64))))
            max_err = max(max_err, err)
            if err > atol:
                raise SimMismatch(
                    f"co-sim mismatch on output {buf.name!r}: "
                    f"max|err|={err:.3e} > atol={atol:g}")
        checked = True
    return CoSimReport(sim=rep, modeled_cycles=modeled,
                       observed_cycles=rep.cycles.total,
                       checked=checked, max_abs_err=max_err)
