"""Subcircuit outlining + time-multiplexed resource sharing over HwIR.

The two transforms that turn the flat, replicate-per-use hardware form
into the hierarchical, shared-resource form (the XLS / ripple-ir
direction named in ROADMAP):

  * :class:`OutlineSubcircuits` — a rewrite-driver pattern that hashes
    the canonical textual form of every control subtree (storage names
    anonymised to positional ports, units and counters renamed, address
    generators normalised) and outlines structurally repeated subtrees
    into one sub-module definition + :class:`~repro.core.hw_ir.HwInstance`
    call states.  The repeated datapath is then *declared once*, however
    many call sites reference it.

  * :func:`share_units` — a port-conflict-aware binding scheduler: unit
    declarations of the same kind whose uses sit in different FSM states
    (one control program = one state active at a time, so distinct steps
    provably never drive a unit's ports concurrently) fold onto one
    shared physical unit via the module's binding table.  Where the
    physical unit provides fewer spatial copies than a virtual user was
    lowered with, the binding carries ``serial > 1`` — the activation
    serialises into rounds, and *both* ``machine_model.cycles`` and
    ``hw_sim`` charge the same per-invocation stall, so cosim stays
    within tolerance with sharing enabled.

``set_sharing`` packages both behind one DSE knob (``none`` / ``share``
/ ``serialize``); the passes register as ``outline-subcircuits``,
``share-units`` and ``set-sharing`` at the hw level.  Neither transform
joins the canonicalize pattern set: sharing is a *scheduling decision*
(it trades mux overhead and serial rounds for area), not a canonical
form, so the DSE chooses it per design point.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .hw_ir import (HwBinding, HwCtrl, HwInstance, HwLoop, HwModule,
                    HwOperand, HwPort, HwStep, HwUnit)
from .loop_ir import AffineExpr
from .rewrite import (Pattern, RewriteDriver, RewriteStats, _publish,
                      _prune_unused_units, normalize_affine)

#: port direction <-> operand role, both ways
ROLE_OF_DIRECTION = {"in": "read", "out": "write", "inout": "acc"}


# --------------------------------------------------------------------------
# canonical subtree signatures
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _SubtreeInfo:
    """An outlineable control subtree, anonymised into a sub-module."""

    module: HwModule                 # the anonymised definition ("sub")
    signature: str                   # its canonical textual form
    storages: List[str]              # parent storage names, port order
    directions: List[str]            # port direction per storage


def _subtree_info(loop: HwLoop, mod: HwModule) -> Optional[_SubtreeInfo]:
    """Anonymise the subtree rooted at ``loop`` into a candidate
    sub-module, or ``None`` when it is not outlineable: it contains an
    instance already, references a bound (shared) unit, or reads a
    counter of an *enclosing* loop (the instance call site binds no
    counters, so free counters have no meaning inside the definition).
    """
    storages: List[str] = []         # first-use order -> p0, p1, ...
    roles: Dict[str, set] = {}
    units: List[str] = []            # first-use order -> u0, u1, ...
    counters: List[str] = []         # pre-order       -> c0, c1, ...
    bound: set = set()
    ok = True

    def scan(nodes: Sequence[HwCtrl]) -> None:
        nonlocal ok
        for n in nodes:
            if not ok:
                return
            if isinstance(n, HwInstance):
                ok = False
                return
            if isinstance(n, HwLoop):
                counters.append(n.counter)
                bound.add(n.counter)
                scan(n.body)
                continue
            if mod.binding_of(n.unit) is not None:
                ok = False
                return
            if n.unit not in units:
                units.append(n.unit)
            for o in n.operands:
                if o.target not in storages:
                    storages.append(o.target)
                roles.setdefault(o.target, set()).add(o.role)
                for e in o.index:
                    for v, _ in e.coeffs:
                        if v not in bound:
                            ok = False   # free counter
                            return

    scan([loop])
    if not ok:
        return None
    pmap = {n: f"p{i}" for i, n in enumerate(storages)}
    umap = {n: f"u{i}" for i, n in enumerate(units)}
    cmap = {n: f"c{i}" for i, n in enumerate(counters)}

    def rebuild(nodes: Sequence[HwCtrl]) -> List[HwCtrl]:
        out: List[HwCtrl] = []
        for n in nodes:
            if isinstance(n, HwLoop):
                out.append(HwLoop(cmap[n.counter], n.trips, n.kind,
                                  rebuild(n.body)))
            else:
                ops = [HwOperand(
                    o.role, pmap[o.target], tuple(o.tile),
                    tuple(normalize_affine(AffineExpr(
                        tuple((cmap[v], s) for v, s in e.coeffs), e.const))
                        for e in o.index))
                    for o in n.operands]
                out.append(HwStep(n.op, umap[n.unit], ops))
        return out

    directions = []
    ports = []
    for name in storages:
        rs = roles[name]
        if "acc" in rs or ("read" in rs and "write" in rs):
            dirn = "inout"
        elif "write" in rs:
            dirn = "out"
        else:
            dirn = "in"
        directions.append(dirn)
        d = mod.storage(name)
        ports.append(HwPort(pmap[name], dirn, d.dtype, tuple(d.shape),
                            mod.space_of(name).value))
    decls = [dataclasses.replace(mod.unit(n), name=umap[n]) for n in units]
    sub = HwModule("sub", ports=ports, regs=[], mems=[], units=decls,
                   ctrl=rebuild([loop]))
    from . import ir_text
    return _SubtreeInfo(module=sub,
                        signature=ir_text.print_hw_module(sub),
                        storages=storages, directions=directions)


def _iter_with_parents(nodes: List[HwCtrl]):
    """Yield ``(node, containing_list)`` over a control forest."""
    for n in nodes:
        yield n, nodes
        if isinstance(n, HwLoop):
            yield from _iter_with_parents(n.body)


def _instance_for(info: _SubtreeInfo, name: str, mod: HwModule) -> HwInstance:
    """Call-site state for one occurrence: each port binds the whole of
    the occurrence's storage (zero block index, full-shape tile)."""
    ops = []
    for target, dirn in zip(info.storages, info.directions):
        shape = tuple(mod.storage(target).shape)
        ops.append(HwOperand(ROLE_OF_DIRECTION[dirn], target, shape,
                             tuple(AffineExpr((), 0) for _ in shape)))
    return HwInstance(name, ops)


class OutlineSubcircuits(Pattern):
    """Outline structurally repeated control subtrees into one sub-module
    definition instanced from every occurrence: subtrees whose canonical
    anonymised form (storages as positional ports, units/counters
    renamed, address generators normalised) prints identically become
    one declaration + N call states, so the repeated datapath pays area
    once."""

    name = "outline-subcircuits"

    def match_and_rewrite(self, parent, siblings, i, root):
        loop = siblings[i]
        if not isinstance(loop, HwLoop) or not isinstance(root, HwModule):
            return None
        info = _subtree_info(loop, root)
        if info is None:
            return None
        # every other occurrence of the same canonical subtree, anywhere
        # in the control tree (equal signatures have equal size, so
        # occurrences are always disjoint)
        occs = []
        for node, holder in _iter_with_parents(root.ctrl):
            if node is loop or not isinstance(node, HwLoop):
                continue
            other = _subtree_info(node, root)
            if other is not None and other.signature == info.signature:
                occs.append((node, holder, other))
        if not occs:
            return None
        taken = {s.name for s in root.submodules}
        n = 0
        while f"sub{n}" in taken:
            n += 1
        info.module.name = f"sub{n}"
        root.submodules.append(info.module)
        for node, holder, other in occs:
            j = next(j for j, x in enumerate(holder) if x is node)
            holder[j] = _instance_for(other, info.module.name, root)
        return (1, [_instance_for(info, info.module.name, root)])


def outline_subcircuits(mod: HwModule) -> HwModule:
    """Run :class:`OutlineSubcircuits` to a fixpoint and prune the unit
    declarations the outlined occurrences orphaned (each occurrence's
    private units are re-declared once inside the definition)."""
    RewriteDriver([OutlineSubcircuits()], max_iterations=8).run(mod)
    pruned = _prune_unused_units(mod)
    if pruned:
        _publish(RewriteStats(hits={"prune-unused-unit": pruned}))
    mod.verify()
    return mod


# --------------------------------------------------------------------------
# the binding scheduler
# --------------------------------------------------------------------------


def share_units(mod: HwModule, max_copies: int = 0) -> HwModule:
    """Time-multiplex datapath units across FSM states via the binding
    table.

    Port-conflict analysis: two steps can share a physical unit iff
    their activations are provably non-overlapping.  Within one control
    program exactly one FSM state is active per cycle, so *distinct
    steps never conflict* — what can conflict are the spatial copies
    *inside* one activation.  The scheduler therefore folds same-kind
    units whose per-copy geometry fits under a representative
    (elementwise ``rep >= member``), keeps enough physical copies to
    cover the widest member (conflict-free in space), and when
    ``max_copies`` clamps below that, serialises the surplus copies into
    ``serial`` rounds — muxing the unit's input buses between rounds
    instead of replicating the datapath.  Every fold is recorded as a
    binding row; steps keep their virtual names, and the pricing /
    simulation layers resolve (and charge) the binding.

    ``max_copies=0`` means "never serialise" (pure sharing); the
    ``serialize`` sharing mode passes 1.  Idempotent: already-bound
    units are never re-folded.
    """
    made = _share_one(mod, max_copies)
    if made:
        _publish(RewriteStats(hits={"bind-shared-unit": made}))
    mod.verify()
    return mod


def _share_one(mod: HwModule, max_copies: int) -> int:
    made = 0
    for sub in mod.submodules:
        made += _share_one(sub, max_copies)
    used = {s.unit for s in mod.steps()}
    phys = {b.unit for b in mod.bindings}
    direct = [u for u in mod.units if u.name in used and u.name not in phys]
    by_kind: Dict[str, List[HwUnit]] = {}
    for u in direct:
        by_kind.setdefault(u.kind, []).append(u)
    taken = ({u.name for u in mod.units}
             | {b.virtual for b in mod.bindings}
             | {d.name for d in mod.ports + mod.regs + mod.mems})
    for kind in sorted(by_kind):
        remaining = sorted(by_kind[kind], key=lambda u: (-u.lanes, u.name))
        while remaining:
            rep = remaining[0]
            members = [u for u in remaining
                       if len(u.geometry) == len(rep.geometry)
                       and all(a >= b for a, b in
                               zip(rep.geometry, u.geometry))]
            mnames = {u.name for u in members}
            remaining = [u for u in remaining if u.name not in mnames]
            maxc = max(u.copies for u in members)
            copies = maxc if max_copies <= 0 else min(maxc, max_copies)
            if len(members) < 2 and copies >= maxc:
                continue            # nothing saved by a 1:1 rebind
            n = 0
            while f"{kind}_shared{n}" in taken:
                n += 1
            pname = f"{kind}_shared{n}"
            taken.add(pname)
            for u in sorted(members, key=lambda u: u.name):
                mod.bindings.append(HwBinding(
                    u.name, pname, math.ceil(u.copies / copies), u.copies))
                made += 1
            mod.units = ([u for u in mod.units if u.name not in mnames]
                         + [HwUnit(pname, kind, rep.geometry, copies)])
    return made


# --------------------------------------------------------------------------
# the DSE knob
# --------------------------------------------------------------------------

SHARING_MODES = ("none", "share", "serialize")


def set_sharing(mod: HwModule, mode: str = "share") -> HwModule:
    """Apply one of the three sharing policies to a hardware module:

    * ``none``      — leave the flat, replicate-per-use form alone;
    * ``share``     — outline repeated subcircuits and fold same-kind
      units, keeping enough physical copies that nothing serialises
      (area drops, cycles unchanged);
    * ``serialize`` — additionally clamp every shared unit to one
      physical copy, trading serial rounds (priced in ``cycles``) for
      the smallest datapath.
    """
    if mode not in SHARING_MODES:
        raise ValueError(f"set-sharing: unknown mode {mode!r}; choose "
                         f"from {'/'.join(SHARING_MODES)}")
    if mode == "none":
        return mod
    outline_subcircuits(mod)
    return share_units(mod, max_copies=0 if mode == "share" else 1)
