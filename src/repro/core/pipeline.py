"""End-to-end compile driver — the paper's "encapsulation script".

``compile_gemm`` / ``compile_traced`` run the full Fig.-1 flow:

    python fn  --frontend-->  TensorIR  --lower-->  LoopIR
        --schedule passes-->  scheduled LoopIR
        --lower-to-hw-->      HwIR (FSM + datapath module)
        --backend-->          {numpy oracle | jitted XLA | pallas kernel}
        --models-->           cycles (TABLE I) + resources (Fig. 3),
                              derived structurally from the HwIR module

and return everything a caller (tests, benchmarks, the integration layer)
needs in one artifact.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from . import (backend_jax, backend_pallas, backend_ref, host_bridge, hw_ir,
               hw_sim, machine_model)
from .frontend import spec, trace
from .hw_ir import HwModule
from .lowering import LoweringOptions, lower_graph
from .machine_model import TPU_V5E, CycleReport, MachineModel, ResourceReport
from .passes import PassManager, PassRecord
from .tensor_ir import Graph


SCHEDULES = ("nested", "inner_flattened", "tpu_mxu", "tpu_mxu_kgrid")


@dataclasses.dataclass
class CompiledKernel:
    name: str
    graph: Graph
    kernel: "Kernel"                  # scheduled LoopIR
    hw_module: HwModule               # lowered FSM + datapath hardware
    schedule: str
    cycles: CycleReport               # structural, from hw_module
    resources: ResourceReport         # structural, from hw_module
    flops: int
    hbm_bytes: int
    run_ref: Callable                  # numpy oracle
    run_jax: Optional[Callable]        # jitted XLA
    run_pallas: Optional[Callable]     # pallas_call (interpret on CPU)
    machine: MachineModel = TPU_V5E    # the model the reports were priced on
    pass_records: List[PassRecord] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        return (f"{self.name}[{self.schedule}]: {self.cycles}, "
                f"{self.resources}, flops={self.flops:,}, "
                f"hbm={self.hbm_bytes:,}B")

    # ---- co-simulation ----------------------------------------------------

    def simulate(self, *inputs, trace: bool = False, check: bool = True,
                 atol: float = 1e-5) -> hw_sim.CoSimReport:
        """Run the lowered hardware module cycle-accurately on ``inputs``
        (the Vivado-simulation leg of the paper's flow).

        Co-simulation: outputs are checked against the numpy oracle
        (``run_ref``) and the observed cycle count is packaged next to
        the analytic ``machine_model.cycles`` prediction.  Raises
        :class:`repro.core.hw_sim.SimMismatch` if any output deviates
        beyond ``atol``.
        """
        return hw_sim.cosim(self.hw_module, self.kernel, list(inputs),
                            machine=self.machine, modeled=self.cycles.total,
                            trace=trace, check=check, atol=atol)

    def simulate_host(self, *inputs,
                      crossbar: host_bridge.Crossbar = host_bridge.AXI4,
                      poll_interval: int = 64,
                      trace: bool = False) -> host_bridge.TransactionReport:
        """Simulate the full host-coupled transaction (DMA in → CSR start
        → poll done → DMA out) over ``crossbar`` — the paper's
        vendor-crossbar integration of the generated IP core."""
        return host_bridge.run_transaction(
            self.hw_module, list(inputs), machine=self.machine,
            crossbar=crossbar, poll_interval=poll_interval, trace=trace)

    # ---- design-space exploration -----------------------------------------

    def explore(self, **kwargs):
        """Design-space exploration around this kernel's source graph:
        search schedule programs × HwIR knobs on this kernel's machine
        and return the priced/validated Pareto frontier
        (:class:`repro.core.dse.DseResult`).  Keyword arguments forward
        to :func:`repro.core.dse.explore` (``validate_top``, ``budget``,
        ``tiles``, ``workers``, ``cache_dir``, ...)."""
        from . import dse

        kwargs.setdefault("machine", self.machine)
        return dse.explore(self.graph, **kwargs)

    def explore_fleet(self, others: Sequence["CompiledKernel"] = (),
                      mix=None, **kwargs):
        """Fleet-level DSE: optimize this kernel *plus* ``others`` as
        one multi-accelerator fabric behind a shared crossbar — which
        kernel gets which frontier schedule, how many copies — against
        a traffic mix, ranked on a requests/s × total-area frontier
        (:class:`repro.core.fabric.FleetResult`).  With no ``mix``, an
        even mix over the kernel set is generated at ~2× the fleet's
        serialized capacity so contention is visible.  Keyword arguments
        forward to :func:`repro.core.fabric.explore_fleet`
        (``budget``, ``crossbar``, ``max_copies``, ``validate_top``,
        ...)."""
        import dataclasses as _dc

        from . import fabric

        kernels = [self, *others]
        graphs = {ck.name: ck.graph for ck in kernels}
        if len(graphs) != len(kernels):
            raise ValueError("explore_fleet: kernel names must be unique, "
                             f"got {[ck.name for ck in kernels]}")
        kwargs.setdefault("machine", self.machine)
        if mix is None:
            crossbar = kwargs.get("crossbar", host_bridge.AXI4)
            mix = fabric.TrafficMix(
                "even", tuple((ck.name, 1.0) for ck in kernels),
                num_requests=8 * len(kernels), rate=1.0)
            mean = sum(fabric.transaction_cost(
                ck.hw_module, crossbar, ck.cycles.total).total
                for ck in kernels) / len(kernels)
            mix = _dc.replace(mix, cycles_per_unit=fabric.
                              saturating_cycles_per_unit(
                                  mix, mean,
                                  load_factor=2.0 * len(kernels)))
        return fabric.explore_fleet(graphs, mix, **kwargs)


def _pipeline_for(schedule: str, tile: Dict[str, int]) -> str:
    t = f"tile_m={tile['m']},tile_n={tile['n']},tile_k={tile['k']}"
    if schedule == "nested":
        return f"lower{{{t}}}"
    if schedule == "inner_flattened":
        return f"lower{{{t}}},flatten-inner"
    if schedule == "tpu_mxu":
        # (i, j) grid, K inside the block — flattened analogue
        return f"lower{{{t}}},fuse-epilogue,grid{{vars=2}}"
    if schedule == "tpu_mxu_kgrid":
        # (i, j, k) grid — time-multiplexed analogue
        return f"lower{{{t}}},fuse-epilogue,grid{{vars=3}}"
    raise ValueError(f"unknown schedule {schedule!r}; choose from {SCHEDULES}")


def compile_traced(fn_or_graph, in_specs: Optional[Sequence[spec]] = None,
                   schedule: str = "tpu_mxu",
                   tile: Optional[Dict[str, int]] = None,
                   machine: MachineModel = TPU_V5E,
                   want_jax: bool = True,
                   want_pallas: bool = True,
                   interpret: bool = True,
                   canonicalize: bool = False,
                   pipeline: Optional[str] = None) -> CompiledKernel:
    """Compile through the full stack; with ``canonicalize=True`` the
    level-agnostic ``canonicalize`` pass runs between lowerings (on the
    TensorIR input, on the scheduled LoopIR, and on the HwIR module) —
    semantics are preserved (cosim-checked in the test suite) but the
    canonical form may drop degenerate structure (extent-1 loops,
    duplicate datapath units), so modeled cycles/resources can differ
    from the uncanonicalized spelling.

    ``pipeline`` overrides the canned ``schedule``/``tile`` pair with an
    explicit pass-pipeline string (the ``reproc --pipeline`` spelling) —
    the schedule label on the artifact becomes the pipeline text.
    """
    if isinstance(fn_or_graph, Graph):
        graph = fn_or_graph
    else:
        graph = trace(fn_or_graph, in_specs)
    if pipeline is not None:
        pipe = schedule = pipeline
    else:
        tile = tile or ({"m": 1, "n": 1, "k": 1}
                        if schedule in ("nested", "inner_flattened")
                        else {"m": 128, "n": 128, "k": 128})
        # clamp tiles to the actual problem inside lowering
        pipe = _pipeline_for(schedule, tile)
    if canonicalize:
        pipe = f"canonicalize,{pipe},canonicalize"
    pres = PassManager.parse(pipe).run(graph)
    kernel = pres.artifact
    hw = hw_ir.lower_to_hw(kernel, mxu_min_dim=machine.mxu_min_dim)
    records = list(pres.records)
    if canonicalize:
        hwres = PassManager().add("canonicalize").run(hw)
        hw = hwres.artifact
        records += hwres.records
    cyc = machine_model.cycles(hw, machine)
    res = machine_model.resources(hw, machine)
    run_ref = lambda *xs: backend_ref.run(kernel, xs)
    run_jax = backend_jax.emit_jit(kernel) if want_jax else None
    run_pal = None
    if want_pallas:
        try:
            run_pal = backend_pallas.emit(kernel, interpret=interpret)
        except backend_pallas.EmitError:
            run_pal = None
    return CompiledKernel(
        name=graph.name, graph=graph, kernel=kernel, hw_module=hw,
        schedule=schedule,
        cycles=cyc, resources=res, flops=machine_model.flops(kernel),
        hbm_bytes=machine_model.hbm_bytes(kernel),
        run_ref=run_ref, run_jax=run_jax, run_pallas=run_pal,
        machine=machine, pass_records=records)


def compile_gemm(m: int, n: int, k: int, schedule: str = "tpu_mxu",
                 dtype: str = "float32", epilogue: str = "none",
                 tile: Optional[Dict[str, int]] = None,
                 machine: MachineModel = TPU_V5E,
                 interpret: bool = True,
                 want_jax: bool = True,
                 want_pallas: bool = True,
                 canonicalize: bool = False) -> CompiledKernel:
    """The paper's GEMM case study, parameterised by schedule/epilogue."""
    from . import frontend as fe

    if epilogue == "none":
        def f(a, b):
            return fe.matmul(a, b)
        specs = [spec((m, k), dtype), spec((k, n), dtype)]
    elif epilogue == "bias_relu":
        def f(a, b, bias):
            return fe.relu(fe.matmul(a, b) + bias)
        specs = [spec((m, k), dtype), spec((k, n), dtype), spec((n,), "float32")]
    elif epilogue == "relu":
        def f(a, b):
            return fe.relu(fe.matmul(a, b))
        specs = [spec((m, k), dtype), spec((k, n), dtype)]
    else:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    g = trace(f, specs, name=f"gemm_{m}x{n}x{k}_{epilogue}")
    return compile_traced(g, schedule=schedule, tile=tile, machine=machine,
                          interpret=interpret, want_jax=want_jax,
                          want_pallas=want_pallas, canonicalize=canonicalize)


from .loop_ir import Kernel  # noqa: E402
