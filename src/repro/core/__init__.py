"""stagecc — the paper's compiler infrastructure, TPU-native.

Levels (Fig. 1 of the paper):
    frontend (SYCL/DPC++ role)  ->  TensorIR (MLIR role)
        ->  LoopIR (Calyx role)  ->  HwIR (FSM + datapath, the RTL role)
        ->  backends (executable emission) + Verilog-style text
with cycle/resource models derived structurally from the HwIR module
(the Vivado-report role).

See docs/ARCHITECTURE.md for the stage-by-stage map,
docs/LOWERING.md (generated) for one GEMM walked through every level,
and docs/PASSES.md (generated) for the pass reference.
"""

from .autotune import best_schedule, compile_gemm_autotuned
from .dse import (DseCandidate, DsePoint, DseResult, DseValidation,
                  ResourceBudget, enumerate_points, explore,
                  pareto_frontier)
from .frontend import spec, trace
from .host_bridge import (AXI4, AXI4_LITE, Crossbar, TransactionReport,
                          csr_map, run_transaction)
from .hw_ir import HwModule, emit_verilog, lower_to_hw
from .hw_sim import (CoSimReport, SimError, SimMismatch, SimReport, cosim,
                     random_inputs, simulate)
from .ir_text import (ir_size, parse_graph, parse_hw_module, parse_ir,
                      parse_kernel, print_graph, print_hw_module, print_ir,
                      print_kernel)
from .lowering import LoweringOptions, lower_graph
from .machine_model import TPU_V5E, MachineModel, cycles, flops, hbm_bytes, resources
from .passes import (PASS_ALIASES, PASS_REGISTRY, PassDef, PassError,
                     PassManager, PassRecord, PipelineResult, parse_pipeline,
                     register_pass, run_pipeline)
from .pipeline import SCHEDULES, CompiledKernel, compile_gemm, compile_traced
from .rewrite import (CANONICAL_PATTERNS, OneShotPattern, Pattern,
                      RewriteDriver, RewriteError, RewriteStats, canonicalize,
                      register_canonical_pattern)
from .tensor_ir import Graph, OP_REGISTRY, TensorType, register_op

__all__ = [
    "spec", "trace", "LoweringOptions", "lower_graph", "TPU_V5E",
    "MachineModel", "cycles", "flops", "hbm_bytes", "resources",
    "PASS_ALIASES", "PASS_REGISTRY", "PassDef", "PassError", "PassManager",
    "PassRecord", "PipelineResult", "parse_pipeline", "register_pass",
    "run_pipeline",
    "HwModule", "emit_verilog", "lower_to_hw",
    "AXI4", "AXI4_LITE", "Crossbar", "TransactionReport", "csr_map",
    "run_transaction",
    "CoSimReport", "SimError", "SimMismatch", "SimReport", "cosim",
    "random_inputs", "simulate",
    "ir_size", "parse_graph", "parse_hw_module", "parse_ir", "parse_kernel",
    "print_graph", "print_hw_module", "print_ir", "print_kernel",
    "SCHEDULES", "CompiledKernel", "compile_gemm", "compile_traced",
    "Graph", "OP_REGISTRY", "TensorType", "register_op",
    "DseCandidate", "DsePoint", "DseResult", "DseValidation",
    "ResourceBudget", "enumerate_points", "explore", "pareto_frontier",
    "CANONICAL_PATTERNS", "OneShotPattern", "Pattern", "RewriteDriver",
    "RewriteError", "RewriteStats", "canonicalize",
    "register_canonical_pattern",
]
