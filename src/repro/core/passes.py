"""Multi-level pass manager — the reusability/extensibility layer.

The paper encapsulates its whole lowering flow "using a script"; here the
script is either a declarative pipeline string, e.g.::

    lower{tile_m=128,tile_n=128,tile_k=128},flatten-inner,grid{vars=2},emit-pallas

or a programmatically-built :class:`PassManager`::

    pm = PassManager().add("lower", tile_m=128).add("flatten-inner")
    result = pm.run(graph)

mirroring MLIR's ``PassManager`` / ``mlir-opt`` split.  The manager owns
an ordered list of registered passes with declared IR levels, checks that
each pass receives an artifact of its level (a ``tensor`` pass gets a
``Graph``, a ``loop`` or ``backend`` pass gets a ``Kernel``, an ``hw``
pass gets an ``HwModule``), re-runs the IR verifier between passes, and
records per-pass instrumentation (wall time, IR-size delta, optional
before/after textual dumps).

New passes register with ``@register_pass`` exactly like new ops register
with ``register_op`` — third parties extend the pipeline without touching
the core (the paper's stated goal for the infrastructure).
"""

from __future__ import annotations

import dataclasses
import difflib
import re
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from . import (backend_jax, backend_pallas, backend_ref, hw_ir, lowering,
               rewrite, schedule)
from .hw_ir import HwModule
from .loop_ir import Kernel, LoopKind, MemSpace
from .tensor_ir import Graph

Artifact = Union[Graph, Kernel, HwModule, Callable, str]

#: IR levels in lowering order; a pass's level names the IR it *consumes*
#: (``lower`` is a tensor pass producing LoopIR, ``lower-to-hw`` a loop
#: pass producing HwIR, ``emit-verilog`` an hw pass producing text).
LEVELS = ("tensor", "loop", "hw", "backend")


class PassError(ValueError):
    """A pass failed or produced IR that does not verify."""


@dataclasses.dataclass(frozen=True)
class PassDef:
    name: str
    #: the IR level(s) the pass consumes — a single name, or a tuple for
    #: level-agnostic passes (``canonicalize`` runs at tensor/loop/hw)
    level: Union[str, Tuple[str, ...]]
    fn: Callable[..., Artifact]
    doc: str = ""
    #: names of the rewrite patterns the pass is built from — a tuple,
    #: or a zero-arg callable resolved on read so registries that grow
    #: after import (``register_canonical_pattern``) stay visible in
    #: ``reproc --list-passes`` and the generated docs
    patterns: Union[Tuple[str, ...], Callable[[], Tuple[str, ...]]] = ()

    @property
    def pattern_names(self) -> Tuple[str, ...]:
        return tuple(self.patterns() if callable(self.patterns)
                     else self.patterns)

    @property
    def levels(self) -> Tuple[str, ...]:
        return (self.level,) if isinstance(self.level, str) else self.level

    @property
    def level_str(self) -> str:
        return "/".join(self.levels)


PASS_REGISTRY: Dict[str, PassDef] = {}

#: alternate spellings accepted by pipeline specs and the reproc driver
PASS_ALIASES: Dict[str, str] = {
    "flatten": "flatten-inner",
    "fuse": "fuse-epilogue",
}


def register_pass(name: str, level: Union[str, Tuple[str, ...]],
                  doc: str = "", patterns=()):
    """Register ``fn`` as pass ``name`` at IR ``level`` (a level name or
    a tuple of levels for level-agnostic passes).

    ``doc`` defaults to the first line of the function's docstring so the
    generated pass reference (``reproc --list-passes``) is never empty.
    ``patterns`` names the rewrite patterns the pass is built from —
    pass a zero-arg callable to resolve the list lazily (used by
    ``canonicalize``, whose pattern registry is runtime-extensible).
    """
    levels = (level,) if isinstance(level, str) else tuple(level)
    for lv in levels:
        if lv not in LEVELS:
            raise ValueError(f"pass {name!r}: level must be one of {LEVELS}, "
                             f"got {lv!r}")

    def deco(fn):
        if name in PASS_REGISTRY:
            raise ValueError(f"pass {name!r} already registered")
        d = doc.strip()
        if not d:
            lines = (fn.__doc__ or "").strip().splitlines()
            d = lines[0].strip() if lines else ""
        PASS_REGISTRY[name] = PassDef(name, level, fn,
                                      d or f"(undocumented {level} pass)",
                                      patterns if callable(patterns)
                                      else tuple(patterns))
        return fn
    return deco


def suggest_pass(name: str) -> Optional[str]:
    """Closest registered pass/alias name, for did-you-mean diagnostics."""
    universe = sorted(set(PASS_REGISTRY) | set(PASS_ALIASES))
    close = difflib.get_close_matches(name, universe, n=1, cutoff=0.5)
    return close[0] if close else None


def resolve_pass(name: str) -> PassDef:
    pd = PASS_REGISTRY.get(PASS_ALIASES.get(name, name))
    if pd is None:
        sugg = suggest_pass(name)
        hint = f"did you mean {sugg!r}? " if sugg else ""
        raise KeyError(f"unknown pass {name!r}; {hint}"
                       f"registered: {sorted(PASS_REGISTRY)}")
    return pd


# ---- built-in passes --------------------------------------------------------


@register_pass("lower", "tensor", "TensorIR -> LoopIR (nested sequential)")
def _lower(g: Graph, tile_m: int = 1, tile_n: int = 1, tile_k: int = 1,
           use_accumulator: int = 1) -> Kernel:
    return lowering.lower_graph(g, lowering.LoweringOptions(
        tile_m=tile_m, tile_n=tile_n, tile_k=tile_k,
        use_accumulator=bool(use_accumulator)))


@register_pass("flatten-inner", "loop", "paper's inner-loop flattening",
               patterns=("set-loop-kind",))
def _flatten(k: Kernel) -> Kernel:
    return schedule.flatten_inner(k)


@register_pass("unroll", "loop", "unroll a named loop",
               patterns=("set-loop-kind",))
def _unroll(k: Kernel, var: str) -> Kernel:
    return schedule.unroll(k, var)


@register_pass("vectorize", "loop", "map a named loop to VPU lanes",
               patterns=("set-loop-kind",))
def _vectorize(k: Kernel, var: str) -> Kernel:
    return schedule.vectorize(k, var)


@register_pass("split", "loop", "split a named loop by a factor",
               patterns=("split-loop",))
def _split(k: Kernel, var: str, factor: int) -> Kernel:
    return schedule.split(k, var, factor)


@register_pass("interchange", "loop", "swap two perfectly nested loops",
               patterns=("interchange-loops",))
def _interchange(k: Kernel, outer: str, inner: str) -> Kernel:
    return schedule.interchange(k, outer, inner)


@register_pass("fuse-epilogue", "loop", "fuse elementwise tail into matmul nest",
               patterns=("fuse-epilogue",))
def _fuse(k: Kernel) -> Kernel:
    return schedule.fuse_epilogue(k)


@register_pass("set-space", "loop",
               "move a scratch buffer between vmem and vreg")
def _set_space(k: Kernel, buffer: str, space: str) -> Kernel:
    try:
        ms = MemSpace(space)
    except ValueError:
        raise ValueError(f"set-space: unknown space {space!r}; choose "
                         f"vmem or vreg")
    if ms == MemSpace.HBM:
        raise ValueError("set-space: scratch buffers cannot move to hbm")
    return schedule.set_space(k, buffer, ms)


@register_pass("grid", "loop", "map the outermost N loops to the pallas grid")
def _grid(k: Kernel, vars: int = 2) -> Kernel:
    count = 0
    stmts = k.body
    while count < vars and len(stmts) >= 1:
        loops = [s for s in stmts if hasattr(s, "kind")]
        if not loops:
            break
        loop = loops[0]
        reason = schedule.carry_axis_reason(loop, LoopKind.GRID)
        if reason:
            raise ValueError(f"grid: {reason}")
        loop.kind = LoopKind.GRID
        count += 1
        stmts = loop.body
    k.verify()
    return k


@register_pass("lower-to-hw", "loop",
               "scheduled LoopIR -> HwIR (FSM + datapath module)")
def _lower_to_hw(k: Kernel, mxu_min_dim: int = 8) -> HwModule:
    return hw_ir.lower_to_hw(k, mxu_min_dim=mxu_min_dim)


@register_pass("emit-verilog", "hw", "emit Verilog-style RTL text")
def _emit_verilog(mod: HwModule) -> str:
    return hw_ir.emit_verilog(mod)


@register_pass("set-sequencer", "hw",
               "re-sequence a loop between @fsm and @stream",
               patterns=("set-sequencer",))
def _set_sequencer(mod: HwModule, counter: str, kind: str) -> HwModule:
    return hw_ir.set_sequencer(mod, counter, kind)


@register_pass("outline-subcircuits", "hw",
               "outline repeated control subtrees into sub-modules",
               patterns=("outline-subcircuits",))
def _outline_subcircuits(mod: HwModule) -> HwModule:
    """Hash the canonical anonymised form of every control subtree and
    outline structural repeats into one sub-module definition + one
    :class:`~repro.core.hw_ir.HwInstance` call state per occurrence, so
    the repeated datapath is declared (and priced) once.  Orphaned unit
    declarations of the outlined occurrences are pruned under
    ``prune-unused-unit``."""
    from . import sharing

    return sharing.outline_subcircuits(mod)


@register_pass("share-units", "hw",
               "time-multiplex datapath units across FSM states")
def _share_units(mod: HwModule, max_copies: int = 0) -> HwModule:
    """Run the port-conflict-aware binding scheduler: same-kind unit
    declarations whose activations sit in different FSM states fold onto
    one shared physical unit via the binding table; ``max_copies`` > 0
    clamps the physical copies, serialising wider virtual users into
    rounds that ``cycles``/``hw_sim`` both price."""
    from . import sharing

    return sharing.share_units(mod, max_copies=max_copies)


@register_pass("set-sharing", "hw",
               "apply a sharing policy: none / share / serialize")
def _set_sharing(mod: HwModule, mode: str = "share") -> HwModule:
    """The DSE's sharing knob: ``none`` keeps the flat form, ``share``
    outlines subcircuits and folds units without serialising, and
    ``serialize`` additionally clamps each shared unit to one physical
    copy, trading serial rounds for the smallest datapath."""
    from . import sharing

    return sharing.set_sharing(mod, mode=mode)


@register_pass("canonicalize", ("tensor", "loop", "hw"),
               "apply the level's canonicalization patterns to a fixpoint",
               patterns=rewrite.canonical_pattern_names)
def _canonicalize(art, max_iterations: int = 32):
    """Drive the artifact level's registered canonicalization pattern
    set (``rewrite.CANONICAL_PATTERNS``) to a fixpoint: TensorIR folds
    identity epilogues and dead ops, LoopIR drops extent-1 loops,
    merges independent adjacent @seq nests and normalizes tile refs,
    HwIR collapses single-trip sequencers, normalizes address
    generators, shares identical datapath units and prunes orphaned
    unit/sub-module declarations.  The one pass registered at all three
    levels; per-pattern hit counts surface on the ``PassRecord``."""
    return rewrite.canonicalize(art, max_iterations=max_iterations)


@register_pass("dse", "tensor",
               "design-space exploration: search schedule programs and "
               "return the Pareto-fastest kernel")
def _dse(g: Graph, validate: int = 0, top: int = 4) -> Kernel:
    """Run :func:`repro.core.dse.explore` over the module and lower the
    winning (feasible, Pareto-fastest) schedule program's loop-level
    pipeline; with ``validate=1`` the ``top`` fastest frontier points
    are co-simulated against the numpy oracle first and the pass FAILS
    if any of them diverges (numerics or modeled cycles).  HwIR-level
    knobs of the winner are dropped (the pass must yield a Kernel so
    the rest of the pipeline can keep lowering); replay the winner's
    full spec through ``reproc`` to keep them."""
    from . import dse

    res = dse.explore(g, validate_top=top if validate else 0)
    bad = [v for v in res.validations if not v.ok]
    if bad:
        raise ValueError(
            f"dse: {len(bad)} frontier point(s) failed co-simulation, "
            f"first: {bad[0].point.spec}: "
            f"{bad[0].detail or f'max|err|={bad[0].max_abs_err:.2e}'}")
    best = res.best()
    if best is None:
        raise ValueError(f"dse: no feasible schedule for {g.name}")
    art = PassManager.parse(best.point.pipeline).run(g).artifact
    return art


@register_pass("simulate", "hw",
               "verification: cycle-accurately execute the module")
def _simulate(mod: HwModule, seed: int = 0, tol_pct: int = 10) -> HwModule:
    """Run the module in ``hw_sim`` on seeded random inputs and fail the
    pipeline if the hardware misbehaves: non-finite outputs, or an
    observed cycle count more than ``tol_pct`` percent away from the
    analytic ``machine_model.cycles`` prediction.  The artifact passes
    through unchanged, so ``...,lower-to-hw,simulate,emit-verilog`` gates
    emission on a clean simulation."""
    from . import hw_sim, machine_model

    try:
        rep = hw_sim.simulate(mod, hw_sim.random_inputs(mod, seed=seed))
    except hw_sim.SimError as e:
        # re-raise on the ValueError channel every pass-failure handler
        # (PassManager -> PassError, reproc diagnostics) listens on
        raise ValueError(f"simulate: {e}") from e
    for name in rep.out_ports:
        if not np.all(np.isfinite(rep.storage[name])):
            raise ValueError(f"simulate: output port {name!r} holds "
                             f"non-finite values")
    modeled = machine_model.cycles(mod).total
    if modeled > 0:
        dev = abs(rep.cycles.total - modeled) / modeled
        if dev > tol_pct / 100.0:
            raise ValueError(
                f"simulate: observed {rep.cycles.total:,} cycles deviates "
                f"{dev:.1%} from modeled {modeled:,} (> {tol_pct}%)")
    return mod


@register_pass("emit-ref", "backend", "emit numpy interpreter callable")
def _emit_ref(k: Kernel):
    return lambda *xs: backend_ref.run(k, xs)


@register_pass("emit-jax", "backend", "emit jitted XLA callable")
def _emit_jax(k: Kernel):
    return backend_jax.emit_jit(k)


@register_pass("emit-pallas", "backend", "emit pallas_call kernel")
def _emit_pallas(k: Kernel, interpret: int = 1):
    return backend_pallas.emit(k, interpret=bool(interpret))


# ---- pipeline parsing ---------------------------------------------------------

_STAGE_RE = re.compile(r"^([a-zA-Z_][\w\-]*)(?:\{(.*)\})?$")


class PipelineParseError(ValueError):
    """Malformed pipeline spec; the message names the offending offset."""

    def __init__(self, spec: str, offset: int, msg: str):
        super().__init__(f"pipeline spec: {msg} at offset {offset}: "
                         f"{spec!r}")
        self.offset = offset


def parse_pipeline(spec: str) -> List[Dict[str, Any]]:
    """``"lower{tile_m=128},flatten-inner"`` -> [{name, kwargs}, ...].

    Stages separate on ``,`` or ``;`` at brace depth 0 (``;`` matches
    mlir-opt-style specs on the command line, where ``,`` also separates
    pass arguments).  Malformed specs — unbalanced or nested braces,
    stray separators producing empty stages, malformed ``key=value``
    arguments — raise :class:`PipelineParseError` naming the offending
    character offset.
    """
    # ---- lex into (start_offset, text) parts, brace-aware ------------------
    depth = 0
    open_at = -1
    token = ""
    start = 0
    parts: List[Tuple[int, str]] = []
    for off, ch in enumerate(spec):
        if ch == "{":
            if depth:
                raise PipelineParseError(spec, off, "nested '{'")
            depth, open_at = 1, off
        elif ch == "}":
            if not depth:
                raise PipelineParseError(spec, off, "unbalanced '}'")
            depth = 0
        if ch in ",;" and depth == 0:
            if not token.strip():
                raise PipelineParseError(
                    spec, off, f"empty pipeline stage before {ch!r}")
            parts.append((start, token))
            token, start = "", off + 1
        else:
            token += ch
    if depth:
        raise PipelineParseError(spec, open_at, "unclosed '{'")
    if token.strip():
        parts.append((start, token))

    # ---- parse each stage ---------------------------------------------------
    stages = []
    for off, part in parts:
        m = _STAGE_RE.match(part.strip())
        if not m:
            raise PipelineParseError(spec, off,
                                     f"bad pipeline stage {part.strip()!r}")
        name, argstr = m.group(1), m.group(2)
        kwargs: Dict[str, Any] = {}
        if argstr is not None and not argstr.strip():
            raise PipelineParseError(spec, off,
                                     f"empty argument braces on {name!r}")
        if argstr:
            for kv in argstr.split(","):
                key, eq, val = kv.partition("=")
                key, val = key.strip(), val.strip()
                if not key or not eq or not val:
                    raise PipelineParseError(
                        spec, off, f"bad pass argument {kv.strip()!r} on "
                                   f"{name!r} (want key=value)")
                kwargs[key] = int(val) if re.fullmatch(r"-?\d+", val) else val
        stages.append({"name": name, "kwargs": kwargs})
    return stages


# ---- pass manager -----------------------------------------------------------


def _artifact_size(art: Artifact) -> Optional[int]:
    from . import ir_text
    return ir_text.ir_size(art)


def _artifact_text(art: Artifact) -> str:
    from . import ir_text
    if isinstance(art, (Graph, Kernel, HwModule)):
        return ir_text.print_ir(art)
    if isinstance(art, str):                    # emitted RTL text
        return art
    return f"<backend artifact {art!r}>"


@dataclasses.dataclass
class PassRecord:
    """Instrumentation for one executed pass."""

    name: str
    level: str
    kwargs: Dict[str, Any]
    wall_ms: float
    size_before: Optional[int]
    size_after: Optional[int]
    dump_before: Optional[str] = None
    dump_after: Optional[str] = None
    #: per-pattern hit counts from every RewriteDriver the pass ran
    pattern_stats: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        from . import ir_text

        def sz(v):
            return "-" if v is None else str(v)
        line = (f"{self.name:16s} [{self.level:7s}] {self.wall_ms:8.3f} ms  "
                f"size {sz(self.size_before)} -> {sz(self.size_after)}")
        if self.pattern_stats:
            line += ("  patterns: "
                     + ir_text.format_pattern_stats(self.pattern_stats))
        return line


@dataclasses.dataclass
class PipelineResult:
    artifact: Artifact
    trace: List[str]               # pass-by-pass textual IR dumps
    records: List[PassRecord] = dataclasses.field(default_factory=list)

    def timing_table(self) -> str:
        return "\n".join(r.summary() for r in self.records)


class PassManager:
    """Ordered, level-checked, verified, instrumented pass pipeline.

    Build programmatically (``add``) or from the string syntax
    (``PassManager.parse``); ``spec()`` round-trips back to the string
    form.  ``run`` executes the pipeline on a Graph/Kernel artifact and
    returns a :class:`PipelineResult` whose ``records`` carry per-pass
    wall time, IR-size deltas, and (when dumping) before/after IR text.
    """

    def __init__(self, *, verify: bool = True, dump_after_each: bool = False,
                 dump_before_each: bool = False):
        self.verify = verify
        self.dump_after_each = dump_after_each
        self.dump_before_each = dump_before_each
        self._stages: List[Tuple[PassDef, Dict[str, Any]]] = []

    # ---- construction ------------------------------------------------------

    def add(self, pass_: Union[str, PassDef], **kwargs) -> "PassManager":
        pd = resolve_pass(pass_) if isinstance(pass_, str) else pass_
        self._stages.append((pd, dict(kwargs)))
        return self

    @classmethod
    def parse(cls, spec: str, **opts) -> "PassManager":
        pm = cls(**opts)
        for st in parse_pipeline(spec):
            pm.add(st["name"], **st["kwargs"])
        return pm

    def spec(self) -> str:
        """Serialise back to the pipeline-string syntax.

        Bools serialise as 0/1: the string syntax only knows ints and
        strings, and ``bool("False")`` is True — so ``str(v)`` would not
        survive a parse round-trip.
        """
        parts = []
        for pd, kwargs in self._stages:
            if kwargs:
                kv = ",".join(f"{k}={int(v) if isinstance(v, bool) else v}"
                              for k, v in kwargs.items())
                parts.append(f"{pd.name}{{{kv}}}")
            else:
                parts.append(pd.name)
        return ",".join(parts)

    @property
    def stages(self) -> List[Tuple[PassDef, Dict[str, Any]]]:
        return list(self._stages)

    # ---- execution ---------------------------------------------------------

    @staticmethod
    def _level_type(level: str) -> type:
        if level == "tensor":
            return Graph
        if level == "hw":
            return HwModule
        return Kernel               # "loop" and "backend" consume LoopIR

    def _check_level(self, pd: PassDef, art: Artifact) -> None:
        wants = tuple(dict.fromkeys(self._level_type(lv)
                                    for lv in pd.levels))
        if not isinstance(art, wants):
            have = type(art).__name__
            names = " or ".join(w.__name__ for w in wants)
            raise PassError(
                f"pass {pd.name!r} is a {pd.level_str}-level pass and needs "
                f"a {names}, but the pipeline artifact is {have} — "
                f"check pass ordering (backend passes are terminal)")

    def _verify(self, pd: PassDef, art: Artifact, when: str) -> None:
        if self.verify and isinstance(art, (Graph, Kernel, HwModule)):
            try:
                art.verify()
            except ValueError as e:
                raise PassError(f"IR verification failed {when} pass "
                                f"{pd.name!r}: {e}") from e

    def run(self, artifact: Artifact) -> PipelineResult:
        art = artifact
        trace: List[str] = []
        records: List[PassRecord] = []
        # textual dumps (trace + PassRecord.dump_*) are only rendered when a
        # dump flag is set: printing the IR after every pass is O(IR size)
        # and run() sits on the compile hot path (autotune sweeps it).
        keep_trace = self.dump_after_each or self.dump_before_each
        if isinstance(art, (Graph, Kernel, HwModule)) and self.verify:
            try:
                art.verify()
            except ValueError as e:
                raise PassError(f"input IR failed verification: {e}") from e
        if keep_trace:
            trace.append(f"== input ==\n{_artifact_text(art)}"
                         if isinstance(art, (Graph, Kernel, HwModule)) else "== input ==")
        for pd, kwargs in self._stages:
            self._check_level(pd, art)
            # multi-level passes record the level they actually ran at
            level = (pd.level if isinstance(pd.level, str)
                     else rewrite.level_of(art))
            size_before = _artifact_size(art)
            dump_before = (_artifact_text(art)
                           if self.dump_before_each else None)
            t0 = time.perf_counter()
            try:
                with rewrite.collect_stats() as pattern_stats:
                    art = pd.fn(art, **kwargs)
            except PassError:
                raise
            except (ValueError, KeyError, TypeError) as e:
                raise PassError(f"pass {pd.name!r} failed: {e}") from e
            wall_ms = (time.perf_counter() - t0) * 1e3
            self._verify(pd, art, "after")
            dump_after = (_artifact_text(art)
                          if self.dump_after_each else None)
            records.append(PassRecord(
                name=pd.name, level=level, kwargs=dict(kwargs),
                wall_ms=wall_ms, size_before=size_before,
                size_after=_artifact_size(art),
                dump_before=dump_before, dump_after=dump_after,
                pattern_stats=pattern_stats))
            if self.dump_after_each:
                if isinstance(art, (Graph, Kernel, HwModule)):
                    trace.append(f"== after {pd.name} ==\n{dump_after}")
                else:
                    trace.append(f"== after {pd.name} == <{pd.level} artifact>")
        return PipelineResult(art, trace, records)


def run_pipeline(graph: Artifact, spec: str, dump: bool = False) -> PipelineResult:
    """The paper's "script": run a declared pass pipeline end to end with
    verification between stages.  Thin wrapper over :class:`PassManager`
    kept for the original seed API (``PipelineResult.trace`` only carries
    dumps when ``dump=True``)."""
    pm = PassManager.parse(spec, dump_after_each=dump)
    return pm.run(graph)
