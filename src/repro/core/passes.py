"""Pass manager + pipeline parser — the reusability/extensibility layer.

The paper encapsulates its whole lowering flow "using a script"; here the
script is a declarative pipeline string, e.g.::

    lower{tile_m=128,tile_n=128,tile_k=128},flatten-inner,grid{vars=2},emit-pallas

New passes register with ``@register_pass`` exactly like new ops register
with ``register_op`` — third parties extend the pipeline without touching
the core (the paper's stated goal for the infrastructure).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Union

from . import backend_jax, backend_pallas, backend_ref, lowering, schedule
from .loop_ir import Kernel, LoopKind, MemSpace
from .tensor_ir import Graph

Artifact = Union[Graph, Kernel, Callable]


@dataclasses.dataclass(frozen=True)
class PassDef:
    name: str
    level: str                       # "tensor" | "loop" | "backend"
    fn: Callable[..., Artifact]
    doc: str = ""


PASS_REGISTRY: Dict[str, PassDef] = {}


def register_pass(name: str, level: str, doc: str = ""):
    def deco(fn):
        if name in PASS_REGISTRY:
            raise ValueError(f"pass {name!r} already registered")
        PASS_REGISTRY[name] = PassDef(name, level, fn, doc)
        return fn
    return deco


# ---- built-in passes --------------------------------------------------------


@register_pass("lower", "tensor", "TensorIR -> LoopIR (nested sequential)")
def _lower(g: Graph, tile_m: int = 1, tile_n: int = 1, tile_k: int = 1,
           use_accumulator: int = 1) -> Kernel:
    return lowering.lower_graph(g, lowering.LoweringOptions(
        tile_m=tile_m, tile_n=tile_n, tile_k=tile_k,
        use_accumulator=bool(use_accumulator)))


@register_pass("flatten-inner", "loop", "paper's inner-loop flattening")
def _flatten(k: Kernel) -> Kernel:
    return schedule.flatten_inner(k)


@register_pass("unroll", "loop", "unroll a named loop")
def _unroll(k: Kernel, var: str) -> Kernel:
    return schedule.unroll(k, var)


@register_pass("vectorize", "loop", "map a named loop to VPU lanes")
def _vectorize(k: Kernel, var: str) -> Kernel:
    return schedule.vectorize(k, var)


@register_pass("split", "loop", "split a named loop by a factor")
def _split(k: Kernel, var: str, factor: int) -> Kernel:
    return schedule.split(k, var, factor)


@register_pass("interchange", "loop", "swap two perfectly nested loops")
def _interchange(k: Kernel, outer: str, inner: str) -> Kernel:
    return schedule.interchange(k, outer, inner)


@register_pass("fuse-epilogue", "loop", "fuse elementwise tail into matmul nest")
def _fuse(k: Kernel) -> Kernel:
    return schedule.fuse_epilogue(k)


@register_pass("grid", "loop", "map the outermost N loops to the pallas grid")
def _grid(k: Kernel, vars: int = 2) -> Kernel:
    count = 0
    stmts = k.body
    while count < vars and len(stmts) >= 1:
        loops = [s for s in stmts if hasattr(s, "kind")]
        if not loops:
            break
        loop = loops[0]
        loop.kind = LoopKind.GRID
        count += 1
        stmts = loop.body
    k.verify()
    return k


@register_pass("emit-ref", "backend", "emit numpy interpreter callable")
def _emit_ref(k: Kernel):
    return lambda *xs: backend_ref.run(k, xs)


@register_pass("emit-jax", "backend", "emit jitted XLA callable")
def _emit_jax(k: Kernel):
    return backend_jax.emit_jit(k)


@register_pass("emit-pallas", "backend", "emit pallas_call kernel")
def _emit_pallas(k: Kernel, interpret: int = 1):
    return backend_pallas.emit(k, interpret=bool(interpret))


# ---- pipeline parsing ---------------------------------------------------------

_STAGE_RE = re.compile(r"^([a-zA-Z_][\w\-]*)(?:\{(.*)\})?$")


def parse_pipeline(spec: str) -> List[Dict[str, Any]]:
    """``"lower{tile_m=128},flatten-inner"`` -> [{name, kwargs}, ...]."""
    stages = []
    depth = 0
    token = ""
    parts: List[str] = []
    for ch in spec:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(token)
            token = ""
        else:
            token += ch
    if token.strip():
        parts.append(token)
    for part in parts:
        m = _STAGE_RE.match(part.strip())
        if not m:
            raise ValueError(f"bad pipeline stage {part!r}")
        name, argstr = m.group(1), m.group(2)
        kwargs: Dict[str, Any] = {}
        if argstr:
            for kv in argstr.split(","):
                key, _, val = kv.partition("=")
                key, val = key.strip(), val.strip()
                kwargs[key] = int(val) if re.fullmatch(r"-?\d+", val) else val
        stages.append({"name": name, "kwargs": kwargs})
    return stages


@dataclasses.dataclass
class PipelineResult:
    artifact: Artifact
    trace: List[str]               # pass-by-pass textual IR dumps


def run_pipeline(graph: Graph, spec: str, dump: bool = False) -> PipelineResult:
    """The paper's "script": run a declared pass pipeline end to end with
    verification between stages."""
    stages = parse_pipeline(spec)
    art: Artifact = graph
    trace: List[str] = []
    if dump:
        trace.append(f"== input ==\n{graph}")
    for st in stages:
        pd = PASS_REGISTRY.get(st["name"])
        if pd is None:
            raise KeyError(f"unknown pass {st['name']!r}; "
                           f"registered: {sorted(PASS_REGISTRY)}")
        art = pd.fn(art, **st["kwargs"])
        if isinstance(art, (Graph, Kernel)):
            art.verify()
            if dump:
                trace.append(f"== after {st['name']} ==\n{art}")
        elif dump:
            trace.append(f"== after {st['name']} == <{pd.level} artifact>")
    return PipelineResult(art, trace)
