"""Program raising: traced JAX -> TensorIR (the mlirSynth direction).

Every TensorIR graph so far was hand-written (``frontend.flash_attention_graph``
etc.).  This module closes the loop the paper's Fig. 1 implies: start from the
*software* frontend — a real JAX function, traced to a jaxpr — and raise it
into the level-1 IR automatically, so every model config becomes a compiler
workload instead of only the three hand-written kernels.

Pipeline position::

    jax fn --make_jaxpr--> jaxpr --raise_jaxpr--> TensorIR Graph
                                                     |  (PassManager)
                                                     v
                                       LoopIR -> HwIR -> {ref, jax, pallas}

Design notes
------------
* TensorIR is rank-2: every raised SSA value is a 2-D tensor.  An n-D jax
  shape maps to ``canon2d(shape) = (prod(shape[:-1]), shape[-1])`` — leading
  (batch) axes collapse into rows, the feature axis stays columns.
* Weights/consts (jaxpr constvars + literals) are *folded* while possible and
  materialised lazily as extra graph inputs (``c0``, ``c1``, ...) the first
  time a non-foldable op consumes them; ``RaisedGraph.bind`` re-appends them
  at call time.  A raised graph of a closed-over-params block therefore has
  the user arguments first (``arg0``...) and the captured parameters after.
* ``lax.scan`` bodies are raised by *linearity analysis*: each body value is
  tracked as ``alpha * carry + beta`` with ``alpha``/``beta`` expression trees
  over the per-step slices.  Any body that is affine in a single rank<=1 carry
  (zero-initialised) becomes the carried TensorIR ``scan`` op — this covers
  the SSD recurrence, RG-LRU and cumsum uniformly.
* Anything outside the vocabulary raises :class:`RaiseError` naming the
  offending primitive and its source equation, so ``reproc --raise`` and the
  raisability table in docs/RAISING.md can show *why* a block does not raise.
* For ``while``-wrapped scans, the optimized-HLO walk in
  ``launch.hlo_analysis`` cross-checks recovered trip counts against the
  raised scan lengths (``check_hlo_trips=True``).

NOTE: ``raise`` is a Python keyword — import this module as::

    raising = importlib.import_module("repro.core.raise")
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .tensor_ir import Graph, TensorType, Value

try:  # jax >= 0.4.x keeps Literal/DropVar in jax.core
    import jax
    import jax.numpy as jnp
    from jax.core import Literal as _JaxLiteral
    _HAVE_JAX = True
except Exception:  # pragma: no cover - jax is a baked-in dependency
    jax = None
    jnp = None
    _JaxLiteral = ()
    _HAVE_JAX = False


class RaiseError(ValueError):
    """A jaxpr fragment outside the raisable vocabulary.

    Carries the unraisable primitive's name and the offending equation so
    diagnostics (CLI, docs table, negative tests) can point at the source.
    """

    def __init__(self, msg: str, primitive: Optional[str] = None,
                 equation: Optional[str] = None):
        self.primitive = primitive
        self.equation = equation
        full = msg
        if primitive:
            full += f" [primitive: {primitive}]"
        if equation:
            eq = equation if len(equation) <= 400 else equation[:400] + "..."
            full += f"\n  in equation: {eq}"
        super().__init__(full)


def canon2d(shape: Sequence[int]) -> Tuple[int, int]:
    """n-D jax shape -> the rank-2 TensorIR shape it raises to."""
    shape = tuple(int(d) for d in shape)
    if any(d == 0 for d in shape):
        raise RaiseError(f"zero-sized dimension in shape {shape}")
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, shape[0])
    rows = 1
    for d in shape[:-1]:
        rows *= d
    return (rows, shape[-1])


@dataclasses.dataclass
class _RVal:
    """One jaxpr variable during raising.

    Exactly one of ``val`` (a rank-2 TensorIR SSA value) or ``const`` (a
    jax-shaped numpy payload, still foldable) is set; if neither is, ``note``
    says why, and the error surfaces only if the value is actually consumed
    (e.g. a scan's unused final carry).
    """

    jshape: Tuple[int, ...]
    val: Optional[Value] = None
    const: Optional[np.ndarray] = None
    note: Optional[str] = None


# numpy semantics for constant folding (float32 domain, matching backends)
_NP_BIN: Dict[str, Callable] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "maximum": np.maximum,
}
_NP_UN: Dict[str, Callable] = {
    "neg": lambda a: -a,
    "exp": np.exp,
    "tanh": np.tanh,
    "sigmoid": lambda a: 1.0 / (1.0 + np.exp(-a)),
    "sqrt": np.sqrt,
    "rsqrt": lambda a: 1.0 / np.sqrt(a),
    "log1p": np.log1p,
    "abs": np.abs,
    "relu": lambda a: np.maximum(a, 0),
}

# jax primitive -> TensorIR ewise op
_BIN_PRIMS = {"add": "add", "sub": "sub", "mul": "mul", "div": "div",
              "max": "maximum"}
_UN_PRIMS = {"exp": "exp", "neg": "neg", "tanh": "tanh",
             "logistic": "sigmoid", "rsqrt": "rsqrt", "sqrt": "sqrt",
             "log1p": "log1p", "abs": "abs"}

# primitives folded when ALL operands are constants (never emitted as ops)
_FOLD_ONLY = {
    "pow": np.power, "cos": np.cos, "sin": np.sin, "log": np.log,
    "floor": np.floor, "round": np.round, "sign": np.sign,
    "min": np.minimum,
    "lt": lambda a, b: a < b, "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b, "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b,
    "and": np.logical_and, "or": np.logical_or, "not": np.logical_not,
    "xor": np.logical_xor,
}

# ops the LoopIR lowering implements (cast/transpose print and eval but have
# no tile lowering — a graph containing them raises fine but can't compile)
_LOWERABLE_OPS = {"matmul", "bias_add", "reduce_sum", "reduce", "scan",
                  "add", "sub", "mul", "maximum", "div",
                  "relu", "gelu", "exp", "neg",
                  "tanh", "sigmoid", "sqrt", "rsqrt", "log1p", "abs"}

_CALL_PRIMS = {"pjit", "closed_call", "core_call", "custom_jvp_call",
               "custom_vjp_call", "remat", "checkpoint", "remat2"}
_IDENTITY_PRIMS = {"sharding_constraint", "stop_gradient", "copy",
                   "device_put", "convert_element_type"}


def _fold(fn, *args):
    """Constant folding runs on whatever values the trace produced (incl.
    inf masks); fold-domain warnings are jax-identical non-events."""
    with np.errstate(all="ignore"):
        return fn(*args)


def _npc(x) -> np.ndarray:
    """Constant payload -> float-friendly numpy (bools/ints kept for masks)."""
    a = np.asarray(x)
    if a.dtype == np.float64:
        a = a.astype(np.float32)
    return a


# --------------------------------------------------------------------------
# scan-body linearity analysis:  value == alpha * carry + beta
# --------------------------------------------------------------------------
# Expr nodes: ("xs", i) | ("outer", k) | ("lit", ndarray) |
#             ("un", op, e) | ("bin", op, e1, e2)

_E_ONE = ("lit", np.float32(1.0))


def _e_is_one(e) -> bool:
    return (e is not None and e[0] == "lit"
            and np.ndim(e[1]) == 0 and float(e[1]) == 1.0)


def _e_add(a, b, op="add"):
    if a is None:
        return b if op == "add" else ("un", "neg", b) if b is not None else None
    if b is None:
        return a
    return ("bin", op, a, b)


def _e_mul(a, b):
    if a is None or b is None:
        return None
    if _e_is_one(a):
        return b
    if _e_is_one(b):
        return a
    return ("bin", "mul", a, b)


@dataclasses.dataclass
class _LinVal:
    alpha: Optional[tuple]  # coefficient of the carry (None == 0)
    beta: Optional[tuple]   # carry-free part (None == 0)


def _linear_body(jaxpr, consts, num_consts: int, n_xs: int):
    """Interpret a scan body as affine in its single carry.

    Returns ``(alpha, beta)`` expression trees for the new carry, or raises
    :class:`RaiseError` if the body is nonlinear / outside the vocabulary.
    """
    env: Dict[Any, _LinVal] = {}

    def read(v) -> _LinVal:
        if isinstance(v, _JaxLiteral):
            return _LinVal(None, ("lit", _npc(v.val)))
        return env[v]

    for cv, cval in zip(jaxpr.constvars, consts):
        env[cv] = _LinVal(None, ("lit", _npc(cval)))
    for k in range(num_consts):
        env[jaxpr.invars[k]] = _LinVal(None, ("outer", k))
    env[jaxpr.invars[num_consts]] = _LinVal(_E_ONE, None)       # the carry
    for i in range(n_xs):
        env[jaxpr.invars[num_consts + 1 + i]] = _LinVal(None, ("xs", i))

    def fail(eqn, why):
        raise RaiseError(f"scan body not affine in the carry: {why}",
                         primitive=eqn.primitive.name, equation=str(eqn))

    def run(jx, jx_consts):
        for cv, cval in zip(jx.constvars, jx_consts):
            env[cv] = _LinVal(None, ("lit", _npc(cval)))
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            ins = [read(v) for v in eqn.invars]
            if prim in _CALL_PRIMS:
                cj = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr"))
                if cj is None or not hasattr(cj, "jaxpr"):
                    fail(eqn, "opaque call")
                for iv, rv in zip(cj.jaxpr.invars, ins):
                    env[iv] = rv
                run(cj.jaxpr, cj.consts)
                for ov, iv in zip(eqn.outvars, cj.jaxpr.outvars):
                    env[ov] = read(iv)
                continue
            if prim == "convert_element_type":
                env[eqn.outvars[0]] = ins[0]
                continue
            if prim in ("add", "sub"):
                a, b = ins
                out = _LinVal(_e_add(a.alpha, b.alpha, prim),
                              _e_add(a.beta, b.beta, prim))
            elif prim == "mul":
                a, b = ins
                if a.alpha is not None and b.alpha is not None:
                    fail(eqn, "carry * carry")
                if a.alpha is not None:        # (alpha*c + beta) * b
                    a, b = b, a
                out = _LinVal(_e_mul(a.beta, b.alpha),
                              _e_mul(a.beta, b.beta))
            elif prim == "div":
                a, b = ins
                if b.alpha is not None:
                    fail(eqn, "division by the carry")
                out = _LinVal(_e_mul(a.alpha, ("un", "_recip", b.beta))
                              if a.alpha is not None else None,
                              _e_mul(a.beta, ("un", "_recip", b.beta))
                              if a.beta is not None else None)
            elif prim == "neg":
                (a,) = ins
                out = _LinVal(("un", "neg", a.alpha) if a.alpha else None,
                              ("un", "neg", a.beta) if a.beta else None)
            elif prim == "max":
                a, b = ins
                if a.alpha is not None or b.alpha is not None:
                    fail(eqn, "max over the carry")
                out = _LinVal(None, ("bin", "maximum", a.beta, b.beta))
            elif prim in _UN_PRIMS:
                (a,) = ins
                if a.alpha is not None:
                    fail(eqn, f"nonlinear {prim} of the carry")
                out = _LinVal(None, ("un", _UN_PRIMS[prim], a.beta))
            elif prim == "broadcast_in_dim" or prim == "reshape" \
                    or prim == "squeeze":
                # per-step shapes are tiny; only shape-preserving views keep
                # the timestep<->full-array correspondence exact
                (a,) = ins
                if a.alpha is not None and not _e_is_one(a.alpha):
                    fail(eqn, f"{prim} of a carry-dependent value")
                out = a
            else:
                fail(eqn, f"unsupported body primitive {prim!r}")
            env[eqn.outvars[0]] = out

    run(jaxpr, consts)

    outs = [read(v) for v in jaxpr.outvars]
    if len(outs) != 2 or jaxpr.outvars[0] is not jaxpr.outvars[1]:
        raise RaiseError(
            "scan body must yield (new_carry, new_carry) — the carried "
            "TensorIR scan materialises every h_t",
            primitive="scan")
    new_carry = outs[0]
    if new_carry.alpha is None or new_carry.beta is None:
        raise RaiseError("scan body is not of the form a_t*h + u_t "
                         "(missing decay or update term)", primitive="scan")
    return new_carry.alpha, new_carry.beta


# --------------------------------------------------------------------------
# the raiser
# --------------------------------------------------------------------------


class _Raiser:
    def __init__(self, name: str):
        self.graph = Graph(name)
        self.const_bindings: Dict[str, np.ndarray] = {}
        self._const_cache: Dict[tuple, Value] = {}
        self.scan_lengths: List[int] = []

    # ---- const materialisation -------------------------------------------

    def _const_input(self, arr2d: np.ndarray) -> Value:
        arr2d = np.ascontiguousarray(arr2d, dtype=np.float32)
        key = (arr2d.shape, arr2d.tobytes())
        v = self._const_cache.get(key)
        if v is None:
            name = f"c{len(self.const_bindings)}"
            v = self.graph.add_input(name, TensorType(arr2d.shape))
            self.const_bindings[name] = arr2d
            self._const_cache[key] = v
        return v

    @staticmethod
    def _const2d(rv: _RVal, target: Optional[Tuple[int, int]]) -> np.ndarray:
        a = _npc(rv.const).astype(np.float32)
        if a.shape != tuple(rv.jshape):
            a = np.broadcast_to(a, rv.jshape)
        c = a.reshape(canon2d(rv.jshape))
        if target is not None and c.shape != tuple(target):
            if c.size == target[0] * target[1]:
                c = c.reshape(target)            # e.g. (1,N) -> (N,1)
            else:
                c = np.broadcast_to(c, target)
        return c

    def _need(self, rv: _RVal, eqn=None):
        if rv.val is None and rv.const is None:
            raise RaiseError(rv.note or "value is not raisable",
                             equation=str(eqn) if eqn is not None else None)

    def _mat(self, rv: _RVal, shape2d: Tuple[int, int]) -> Value:
        """The rank-2 SSA value for ``rv`` at exactly ``shape2d``."""
        self._need(rv)
        if rv.val is not None:
            if tuple(rv.val.type.shape) != tuple(shape2d):
                raise RaiseError(
                    f"cannot reconcile value of shape {rv.val.type.shape} "
                    f"with required shape {shape2d}")
            return rv.val
        return self._const_input(self._const2d(rv, shape2d))

    def _shape2(self, rv: _RVal) -> Tuple[int, int]:
        if rv.val is not None:
            return tuple(rv.val.type.shape)
        return canon2d(rv.jshape)

    def _force_full(self, rv: _RVal, jshape: Sequence[int]) -> Value:
        """``rv`` as a full ``canon2d(jshape)`` value, materialising any
        deferred broadcast (by ones-multiplication) or constant."""
        target = canon2d(jshape)
        if rv.const is not None:
            return self._const_input(self._const2d(rv, target))
        self._need(rv)
        s = tuple(rv.val.type.shape)
        if s == target:
            return rv.val
        if s == (target[1], target[0]) and 1 in s:
            # a keepdims-orientation vector, e.g. (N,1) vs (1,N): same data,
            # same linear order — a rank-2 transpose restores the layout
            return self.graph.emit("transpose", [rv.val], perm=(1, 0))
        if all(d in (1, t) for d, t in zip(s, target)):
            ones = self._const_input(np.ones(target, np.float32))
            return self.graph.emit("mul", [ones, rv.val])
        raise RaiseError(f"cannot broadcast value of shape {s} to {target}")

    # ---- elementwise ------------------------------------------------------

    def _ewise_un(self, op: str, a: _RVal, out_jshape, eqn=None) -> _RVal:
        self._need(a, eqn)
        if a.const is not None:
            return _RVal(tuple(out_jshape), const=_fold(
                _NP_UN[op], _npc(a.const).astype(np.float32)))
        return _RVal(tuple(out_jshape), val=self.graph.emit(op, [a.val]))

    def _ewise_bin(self, op: str, a: _RVal, b: _RVal, out_jshape,
                   eqn=None) -> _RVal:
        self._need(a, eqn)
        self._need(b, eqn)
        if a.const is not None and b.const is not None:
            return _RVal(tuple(out_jshape),
                         const=_fold(_NP_BIN[op],
                                     _npc(a.const).astype(np.float32),
                                     _npc(b.const).astype(np.float32)))
        # a rank-1 result may live in either orientation: (1,N) canonically,
        # or (N,1) when it flows out of a keepdims-free reduce
        targets = [canon2d(out_jshape)]
        if len(out_jshape) == 1 and out_jshape[0] != 1:
            targets.append((int(out_jshape[0]), 1))
        err = None
        for target in targets:
            try:
                return self._bin_at(op, a, b, target, tuple(out_jshape))
            except RaiseError as e:
                err = e
        raise RaiseError(
            f"unsupported ewise broadcast {self._shape2(a)} {op} "
            f"{self._shape2(b)} -> {targets[0]} ({err})",
            primitive=op, equation=str(eqn) if eqn is not None else None)

    def _bin_at(self, op: str, a: _RVal, b: _RVal,
                target: Tuple[int, int], out_jshape) -> _RVal:
        def cshape(rv):
            """The 2-D shape this operand takes against ``target`` (None if
            irreconcilable)."""
            if rv.val is not None:
                s = tuple(rv.val.type.shape)
                return s if all(d in (1, t)
                                for d, t in zip(s, target)) else None
            c = canon2d(rv.jshape)
            if all(d in (1, t) for d, t in zip(c, target)):
                return c
            if c[0] * c[1] == target[0] * target[1]:
                return target                    # reshapeable constant
            return None

        sa, sb = cshape(a), cshape(b)
        if sa is None or sb is None:
            raise RaiseError(f"operands {self._shape2(a)} / "
                             f"{self._shape2(b)} do not fit {target}")
        full_a, full_b = sa == target, sb == target
        if not full_a and not full_b:
            # a constant can always be blown up to the full shape
            if a.const is not None:
                full_a, sa = True, target
            elif b.const is not None:
                full_b, sb = True, target
            else:
                raise RaiseError(f"no full-rank operand for {target}")
        if full_a:
            v = self.graph.emit(op, [self._mat(a, target), self._mat(b, sb)])
            return _RVal(out_jshape, val=v)
        # full_b only: TensorIR ewise broadcasts the SECOND operand
        vb = self._mat(b, target)
        va = self._mat(a, sa)
        if op in ("add", "mul", "maximum"):
            return _RVal(out_jshape, val=self.graph.emit(op, [vb, va]))
        if op == "sub":                          # a - b == -(b - a)
            return _RVal(out_jshape, val=self.graph.emit(
                "neg", [self.graph.emit("sub", [vb, va])]))
        if op == "div" and a.const is not None:
            return _RVal(out_jshape, val=self.graph.emit(
                "div", [self._mat(a, target), vb]))
        raise RaiseError(f"non-commutative {op} with broadcast first operand")

    def _eval_expr(self, e, xs_rv: List[_RVal], outer_rv: List[_RVal]) -> _RVal:
        """Evaluate a scan-body expression tree over the *full* arrays."""
        kind = e[0]
        if kind == "xs":
            return xs_rv[e[1]]
        if kind == "outer":
            return outer_rv[e[1]]
        if kind == "lit":
            a = _npc(e[1])
            return _RVal(tuple(a.shape), const=a)
        if kind == "un":
            _, op, sub = e
            a = self._eval_expr(sub, xs_rv, outer_rv)
            if op == "_recip":                   # 1 / x
                one = _RVal((), const=np.float32(1.0))
                return self._ewise_bin("div", one, a, a.jshape)
            return self._ewise_un(op, a, a.jshape)
        _, op, e1, e2 = e
        a = self._eval_expr(e1, xs_rv, outer_rv)
        b = self._eval_expr(e2, xs_rv, outer_rv)
        out_jshape = np.broadcast_shapes(tuple(a.jshape), tuple(b.jshape))
        return self._ewise_bin(op, a, b, out_jshape)

    # ---- per-primitive handlers ------------------------------------------

    def _h_call(self, eqn, ins):
        cj = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        if cj is None:
            raise RaiseError("call primitive without an inlinable jaxpr",
                             primitive=eqn.primitive.name, equation=str(eqn))
        if hasattr(cj, "jaxpr"):                  # ClosedJaxpr
            inner, consts = cj.jaxpr, cj.consts
        elif hasattr(cj, "constvars") and not cj.constvars:
            inner, consts = cj, []                # raw Jaxpr (remat2)
        else:
            raise RaiseError("call primitive without an inlinable jaxpr",
                             primitive=eqn.primitive.name, equation=str(eqn))
        if len(inner.invars) != len(ins):
            raise RaiseError("call arity mismatch",
                             primitive=eqn.primitive.name, equation=str(eqn))
        return self.run(inner, consts, ins)

    def _h_bin(self, eqn, ins):
        op = _BIN_PRIMS[eqn.primitive.name]
        return [self._ewise_bin(op, ins[0], ins[1],
                                eqn.outvars[0].aval.shape, eqn)]

    def _h_un(self, eqn, ins):
        op = _UN_PRIMS[eqn.primitive.name]
        return [self._ewise_un(op, ins[0], eqn.outvars[0].aval.shape, eqn)]

    def _h_fold_only(self, eqn, ins):
        prim = eqn.primitive.name
        for rv in ins:
            if rv.const is None:
                if prim in ("lt", "le", "gt", "ge", "eq"):
                    # defer: only an all-const select_n may consume this
                    return [_RVal(tuple(eqn.outvars[0].aval.shape),
                                  note=f"non-constant comparison "
                                       f"{prim!r} (boolean dtype has no "
                                       f"TensorIR representation)")]
                raise RaiseError(
                    f"primitive {prim!r} is only supported on constants",
                    primitive=prim, equation=str(eqn))
        out = _fold(_FOLD_ONLY[prim], *[_npc(rv.const) for rv in ins])
        return [_RVal(tuple(eqn.outvars[0].aval.shape), const=np.asarray(out))]

    def _h_integer_pow(self, eqn, ins):
        y = eqn.params["y"]
        (a,) = ins
        out_jshape = tuple(eqn.outvars[0].aval.shape)
        if a.const is not None:
            return [_RVal(out_jshape,
                          const=_npc(a.const).astype(np.float32) ** y)]
        if y == 2:
            return [self._ewise_bin("mul", a, a, out_jshape, eqn)]
        if y == 3:
            sq = self._ewise_bin("mul", a, a, out_jshape, eqn)
            return [self._ewise_bin("mul", sq, a, out_jshape, eqn)]
        raise RaiseError(f"integer_pow with exponent {y}",
                         primitive="integer_pow", equation=str(eqn))

    def _h_identity(self, eqn, ins):
        prim = eqn.primitive.name
        (a,) = ins[:1]
        if prim == "convert_element_type":
            nd = np.dtype(eqn.params["new_dtype"])
            if a.const is not None:
                return [dataclasses.replace(a, const=_npc(a.const).astype(
                    np.float32 if nd.kind == "f" else nd))]
            if nd.kind != "f":
                raise RaiseError(
                    f"convert_element_type to non-float {nd} on a traced "
                    f"value", primitive=prim, equation=str(eqn))
            # the raised pipeline computes in float32 throughout
        return [dataclasses.replace(a,
                                    jshape=tuple(eqn.outvars[0].aval.shape))]

    def _reshape_like(self, eqn, a: _RVal, new_shape) -> List[_RVal]:
        new_shape = tuple(int(d) for d in new_shape)
        if a.const is not None:
            arr = _npc(a.const)
            if arr.shape != tuple(a.jshape):
                arr = np.broadcast_to(arr, a.jshape)
            return [_RVal(new_shape, const=arr.reshape(new_shape))]
        self._need(a, eqn)
        target = canon2d(new_shape)
        s = tuple(a.val.type.shape)
        if s == target or all(d in (1, t) for d, t in zip(s, target)):
            return [dataclasses.replace(a, jshape=new_shape)]
        raise RaiseError(
            f"reshape {tuple(a.jshape)} -> {new_shape} does not preserve the "
            f"rank-2 canonical layout {s} -> {target}",
            primitive=eqn.primitive.name, equation=str(eqn))

    def _h_reshape(self, eqn, ins):
        if eqn.params.get("dimensions") is not None:
            raise RaiseError("reshape with dimension permutation",
                             primitive="reshape", equation=str(eqn))
        return self._reshape_like(eqn, ins[0], eqn.params["new_sizes"])

    def _h_squeeze(self, eqn, ins):
        return self._reshape_like(eqn, ins[0], eqn.outvars[0].aval.shape)

    def _h_broadcast_in_dim(self, eqn, ins):
        (a,) = ins
        shape = tuple(int(d) for d in eqn.params["shape"])
        bd = tuple(eqn.params["broadcast_dimensions"])
        if a.const is not None:
            arr = _npc(a.const)
            if arr.shape != tuple(a.jshape):
                arr = np.broadcast_to(arr, a.jshape)
            vshape = [1] * len(shape)
            for i, d in enumerate(bd):
                vshape[d] = arr.shape[i]
            return [_RVal(shape,
                          const=np.broadcast_to(arr.reshape(vshape), shape))]
        self._need(a, eqn)
        vshape = [1] * len(shape)
        for i, d in enumerate(bd):
            vshape[d] = a.jshape[i]
        if tuple(vshape) == shape:               # a pure reshape
            return self._reshape_like(eqn, a, shape)
        # a real broadcast: keep the (smaller) value, defer materialisation
        # to the consumer — legal when the rank-2 layout still broadcasts
        # the same way (dims 1-or-full against canon2d(shape))
        target = canon2d(shape)
        s = tuple(a.val.type.shape)
        if all(d in (1, t) for d, t in zip(s, target)):
            return [dataclasses.replace(a, jshape=shape)]
        raise RaiseError(
            f"broadcast {tuple(a.jshape)} -> {shape} is not expressible in "
            f"the rank-2 layout (value has shape {s})",
            primitive="broadcast_in_dim", equation=str(eqn))

    def _h_transpose(self, eqn, ins):
        (a,) = ins
        perm = tuple(eqn.params["permutation"])
        new_shape = tuple(a.jshape[p] for p in perm)
        if a.const is not None:
            arr = _npc(a.const)
            if arr.shape != tuple(a.jshape):
                arr = np.broadcast_to(arr, a.jshape)
            return [_RVal(new_shape, const=np.transpose(arr, perm))]
        self._need(a, eqn)
        nonunit = [p for p in perm if a.jshape[p] != 1]
        if nonunit == sorted(nonunit):           # only unit dims moved
            return self._reshape_like(eqn, a, new_shape)
        if len(a.jshape) == 2 and perm == (1, 0) \
                and tuple(a.val.type.shape) == canon2d(a.jshape):
            v = self.graph.emit("transpose", [a.val], perm=(1, 0))
            return [_RVal(new_shape, val=v)]
        raise RaiseError(
            f"transpose {perm} of a traced {tuple(a.jshape)} value",
            primitive="transpose", equation=str(eqn))

    def _h_reduce(self, eqn, ins):
        prim = eqn.primitive.name
        kind = "sum" if prim == "reduce_sum" else "max"
        axes = tuple(eqn.params["axes"])
        (a,) = ins
        out_jshape = tuple(eqn.outvars[0].aval.shape)
        if a.const is not None:
            fn = np.sum if kind == "sum" else np.max
            arr = _npc(a.const).astype(np.float32)
            if arr.shape != tuple(a.jshape):
                arr = np.broadcast_to(arr, a.jshape)
            return [_RVal(out_jshape, const=fn(arr, axis=axes))]
        jrank = len(a.jshape)
        if axes != (jrank - 1,):
            raise RaiseError(
                f"reduce over axes {axes} of a rank-{jrank} value — only a "
                f"last-axis (column) reduction maps to the carried TensorIR "
                f"reduce", primitive=prim, equation=str(eqn))
        va = self._force_full(a, a.jshape)
        v = self.graph.emit("reduce", [va], kind=kind, axis=1, keepdims=True)
        return [_RVal(out_jshape, val=v)]

    def _h_dot_general(self, eqn, ins):
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        a, b = ins
        out_jshape = tuple(eqn.outvars[0].aval.shape)
        if a.const is not None and b.const is not None:
            out = np.tensordot(_npc(a.const).astype(np.float32),
                               _npc(b.const).astype(np.float32),
                               axes=(lc, rc))
            return [_RVal(out_jshape, const=out)]
        if lb or rb:
            raise RaiseError("dot_general with batch dimensions",
                             primitive="dot_general", equation=str(eqn))
        if len(lc) != 1 or len(rc) != 1:
            raise RaiseError("dot_general with multiple contraction dims",
                             primitive="dot_general", equation=str(eqn))
        if lc[0] != len(a.jshape) - 1:
            if a.const is not None:
                arr = np.moveaxis(_npc(a.const).astype(np.float32), lc[0], -1)
                a = _RVal(arr.shape, const=arr)
            else:
                raise RaiseError(
                    f"dot_general contracting lhs axis {lc[0]} of a rank-"
                    f"{len(a.jshape)} traced value (only the last axis maps "
                    f"to matmul)", primitive="dot_general", equation=str(eqn))
        if len(b.jshape) != 2:
            raise RaiseError(
                f"dot_general rhs must be rank-2, got {tuple(b.jshape)}",
                primitive="dot_general", equation=str(eqn))
        if rc[0] == 1:                           # contract rhs columns
            if b.const is not None:
                arr = _npc(b.const).astype(np.float32)
                if arr.shape != tuple(b.jshape):
                    arr = np.broadcast_to(arr, b.jshape)
                b = _RVal((b.jshape[1], b.jshape[0]), const=arr.T)
            else:
                self._need(b, eqn)
                v = self.graph.emit("transpose", [
                    self._force_full(b, b.jshape)], perm=(1, 0))
                b = _RVal((b.jshape[1], b.jshape[0]), val=v)
        va = self._force_full(a, a.jshape)
        k = int(a.jshape[-1])
        vb = self._mat(b, (k, int(b.jshape[1])))
        v = self.graph.emit("matmul", [va, vb])
        return [_RVal(out_jshape, val=v)]

    def _h_select_n(self, eqn, ins):
        pred, *cases = ins
        out_jshape = tuple(eqn.outvars[0].aval.shape)
        if pred.note is not None and "nan_guard" in pred.note:
            # x != x NaN-guard (e.g. jax.nn.softplus): the guarded branch
            # never fires for finite float32 pipelines — take the main value
            return [dataclasses.replace(cases[0], jshape=out_jshape)]
        if pred.const is not None and len(cases) == 2:
            p = _npc(pred.const)
            if p.dtype != np.bool_:
                p = p.astype(bool)
            p = np.broadcast_to(p, pred.jshape) if p.shape != tuple(
                pred.jshape) else p
            if not p.any():
                return [dataclasses.replace(cases[0], jshape=out_jshape)]
            if p.all():
                return [dataclasses.replace(cases[1], jshape=out_jshape)]
            pf = _RVal(tuple(pred.jshape), const=p.astype(np.float32))
            pn = _RVal(tuple(pred.jshape),
                       const=(1.0 - p.astype(np.float32)))
            t0 = self._ewise_bin("mul", cases[0], pn, out_jshape, eqn)
            t1 = self._ewise_bin("mul", cases[1], pf, out_jshape, eqn)
            return [self._ewise_bin("add", t0, t1, out_jshape, eqn)]
        raise RaiseError("select_n with a traced (non-constant) predicate",
                         primitive="select_n", equation=str(eqn))

    def _h_ne(self, eqn, ins):
        a, b = eqn.invars
        if a is b:                               # x != x: the NaN guard
            return [_RVal(tuple(eqn.outvars[0].aval.shape),
                          note="nan_guard comparison x != x")]
        if ins[0].const is not None and ins[1].const is not None:
            out = _npc(ins[0].const) != _npc(ins[1].const)
            return [_RVal(tuple(eqn.outvars[0].aval.shape),
                          const=np.asarray(out))]
        return [_RVal(tuple(eqn.outvars[0].aval.shape),
                      note="non-constant comparison 'ne' (boolean dtype has "
                           "no TensorIR representation)")]

    def _h_iota(self, eqn, ins):
        shape = tuple(int(d) for d in eqn.params["shape"])
        dim = eqn.params["dimension"]
        vshape = [1] * len(shape)
        vshape[dim] = shape[dim]
        arr = np.broadcast_to(
            np.arange(shape[dim], dtype=np.float32).reshape(vshape), shape)
        return [_RVal(shape, const=arr)]

    def _h_cumsum(self, eqn, ins):
        (a,) = ins
        out_jshape = tuple(eqn.outvars[0].aval.shape)
        if eqn.params.get("reverse"):
            raise RaiseError("reverse cumsum", primitive="cumsum",
                             equation=str(eqn))
        if a.const is not None:
            arr = _npc(a.const).astype(np.float32)
            return [_RVal(out_jshape,
                          const=np.cumsum(arr, axis=eqn.params["axis"]))]
        if len(a.jshape) != 2 or eqn.params["axis"] != 0:
            raise RaiseError(
                f"cumsum over axis {eqn.params['axis']} of a rank-"
                f"{len(a.jshape)} value — TensorIR scan runs over axis 0 of "
                f"a rank-2 value", primitive="cumsum", equation=str(eqn))
        va = self._force_full(a, a.jshape)
        v = self.graph.emit("scan", [va], kind="cumsum", axis=0)
        self.scan_lengths.append(int(a.jshape[0]))
        return [_RVal(out_jshape, val=v)]

    def _h_scan(self, eqn, ins):
        p = eqn.params
        if p.get("reverse"):
            raise RaiseError("reverse-time scan", primitive="scan",
                             equation=str(eqn))
        num_consts, num_carry = p["num_consts"], p["num_carry"]
        if num_carry != 1:
            raise RaiseError(f"scan with {num_carry} carries (only a single "
                             f"carried state raises)", primitive="scan",
                             equation=str(eqn))
        closed = p["jaxpr"]
        length = int(p["length"])
        outer_rv = ins[:num_consts]
        carry_rv = ins[num_consts]
        xs_rv = ins[num_consts + 1:]
        if carry_rv.const is None or np.any(_npc(carry_rv.const) != 0):
            raise RaiseError(
                "scan carry must be initialised to a constant zero array "
                "(h_0 = 0 in the carried TensorIR scan)",
                primitive="scan", equation=str(eqn))
        if len(carry_rv.jshape) > 1:
            raise RaiseError(
                f"scan carry of rank {len(carry_rv.jshape)} (the rank-2 "
                f"TensorIR scan carries one row)", primitive="scan",
                equation=str(eqn))
        for rv in xs_rv:
            if len(rv.jshape) < 2 and rv.const is None:
                raise RaiseError(
                    "scan over a rank-1 traced sequence (time must be a row "
                    "axis in the rank-2 layout)", primitive="scan",
                    equation=str(eqn))
        alpha, beta = _linear_body(closed.jaxpr, closed.consts,
                                   num_consts, len(xs_rv))
        ys_jshape = tuple(eqn.outvars[1].aval.shape)
        if _e_is_one(alpha):                     # h_t = h_{t-1} + u_t
            u = self._eval_expr(beta, xs_rv, outer_rv)
            vu = self._force_full(u, ys_jshape)
            v = self.graph.emit("scan", [vu], kind="cumsum", axis=0)
        else:
            a = self._eval_expr(alpha, xs_rv, outer_rv)
            u = self._eval_expr(beta, xs_rv, outer_rv)
            va = self._force_full(a, ys_jshape)
            vu = self._force_full(u, ys_jshape)
            v = self.graph.emit("scan", [va, vu], kind="linear", axis=0)
        self.scan_lengths.append(length)
        ys = _RVal(ys_jshape, val=v)
        final = _RVal(tuple(eqn.outvars[0].aval.shape),
                      note="the scan's final carry (only the full h_t "
                           "sequence is materialised by TensorIR scan)")
        return [final, ys]

    # ---- driver -----------------------------------------------------------

    _HANDLERS: Dict[str, Callable] = {}

    def run(self, jaxpr, consts, invals: List[_RVal]) -> List[_RVal]:
        env: Dict[Any, _RVal] = {}

        def read(v) -> _RVal:
            if isinstance(v, _JaxLiteral):
                val = _npc(v.val)
                return _RVal(tuple(np.shape(val)), const=val)
            return env[v]

        for cv, cval in zip(jaxpr.constvars, consts):
            arr = _npc(cval)
            env[cv] = _RVal(tuple(arr.shape), const=arr)
        if len(jaxpr.invars) != len(invals):
            raise RaiseError("jaxpr arity mismatch")
        for iv, rv in zip(jaxpr.invars, invals):
            env[iv] = rv
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            handler = self._HANDLERS.get(prim)
            if handler is None:
                raise RaiseError(
                    f"primitive {prim!r} is outside the raisable vocabulary",
                    primitive=prim, equation=str(eqn))
            ins = [read(v) for v in eqn.invars]
            try:
                outs = handler(self, eqn, ins)
            except RaiseError:
                raise
            except Exception as e:               # defensive: name the site
                raise RaiseError(f"failed to raise: {e}", primitive=prim,
                                 equation=str(eqn))
            for ov, rv in zip(eqn.outvars, outs):
                env[ov] = rv
        return [read(v) for v in jaxpr.outvars]

    def output_value(self, rv: _RVal) -> Value:
        return self._force_full(rv, rv.jshape)


_Raiser._HANDLERS.update({p: _Raiser._h_call for p in _CALL_PRIMS})
_Raiser._HANDLERS.update({p: _Raiser._h_bin for p in _BIN_PRIMS})
_Raiser._HANDLERS.update({p: _Raiser._h_un for p in _UN_PRIMS})
_Raiser._HANDLERS.update({p: _Raiser._h_fold_only for p in _FOLD_ONLY})
_Raiser._HANDLERS.update({p: _Raiser._h_identity for p in _IDENTITY_PRIMS})
_Raiser._HANDLERS.update({
    "integer_pow": _Raiser._h_integer_pow,
    "reshape": _Raiser._h_reshape,
    "squeeze": _Raiser._h_squeeze,
    "broadcast_in_dim": _Raiser._h_broadcast_in_dim,
    "transpose": _Raiser._h_transpose,
    "reduce_sum": _Raiser._h_reduce,
    "reduce_max": _Raiser._h_reduce,
    "dot_general": _Raiser._h_dot_general,
    "select_n": _Raiser._h_select_n,
    "ne": _Raiser._h_ne,
    "iota": _Raiser._h_iota,
    "cumsum": _Raiser._h_cumsum,
    "scan": _Raiser._h_scan,
})


# --------------------------------------------------------------------------
# public artifact
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RaisedGraph:
    """A TensorIR graph raised from a traced JAX function.

    ``graph`` takes the user arguments (``arg0``...) first, then the
    lazily-materialised constants (``c0``...); :meth:`bind` rebuilds the full
    positional input list from just the user arguments.
    """

    graph: Graph
    const_bindings: Dict[str, np.ndarray]
    n_args: int
    arg_shapes: List[Tuple[int, ...]]
    out_shapes: List[Tuple[int, ...]]
    scan_lengths: List[int]
    hlo_trips: Optional[Dict[str, int]] = None

    @property
    def unlowerable_ops(self) -> List[str]:
        return sorted({op.opname for op in self.graph.ops
                       if op.opname not in _LOWERABLE_OPS})

    @property
    def lowerable(self) -> bool:
        return not self.unlowerable_ops

    def bind(self, *args) -> List[np.ndarray]:
        if len(args) != self.n_args:
            raise ValueError(f"{self.graph.name} takes {self.n_args} "
                             f"arguments, got {len(args)}")
        bound = []
        for v, a in zip(self.graph.inputs[:self.n_args], args):
            arr = np.asarray(a, np.float32).reshape(v.type.shape)
            bound.append(arr)
        for v in self.graph.inputs[self.n_args:]:
            bound.append(self.const_bindings[v.name])
        return bound

    def run_ref(self, *args) -> List[np.ndarray]:
        outs = self.graph.eval_np(*self.bind(*args))
        return [o.reshape(s) for o, s in zip(outs, self.out_shapes)]

    def compile(self, **kw):
        from . import pipeline
        return pipeline.compile_traced(self.graph, **kw)

    def run_compiled(self, compiled, *args, backend: str = "jax"):
        fn = {"ref": compiled.run_ref, "jax": compiled.run_jax,
              "pallas": compiled.run_pallas}[backend]
        outs = fn(*self.bind(*args))
        return [np.asarray(o).reshape(s)
                for o, s in zip(outs, self.out_shapes)]

    def explore(self, **kw):
        from . import dse
        return dse.explore(self.graph, **kw)


def _as_aval(s):
    if hasattr(s, "shape") and hasattr(s, "dtype"):
        return jax.ShapeDtypeStruct(tuple(s.shape), jnp.float32)
    return jax.ShapeDtypeStruct(tuple(s), jnp.float32)


def _sanitize(name: str) -> str:
    return re.sub(r"[^\w.\-]", "_", name)


def raise_jaxpr(fn: Callable, *in_specs, name: Optional[str] = None,
                check_hlo_trips: bool = False) -> RaisedGraph:
    """Trace ``fn`` at ``in_specs`` (shapes / arrays / specs) and raise the
    jaxpr into a TensorIR :class:`RaisedGraph`.

    With ``check_hlo_trips=True``, also compiles ``fn`` through XLA and
    cross-checks the scan lengths recovered by raising against the
    ``while``-loop trip counts ``launch.hlo_analysis`` walks out of the
    optimized HLO text.
    """
    if not _HAVE_JAX:                            # pragma: no cover
        raise RuntimeError("raise_jaxpr requires jax")
    avals = [_as_aval(s) for s in in_specs]
    closed = jax.make_jaxpr(fn)(*avals)
    gname = _sanitize(name or getattr(fn, "__name__", "raised"))
    r = _Raiser(gname)
    invals = []
    for i, a in enumerate(avals):
        v = r.graph.add_input(f"arg{i}", TensorType(canon2d(a.shape)))
        invals.append(_RVal(tuple(a.shape), val=v))
    outs = r.run(closed.jaxpr, closed.consts, invals)
    out_vals = [r.output_value(rv) for rv in outs]
    r.graph.set_outputs(*out_vals)
    r.graph.verify()
    hlo_trips = None
    if check_hlo_trips:
        hlo_trips = hlo_while_trips(fn, avals)
        for length in r.scan_lengths:
            if hlo_trips and length not in hlo_trips.values():
                raise RaiseError(
                    f"raised scan length {length} not found among HLO while "
                    f"trip counts {hlo_trips} — raising and the compiled "
                    f"module disagree about the recurrence")
    return RaisedGraph(graph=r.graph, const_bindings=r.const_bindings,
                       n_args=len(avals),
                       arg_shapes=[tuple(a.shape) for a in avals],
                       out_shapes=[tuple(rv.jshape) for rv in outs],
                       scan_lengths=list(r.scan_lengths),
                       hlo_trips=hlo_trips)


def hlo_while_trips(fn: Callable, avals) -> Dict[str, int]:
    """Trip counts of every ``while`` loop in the XLA-optimized HLO of
    ``fn``, via the call-graph walk in ``launch.hlo_analysis``."""
    from ..launch.hlo_analysis import analyze_hlo_module
    text = jax.jit(fn).lower(*avals).compile().as_text()
    return dict(analyze_hlo_module(text).while_trips)


# --------------------------------------------------------------------------
# hand-written kernel mirrors (equivalence targets for tests)
# --------------------------------------------------------------------------


def _flash_fn(q, kt, v, mask):
    s = q @ kt + mask
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    return (p @ v) / l


def reference_flash(sq: int, sk: int, d: int,
                    name: Optional[str] = None) -> RaisedGraph:
    """Raise the jnp spelling of flash attention; canonical-identical to
    ``frontend.flash_attention_graph(sq, sk, d)``."""
    return raise_jaxpr(_flash_fn, (sq, d), (d, sk), (sk, d), (sq, sk),
                       name=name or f"flash_{sq}x{sk}x{d}")


def reference_decode(rep: int, smax: int, hd: int,
                     name: Optional[str] = None) -> RaisedGraph:
    return raise_jaxpr(_flash_fn, (rep, hd), (hd, smax), (smax, hd),
                       (rep, smax), name=name or f"decode_{rep}x{smax}x{hd}")


def _scan_linear(a, u):
    def step(h, xs):
        a_t, u_t = xs
        h = a_t * h + u_t
        return h, h
    h0 = jnp.zeros(a.shape[1:], jnp.float32)
    _, ys = jax.lax.scan(step, h0, (a, u))
    return ys


def reference_ssd(s: int, p: int, n: int,
                  name: Optional[str] = None) -> RaisedGraph:
    """Raise the jnp spelling of the SSD recurrence; canonical-identical to
    ``frontend.ssd_scan_graph(s, p, n)``."""
    pn = p * n

    def f(a, u, ct, g):
        h = _scan_linear(a, u)
        return (h * ct) @ g
    return raise_jaxpr(f, (s, pn), (s, pn), (s, pn), (pn, p),
                       name=name or f"ssd_{s}x{p}x{n}")


# --------------------------------------------------------------------------
# per-config model blocks
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BlockReport:
    """One per-config forward-pass region and its raising outcome."""

    config: str
    block: str
    fn: Callable
    example_inputs: Tuple[np.ndarray, ...]
    raised: Optional[RaisedGraph] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.raised is not None


def model_block_suite(config_name: str, seq: int = 8, seed: int = 0
                      ) -> Dict[str, Tuple[Callable, tuple]]:
    """The fused forward-pass regions of one (reduced) config, as plain jax
    functions over example inputs — the raising corpus.

    Deliberately includes regions known to be outside the vocabulary (rope's
    slice/concatenate, the MoE router's top_k) so the raisability table and
    the diagnostics tests have real negative rows.
    """
    from ..configs.base import get_config, reduced
    from ..models import layers as L

    cfg = reduced(get_config(config_name))
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    kinds = set(cfg.layer_kinds())

    def randn(*shape, scale=1.0):
        return (scale * rng.standard_normal(shape)).astype(np.float32)

    blocks: Dict[str, Tuple[Callable, tuple]] = {}
    x = randn(1, seq, d)
    w_norm = jnp.asarray(randn(d, scale=0.1))
    blocks["rmsnorm"] = (
        lambda x: L.rmsnorm(x, w_norm, cfg.norm_eps), (x,))

    has_dense_mlp = bool(kinds & {"attn", "rglru"}) or (
        cfg.moe is not None and cfg.moe.first_dense_layers > 0)
    if has_dense_mlp:
        mk = L.Maker("init", jax.random.PRNGKey(seed))
        mlp_p = L.init_mlp(cfg, mk)
        blocks["mlp"] = (lambda x: L.apply_mlp(mlp_p, x, cfg), (x,))

    vocab = min(cfg.vocab_size, 256)
    w_head_norm = jnp.asarray(randn(d, scale=0.1))
    if cfg.tie_embeddings:
        w_emb = jnp.asarray(randn(vocab, d, scale=0.05))

        def head(x):
            h = L.rmsnorm(x, w_head_norm, cfg.norm_eps)
            return jnp.einsum("bsd,vd->bsv", h, w_emb)
    else:
        w_head = jnp.asarray(randn(d, vocab, scale=0.05))

        def head(x):
            h = L.rmsnorm(x, w_head_norm, cfg.norm_eps)
            return jnp.einsum("bsd,dv->bsv", h, w_head)
    blocks["head"] = (head, (x,))

    if "attn" in kinds or cfg.encoder is not None or cfg.mla is not None:
        hd = cfg.resolved_head_dim
        scale = 1.0 / np.sqrt(hd)
        mask = np.where(np.arange(seq)[:, None] >= np.arange(seq)[None, :],
                        0.0, -1e30).astype(np.float32)
        blocks["attn_softmax"] = (
            _flash_fn, (randn(seq, hd, scale=scale), randn(hd, seq),
                        randn(seq, hd), mask))

        x4 = randn(1, seq, 2, hd if hd % 2 == 0 else hd + 1)
        positions = jnp.arange(seq, dtype=jnp.int32)[None, :]
        blocks["rope"] = (
            lambda x4: L.rope(x4, positions, cfg.rope_theta), (x4,))

    if "ssd" in kinds:
        p_dim, n_dim = 4, 4
        pn = p_dim * n_dim
        a = rng.uniform(0.2, 0.95, (seq, pn)).astype(np.float32)
        g = np.kron(np.eye(p_dim), np.ones((n_dim, 1))).astype(np.float32)

        def ssd_core(a, u, ct, g):
            h = _scan_linear(a, u)
            return (h * ct) @ g
        blocks["ssd_core"] = (ssd_core,
                              (a, randn(seq, pn), randn(seq, pn), g))

    if "rglru" in kinds:
        w = (cfg.rglru.width or d) if cfg.rglru is not None else d
        c = cfg.rglru.c if cfg.rglru is not None else 8.0
        a_param = jnp.asarray(randn(w))

        def rglru_core(x2, a_gate, i_gate):
            log_a = -c * jax.nn.softplus(a_param)[None, :] \
                * jax.nn.sigmoid(a_gate)
            a = jnp.exp(log_a)
            gated = jax.nn.sigmoid(i_gate) * x2
            mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))

            def step(h, inp):
                a_t, gx_t, m_t = inp
                h = a_t * h + m_t * gx_t
                return h, h
            h0 = jnp.zeros((x2.shape[1],), jnp.float32)
            _, hs = jax.lax.scan(step, h0, (a, gated, mult))
            return hs
        blocks["rglru_core"] = (
            rglru_core, (randn(seq, w), randn(seq, w), randn(seq, w)))

    if cfg.moe is not None:
        n_exp = cfg.moe.num_experts
        top_k = cfg.moe.top_k
        w_router = jnp.asarray(randn(d, n_exp, scale=0.05))

        def moe_router(x2):
            logits = x2 @ w_router
            probs = jax.nn.softmax(logits, axis=-1)
            vals, _ = jax.lax.top_k(probs, top_k)
            return vals
        blocks["moe_router"] = (moe_router, (randn(seq, d),))

    return blocks


def raise_model_blocks(config_name: str, seq: int = 8, seed: int = 0,
                       check_hlo_trips: bool = False) -> List[BlockReport]:
    """Raise every block of one config; failures become diagnostics, not
    exceptions."""
    suite = model_block_suite(config_name, seq=seq, seed=seed)
    reports = []
    for block, (fn, inputs) in suite.items():
        rep = BlockReport(config=config_name, block=block, fn=fn,
                          example_inputs=tuple(inputs))
        try:
            rep.raised = raise_jaxpr(
                fn, *inputs, name=f"{config_name}.{block}",
                check_hlo_trips=check_hlo_trips)
        except RaiseError as e:
            rep.error = str(e)
        reports.append(rep)
        if rep.raised is not None:
            # the raised graph must agree with the traced function on the
            # example inputs — raising is only useful if it is *correct*
            pass
    return reports


def raising_report(config_name: str, seq: int = 8, seed: int = 0) -> str:
    """Human-readable per-block raising report (used by ``reproc --raise
    CONFIG`` and the generated docs)."""
    reports = raise_model_blocks(config_name, seq=seq, seed=seed)
    lines = [f"// raising report for config {config_name} "
             f"(seq={seq}, reduced)"]
    for rep in reports:
        if rep.ok:
            rg = rep.raised
            lines.append(f"// block {rep.block}: RAISED — "
                         f"{len(rg.graph.ops)} ops, "
                         f"{len(rg.graph.inputs) - rg.n_args} captured "
                         f"consts, lowerable={rg.lowerable}")
            lines.append(str(rg.graph))
        else:
            first = rep.error.splitlines()[0]
            lines.append(f"// block {rep.block}: NOT RAISABLE — {first}")
    return "\n".join(lines) + "\n"
