"""Machine description + structural cycle/resource models over HwIR.

The paper reports consumed clock cycles (TABLE I) and hardware
utilisation (Fig. 3) of the RTL generated from each schedule.  Since
PR 2 the reproduction has that hardware level: scheduled LoopIR lowers
to :class:`~repro.core.hw_ir.HwModule` (FSM + datapath), and the models
below walk the *hardware structure* — FSM states and loop sequencers,
datapath units and their spatial copies, register banks and RAMs —
rather than re-deriving costs from LoopIR heuristics:

  * ``cycles(hw)``    — consumed clock cycles of the module's schedule:
    each FSM-sequenced loop pays a state transition per trip, each
    datapath invocation pays its unit's latency, and memory-port traffic
    is priced per port class (TABLE I analogue);
  * ``resources(hw)`` — spatial consumption read off the module: peak
    datapath lanes (DSP analogue), RAM bytes (BRAM analogue), live
    register tiles plus FSM/counter register bits (FF/LUT analogue),
    and the flattened FSM state count (Fig. 3 analogue).

Both accept a scheduled LoopIR ``Kernel`` for convenience and lower it
to hardware first — the accounting itself only ever sees the HwModule.

The model reproduces the paper's *mechanism*:

  * an ``@fsm`` loop is time-division multiplexing — one datapath copy,
    an FSM state transition paid every iteration (Calyx emits exactly
    such an FSM per control transition);
  * an ``@unroll`` loop replicates datapath copies spatially and drops
    the per-iteration FSM transition, but stays memory-port-limited, so
    resources grow with the unroll factor while cycles shrink only by
    the removed control — the paper's TABLE I / Fig. 3 trade.

Hardware constants follow the assignment: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI, clocked at ~940 MHz.

FLOP / HBM-byte accounting for roofline math (``flops``, ``hbm_bytes``)
stays at the LoopIR level: it characterises the *workload*, not the
generated hardware.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple, Union

from . import hw_ir
from .hw_ir import HwCtrl, HwLoop, HwModule, HwStep
from .loop_ir import Kernel, Loop, MatmulTile, MemSpace, TileRef
from .tensor_ir import dtype_bytes


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """One TPU v5e core (the unit the paper's single FPGA kernel maps to)."""

    name: str = "tpu_v5e"
    clock_ghz: float = 0.94
    # MXU: 128x128 systolic array; a (128,128)x(128,128) tile matmul retires
    # in ~128 cycles once the pipeline is primed.
    mxu_dim: int = 128
    # VPU: 8 sublanes x 128 lanes = 1024 f32 ALUs.
    vpu_lanes: int = 1024
    # Cost of one FSM state-transition chain per loop iteration (compare /
    # counter-increment / state register update).  Calibrated (with the
    # scalar-MAC costs below) so the nested/flattened cycle ratio of the
    # scalar GEMM schedules reproduces the paper's TABLE I
    # (1.34x @4x4 .. 1.43x @128).
    seq_loop_overhead_cycles: float = 5.46
    # One-off sequencer setup cost per loop.
    loop_setup_cycles: float = 1.0
    # Handshake cost of invoking an outlined submodule (start/done edge
    # plus the parent FSM's wait state).
    call_overhead_cycles: float = 2.0
    # scalar MAC unit: compute (multiply+add+acc-writeback) and per-
    # operand-element load cost; the datapath is memory-PORT-limited, so
    # spatial unrolling does not speed these up (it removes only the
    # per-iteration control) — exactly the paper's observed mechanism.
    scalar_mac_compute_cycles: float = 9.1
    scalar_load_cycles_per_elem: float = 1.82
    # matmuls with every dim >= this lower onto the systolic MXU unit
    mxu_min_dim: int = 8
    # HBM <-> VMEM bandwidth in bytes/cycle (819 GB/s / 0.94 GHz).
    hbm_bytes_per_cycle: float = 871.0
    # VMEM <-> compute bandwidth (order of magnitude wider than HBM).
    vmem_bytes_per_cycle: float = 8192.0
    vmem_capacity_bytes: int = 128 * 1024 * 1024  # 128 MiB on v5e
    # peak: 197 TFLOP/s bf16.
    peak_flops: float = 197e12
    hbm_gbps: float = 819e9
    ici_gbps_per_link: float = 50e9


TPU_V5E = MachineModel()

#: what the models accept: hardware, or a scheduled kernel to be lowered
HwLike = Union[HwModule, Kernel]


def _as_hw(x: HwLike, m: MachineModel) -> HwModule:
    if isinstance(x, HwModule):
        return x
    return hw_ir.lower_to_hw(x, mxu_min_dim=m.mxu_min_dim)


@dataclasses.dataclass
class CycleReport:
    total: int
    compute: int
    memory: int
    control: int

    def __str__(self):
        return (f"cycles(total={self.total:,}, compute={self.compute:,}, "
                f"memory={self.memory:,}, control={self.control:,})")


@dataclasses.dataclass
class ResourceReport:
    """Spatial consumption — the Fig. 3 analogue."""

    compute_lanes: int       # peak datapath lanes x copies (DSP analogue)
    vmem_bytes: int          # on-chip RAM bytes (BRAM analogue)
    vreg_tiles: int          # live register tiles (FF/LUT analogue)
    fsm_states: int = 0      # flattened control-FSM states
    reg_bits: int = 0        # architectural + counter + state register bits
    total_lanes: int = 0     # summed lanes x copies across every unit decl
    mux_bits: int = 0        # input-mux overhead of time-multiplexed units
    shared_units: int = 0    # physical units carrying >= 1 binding

    def __str__(self):
        return (f"resources(lanes={self.compute_lanes:,}, "
                f"vmem={self.vmem_bytes:,}B, vregs={self.vreg_tiles}, "
                f"fsm_states={self.fsm_states}, reg_bits={self.reg_bits})")


# --------------------------------------------------------------------------
# Cycle model — walks the HwModule control tree
# --------------------------------------------------------------------------


def _operand_bytes(mod: HwModule, opnd: hw_ir.HwOperand) -> int:
    return opnd.elems * dtype_bytes(mod.storage(opnd.target).dtype)


def _port_cycles(mod: HwModule, opnd: hw_ir.HwOperand, m: MachineModel,
                 vreg_free: bool) -> float:
    """Memory-port cost of moving one operand tile."""
    space = mod.space_of(opnd.target)
    if space == MemSpace.HBM:
        return _operand_bytes(mod, opnd) / m.hbm_bytes_per_cycle
    if space == MemSpace.VMEM or not vreg_free:
        return _operand_bytes(mod, opnd) / m.vmem_bytes_per_cycle
    return 0.0      # register-file operands ride dedicated bypass paths


def _binding_control(step: HwStep, mod: HwModule, m: MachineModel) -> float:
    """Serialization cost of running ``step`` on a time-multiplexed unit.

    A binding with ``serial > 1`` means the virtual unit's spatial copies
    are replayed on fewer physical copies: each dynamic invocation pays
    ``serial - 1`` extra sequencing transitions.  The charge is spread
    over the virtual copies because the enclosing ``@unroll`` executes
    the step once per copy — summed over the replication this totals
    ``seq_loop_overhead_cycles * (serial - 1)`` per logical use.
    """
    b = mod.binding_of(step.unit)
    if b is None or b.serial <= 1:
        return 0.0
    return m.seq_loop_overhead_cycles * (b.serial - 1) / max(1, b.copies)


def step_cycles(step: HwStep, mod: HwModule, m: MachineModel,
                simd_lanes: int) -> Dict[str, float]:
    """Cycles for one invocation of a datapath unit.

    ``simd_lanes`` > 1 when the step sits under ``@simd`` loops (true
    SIMD with widened ports).  Plain ``@unroll`` replication does NOT
    speed an invocation up: the unit stays memory-port-limited, so
    spatial flattening removes only control — the paper's measured
    behaviour (TABLE I gains of 1.34-1.43x for proportional hardware
    growth in Fig. 3).

    Steps bound onto a shared physical unit with ``serial > 1`` carry an
    extra ``"control"`` entry: the serialization stall is priced, not
    hidden (identical formula in the simulator keeps cosim symmetric).
    """
    unit = mod.unit(step.unit)
    ctrl = _binding_control(step, mod, m)
    if step.op == "zero":
        elems = step.operands[0].elems
        compute = max(1.0, elems / min(m.vpu_lanes,
                                       simd_lanes * max(1, elems)))
        return {"compute": compute, "memory": 0.0, "control": ctrl}
    if step.op == "matmul":
        dst, lhs, rhs = step.operands
        mt, kt = lhs.tile[-2], lhs.tile[-1]
        nt = rhs.tile[-1]
        if unit.kind == "mxu":
            # systolic regime: ceil-div each output dim to the array grid;
            # a pass costs k-depth cycles (pipelined) per array tile.
            tiles = (math.ceil(mt / m.mxu_dim) * math.ceil(nt / m.mxu_dim))
            compute = tiles * max(kt, m.mxu_dim)
            mem = sum(_port_cycles(mod, o, m, vreg_free=False)
                      for o in (lhs, rhs, dst))
            return {"compute": compute, "memory": mem, "control": ctrl}
        # scalar MAC unit (the paper's Calyx-generated GEMM datapath)
        macs = mt * nt * kt
        compute = m.scalar_mac_compute_cycles * macs / simd_lanes
        loads = (mt * kt + kt * nt) * m.scalar_load_cycles_per_elem
        return {"compute": compute, "memory": loads, "control": ctrl}
    # vpu elementwise
    elems = step.operands[0].elems
    compute = max(1.0, elems / min(m.vpu_lanes, simd_lanes))
    mem = sum(_port_cycles(mod, o, m, vreg_free=True)
              for o in step.operands)
    return {"compute": compute, "memory": mem, "control": ctrl}


def cycles(x: HwLike, m: MachineModel = TPU_V5E) -> CycleReport:
    """Walk the hardware module's control tree and accumulate cycles.

    ``@fsm`` loops multiply body cost by the trip count and add an FSM
    state transition per trip (time-division multiplexing of one
    datapath copy).  ``@unroll`` loops multiply work by the trip count
    but pay control only ONCE: spatial flattening removes the FSM
    transitions yet stays port-limited — the paper's TABLE I mechanism
    (1.34-1.43x, not trips-x, speedups).  ``@simd`` loops are true SIMD:
    compute divides across VPU lanes.  ``@stream`` loops are the pallas
    grid: sequential on one core with double-buffered DMA (memory
    overlapped with compute across steps).
    """
    mod = _as_hw(x, m)

    def go(nodes: List[HwCtrl], lanes: int, scope: HwModule) -> Dict[str, float]:
        acc = {"compute": 0.0, "memory": 0.0, "control": 0.0}
        for n in nodes:
            if isinstance(n, HwLoop):
                if n.kind == "fsm":
                    body = go(n.body, lanes, scope)
                    acc["compute"] += body["compute"] * n.trips
                    acc["memory"] += body["memory"] * n.trips
                    acc["control"] += (m.loop_setup_cycles +
                                       body["control"] * n.trips +
                                       m.seq_loop_overhead_cycles * n.trips)
                elif n.kind == "unroll":
                    body = go(n.body, lanes, scope)
                    acc["compute"] += body["compute"] * n.trips
                    acc["memory"] += body["memory"] * n.trips
                    acc["control"] += (m.loop_setup_cycles +
                                       body["control"] * n.trips)
                elif n.kind == "simd":
                    body = go(n.body, lanes * n.trips, scope)
                    acc["compute"] += body["compute"] * n.trips
                    acc["memory"] += body["memory"] * n.trips
                    acc["control"] += (m.loop_setup_cycles +
                                       body["control"] * n.trips)
                elif n.kind == "stream":
                    body = go(n.body, lanes, scope)
                    # double-buffered: memory overlaps compute across steps
                    comp = body["compute"] * n.trips
                    mem = body["memory"] * n.trips
                    acc["compute"] += max(comp, mem)    # overlap: pay the max
                    acc["control"] += (m.loop_setup_cycles +
                                       body["control"] * n.trips +
                                       m.seq_loop_overhead_cycles * n.trips)
                else:
                    raise ValueError(n.kind)
            elif isinstance(n, hw_ir.HwInstance):
                sub = scope.submodule(n.module)
                body = go(sub.ctrl, lanes, sub)
                acc["compute"] += body["compute"]
                acc["memory"] += body["memory"]
                acc["control"] += body["control"] + m.call_overhead_cycles
            else:
                c = step_cycles(n, scope, m, lanes)
                acc["compute"] += c["compute"]
                acc["memory"] += c["memory"]
                acc["control"] += c.get("control", 0.0)
        return acc

    a = go(mod.ctrl, 1, mod)
    total = int(round(a["compute"] + a["memory"] + a["control"]))
    return CycleReport(total=total, compute=int(round(a["compute"])),
                       memory=int(round(a["memory"])),
                       control=int(round(a["control"])))


# --------------------------------------------------------------------------
# Resource model (Fig. 3 analogue) — reads the module structure
# --------------------------------------------------------------------------


def resources(x: HwLike, m: MachineModel = TPU_V5E) -> ResourceReport:
    """Spatial resources of the hardware module.

    The datapath under an ``@fsm``/``@stream`` loop is instantiated
    *once* and reused each trip (paper: "time division multiplexing,
    allowing the reuse of data paths and DSPs"); under ``@unroll`` /
    ``@simd`` its units carry ``copies`` = the replication product
    (paper: "hardware consumption is directly proportional to the size
    of matrix").  Lane and RAM totals are read straight off the
    declarations; live register tiles walk the control tree because a
    register bank replicated with its datapath counts once per copy.
    """
    mod = _as_hw(x, m)

    vmem = mod.mem_bytes()
    if vmem > m.vmem_capacity_bytes:
        raise ResourceWarning(
            f"module {mod.name} RAM footprint {vmem} exceeds "
            f"capacity {m.vmem_capacity_bytes}")
    return ResourceReport(compute_lanes=mod.lane_count(), vmem_bytes=vmem,
                          vreg_tiles=_max_vregs(mod),
                          fsm_states=mod.fsm_state_count(),
                          reg_bits=mod.register_bits(),
                          total_lanes=mod.total_lanes(),
                          mux_bits=mod.mux_bits(),
                          shared_units=mod.shared_unit_count())


def _max_vregs(mod: HwModule) -> int:
    """Peak live register tiles; instance port maps pin their operands
    live across the whole call, and each submodule's own peak counts."""
    reg_names = {r.name for r in mod.regs}
    best = 0
    for node, _, trail in mod.walk():
        if isinstance(node, HwStep):
            operands = node.operands
        elif isinstance(node, hw_ir.HwInstance):
            operands = node.portmap
        else:
            continue
        rep = 1
        for loop in trail:
            if loop.kind in ("unroll", "simd"):
                rep *= loop.trips
        live = sum(1 for o in operands if o.target in reg_names)
        best = max(best, live * rep)
    for sub in mod.submodules:
        best = max(best, _max_vregs(sub))
    return best


# --------------------------------------------------------------------------
# FLOP / byte accounting used by roofline math elsewhere (workload-side,
# so it stays on LoopIR)
# --------------------------------------------------------------------------


def flops(kernel: Kernel) -> int:
    total = 0
    for s, _, trail in kernel.walk():
        if isinstance(s, Loop):
            continue
        trip = 1
        for loop in trail:
            trip *= loop.var.extent
        if isinstance(s, MatmulTile):
            total += 2 * s.macs * trip
        else:
            total += s.dst.tile_elems * trip
    return total


def hbm_bytes(kernel: Kernel) -> int:
    """Bytes moved between HBM and on-chip storage (once per touch)."""
    from .loop_ir import _stmt_refs

    total = 0
    for s, _, trail in kernel.walk():
        if isinstance(s, Loop):
            continue
        trip = 1
        for loop in trail:
            trip *= loop.var.extent
        for ref in _stmt_refs(s):
            if ref.buffer.space == MemSpace.HBM:
                total += ref.tile_bytes * trip
    return total
