"""Machine description + analytic cycle/resource models for scheduled LoopIR.

This is the Vivado-simulation analogue of the paper: the paper reports
consumed clock cycles (TABLE I) and hardware utilisation (Fig. 3) of the
RTL generated from each schedule.  We have no RTL flow on TPU, so the
models below walk the *scheduled LoopIR* and produce:

  * ``cycles(kernel)``    — consumed clock cycles under a simple in-order
    issue model of one TPU v5e core (TABLE I analogue);
  * ``resources(kernel)`` — spatial resource consumption: concurrently-
    live compute lanes (DSP analogue), VMEM bytes (BRAM analogue) and
    VREG tiles (FF/LUT analogue) (Fig. 3 analogue).

The model intentionally reproduces the paper's *mechanism*:

  * a SEQUENTIAL loop is time-division multiplexing — one datapath,
    control overhead paid every iteration (Calyx emits an FSM step per
    control transition; TPU pays scalar-core loop issue);
  * an UNROLLED loop removes the per-iteration control overhead and
    (for VECTOR/UNROLLED compute) replicates datapath lanes spatially, so
    resources grow with the unroll factor while cycles shrink.

Hardware constants follow the assignment: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI, clocked at ~940 MHz.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .loop_ir import (EwiseTile, Kernel, Loop, LoopKind, MatmulTile, MemSpace,
                      Stmt, TileRef, ZeroTile)
from .tensor_ir import dtype_bytes


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """One TPU v5e core (the unit the paper's single FPGA kernel maps to)."""

    name: str = "tpu_v5e"
    clock_ghz: float = 0.94
    # MXU: 128x128 systolic array; a (128,128)x(128,128) tile matmul retires
    # in ~128 cycles once the pipeline is primed.
    mxu_dim: int = 128
    # VPU: 8 sublanes x 128 lanes = 1024 f32 ALUs.
    vpu_lanes: int = 1024
    # Per-iteration control overhead of a sequential (time-multiplexed) loop:
    # scalar-core bookkeeping (compare/branch/index update). Calyx pays an
    # FSM state transition; we pay this. Calibrated (with the scalar-MAC
    # costs below) so the nested/flattened cycle ratio of the scalar GEMM
    # schedules reproduces the paper's TABLE I (1.34x @4x4 .. 1.43x @128).
    seq_loop_overhead_cycles: float = 5.46
    # One-off loop setup cost.
    loop_setup_cycles: float = 1.0
    # scalar-datapath MAC: compute (multiply+add+acc-writeback) and per-
    # operand-element load cost; the datapath is memory-PORT-limited, so
    # spatial unrolling does not speed these up (it removes only the
    # per-iteration control) — exactly the paper's observed mechanism.
    scalar_mac_compute_cycles: float = 9.1
    scalar_load_cycles_per_elem: float = 1.82
    # tiles with every dim >= this use the systolic-MXU cost model
    mxu_min_dim: int = 8
    # HBM <-> VMEM bandwidth in bytes/cycle (819 GB/s / 0.94 GHz).
    hbm_bytes_per_cycle: float = 871.0
    # VMEM <-> compute bandwidth (order of magnitude wider than HBM).
    vmem_bytes_per_cycle: float = 8192.0
    vmem_capacity_bytes: int = 128 * 1024 * 1024  # 128 MiB on v5e
    # peak: 197 TFLOP/s bf16.
    peak_flops: float = 197e12
    hbm_gbps: float = 819e9
    ici_gbps_per_link: float = 50e9


TPU_V5E = MachineModel()


@dataclasses.dataclass
class CycleReport:
    total: int
    compute: int
    memory: int
    control: int

    def __str__(self):
        return (f"cycles(total={self.total:,}, compute={self.compute:,}, "
                f"memory={self.memory:,}, control={self.control:,})")


@dataclasses.dataclass
class ResourceReport:
    """Spatial consumption — the Fig. 3 analogue."""

    compute_lanes: int       # concurrently-live MAC lanes (DSP analogue)
    vmem_bytes: int          # on-chip scratch (BRAM analogue)
    vreg_tiles: int          # live register tiles (FF/LUT analogue)

    def __str__(self):
        return (f"resources(lanes={self.compute_lanes:,}, "
                f"vmem={self.vmem_bytes:,}B, vregs={self.vreg_tiles})")


# --------------------------------------------------------------------------
# Cycle model
# --------------------------------------------------------------------------


def _tile_io_bytes(ref: TileRef) -> int:
    return ref.tile_bytes


def _stmt_cycles(s: Stmt, m: MachineModel, vector_lanes: int) -> Dict[str, float]:
    """Cycles for one execution of a leaf statement.

    ``vector_lanes`` > 1 when the statement sits under VECTOR loops (true
    SIMD with widened ports).  Plain UNROLLED replication does NOT speed a
    statement up: the scalar datapath is memory-port-limited, so spatial
    flattening removes only loop-control overhead — this is the paper's
    measured behaviour (TABLE I gains of 1.34-1.43x for proportional
    hardware growth in Fig. 3).
    """
    import math

    if isinstance(s, ZeroTile):
        compute = max(1.0, s.dst.tile_elems / min(m.vpu_lanes, vector_lanes *
                                                  max(1, s.dst.tile_elems)))
        return {"compute": compute, "memory": 0.0}
    if isinstance(s, MatmulTile):
        mt, kt = s.lhs.tile[-2:]
        nt = s.rhs.tile[-1]
        if min(mt, nt, kt) >= m.mxu_min_dim:
            # systolic regime: ceil-div each output dim to the 128 grid; a
            # pass costs k-depth cycles (pipelined) per 128x128 tile.
            tiles = math.ceil(mt / m.mxu_dim) * math.ceil(nt / m.mxu_dim)
            compute = tiles * max(kt, m.mxu_dim)
            mem = 0.0
            for ref in (s.lhs, s.rhs, s.dst):
                bw = (m.vmem_bytes_per_cycle if ref.buffer.space != MemSpace.HBM
                      else m.hbm_bytes_per_cycle)
                mem += _tile_io_bytes(ref) / bw
            return {"compute": compute, "memory": mem}
        # scalar-datapath regime (the paper's Calyx-generated GEMM)
        macs = mt * nt * kt
        compute = m.scalar_mac_compute_cycles * macs / vector_lanes
        loads = (mt * kt + kt * nt) * m.scalar_load_cycles_per_elem
        return {"compute": compute, "memory": loads}
    if isinstance(s, EwiseTile):
        compute = max(1.0, s.dst.tile_elems / min(m.vpu_lanes, vector_lanes))
        mem = 0.0
        for ref in [s.dst, *s.srcs]:
            if ref.buffer.space == MemSpace.HBM:
                mem += _tile_io_bytes(ref) / m.hbm_bytes_per_cycle
            elif ref.buffer.space == MemSpace.VMEM:
                mem += _tile_io_bytes(ref) / m.vmem_bytes_per_cycle
        return {"compute": compute, "memory": mem}
    raise TypeError(f"unknown stmt {type(s)}")


def cycles(kernel: Kernel, m: MachineModel = TPU_V5E) -> CycleReport:
    """Walk the schedule and accumulate cycles.

    SEQUENTIAL loops multiply body cost by the extent and add per-iteration
    control overhead (time-division multiplexing of one datapath).
    UNROLLED loops multiply work by the extent but pay control only ONCE:
    spatial flattening removes FSM/loop overhead yet stays port-limited —
    the paper's TABLE I mechanism (1.34-1.43x, not extent-x, speedups).
    VECTOR loops are true SIMD: compute is divided across VPU lanes.
    GRID loops are the pallas grid: sequential on one core, but with
    double-buffered DMA (memory overlapped with compute across steps).
    """

    def go(stmts: List[Stmt], vlanes: int) -> Dict[str, float]:
        acc = {"compute": 0.0, "memory": 0.0, "control": 0.0}
        for s in stmts:
            if isinstance(s, Loop):
                if s.kind == LoopKind.SEQUENTIAL:
                    body = go(s.body, vlanes)
                    acc["compute"] += body["compute"] * s.var.extent
                    acc["memory"] += body["memory"] * s.var.extent
                    acc["control"] += (m.loop_setup_cycles +
                                       body["control"] * s.var.extent +
                                       m.seq_loop_overhead_cycles * s.var.extent)
                elif s.kind == LoopKind.UNROLLED:
                    body = go(s.body, vlanes)
                    acc["compute"] += body["compute"] * s.var.extent
                    acc["memory"] += body["memory"] * s.var.extent
                    acc["control"] += m.loop_setup_cycles + body["control"] * s.var.extent
                elif s.kind == LoopKind.VECTOR:
                    body = go(s.body, vlanes * s.var.extent)
                    acc["compute"] += body["compute"] * s.var.extent
                    acc["memory"] += body["memory"] * s.var.extent
                    acc["control"] += m.loop_setup_cycles + body["control"] * s.var.extent
                elif s.kind == LoopKind.GRID:
                    body = go(s.body, vlanes)
                    # double-buffered: memory overlaps compute across grid steps
                    comp = body["compute"] * s.var.extent
                    mem = body["memory"] * s.var.extent
                    acc["compute"] += max(comp, mem)  # overlap: pay the max
                    acc["control"] += (m.loop_setup_cycles +
                                       body["control"] * s.var.extent +
                                       m.seq_loop_overhead_cycles * s.var.extent)
                else:
                    raise ValueError(s.kind)
            else:
                c = _stmt_cycles(s, m, vlanes)
                acc["compute"] += c["compute"]
                acc["memory"] += c["memory"]
        return acc

    a = go(kernel.body, 1)
    total = int(round(a["compute"] + a["memory"] + a["control"]))
    return CycleReport(total=total, compute=int(round(a["compute"])),
                       memory=int(round(a["memory"])),
                       control=int(round(a["control"])))


# --------------------------------------------------------------------------
# Resource model (Fig. 3 analogue)
# --------------------------------------------------------------------------


def resources(kernel: Kernel, m: MachineModel = TPU_V5E) -> ResourceReport:
    """Spatial resources of the schedule.

    The datapath under a SEQUENTIAL/GRID loop is instantiated *once* and
    reused each iteration (paper: "time division multiplexing, allowing
    the reuse of data paths and DSPs").  Under UNROLLED/VECTOR loops it is
    replicated ``extent`` times (paper: "hardware consumption is directly
    proportional to the size of matrix").
    """

    max_lanes = 0
    max_vregs = 0

    def go(stmts: List[Stmt], replication: int):
        nonlocal max_lanes, max_vregs
        live_vregs = 0
        for s in stmts:
            if isinstance(s, Loop):
                rep = replication
                if s.kind in (LoopKind.UNROLLED, LoopKind.VECTOR):
                    rep *= s.var.extent
                go(s.body, rep)
            else:
                lanes = 0
                if isinstance(s, MatmulTile):
                    lanes = min(s.lhs.tile[-2], m.mxu_dim) * min(s.rhs.tile[-1], m.mxu_dim)
                elif isinstance(s, (EwiseTile, ZeroTile)):
                    lanes = min(s.dst.tile_elems, m.vpu_lanes)
                vregs = sum(1 for ref in _refs(s) if ref.buffer.space == MemSpace.VREG)
                max_lanes = max(max_lanes, lanes * replication)
                live_vregs = max(live_vregs, vregs * replication)
        max_vregs = max(max_vregs, live_vregs)

    go(kernel.body, 1)
    vmem = kernel.vmem_bytes()
    if vmem > m.vmem_capacity_bytes:
        raise ResourceWarning(
            f"kernel {kernel.name} VMEM footprint {vmem} exceeds "
            f"capacity {m.vmem_capacity_bytes}")
    return ResourceReport(compute_lanes=max_lanes, vmem_bytes=vmem,
                          vreg_tiles=max_vregs)


def _refs(s: Stmt):
    from .loop_ir import _stmt_refs
    return _stmt_refs(s)


# --------------------------------------------------------------------------
# FLOP / byte accounting used by roofline math elsewhere
# --------------------------------------------------------------------------


def flops(kernel: Kernel) -> int:
    total = 0
    for s, _, trail in kernel.walk():
        if isinstance(s, (MatmulTile, EwiseTile, ZeroTile)):
            trip = 1
            for loop in trail:
                trip *= loop.var.extent
            if isinstance(s, MatmulTile):
                total += 2 * s.macs * trip
            elif isinstance(s, EwiseTile):
                total += s.dst.tile_elems * trip
            else:
                total += s.dst.tile_elems * trip
    return total


def hbm_bytes(kernel: Kernel) -> int:
    """Bytes moved between HBM and on-chip storage (once per touch)."""
    total = 0
    for s, _, trail in kernel.walk():
        if isinstance(s, Loop):
            continue
        trip = 1
        for loop in trail:
            trip *= loop.var.extent
        for ref in _refs(s):
            if ref.buffer.space == MemSpace.HBM:
                total += ref.tile_bytes * trip
    return total
