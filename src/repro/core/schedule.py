"""Scheduling passes on LoopIR — the paper's optimization layer.

The paper's single studied transformation is *inner-for-loop flattening*
(unrolling the innermost loop so the datapath is replicated spatially
instead of time-multiplexed).  ``flatten_inner`` below is exactly that
pass.  Around it we provide the passes a reusable scheduling layer needs
on TPU: loop splitting, interchange, grid-parallelisation (pallas grid),
vectorisation, and memory-space placement.

All passes are destructive on the Kernel (cheap dataclasses) and re-verify
afterwards, mirroring MLIR's pass + verifier discipline.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .loop_ir import (AffineExpr, Buffer, EwiseTile, Kernel, Loop, LoopKind,
                      LoopVar, MatmulTile, MemSpace, Stmt, TileRef, ZeroTile,
                      _stmt_refs)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _parent_and_list(kernel: Kernel, var: str) -> Tuple[List[Stmt], int, Loop]:
    """Locate the Loop with variable ``var`` and the list containing it."""

    def go(stmts: List[Stmt]):
        for idx, s in enumerate(stmts):
            if isinstance(s, Loop):
                if s.var.name == var:
                    return stmts, idx, s
                found = go(s.body)
                if found:
                    return found
        return None

    found = go(kernel.body)
    if not found:
        raise KeyError(f"loop {var!r} not found in kernel {kernel.name}")
    return found


def _rewrite_refs(stmts: List[Stmt], fn) -> None:
    for s in stmts:
        if isinstance(s, Loop):
            _rewrite_refs(s.body, fn)
        elif isinstance(s, ZeroTile):
            s.dst = fn(s.dst)
        elif isinstance(s, MatmulTile):
            s.dst, s.lhs, s.rhs = fn(s.dst), fn(s.lhs), fn(s.rhs)
        elif isinstance(s, EwiseTile):
            s.dst = fn(s.dst)
            s.srcs = [fn(r) for r in s.srcs]


# --------------------------------------------------------------------------
# passes
# --------------------------------------------------------------------------


def unroll(kernel: Kernel, var: str) -> Kernel:
    """Mark loop ``var`` UNROLLED: spatial replication of its datapath."""
    _, _, loop = _parent_and_list(kernel, var)
    loop.kind = LoopKind.UNROLLED
    kernel.verify()
    return kernel


def vectorize(kernel: Kernel, var: str) -> Kernel:
    _, _, loop = _parent_and_list(kernel, var)
    loop.kind = LoopKind.VECTOR
    kernel.verify()
    return kernel


def parallelize(kernel: Kernel, var: str) -> Kernel:
    """Map loop ``var`` to the pallas grid (must be loop-carried-free)."""
    _, _, loop = _parent_and_list(kernel, var)
    loop.kind = LoopKind.GRID
    kernel.verify()
    return kernel


def flatten_inner(kernel: Kernel) -> Kernel:
    """The paper's transformation: fully unroll the innermost loop of the
    deepest nest (TABLE I: "Inner Flattened for-loop")."""
    deepest: Optional[Loop] = None
    depth_of = -1
    for s, depth, _ in kernel.walk():
        if isinstance(s, Loop) and not any(isinstance(b, Loop) for b in s.body):
            if depth > depth_of:
                depth_of, deepest = depth, s
    if deepest is None:
        raise ValueError(f"kernel {kernel.name} has no innermost loop")
    deepest.kind = LoopKind.UNROLLED
    kernel.verify()
    return kernel


def interchange(kernel: Kernel, outer: str, inner: str) -> Kernel:
    """Swap two perfectly-nested loops."""
    _, _, lo = _parent_and_list(kernel, outer)
    if not (len(lo.body) == 1 and isinstance(lo.body[0], Loop)
            and lo.body[0].var.name == inner):
        raise ValueError(f"{outer} and {inner} are not perfectly nested")
    li = lo.body[0]
    lo.var, li.var = li.var, lo.var
    lo.kind, li.kind = li.kind, lo.kind
    kernel.verify()
    return kernel


def split(kernel: Kernel, var: str, factor: int) -> Kernel:
    """var(E) -> var_o(E/factor) x var_i(factor); rewrites affine indices."""
    _, _, loop = _parent_and_list(kernel, var)
    E = loop.var.extent
    if E % factor:
        raise ValueError(f"split: {factor} does not divide extent {E} of {var}")
    vo = LoopVar(var + "_o", E // factor)
    vi = LoopVar(var + "_i", factor)

    def rw(ref: TileRef) -> TileRef:
        new_idx = []
        for e in ref.index:
            coeffs = []
            for v, s in e.coeffs:
                if v == var:
                    coeffs.append((vo.name, s * factor))
                    coeffs.append((vi.name, s))
                else:
                    coeffs.append((v, s))
            new_idx.append(AffineExpr(tuple(coeffs), e.const))
        return TileRef(ref.buffer, tuple(new_idx), ref.tile)

    _rewrite_refs(loop.body, rw)
    inner_loop = Loop(vi, loop.kind, loop.body)
    loop.var = vo
    loop.body = [inner_loop]
    kernel.verify()
    return kernel


def set_space(kernel: Kernel, buffer_name: str, space: MemSpace) -> Kernel:
    """Move a scratch buffer between VMEM and VREG (HBM params are fixed)."""
    for i, b in enumerate(kernel.scratch):
        if b.name == buffer_name:
            nb = Buffer(b.name, b.type, space)
            kernel.scratch[i] = nb

            def rw(ref: TileRef) -> TileRef:
                if ref.buffer.name == buffer_name:
                    return TileRef(nb, ref.index, ref.tile)
                return ref

            _rewrite_refs(kernel.body, rw)
            kernel.verify()
            return kernel
    raise KeyError(f"scratch buffer {buffer_name!r} not found")


def fuse_epilogue(kernel: Kernel) -> Kernel:
    """Fuse a following elementwise loop nest that consumes a matmul's
    output tile-for-tile into the matmul nest (removes an HBM round-trip).

    Handles the canonical ``matmul -> ewise(C, ...)`` chain produced by
    ``lowering.py`` when both nests walk the same tile grid.  This is the
    TPU equivalent of keeping the epilogue on the accelerator fabric
    instead of bouncing through the AXI bus.
    """
    body = kernel.body
    fused = True
    while fused:
        fused = False
        for i in range(len(body) - 1):
            a, b = body[i], body[i + 1]
            if not (isinstance(a, Loop) and isinstance(b, Loop)):
                continue
            prods = _stored_hbm_buffers(a)
            if not prods:
                continue
            cons_srcs = _loopnest_leaf(b)
            if cons_srcs is None:
                continue
            leaf_stmts, b_vars = cons_srcs
            if len(leaf_stmts) != 1 or not isinstance(leaf_stmts[0], EwiseTile):
                continue
            ew = leaf_stmts[0]
            hits = [p for p in prods if any(r.buffer.name == p for r in ew.srcs)]
            if not hits:
                continue
            prod = hits[0]
            a_vars = _nest_vars(a)
            if len(a_vars) < len(b_vars):
                continue
            # the consumer must walk the *same tile grid* as the producer's
            # outer loops: equal extents, and its refs use matching tiles.
            if any(av.extent != bv.extent
                   for av, bv in zip(a_vars, b_vars)):
                continue
            prod_tile = _store_tile(a, prod)
            if prod_tile is not None and ew.dst.tile[-len(prod_tile):] != prod_tile:
                continue
            # substitute the consumer's loop vars by the producer's outer vars
            mapping = dict(zip([v.name for v in b_vars], [v.name for v in a_vars]))

            def rw(ref: TileRef) -> TileRef:
                idx = tuple(AffineExpr(tuple((mapping.get(v, v), s)
                                             for v, s in e.coeffs), e.const)
                            for e in ref.index)
                return TileRef(ref.buffer, idx, ref.tile)

            new_leaf = EwiseTile(ew.op, rw(ew.dst), [rw(r) for r in ew.srcs])
            _append_to_innermost(a, new_leaf, depth=len(b_vars))
            del body[i + 1]
            fused = True
            break
    kernel.verify()
    return kernel


def _store_tile(loop: Loop, buffer_name: str) -> Optional[Tuple[int, ...]]:
    """Tile shape with which ``buffer_name`` is stored inside the nest."""
    found: List[Tuple[int, ...]] = []

    def go(stmts):
        for s in stmts:
            if isinstance(s, Loop):
                go(s.body)
            elif isinstance(s, (EwiseTile, MatmulTile, ZeroTile)):
                if s.dst.buffer.name == buffer_name:
                    found.append(s.dst.tile)

    go([loop])
    return found[0] if found else None


def _stored_hbm_buffers(loop: Loop) -> List[str]:
    stores: List[str] = []
    def go(stmts):
        for s in stmts:
            if isinstance(s, Loop):
                go(s.body)
            elif isinstance(s, (EwiseTile, MatmulTile, ZeroTile)):
                dst = s.dst
                if dst.buffer.space == MemSpace.HBM and dst.buffer.name not in stores:
                    stores.append(dst.buffer.name)
    go([loop])
    return stores


def _loopnest_leaf(loop: Loop):
    vars_ = []
    cur: Stmt = loop
    while isinstance(cur, Loop):
        vars_.append(cur.var)
        if len(cur.body) != 1:
            return None
        cur = cur.body[0]
    return [cur], vars_


def _nest_vars(loop: Loop) -> List[LoopVar]:
    vars_ = []
    cur: Stmt = loop
    while isinstance(cur, Loop):
        vars_.append(cur.var)
        nested = [s for s in cur.body if isinstance(s, Loop)]
        if len(nested) != 1:
            break
        cur = nested[0]
    return vars_


def _append_to_innermost(loop: Loop, stmt: Stmt, depth: int) -> None:
    cur = loop
    d = 1
    while d < depth:
        nxt = [s for s in cur.body if isinstance(s, Loop)]
        if not nxt:
            break
        cur = nxt[0]
        d += 1
    cur.body.append(stmt)


# --------------------------------------------------------------------------
# canned schedules for the GEMM case study
# --------------------------------------------------------------------------


def schedule_nested(kernel: Kernel) -> Kernel:
    """Paper baseline: leave every loop SEQUENTIAL (time-multiplexed)."""
    return kernel


def schedule_inner_flattened(kernel: Kernel) -> Kernel:
    """Paper optimisation: flatten (fully unroll) the innermost loop."""
    return flatten_inner(kernel)


def schedule_tpu_mxu(kernel: Kernel) -> Kernel:
    """Beyond-paper TPU-native schedule: outer tiles on the pallas grid,
    K-accumulation sequential in VREG (time-multiplexing the MXU — the
    *good* kind of datapath reuse)."""
    loops = kernel.loops()
    # lowering emits i, j, k nests per matmul; grid-map the first two levels
    tops = [s for s in kernel.body if isinstance(s, Loop)]
    for top in tops:
        top.kind = LoopKind.GRID
        inner = [s for s in top.body if isinstance(s, Loop)]
        if inner:
            inner[0].kind = LoopKind.GRID
    kernel.verify()
    return kernel
