"""Scheduling passes on LoopIR — the paper's optimization layer.

The paper's single studied transformation is *inner-for-loop flattening*
(unrolling the innermost loop so the datapath is replicated spatially
instead of time-multiplexed).  ``flatten_inner`` below is exactly that
pass.  Around it we provide the passes a reusable scheduling layer needs
on TPU: loop splitting, interchange, grid-parallelisation (pallas grid),
vectorisation, and memory-space placement.

Every structural transform here is a :class:`~repro.core.rewrite.Pattern`
applied by the shared :class:`~repro.core.rewrite.RewriteDriver` — the
module no longer hand-rolls its own traversal/reconstruction.  The
public pass functions keep their pre-refactor signatures, in-place
semantics, and diagnostics; they construct the pattern, run the driver,
and re-verify, mirroring MLIR's pass + verifier discipline.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from . import rewrite
from .loop_ir import (AffineExpr, Buffer, EwiseTile, FillTile, Kernel, Loop,
                      LoopKind, LoopVar, MatmulTile, MemSpace, ReduceTile,
                      ScanTile, Stmt, TileRef, ZeroTile)
from .rewrite import OneShotPattern, RewriteDriver, RewriteError


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _rewrite_refs(stmts: List[Stmt], fn) -> None:
    rewrite._map_stmt_refs(stmts, fn)


def _body_stmts(stmts):
    for s in stmts:
        yield s
        if isinstance(s, Loop):
            yield from _body_stmts(s.body)


def carry_axis_reason(loop: Loop, kind: LoopKind) -> Optional[str]:
    """Why re-annotating ``loop`` as ``kind`` would break a carried
    reduction/scan in its body — ``None`` when legal.

    Spatial kinds (@grid/@vector) replicate the loop's datapath, so a
    loop that *iterates a carry* (the running max/sum of an online
    softmax, the state of an SSD scan) cannot take them: each replica
    would see only its own slice of the recurrence.  SEQUENTIAL and
    UNROLLED preserve program order and stay legal, as does splitting
    the axis (both halves remain sequential).  ``MatmulTile``
    k-accumulation is exempt — the pallas backend threads that carry
    with a revisit-aware ``pl.when`` init.
    """
    if kind not in (LoopKind.GRID, LoopKind.VECTOR):
        return None
    v = loop.var.name
    # accumulators (re)initialised inside the body are confined to one
    # iteration — only a carry that *crosses* iterations of this loop
    # (its init lives outside) makes the spatial kind illegal
    inits = {s.dst.buffer.name for s in _body_stmts(loop.body)
             if isinstance(s, (FillTile, ZeroTile))}
    for s in _body_stmts(loop.body):
        if isinstance(s, ReduceTile) and s.accumulate and \
                s.dst.buffer.name not in inits and \
                not any(var == v for e in s.dst.index for var, _ in e.coeffs):
            return (f"loop %{v} iterates the carried reduction axis of "
                    f"reduce<{s.kind}> into {s.dst.buffer.name}: "
                    f"@{kind.value} would replicate the running statistic "
                    f"spatially without threading the carry (keep it @seq, "
                    f"unroll it, or split it)")
        if isinstance(s, ScanTile) and \
                any(var == v for var, _ in s.dst.index[0].coeffs):
            return (f"loop %{v} iterates the scan axis of scan<{s.kind}> "
                    f"into {s.dst.buffer.name}: the carry threads "
                    f"sequentially, so @{kind.value} on the time axis "
                    f"would miscompile (keep it @seq, unroll it, or "
                    f"split it)")
    return None


def _run_one_shot(kernel: Kernel, pat: OneShotPattern,
                  missing: str) -> Kernel:
    """Drive a one-shot pattern over ``kernel`` (in place); raise
    ``KeyError(missing)`` if its target never matched."""
    RewriteDriver([pat], max_iterations=2).run(kernel)
    if not pat.applied:
        raise KeyError(missing)
    kernel.verify()
    return kernel


# --------------------------------------------------------------------------
# patterns (the ported transforms)
# --------------------------------------------------------------------------


class SetLoopKind(OneShotPattern):
    """Re-annotate the named loop with a new ``LoopKind``."""

    name = "set-loop-kind"

    def __init__(self, var: str, kind: LoopKind):
        super().__init__()
        self.var = var
        self.kind = kind

    def apply_once(self, parent, siblings, i, root):
        loop = siblings[i]
        if not isinstance(loop, Loop) or loop.var.name != self.var:
            return None
        reason = carry_axis_reason(loop, self.kind)
        if reason:
            raise RewriteError(f"set-loop-kind: {reason}")
        loop.kind = self.kind
        return (1, [loop])


class SplitLoop(OneShotPattern):
    """var(E) -> var_o(E/factor) x var_i(factor); rewrites affine indices."""

    name = "split-loop"

    def __init__(self, var: str, factor: int):
        super().__init__()
        self.var = var
        self.factor = factor

    def apply_once(self, parent, siblings, i, root):
        loop = siblings[i]
        if not isinstance(loop, Loop) or loop.var.name != self.var:
            return None
        E, var, factor = loop.var.extent, self.var, self.factor
        if E % factor:
            raise RewriteError(
                f"split: {factor} does not divide extent {E} of {var}")
        vo = LoopVar(var + "_o", E // factor)
        vi = LoopVar(var + "_i", factor)

        def rw(ref: TileRef) -> TileRef:
            new_idx = []
            for e in ref.index:
                coeffs = []
                for v, s in e.coeffs:
                    if v == var:
                        coeffs.append((vo.name, s * factor))
                        coeffs.append((vi.name, s))
                    else:
                        coeffs.append((v, s))
                new_idx.append(AffineExpr(tuple(coeffs), e.const))
            return TileRef(ref.buffer, tuple(new_idx), ref.tile)

        _rewrite_refs(loop.body, rw)
        inner_loop = Loop(vi, loop.kind, loop.body)
        loop.var = vo
        loop.body = [inner_loop]
        return (1, [loop])


class InterchangeLoops(OneShotPattern):
    """Swap two perfectly-nested loops (vars and kinds trade places)."""

    name = "interchange-loops"

    def __init__(self, outer: str, inner: str):
        super().__init__()
        self.outer = outer
        self.inner = inner

    def apply_once(self, parent, siblings, i, root):
        lo = siblings[i]
        if not isinstance(lo, Loop) or lo.var.name != self.outer:
            return None
        if not (len(lo.body) == 1 and isinstance(lo.body[0], Loop)
                and lo.body[0].var.name == self.inner):
            raise RewriteError(
                f"{self.outer} and {self.inner} are not perfectly nested")
        li = lo.body[0]
        lo.var, li.var = li.var, lo.var
        lo.kind, li.kind = li.kind, lo.kind
        return (1, [lo])


# --------------------------------------------------------------------------
# passes
# --------------------------------------------------------------------------


def _not_found(kernel: Kernel, var: str) -> str:
    return f"loop {var!r} not found in kernel {kernel.name}"


def unroll(kernel: Kernel, var: str) -> Kernel:
    """Mark loop ``var`` UNROLLED: spatial replication of its datapath."""
    return _run_one_shot(kernel, SetLoopKind(var, LoopKind.UNROLLED),
                         _not_found(kernel, var))


def vectorize(kernel: Kernel, var: str) -> Kernel:
    return _run_one_shot(kernel, SetLoopKind(var, LoopKind.VECTOR),
                         _not_found(kernel, var))


def parallelize(kernel: Kernel, var: str) -> Kernel:
    """Map loop ``var`` to the pallas grid (must be loop-carried-free)."""
    return _run_one_shot(kernel, SetLoopKind(var, LoopKind.GRID),
                         _not_found(kernel, var))


def flatten_inner(kernel: Kernel) -> Kernel:
    """The paper's transformation: fully unroll the innermost loop of the
    deepest nest (TABLE I: "Inner Flattened for-loop")."""
    deepest: Optional[Loop] = None
    depth_of = -1
    for s, depth, _ in kernel.walk():
        if isinstance(s, Loop) and not any(isinstance(b, Loop) for b in s.body):
            if depth > depth_of:
                depth_of, deepest = depth, s
    if deepest is None:
        raise ValueError(f"kernel {kernel.name} has no innermost loop")
    return _run_one_shot(kernel,
                         SetLoopKind(deepest.var.name, LoopKind.UNROLLED),
                         _not_found(kernel, deepest.var.name))


def interchange(kernel: Kernel, outer: str, inner: str) -> Kernel:
    """Swap two perfectly-nested loops."""
    return _run_one_shot(kernel, InterchangeLoops(outer, inner),
                         _not_found(kernel, outer))


def split(kernel: Kernel, var: str, factor: int) -> Kernel:
    """var(E) -> var_o(E/factor) x var_i(factor); rewrites affine indices."""
    return _run_one_shot(kernel, SplitLoop(var, factor),
                         _not_found(kernel, var))


def set_space(kernel: Kernel, buffer_name: str, space: MemSpace) -> Kernel:
    """Move a scratch buffer between VMEM and VREG (HBM params are fixed)."""
    for i, b in enumerate(kernel.scratch):
        if b.name == buffer_name:
            nb = Buffer(b.name, b.type, space)
            kernel.scratch[i] = nb

            def rw(ref: TileRef) -> TileRef:
                if ref.buffer.name == buffer_name:
                    return TileRef(nb, ref.index, ref.tile)
                return ref

            _rewrite_refs(kernel.body, rw)
            kernel.verify()
            return kernel
    raise KeyError(f"scratch buffer {buffer_name!r} not found")


class FuseEpiloguePattern(rewrite.Pattern):
    """Fuse an adjacent elementwise nest that consumes a matmul's output
    tile-for-tile into the producer nest (removes an HBM round-trip)."""

    name = "fuse-epilogue"

    def match_and_rewrite(self, parent, siblings, i, root):
        # only top-level nests fuse (the canonical matmul -> ewise chain
        # produced by lowering.py sits directly in the kernel body)
        if not isinstance(parent, Kernel) or i + 1 >= len(siblings):
            return None
        a, b = siblings[i], siblings[i + 1]
        if not (isinstance(a, Loop) and isinstance(b, Loop)):
            return None
        prods = _stored_hbm_buffers(a)
        if not prods:
            return None
        cons_srcs = _loopnest_leaf(b)
        if cons_srcs is None:
            return None
        leaf_stmts, b_vars = cons_srcs
        if len(leaf_stmts) != 1 or not isinstance(leaf_stmts[0], EwiseTile):
            return None
        ew = leaf_stmts[0]
        hits = [p for p in prods if any(r.buffer.name == p for r in ew.srcs)]
        if not hits:
            return None
        prod = hits[0]
        a_vars = _nest_vars(a)
        if len(a_vars) < len(b_vars):
            return None
        # the consumer must walk the *same tile grid* as the producer's
        # outer loops: equal extents, and its refs use matching tiles.
        if any(av.extent != bv.extent for av, bv in zip(a_vars, b_vars)):
            return None
        prod_tile = _store_tile(a, prod)
        if prod_tile is not None and ew.dst.tile[-len(prod_tile):] != prod_tile:
            return None
        # the fused stmt lands at the END of the loop at depth
        # len(b_vars), so the producer's store of `prod` must happen
        # inside that loop (a matmul accumulates its HBM dst there).  A
        # carried reduce stores its result via a copy from the
        # accumulator *outside* the inner loop — fusing would read the
        # stale pre-reduction tile, so keep the separate nest.
        target = a
        d = 1
        while d < len(b_vars):
            nxt = [s for s in target.body if isinstance(s, Loop)]
            if not nxt:
                break
            target = nxt[0]
            d += 1
        if _store_tile(target, prod) is None:
            return None
        # substitute the consumer's loop vars by the producer's outer vars
        mapping = dict(zip([v.name for v in b_vars],
                           [v.name for v in a_vars]))

        def rw(ref: TileRef) -> TileRef:
            idx = tuple(AffineExpr(tuple((mapping.get(v, v), s)
                                         for v, s in e.coeffs), e.const)
                        for e in ref.index)
            return TileRef(ref.buffer, idx, ref.tile)

        new_leaf = EwiseTile(ew.op, rw(ew.dst), [rw(r) for r in ew.srcs])
        _append_to_innermost(a, new_leaf, depth=len(b_vars))
        return (2, [a])


def fuse_epilogue(kernel: Kernel) -> Kernel:
    """Fuse a following elementwise loop nest that consumes a matmul's
    output tile-for-tile into the matmul nest (removes an HBM round-trip).

    Handles the canonical ``matmul -> ewise(C, ...)`` chain produced by
    ``lowering.py`` when both nests walk the same tile grid — chained
    epilogues (bias_add then relu) fuse one per driver sweep until the
    fixpoint.  This is the TPU equivalent of keeping the epilogue on the
    accelerator fabric instead of bouncing through the AXI bus.
    """
    RewriteDriver([FuseEpiloguePattern()]).run(kernel)
    kernel.verify()
    return kernel


def _store_tile(loop: Loop, buffer_name: str) -> Optional[Tuple[int, ...]]:
    """Tile shape with which ``buffer_name`` is stored inside the nest."""
    found: List[Tuple[int, ...]] = []

    def go(stmts):
        for s in stmts:
            if isinstance(s, Loop):
                go(s.body)
            elif isinstance(s, (EwiseTile, MatmulTile, ZeroTile)):
                if s.dst.buffer.name == buffer_name:
                    found.append(s.dst.tile)

    go([loop])
    return found[0] if found else None


def _stored_hbm_buffers(loop: Loop) -> List[str]:
    stores: List[str] = []
    def go(stmts):
        for s in stmts:
            if isinstance(s, Loop):
                go(s.body)
            elif isinstance(s, (EwiseTile, MatmulTile, ZeroTile)):
                dst = s.dst
                if dst.buffer.space == MemSpace.HBM and dst.buffer.name not in stores:
                    stores.append(dst.buffer.name)
    go([loop])
    return stores


def _loopnest_leaf(loop: Loop):
    vars_ = []
    cur: Stmt = loop
    while isinstance(cur, Loop):
        vars_.append(cur.var)
        if len(cur.body) != 1:
            return None
        cur = cur.body[0]
    return [cur], vars_


def _nest_vars(loop: Loop) -> List[LoopVar]:
    vars_ = []
    cur: Stmt = loop
    while isinstance(cur, Loop):
        vars_.append(cur.var)
        nested = [s for s in cur.body if isinstance(s, Loop)]
        if len(nested) != 1:
            break
        cur = nested[0]
    return vars_


def _append_to_innermost(loop: Loop, stmt: Stmt, depth: int) -> None:
    cur = loop
    d = 1
    while d < depth:
        nxt = [s for s in cur.body if isinstance(s, Loop)]
        if not nxt:
            break
        cur = nxt[0]
        d += 1
    cur.body.append(stmt)


# --------------------------------------------------------------------------
# canned schedules for the GEMM case study
# --------------------------------------------------------------------------


def schedule_nested(kernel: Kernel) -> Kernel:
    """Paper baseline: leave every loop SEQUENTIAL (time-multiplexed)."""
    return kernel


def schedule_inner_flattened(kernel: Kernel) -> Kernel:
    """Paper optimisation: flatten (fully unroll) the innermost loop."""
    return flatten_inner(kernel)


def schedule_tpu_mxu(kernel: Kernel) -> Kernel:
    """Beyond-paper TPU-native schedule: outer tiles on the pallas grid,
    K-accumulation sequential in VREG (time-multiplexing the MXU — the
    *good* kind of datapath reuse)."""
    loops = kernel.loops()
    # lowering emits i, j, k nests per matmul; grid-map the first two levels
    # (carry-iterating loops stay sequential: the running softmax/scan
    # state cannot be replicated across grid steps)
    tops = [s for s in kernel.body if isinstance(s, Loop)]
    for top in tops:
        if carry_axis_reason(top, LoopKind.GRID) is None:
            top.kind = LoopKind.GRID
        inner = [s for s in top.body if isinstance(s, Loop)]
        if inner and carry_axis_reason(inner[0], LoopKind.GRID) is None:
            inner[0].kind = LoopKind.GRID
    kernel.verify()
    return kernel
