"""TensorIR -> LoopIR lowering (the MLIR -> Calyx step of the paper's Fig. 1).

Each TensorIR op lowers to a canonical *nested sequential* loop nest over
tiles — the direct analogue of the paper's "nested for-loop" baseline
schedule, where a single time-multiplexed datapath walks the iteration
space.  All scheduling (tiling choice aside) is left to subsequent passes
in ``schedule.py``; this separation of lowering from scheduling is the
reusability property the paper argues for.

Tile sizes default to 1 (fully scalar — what Calyx generates from the
paper's MLIR in Fig. 2) and can be set per-op for MXU-shaped lowering.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .loop_ir import (AffineExpr, Buffer, EwiseTile, FillTile, Kernel, Loop,
                      LoopKind, LoopVar, MatmulTile, MemSpace, ReduceTile,
                      ScanTile, TileRef, ZeroTile)
from .tensor_ir import Graph, Op, TensorType, Value, reduce_identity


def fit_tile(tile: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is <= ``tile`` (always >= 1)."""
    t = min(tile, dim)
    while dim % t:
        t -= 1
    return t


@dataclasses.dataclass
class LoweringOptions:
    """Tiling choices consumed at lowering time (like linalg tiling)."""

    tile_m: int = 1
    tile_n: int = 1
    tile_k: int = 1
    # accumulate in a VREG tile instead of writing C through HBM each k-step
    use_accumulator: bool = True

    def clamp(self, m: int, n: int, k: int) -> "LoweringOptions":
        return LoweringOptions(tile_m=fit_tile(self.tile_m, m),
                               tile_n=fit_tile(self.tile_n, n),
                               tile_k=fit_tile(self.tile_k, k),
                               use_accumulator=self.use_accumulator)


_EWISE_BIN = {"add", "sub", "mul", "maximum", "div"}
_EWISE_UN = {"relu", "gelu", "exp", "neg",
             "tanh", "sigmoid", "sqrt", "rsqrt", "log1p", "abs"}


class _Lowerer:
    def __init__(self, graph: Graph, opts: LoweringOptions):
        graph.verify()
        self.graph = graph
        self.opts = opts
        self.buffers: Dict[int, Buffer] = {}
        self.scratch: List[Buffer] = []
        self.body: List[Stmt] = []  # type: ignore[name-defined]
        self._uid = 0

    def uid(self, hint: str) -> str:
        self._uid += 1
        return f"{hint}{self._uid}"

    def buf_for(self, v: Value, space: MemSpace = MemSpace.HBM) -> Buffer:
        if id(v) not in self.buffers:
            self.buffers[id(v)] = Buffer(v.name, v.type, space)
        return self.buffers[id(v)]

    # ---- op lowerings ------------------------------------------------------

    def lower_matmul(self, op: Op) -> None:
        a, b = op.inputs
        c = op.result
        M, K = a.type.shape
        _, N = b.type.shape
        o = self.opts.clamp(M, N, K)
        A, B, C = self.buf_for(a), self.buf_for(b), self.buf_for(c)

        i = LoopVar(self.uid("i"), M // o.tile_m)
        j = LoopVar(self.uid("j"), N // o.tile_n)
        k = LoopVar(self.uid("k"), K // o.tile_k)

        ij = (AffineExpr.of(i), AffineExpr.of(j))
        a_ref = TileRef(A, (AffineExpr.of(i), AffineExpr.of(k)), (o.tile_m, o.tile_k))
        b_ref = TileRef(B, (AffineExpr.of(k), AffineExpr.of(j)), (o.tile_k, o.tile_n))

        if o.use_accumulator:
            acc = Buffer(self.uid("acc"), TensorType((o.tile_m, o.tile_n), c.type.dtype),
                         MemSpace.VREG)
            self.scratch.append(acc)
            zero = (AffineExpr.of(None), AffineExpr.of(None))
            acc_ref = TileRef(acc, zero, (o.tile_m, o.tile_n))
            c_ref = TileRef(C, ij, (o.tile_m, o.tile_n))
            kloop = Loop(k, LoopKind.SEQUENTIAL,
                         [MatmulTile(acc_ref, a_ref, b_ref, accumulate=True)])
            inner = [ZeroTile(acc_ref), kloop,
                     EwiseTile("copy", c_ref, [acc_ref])]
        else:
            c_ref = TileRef(C, ij, (o.tile_m, o.tile_n))
            kloop = Loop(k, LoopKind.SEQUENTIAL,
                         [MatmulTile(c_ref, a_ref, b_ref, accumulate=True)])
            inner = [ZeroTile(c_ref), kloop]

        nest = Loop(i, LoopKind.SEQUENTIAL, [Loop(j, LoopKind.SEQUENTIAL, inner)])
        self.body.append(nest)

    def lower_ewise(self, op: Op) -> None:
        out = op.result
        O = self.buf_for(out)
        shape = out.type.shape

        # tile the trailing two dims like the matmul output (tile_m, tile_n)
        # so elementwise epilogues walk the same tile grid as the producer
        # and ``fuse_epilogue`` can merge the nests.
        tiles = [1] * len(shape)
        if shape:
            tiles[-1] = fit_tile(self.opts.tile_n, shape[-1])
        if len(shape) >= 2:
            tiles[-2] = fit_tile(self.opts.tile_m, shape[-2])
        loop_vars = [LoopVar(self.uid("e"), shape[d] // tiles[d])
                     for d in range(len(shape))]
        idx = tuple(AffineExpr.of(v) for v in loop_vars)
        dst = TileRef(O, idx, tuple(tiles))
        srcs = []
        for v in op.inputs:
            buf = self.buf_for(v)
            if v.type.shape == shape:
                srcs.append(TileRef(buf, idx, tuple(tiles)))
            elif op.opname == "bias_add" and v.type.rank == 1:
                srcs.append(TileRef(buf, (idx[-1],), (tiles[-1],)))
            elif v.type.rank == len(shape) and \
                    all(db == da or db == 1
                        for da, db in zip(shape, v.type.shape)):
                # size-1 broadcast dims (per-row softmax statistics):
                # pin the index to 0 and the tile to 1 on those dims
                bidx = tuple(idx[d] if v.type.shape[d] == shape[d]
                             else AffineExpr.of(None)
                             for d in range(len(shape)))
                btile = tuple(tiles[d] if v.type.shape[d] == shape[d] else 1
                              for d in range(len(shape)))
                srcs.append(TileRef(buf, bidx, btile))
            else:
                raise NotImplementedError(
                    f"broadcast lowering for {op.opname} {v.type} vs {shape}")
        name = {"bias_add": "add"}.get(op.opname, op.opname)
        stmt: Stmt = EwiseTile(name, dst, srcs)  # type: ignore[name-defined]
        for v in reversed(loop_vars):
            stmt = Loop(v, LoopKind.SEQUENTIAL, [stmt])
        self.body.append(stmt)

    def lower_reduce_sum(self, op: Op) -> None:
        """Row reduction over the last axis: (M, N) -> (M,).

        Lowered as a matmul against a ones-vector — the GEMM-ification of
        reductions (the MXU *is* the reduction tree on TPU), mirroring how
        the paper's future work folds tensor ops onto its GEMM datapath.
        """
        (src,) = op.inputs
        if src.type.rank != 2 or op.attrs.get("axis") != 1:
            raise NotImplementedError(
                "reduce_sum lowering supports rank-2, axis=1")
        M, N = src.type.shape
        o = self.opts.clamp(M, 1, N)
        A = self.buf_for(src)
        OUT = self.buf_for(op.result)
        ones = Buffer(self.uid("ones"), TensorType((N, 1), src.type.dtype),
                      MemSpace.VMEM)
        self.scratch.append(ones)
        i = LoopVar(self.uid("i"), M // o.tile_m)
        k = LoopVar(self.uid("k"), N // o.tile_k)
        acc = Buffer(self.uid("acc"), TensorType((o.tile_m, 1), "float32"),
                     MemSpace.VREG)
        self.scratch.append(acc)
        zero2 = (AffineExpr.of(None), AffineExpr.of(None))
        acc_ref = TileRef(acc, zero2, (o.tile_m, 1))
        a_ref = TileRef(A, (AffineExpr.of(i), AffineExpr.of(k)),
                        (o.tile_m, o.tile_k))
        ones_ref = TileRef(ones, (AffineExpr.of(k), AffineExpr.of(None)),
                           (o.tile_k, 1))
        out_ref = TileRef(OUT, (AffineExpr.of(i),), (o.tile_m,))
        # initialise the ones vector once (elementwise broadcast of 1.0 is
        # modelled as copy of itself after backend-side init; backends zero
        # scratch, so materialise ones via a dedicated statement)
        init = EwiseTile("ones", TileRef(ones, (AffineExpr.of(None),
                                                AffineExpr.of(None)),
                                         (N, 1)), [])
        kloop = Loop(k, LoopKind.SEQUENTIAL,
                     [MatmulTile(acc_ref, a_ref, ones_ref, accumulate=True)])
        body = Loop(i, LoopKind.SEQUENTIAL,
                    [ZeroTile(acc_ref), kloop,
                     EwiseTile("copy1", out_ref, [acc_ref])])
        self.body.extend([init, body])

    def lower_reduce(self, op: Op) -> None:
        """Carried reduction over the last axis: (M, N) -> (M, 1) / (M,).

        The running statistic (max or sum) lives in a VREG accumulator
        that is *carried* across the sequential k-loop — the online-softmax
        structure.  Tiling the k axis is legal only because the carry
        threads through ``ReduceTile(accumulate=True)``; schedule passes
        that would replicate the k loop spatially must refuse (see
        ``schedule.carry_axis_reason``).
        """
        (src,) = op.inputs
        kind = op.attrs["kind"]
        if src.type.rank != 2 or op.attrs.get("axis") != 1:
            raise NotImplementedError("reduce lowering supports rank-2, axis=1")
        keepdims = op.attrs.get("keepdims", True)
        M, N = src.type.shape
        o = self.opts.clamp(M, 1, N)
        A = self.buf_for(src)
        OUT = self.buf_for(op.result)
        i = LoopVar(self.uid("i"), M // o.tile_m)
        k = LoopVar(self.uid("k"), N // o.tile_k)
        acc = Buffer(self.uid("acc"), TensorType((o.tile_m, 1), "float32"),
                     MemSpace.VREG)
        self.scratch.append(acc)
        zero2 = (AffineExpr.of(None), AffineExpr.of(None))
        acc_ref = TileRef(acc, zero2, (o.tile_m, 1))
        a_ref = TileRef(A, (AffineExpr.of(i), AffineExpr.of(k)),
                        (o.tile_m, o.tile_k))
        kloop = Loop(k, LoopKind.SEQUENTIAL,
                     [ReduceTile(kind, acc_ref, a_ref, accumulate=True)])
        if keepdims:
            out_ref = TileRef(OUT, (AffineExpr.of(i), AffineExpr.of(None)),
                              (o.tile_m, 1))
            copy = EwiseTile("copy", out_ref, [acc_ref])
        else:
            out_ref = TileRef(OUT, (AffineExpr.of(i),), (o.tile_m,))
            copy = EwiseTile("copy1", out_ref, [acc_ref])
        body = Loop(i, LoopKind.SEQUENTIAL,
                    [FillTile(acc_ref, reduce_identity(kind)), kloop, copy])
        self.body.append(body)

    def lower_scan(self, op: Op) -> None:
        """Associative scan along axis 0: h_t = a_t * h_{t-1} + x_t.

        The carry row (last state of the previous time tile) lives in a
        VREG buffer threaded across the sequential time loop; column tiles
        are independent and free to parallelise, the time axis is not.
        """
        kind = op.attrs["kind"]
        if op.result.type.rank != 2 or op.attrs.get("axis") != 0:
            raise NotImplementedError("scan lowering supports rank-2, axis=0")
        x = op.inputs[-1]
        S, C = x.type.shape
        ts = fit_tile(self.opts.tile_m, S)
        tc = fit_tile(self.opts.tile_n, C)
        OUT = self.buf_for(op.result)
        j = LoopVar(self.uid("j"), C // tc)
        t = LoopVar(self.uid("t"), S // ts)
        carry = Buffer(self.uid("carry"), TensorType((1, tc), "float32"),
                       MemSpace.VREG)
        self.scratch.append(carry)
        zero2 = (AffineExpr.of(None), AffineExpr.of(None))
        carry_ref = TileRef(carry, zero2, (1, tc))
        tj = (AffineExpr.of(t), AffineExpr.of(j))
        srcs = [TileRef(self.buf_for(v), tj, (ts, tc)) for v in op.inputs]
        dst = TileRef(OUT, tj, (ts, tc))
        tloop = Loop(t, LoopKind.SEQUENTIAL,
                     [ScanTile(kind, dst, srcs, carry_ref)])
        body = Loop(j, LoopKind.SEQUENTIAL, [FillTile(carry_ref, 0.0), tloop])
        self.body.append(body)

    # ---- driver --------------------------------------------------------------

    def run(self) -> Kernel:
        for v in self.graph.inputs:
            self.buf_for(v)
        for op in self.graph.ops:
            if op.opname == "matmul":
                self.lower_matmul(op)
            elif op.opname == "reduce_sum":
                self.lower_reduce_sum(op)
            elif op.opname == "reduce":
                self.lower_reduce(op)
            elif op.opname == "scan":
                self.lower_scan(op)
            elif op.opname in _EWISE_BIN | _EWISE_UN | {"bias_add"}:
                self.lower_ewise(op)
            else:
                raise NotImplementedError(
                    f"no LoopIR lowering for op {op.opname!r} yet")
        out_ids = {id(v) for v in self.graph.outputs}
        params = [self.buffers[id(v)] for v in self.graph.inputs]
        inter = [self.buffers[id(op.result)] for op in self.graph.ops]
        # intermediates that are not outputs stay HBM temporaries (params at
        # the end so backends can allocate them); outputs are params too.
        outputs = [self.buffers[id(v)] for v in self.graph.outputs]
        temps = [b for op in self.graph.ops
                 for b in [self.buffers[id(op.result)]]
                 if id(op.result) not in out_ids]
        kern = Kernel(name=self.graph.name, params=params + temps + outputs,
                      outputs=outputs, scratch=self.scratch, body=self.body)
        kern.verify()
        return kern


def lower_graph(graph: Graph, opts: Optional[LoweringOptions] = None) -> Kernel:
    return _Lowerer(graph, opts or LoweringOptions()).run()


# placate the forward references used above
from .loop_ir import Stmt  # noqa: E402  (cycle-free: loop_ir has no deps on us)
