"""Unified pattern-rewrite core — one walk/rewrite/canonicalize
infrastructure shared by all three IR levels.

This is the MLIR greedy-pattern-rewrite analogue the paper's
"reusable and extensible" claim ultimately rests on: instead of every
transform hand-rolling its own traversal, reconstruction and legality
checks (the pre-refactor state: TensorIR passes, LoopIR schedule
transforms and HwIR tree surgery each walked their IR differently),
every level plugs into one driver through a small structural protocol:

  * ``node.children()``      — the node's *mutable* child list (ops of a
    ``Graph``, body of a ``Kernel``/``Loop``, ctrl of an ``HwModule``,
    body of an ``HwLoop``; leaves return ``[]``).  The driver splices
    replacements into this list in place, so artifact identity is
    preserved (passes stay in-place, like the pre-refactor transforms);
  * ``node.rebuild(children)`` — a same-type copy with a new child list
    (the functional counterpart, used by patterns building replacements
    and by anything that wants a structural copy);
  * ``node.is_equivalent(other)`` — structural equivalence via the
    canonical textual form of ``ir_text`` (two nodes are equivalent iff
    they print identically).

On top of the protocol:

  * :class:`Pattern` — match-and-rewrite at one position of a sibling
    list, MLIR-style: return ``None`` when the IR is already in the
    target form (this is what makes fixpoints terminate), otherwise a
    ``(consumed, replacement)`` pair.  ``benefit`` orders competing
    patterns (higher first);
  * :class:`RewriteDriver` — greedy fixpoint application: sweep the
    tree post-order, apply the highest-benefit matching pattern at each
    position, repeat until a full sweep changes nothing or the
    iteration cap trips.  Per-pattern hit counts land in a
    :class:`RewriteStats` and in any active ``collect_stats`` scope —
    the :class:`~repro.core.passes.PassManager` opens one around every
    pass, so pattern statistics surface on ``PassRecord``;
  * a per-level **canonicalization pattern registry**
    (``register_canonical_pattern``) feeding the ``canonicalize`` pass,
    which is registered at tensor, loop AND hw level — the first truly
    level-agnostic pass of the stack.

The LoopIR scheduling passes (``split``/``interchange``/``unroll``/
``vectorize``/``fuse-epilogue`` in ``schedule.py``) and the HwIR
``set-sequencer`` knob are ported onto this driver; see those modules
for the pattern classes.  ``docs/REWRITE.md`` (generated) documents the
registered canonicalization pattern sets.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .hw_ir import HwLoop, HwModule, HwStep
from .loop_ir import (AffineExpr, EwiseTile, FillTile, Kernel, Loop,
                      MatmulTile, ReduceTile, ScanTile, Stmt, TileRef,
                      ZeroTile, _stmt_refs, _stmt_written_refs)
from .tensor_ir import Graph, Op


class RewriteError(ValueError):
    """A pattern matched IR it cannot legally rewrite."""


# --------------------------------------------------------------------------
# patterns
# --------------------------------------------------------------------------


#: a pattern's answer: how many siblings it consumed and what replaces them
Replacement = Tuple[int, List[object]]


class Pattern:
    """One rewrite rule.

    Subclasses set ``name`` (kebab-case; defaults to a kebab-cased class
    name) and implement :meth:`match_and_rewrite`.  ``benefit`` breaks
    ties between patterns matching the same position: higher applies
    first (MLIR's ``PatternBenefit``).

    The contract mirrors MLIR's ``matchAndRewrite``: return ``None``
    when the node is *already in the target form* — a pattern that
    keeps reporting a rewrite on its own output livelocks the driver
    into the iteration cap.  In-place mutation of the matched nodes is
    allowed (all three IRs are mutable dataclasses); the returned
    replacement list is spliced over the consumed slice either way.
    """

    benefit: int = 1
    name: str = ""

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        if not cls.__dict__.get("name"):
            n = cls.__name__.lstrip("_")
            cls.name = "".join("-" + c.lower() if c.isupper() else c
                               for c in n).lstrip("-")

    def match_and_rewrite(self, parent, siblings: List, i: int,
                          root) -> Optional[Replacement]:
        """Try to rewrite ``siblings[i]`` (child list of ``parent``).

        ``root`` is the artifact the driver was started on (patterns
        needing global context — SSA uses, symbol tables — reach it
        here).  Return ``None`` for no match, else ``(consumed,
        replacement)`` where ``consumed >= 1`` nodes starting at ``i``
        are replaced by the ``replacement`` list.
        """
        raise NotImplementedError

    def describe(self) -> str:
        """First docstring paragraph, collapsed to one line (used by the
        generated pattern reference in docs/REWRITE.md)."""
        doc = (self.__doc__ or type(self).__doc__ or "").strip()
        first = doc.split("\n\n", 1)[0]
        return " ".join(ln.strip() for ln in first.splitlines())


class OneShotPattern(Pattern):
    """A directed (parameterised) transform that applies exactly once.

    The ported scheduling passes (``split``, ``interchange``,
    ``set-sequencer``, ...) are one-shots: they name their target, fire
    on it a single time, and the wrapper pass raises if the target was
    never found (``applied`` stays False).  An ineligible target raises
    :class:`RewriteError` from inside the match, preserving the
    pre-refactor diagnostics.
    """

    def __init__(self):
        self.applied = False

    def match_and_rewrite(self, parent, siblings, i, root):
        if self.applied:
            return None
        res = self.apply_once(parent, siblings, i, root)
        if res is not None:
            self.applied = True
        return res

    def apply_once(self, parent, siblings, i, root):
        raise NotImplementedError


class SetSequencer(OneShotPattern):
    """Re-sequence the named HwIR loop between @fsm and @stream."""

    name = "set-sequencer"

    def __init__(self, counter: str, kind: str):
        super().__init__()
        self.counter = counter
        self.kind = kind

    def apply_once(self, parent, siblings, i, root):
        loop = siblings[i]
        if not isinstance(loop, HwLoop) or loop.counter != self.counter:
            return None
        if loop.kind not in ("fsm", "stream"):
            raise RewriteError(
                f"set-sequencer: loop %{self.counter} is @{loop.kind} "
                f"(spatial), not a temporal sequencer")
        loop.kind = self.kind
        return (1, [loop])


# --------------------------------------------------------------------------
# statistics
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RewriteStats:
    """Outcome of one driver run: per-pattern hit counts + convergence."""

    hits: Dict[str, int] = dataclasses.field(default_factory=dict)
    iterations: int = 0
    converged: bool = True

    @property
    def total(self) -> int:
        return sum(self.hits.values())

    def count(self, pattern_name: str, n: int = 1) -> None:
        self.hits[pattern_name] = self.hits.get(pattern_name, 0) + n

    def __str__(self):
        from . import ir_text
        body = ir_text.format_pattern_stats(self.hits) or "no hits"
        tail = "" if self.converged else " (iteration cap hit!)"
        return f"{body} in {self.iterations} sweep(s){tail}"


#: active ``collect_stats`` scopes (per thread — the DSE prices design
#: points on a thread pool and each thread's pipelines must not leak
#: statistics into another's records); driver runs merge into all scopes
#: of their own thread
_TLS = threading.local()


def _collectors() -> List[Dict[str, int]]:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


@contextlib.contextmanager
def collect_stats():
    """Collect per-pattern hit counts from every driver run in scope.

    The PassManager wraps each pass invocation in one of these so
    pattern statistics surface on the pass's ``PassRecord`` regardless
    of how many drivers the pass ran internally.
    """
    acc: Dict[str, int] = {}
    stack = _collectors()
    stack.append(acc)
    try:
        yield acc
    finally:
        # identity-based removal: two scopes with no hits yet are equal
        # ({} == {}), so list.remove would pop the wrong one
        for idx in range(len(stack) - 1, -1, -1):
            if stack[idx] is acc:
                del stack[idx]
                break


def _publish(stats: RewriteStats) -> None:
    for acc in _collectors():
        for k, v in stats.hits.items():
            acc[k] = acc.get(k, 0) + v


# --------------------------------------------------------------------------
# the driver
# --------------------------------------------------------------------------


class RewriteDriver:
    """Greedy fixpoint pattern application over the structural protocol.

    Sweeps the tree post-order (children before parents, so collapsed
    inner structure is visible to outer matches within one sweep),
    applying the highest-benefit matching pattern at each sibling
    position and re-trying the same position after a hit (a replacement
    may immediately enable another pattern).  Sweeps repeat until one
    changes nothing (``converged``) or ``max_iterations`` trips.
    """

    def __init__(self, patterns: Sequence[Pattern],
                 max_iterations: int = 32):
        if max_iterations < 1:
            raise ValueError("max_iterations must be >= 1")
        # stable sort: declaration order breaks benefit ties
        self.patterns = sorted(patterns, key=lambda p: -p.benefit)
        self.max_iterations = max_iterations

    def run(self, root) -> RewriteStats:
        stats = RewriteStats()
        changed = True
        while changed and stats.iterations < self.max_iterations:
            stats.iterations += 1
            changed = self._sweep(root, stats)
        stats.converged = not changed
        _publish(stats)
        return stats

    # one full post-order sweep; True if any pattern fired
    def _sweep(self, root, stats: RewriteStats) -> bool:
        changed = False

        def visit(node) -> None:
            nonlocal changed
            kids = node.children()
            i = 0
            while i < len(kids):
                visit(kids[i])
                i += 1
            i = 0
            while i < len(kids):
                for p in self.patterns:
                    res = p.match_and_rewrite(node, kids, i, root)
                    if res is None:
                        continue
                    consumed, repl = res
                    if consumed < 1 or i + consumed > len(kids):
                        raise RewriteError(
                            f"pattern {p.name} returned a bad consumed "
                            f"count {consumed} at position {i}")
                    kids[i:i + consumed] = repl
                    stats.count(p.name)
                    changed = True
                    break
                # always advance: a replacement that enables another match
                # (at this or an earlier position) is picked up by the next
                # sweep — retrying in place would let a misbehaving pattern
                # livelock inside one sweep, out of the iteration cap's reach
                i += 1

        visit(root)
        return changed


# --------------------------------------------------------------------------
# affine normalization (shared by LoopIR tile refs and HwIR address
# generators — the two spellings of the same block-index addressing)
# --------------------------------------------------------------------------


def normalize_affine(e: AffineExpr) -> AffineExpr:
    """Canonical affine form: duplicate variable terms merged, zero
    coefficients dropped, terms sorted by variable name."""
    merged: Dict[str, int] = {}
    for v, s in e.coeffs:
        merged[v] = merged.get(v, 0) + s
    coeffs = tuple(sorted((v, s) for v, s in merged.items() if s != 0))
    return AffineExpr(coeffs, e.const)


def _affine_is_normal(e: AffineExpr) -> bool:
    return e.coeffs == normalize_affine(e).coeffs


def _normalize_tileref(r: TileRef) -> TileRef:
    return TileRef(r.buffer, tuple(normalize_affine(e) for e in r.index),
                   r.tile)


# --------------------------------------------------------------------------
# canonicalization pattern registry
# --------------------------------------------------------------------------


#: per-level canonicalization pattern sets feeding the ``canonicalize``
#: pass; extend from outside the core with ``register_canonical_pattern``
CANONICAL_PATTERNS: Dict[str, List[Pattern]] = {
    "tensor": [], "loop": [], "hw": [],
}


def register_canonical_pattern(level: str):
    """Class decorator: instantiate ``cls`` into the ``level`` canonical
    set (the ``register_op``/``register_pass`` analogue for patterns)."""
    if level not in CANONICAL_PATTERNS:
        raise ValueError(f"no canonicalization set for level {level!r}; "
                         f"choose from {sorted(CANONICAL_PATTERNS)}")

    def deco(cls):
        CANONICAL_PATTERNS[level].append(cls())
        return cls
    return deco


def canonical_pattern_names() -> Tuple[str, ...]:
    """``level:name`` for every registered canonicalization pattern."""
    return tuple(f"{lvl}:{p.name}" for lvl in ("tensor", "loop", "hw")
                 for p in CANONICAL_PATTERNS[lvl])


# ---- TensorIR canonicalization ---------------------------------------------


def replace_value_uses(g: Graph, old, new) -> None:
    for op in g.ops:
        op.inputs = [new if v is old else v for v in op.inputs]
    g.outputs = [new if v is old else v for v in g.outputs]


def _use_count(g: Graph, val) -> int:
    n = sum(1 for op in g.ops for v in op.inputs if v is val)
    return n + sum(1 for v in g.outputs if v is val)


@register_canonical_pattern("tensor")
class DeadOpElim(Pattern):
    """Remove ops whose result is never used and is not an output."""

    name = "dead-op-elim"
    benefit = 2

    def match_and_rewrite(self, parent, siblings, i, root):
        op = siblings[i]
        if not isinstance(op, Op) or not isinstance(root, Graph):
            return None
        if _use_count(root, op.result):
            return None
        return (1, [])


@register_canonical_pattern("tensor")
class FoldIdentityCast(Pattern):
    """Fold ``cast`` to the operand's own dtype (an identity epilogue)."""

    name = "fold-identity-cast"

    def match_and_rewrite(self, parent, siblings, i, root):
        op = siblings[i]
        if not isinstance(op, Op) or op.opname != "cast":
            return None
        src = op.inputs[0]
        if op.attrs.get("dtype") != src.type.dtype:
            return None
        replace_value_uses(root, op.result, src)
        return (1, [])


@register_canonical_pattern("tensor")
class FoldIdentityTranspose(Pattern):
    """Fold ``transpose`` with the identity permutation."""

    name = "fold-identity-transpose"

    def match_and_rewrite(self, parent, siblings, i, root):
        op = siblings[i]
        if not isinstance(op, Op) or op.opname != "transpose":
            return None
        perm = list(op.attrs.get("perm", ()))
        if perm != list(range(len(perm))) or not perm:
            return None
        replace_value_uses(root, op.result, op.inputs[0])
        return (1, [])


@register_canonical_pattern("tensor")
class FoldIdempotentEwise(Pattern):
    """Fold ``f(f(x))`` for idempotent elementwise ops (``relu``)."""

    name = "fold-idempotent-ewise"
    _IDEMPOTENT = ("relu",)

    def match_and_rewrite(self, parent, siblings, i, root):
        op = siblings[i]
        if not isinstance(op, Op) or op.opname not in self._IDEMPOTENT:
            return None
        prod = op.inputs[0].producer
        if prod is None or prod.opname != op.opname:
            return None
        replace_value_uses(root, op.result, op.inputs[0])
        return (1, [])


# ---- LoopIR canonicalization -----------------------------------------------


def _subst_zero(stmts: Sequence[Stmt], var: str) -> None:
    """Substitute loop variable ``var`` := 0 in every tile ref under
    ``stmts`` (in place): its affine terms simply drop."""

    def fix(ref: TileRef) -> TileRef:
        idx = tuple(AffineExpr(tuple((v, s) for v, s in e.coeffs
                                     if v != var), e.const)
                    for e in ref.index)
        return TileRef(ref.buffer, idx, ref.tile)

    _map_stmt_refs(stmts, fix)


def _map_stmt_refs(stmts: Sequence[Stmt], fn) -> None:
    for s in stmts:
        if isinstance(s, Loop):
            _map_stmt_refs(s.body, fn)
        elif isinstance(s, ZeroTile):
            s.dst = fn(s.dst)
        elif isinstance(s, MatmulTile):
            s.dst, s.lhs, s.rhs = fn(s.dst), fn(s.lhs), fn(s.rhs)
        elif isinstance(s, EwiseTile):
            s.dst = fn(s.dst)
            s.srcs = [fn(r) for r in s.srcs]
        elif isinstance(s, FillTile):
            s.dst = fn(s.dst)
        elif isinstance(s, ReduceTile):
            s.dst, s.src = fn(s.dst), fn(s.src)
        elif isinstance(s, ScanTile):
            s.dst = fn(s.dst)
            s.srcs = [fn(r) for r in s.srcs]
            s.carry = fn(s.carry)


@register_canonical_pattern("loop")
class DropUnitLoop(Pattern):
    """Inline @seq loops with extent 1 (their variable is constantly 0).
    Annotation-bearing kinds (@grid/@vector/@unrolled) are kept even at
    extent 1: they carry the backend mapping (a @grid loop IS the pallas
    grid), so erasing them would silently change what a kernel can emit
    to.  Their hardware spelling still canonicalizes — trip-1 @stream
    sequencers collapse at the hw level."""

    name = "drop-unit-loop"
    benefit = 2

    def match_and_rewrite(self, parent, siblings, i, root):
        from .loop_ir import LoopKind
        loop = siblings[i]
        if not isinstance(loop, Loop) or loop.var.extent != 1:
            return None
        if loop.kind != LoopKind.SEQUENTIAL:
            return None
        _subst_zero(loop.body, loop.var.name)
        return (1, list(loop.body))


def _buffer_names(stmts: Sequence[Stmt], written: bool) -> set:
    out: set = set()

    def go(ss):
        for s in ss:
            if isinstance(s, Loop):
                go(s.body)
                continue
            refs = _stmt_refs(s)
            if written:
                # _stmt_written_refs: dst, plus the carry for ScanTile
                out.update(r.buffer.name for r in _stmt_written_refs(s))
            else:
                out.update(r.buffer.name for r in refs[1:])
                if isinstance(s, (MatmulTile, ReduceTile)) and s.accumulate:
                    out.add(s.dst.buffer.name)      # read-modify-write
    go(stmts)
    return out


def _loop_var_names(stmts: Sequence[Stmt]) -> set:
    out: set = set()

    def go(ss):
        for s in ss:
            if isinstance(s, Loop):
                out.add(s.var.name)
                go(s.body)
    go(stmts)
    return out


@register_canonical_pattern("loop")
class MergeAdjacentSeqLoops(Pattern):
    """Merge adjacent SEQUENTIAL loops of equal extent whose bodies touch
    disjoint buffers (independent nests: any interleaving is legal)."""

    name = "merge-seq-loops"

    def match_and_rewrite(self, parent, siblings, i, root):
        from .loop_ir import LoopKind
        if i + 1 >= len(siblings):
            return None
        a, b = siblings[i], siblings[i + 1]
        if not (isinstance(a, Loop) and isinstance(b, Loop)):
            return None
        if a.kind != LoopKind.SEQUENTIAL or b.kind != LoopKind.SEQUENTIAL:
            return None
        if a.var.extent != b.var.extent:
            return None
        wa, ra = _buffer_names(a.body, True), _buffer_names(a.body, False)
        wb, rb = _buffer_names(b.body, True), _buffer_names(b.body, False)
        if (wa & (rb | wb)) or (wb & ra):
            return None                     # dependent nests: not our call
        # renaming b's var to a's must not capture a nested loop name
        if a.var.name in _loop_var_names(b.body):
            return None

        def rename(ref: TileRef) -> TileRef:
            idx = tuple(AffineExpr(
                tuple((a.var.name if v == b.var.name else v, s)
                      for v, s in e.coeffs), e.const) for e in ref.index)
            return TileRef(ref.buffer, idx, ref.tile)

        _map_stmt_refs(b.body, rename)
        a.body.extend(b.body)
        return (2, [a])


@register_canonical_pattern("loop")
class NormalizeTileRefs(Pattern):
    """Normalize tile-ref address expressions (merge duplicate terms,
    drop zero coefficients, sort terms by variable)."""

    name = "normalize-tileref"

    def match_and_rewrite(self, parent, siblings, i, root):
        s = siblings[i]
        if isinstance(s, Loop) or not isinstance(s, Stmt):
            return None
        if all(_affine_is_normal(e) for r in _stmt_refs(s) for e in r.index):
            return None
        _map_stmt_refs([s], _normalize_tileref)
        return (1, [s])


# ---- HwIR canonicalization -------------------------------------------------


@register_canonical_pattern("hw")
class CollapseTrip1Sequencer(Pattern):
    """Collapse @fsm/@stream sequencers with a single trip (their counter
    is constantly 0; the header state is pure overhead)."""

    name = "collapse-trip1-sequencer"
    benefit = 2

    def match_and_rewrite(self, parent, siblings, i, root):
        loop = siblings[i]
        if not isinstance(loop, HwLoop) or loop.trips != 1:
            return None
        if loop.kind not in ("fsm", "stream"):
            return None
        for node in _walk_hw(loop.body):
            if isinstance(node, HwStep):
                for o in node.operands:
                    idx = tuple(
                        AffineExpr(tuple((v, s) for v, s in e.coeffs
                                         if v != loop.counter), e.const)
                        for e in o.index)
                    if idx != o.index:
                        object.__setattr__(o, "index", idx)
        return (1, list(loop.body))


def _walk_hw(nodes):
    for n in nodes:
        yield n
        if isinstance(n, HwLoop):
            yield from _walk_hw(n.body)


@register_canonical_pattern("hw")
class NormalizeAddrGen(Pattern):
    """Dedupe identical terms inside operand address generators and sort
    them (the HwIR spelling of tile-ref normalization)."""

    name = "normalize-addr-gen"

    def match_and_rewrite(self, parent, siblings, i, root):
        step = siblings[i]
        if not isinstance(step, HwStep):
            return None
        dirty = False
        for o in step.operands:
            norm = tuple(normalize_affine(e) for e in o.index)
            if norm != o.index:
                object.__setattr__(o, "index", norm)
                dirty = True
        return (1, [step]) if dirty else None


@register_canonical_pattern("hw")
class DedupeUnits(Pattern):
    """Share identical datapath units: steps invoking a unit with the
    same (kind, geometry, copies) as an earlier unit are repointed to
    the first instance; orphaned duplicates are pruned by the
    canonicalize pass."""

    name = "dedupe-units"

    def match_and_rewrite(self, parent, siblings, i, root):
        step = siblings[i]
        if not isinstance(step, HwStep) or not isinstance(root, HwModule):
            return None
        if root.binding_of(step.unit) is not None:
            # the step runs on a shared physical unit through the binding
            # table; repointing it at a bare declaration would silently
            # drop the binding's serialization accounting
            return None
        mine = root.unit(step.unit)
        for u in root.units:
            if u.name == mine.name:
                return None                 # already the first instance
            if (u.kind, u.geometry, u.copies) == \
                    (mine.kind, mine.geometry, mine.copies):
                step.unit = u.name
                return (1, [step])
        return None


def _prune_unused_units(mod: HwModule) -> int:
    """Drop unit declarations no step references (counted in stats under
    ``prune-unused-unit`` — they may predate the canonicalize run).

    Binding-aware: a physical unit is live while any binding row still
    points at it, and a binding row is live while any step references
    its virtual name (dangling rows drop with their virtual).  Recurses
    into sub-module definitions — each owns its own declarations.
    """
    removed = sum(_prune_unused_units(s) for s in mod.submodules)
    used = {s.unit for s in mod.steps()}
    mod.bindings = [b for b in mod.bindings if b.virtual in used]
    keep = used | {b.unit for b in mod.bindings}
    before = len(mod.units)
    mod.units = [u for u in mod.units if u.name in keep]
    return removed + before - len(mod.units)


def _prune_unused_modules(mod: HwModule) -> int:
    """Drop sub-module definitions no instance references (counted under
    ``prune-unused-module`` — rewrites may have orphaned a definition by
    replacing its last call site)."""
    removed = sum(_prune_unused_modules(s) for s in mod.submodules)
    from .hw_ir import HwInstance
    used = {n.module for n, _, _ in mod.walk() if isinstance(n, HwInstance)}
    before = len(mod.submodules)
    mod.submodules = [s for s in mod.submodules if s.name in used]
    return removed + before - len(mod.submodules)


# --------------------------------------------------------------------------
# the canonicalize entry point
# --------------------------------------------------------------------------


def level_of(art) -> str:
    """IR level of an artifact (the dispatch the canonicalize pass uses)."""
    if isinstance(art, Graph):
        return "tensor"
    if isinstance(art, Kernel):
        return "loop"
    if isinstance(art, HwModule):
        return "hw"
    raise TypeError(f"no rewrite level for {type(art).__name__}")


def canonicalize(art, max_iterations: int = 32) -> "art":
    """Drive the artifact's level-specific canonicalization pattern set
    to a fixpoint (in place) and return it.  Idempotent: a second run
    is a no-op — the CI canonicalize-smoke step diffs exactly that."""
    lvl = level_of(art)
    stats = RewriteDriver(CANONICAL_PATTERNS[lvl],
                          max_iterations=max_iterations).run(art)
    if lvl == "hw":
        pruned = _prune_unused_units(art)
        if pruned:
            stats.count("prune-unused-unit", pruned)
            _publish(RewriteStats(hits={"prune-unused-unit": pruned}))
        orphaned = _prune_unused_modules(art)
        if orphaned:
            stats.count("prune-unused-module", orphaned)
            _publish(RewriteStats(hits={"prune-unused-module": orphaned}))
    if not stats.converged:
        raise RewriteError(
            f"canonicalize: no fixpoint after {stats.iterations} sweeps "
            f"on {lvl} artifact ({stats})")
    return art


def canonical_text(art) -> str:
    """Canonical textual form of a *copy* of ``art`` (the artifact is
    re-parsed first so the caller's object is never mutated).  The DSE
    applies this to each design point's lowered HwModule to build its
    dedupe key (:func:`repro.core.dse.canonical_key`)."""
    from . import ir_text
    copy = ir_text.parse_ir(ir_text.print_ir(art))
    return ir_text.print_ir(canonicalize(copy))
