"""HwIR — level-3 (hardware) dialect of the stagecc stack.

This is the Calyx/RTL half of the paper's Fig. 1 that the reproduction
previously only *simulated*: a scheduled LoopIR kernel lowers to an
explicit FSM + datapath hardware description, and the TABLE I / Fig. 3
measurements are then derived *structurally* from that hardware (count
FSM steps, registers, datapath lanes, buffer bytes) instead of from
LoopIR-walking heuristics.

An :class:`HwModule` is one synthesisable unit, Calyx-component-shaped:

  * **ports** — the module's memory-mapped I/O (one per HBM kernel
    argument; the AXI interface of the paper's generated IP core);
  * **regs** — architectural registers: accumulator tiles that lived in
    ``@vreg`` (loop counters are implicit in the control tree — each
    ``@fsm``/``@stream`` loop owns one);
  * **mems** — on-chip RAMs (``@vmem`` scratch; the BRAM analogue);
  * **units** — datapath functional units (``mac`` scalar multiply-
    accumulate, ``mxu`` systolic tile engine, ``vpu`` elementwise lane
    array), each with a geometry (lanes per copy) and a spatial
    ``copies`` count ( > 1 under unrolled/vector loops);
  * **ctrl** — the control program, Calyx-control-shaped: ``HwStep``
    leaves (one datapath invocation ≙ one FSM state) under ``HwLoop``
    nodes whose kind says how the hardware sequences them:

      - ``fsm``     — an FSM-stepped (time-multiplexed) loop: one body
                      datapath, a counter register, a state transition
                      per iteration (LoopIR ``@seq``);
      - ``unroll``  — spatially replicated body hardware, control paid
                      once; stays memory-port-limited (LoopIR
                      ``@unrolled``, the paper's inner-flattening);
      - ``simd``    — true SIMD lane replication (LoopIR ``@vector``);
      - ``stream``  — a grid sequencer with double-buffered DMA: memory
                      traffic overlaps compute across steps (LoopIR
                      ``@grid``, the pallas-grid analogue).

Every step operand carries an affine *address generator* (``index``) in
the enclosing loop counters, so the hardware level is **executable**:
``hw_sim.simulate`` walks the control tree cycle-by-cycle against real
numpy buffers (the Vivado-simulation role), and ``host_bridge`` couples
the module to a modelled host CPU over a crossbar (the paper's AXI/CSR
integration).

``lower_to_hw`` is the only producer; ``emit_verilog`` pretty-prints a
Verilog-style module (FSM state encoding, counters, register/memory
declarations, generate-replicated units) and the textual round-trip form
lives in ``ir_text`` (``print(parse(print(hw)))`` is a fixpoint, like
the two levels above).  ``machine_model.cycles``/``resources`` price an
``HwModule``; this module deliberately knows nothing about cost.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .loop_ir import (AffineExpr, EwiseTile, FillTile, Kernel, Loop, LoopKind,
                      MatmulTile, MemSpace, ReduceTile, ScanTile, Stmt,
                      TileRef, ZeroTile)
from .tensor_ir import dtype_bytes

#: LoopIR loop kinds -> HwIR sequencing disciplines
CTRL_OF_LOOPKIND = {
    LoopKind.SEQUENTIAL: "fsm",
    LoopKind.UNROLLED: "unroll",
    LoopKind.VECTOR: "simd",
    LoopKind.GRID: "stream",
}
LOOP_CTRL_KINDS = tuple(CTRL_OF_LOOPKIND.values())

#: datapath unit kinds
UNIT_KINDS = ("mac", "mxu", "vpu")

#: ops that an MXU tile engine can be invoked with
_MATMUL_OPS = ("matmul",)


# --------------------------------------------------------------------------
# storage + datapath declarations
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HwPort:
    """Module I/O.  Top-level module ports are backed by off-chip (HBM)
    memory — the AXI channel.  Sub-module ports declare the ``space``
    of the parent storage they are bound to at each instance site
    (``hbm``/``vmem``/``vreg``), so pricing stays honest through the
    hierarchy: a port backed by a parent register tile costs what a
    register read costs, not an HBM burst."""

    name: str
    direction: str                  # "in" | "out" | "inout"
    dtype: str                      # element type, e.g. float32
    shape: Tuple[int, ...]          # backing array shape (elements)
    space: str = "hbm"              # "hbm" | "vmem" | "vreg"

    def __post_init__(self):
        if self.direction not in ("in", "out", "inout"):
            raise ValueError(f"port {self.name}: bad direction "
                             f"{self.direction!r}")
        if self.space not in ("hbm", "vmem", "vreg"):
            raise ValueError(f"port {self.name}: bad space {self.space!r}")

    @property
    def elems(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def width_bits(self) -> int:
        return 8 * dtype_bytes(self.dtype)


@dataclasses.dataclass(frozen=True)
class HwReg:
    """An architectural register bank (a VREG tile): ``elems`` parallel
    registers of ``width_bits`` each."""

    name: str
    dtype: str
    shape: Tuple[int, ...]

    @property
    def elems(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def width_bits(self) -> int:
        return 8 * dtype_bytes(self.dtype)


@dataclasses.dataclass(frozen=True)
class HwMem:
    """An on-chip RAM (VMEM scratch — the BRAM analogue)."""

    name: str
    dtype: str
    shape: Tuple[int, ...]

    @property
    def elems(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        return self.elems * dtype_bytes(self.dtype)


@dataclasses.dataclass(frozen=True)
class HwUnit:
    """A datapath functional unit instance.

    ``geometry`` is the unit's internal parallelism (lanes of one copy):
    ``(m, n)`` output tile for ``mxu``/``mac``, ``(elems,)`` for ``vpu``.
    ``copies`` > 1 means the unit is spatially replicated (it sits under
    an unrolled/vector loop) — the Fig.-3 "hardware grows with matrix
    size" mechanism.
    """

    name: str
    kind: str                       # "mac" | "mxu" | "vpu"
    geometry: Tuple[int, ...]
    copies: int = 1

    def __post_init__(self):
        if self.kind not in UNIT_KINDS:
            raise ValueError(f"unit {self.name}: bad kind {self.kind!r}")
        if self.copies < 1:
            raise ValueError(f"unit {self.name}: copies must be >= 1")

    @property
    def lanes(self) -> int:
        """Spatial compute lanes of one copy (DSP analogue)."""
        return int(np.prod(self.geometry)) if self.geometry else 1


@dataclasses.dataclass(frozen=True)
class HwBinding:
    """One row of the module's resource-binding table: control steps that
    invoke the *virtual* unit ``virtual`` actually execute on the shared
    physical unit ``unit``.

    ``copies`` records the spatial replication the virtual unit was
    lowered with; when the physical unit provides fewer copies, each
    activation of the bound step group serializes into ``serial``
    sequential rounds (``serial = ceil(copies / physical.copies)``) —
    the time-multiplexing the ``share-units`` scheduler trades area for.
    """

    virtual: str                    # name steps reference
    unit: str                       # physical HwUnit name
    serial: int = 1                 # sequential rounds per activation
    copies: int = 1                 # spatial copies of the virtual unit

    def __post_init__(self):
        if self.serial < 1:
            raise ValueError(f"binding {self.virtual}: serial must be >= 1")
        if self.copies < 1:
            raise ValueError(f"binding {self.virtual}: copies must be >= 1")


# --------------------------------------------------------------------------
# control
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HwOperand:
    """One datapath operand: a tile of a port/mem/reg touched per invoke.

    ``role`` is the dataflow direction seen from the unit: ``read``,
    ``write``, or ``acc`` (read-modify-write accumulation).

    ``index`` is the operand's address generator: one affine function of
    the enclosing loop counters per storage dimension, in units of the
    tile size for that dimension — the same block-index addressing as
    :class:`~repro.core.loop_ir.TileRef`.  This is what makes HwIR
    *executable* (``hw_sim`` walks these to real numpy slices) rather
    than merely priceable.
    """

    role: str                       # "read" | "write" | "acc"
    target: str                     # name of a port / mem / reg
    tile: Tuple[int, ...]           # elements moved per invocation
    index: Tuple[AffineExpr, ...] = ()  # block index per storage dim

    def __post_init__(self):
        if self.role not in ("read", "write", "acc"):
            raise ValueError(f"operand {self.target}: bad role {self.role!r}")

    @property
    def elems(self) -> int:
        return int(np.prod(self.tile)) if self.tile else 1

    def slices(self, shape: Tuple[int, ...],
               env: Dict[str, int]) -> Tuple[slice, ...]:
        """Numpy slices of this operand's tile inside storage of ``shape``
        under counter bindings ``env`` (mirrors ``TileRef.slices``)."""
        if len(self.index) != len(shape):
            raise IndexError(
                f"operand {self.target}: index rank {len(self.index)} does "
                f"not match storage rank {len(shape)} — module built "
                f"without address generators?")
        out = []
        for e, t, d in zip(self.index, self.tile, shape):
            start = e.evaluate(env) * t
            if start < 0 or start + t > d:
                raise IndexError(
                    f"operand {self.target}: tile [{start}:{start + t}] out "
                    f"of bounds (dim {d})")
            out.append(slice(start, start + t))
        return tuple(out)


@dataclasses.dataclass
class HwCtrl:
    """Base class of control-tree nodes."""

    # ---- rewrite-core structural protocol (see core/rewrite.py) -----------

    def children(self) -> List["HwCtrl"]:
        return []

    def rebuild(self, children: Sequence["HwCtrl"]) -> "HwCtrl":
        assert not children
        return dataclasses.replace(self)

    def is_equivalent(self, other) -> bool:
        from . import ir_text
        return isinstance(other, HwCtrl) and \
            ir_text.print_hw_ctrl(self) == ir_text.print_hw_ctrl(other)


@dataclasses.dataclass
class HwStep(HwCtrl):
    """One FSM state: invoke ``unit`` with ``op`` over ``operands``.

    Operand order is significant for multi-operand ops (matmul: dst,
    lhs, rhs — mirroring ``MatmulTile``).
    """

    op: str                         # "matmul" | "zero" | vpu op name
    unit: str                       # HwUnit name (or a binding's virtual)
    operands: List[HwOperand]


@dataclasses.dataclass
class HwInstance(HwCtrl):
    """One FSM state that invokes a sub-module definition.

    ``portmap`` carries one operand per sub-module port, in port order:
    the operand's target/index/tile name the region of *parent* storage
    the port is bound to for this call site.  The operand role mirrors
    the port direction (``in``→``read``, ``out``→``write``,
    ``inout``→``acc``).  The sub-module runs its own control program to
    completion before the parent FSM advances — a call, not a fork.
    """

    module: str                     # name in the parent's submodule table
    portmap: List[HwOperand]

    def rebuild(self, children: Sequence["HwCtrl"]) -> "HwInstance":
        assert not children
        return HwInstance(self.module, list(self.portmap))


@dataclasses.dataclass
class HwLoop(HwCtrl):
    """A hardware-sequenced loop: ``counter`` is the implicit counter
    register (``fsm``/``stream``) or the replication index
    (``unroll``/``simd``)."""

    counter: str
    trips: int
    kind: str                       # "fsm" | "unroll" | "simd" | "stream"
    body: List[HwCtrl]

    def __post_init__(self):
        if self.kind not in LOOP_CTRL_KINDS:
            raise ValueError(f"loop %{self.counter}: bad kind {self.kind!r}")

    def children(self) -> List[HwCtrl]:
        return self.body

    def rebuild(self, children: Sequence[HwCtrl]) -> "HwLoop":
        return HwLoop(self.counter, self.trips, self.kind, list(children))

    @property
    def counter_bits(self) -> int:
        return max(1, math.ceil(math.log2(max(2, self.trips))))


def _walk_ctrl(nodes: Sequence[HwCtrl], depth: int = 0, trail=()):
    """Yield ``(node, depth, trail)`` over a control forest."""
    for n in nodes:
        yield n, depth, tuple(trail)
        if isinstance(n, HwLoop):
            yield from _walk_ctrl(n.body, depth + 1, tuple(trail) + (n,))


# --------------------------------------------------------------------------
# module
# --------------------------------------------------------------------------


@dataclasses.dataclass
class HwModule:
    """One hardware module: storage + datapath + control, plus (for the
    hierarchical, shared-resource form) a sub-module definition table and
    a resource-binding table.  ``submodules`` hold outlined subcircuit
    definitions instanced from the control tree via :class:`HwInstance`;
    ``bindings`` map virtual unit names (what steps reference) onto
    shared physical :class:`HwUnit` declarations."""

    name: str
    ports: List[HwPort]
    regs: List[HwReg]
    mems: List[HwMem]
    units: List[HwUnit]
    ctrl: List[HwCtrl]
    submodules: List["HwModule"] = dataclasses.field(default_factory=list)
    bindings: List[HwBinding] = dataclasses.field(default_factory=list)

    # ---- symbol tables -----------------------------------------------------

    def storage(self, name: str):
        for coll in (self.ports, self.regs, self.mems):
            for d in coll:
                if d.name == name:
                    return d
        raise KeyError(f"no storage named {name!r} in module {self.name}")

    def space_of(self, name: str) -> MemSpace:
        d = self.storage(name)
        if isinstance(d, HwPort):
            return MemSpace(d.space)
        if isinstance(d, HwMem):
            return MemSpace.VMEM
        return MemSpace.VREG

    def binding_of(self, name: str) -> Optional[HwBinding]:
        """The binding-table row whose virtual name is ``name``, if any."""
        for b in self.bindings:
            if b.virtual == name:
                return b
        return None

    def unit(self, name: str) -> HwUnit:
        """Resolve a step's unit reference — through the binding table
        first (virtual → physical), then the declaration list."""
        b = self.binding_of(name)
        if b is not None:
            name = b.unit
        for u in self.units:
            if u.name == name:
                return u
        raise KeyError(f"no unit named {name!r} in module {self.name}")

    def submodule(self, name: str) -> "HwModule":
        for s in self.submodules:
            if s.name == name:
                return s
        raise KeyError(f"no submodule named {name!r} in module {self.name}")

    # ---- rewrite-core structural protocol (see core/rewrite.py) -----------

    def children(self) -> List[HwCtrl]:
        """The module's mutable top-level control list."""
        return self.ctrl

    def rebuild(self, children: Sequence[HwCtrl]) -> "HwModule":
        return HwModule(self.name, list(self.ports), list(self.regs),
                        list(self.mems), list(self.units), list(children),
                        submodules=list(self.submodules),
                        bindings=list(self.bindings))

    def is_equivalent(self, other) -> bool:
        """Structural equivalence: identical canonical textual form."""
        from . import ir_text
        return isinstance(other, HwModule) and \
            ir_text.print_hw_module(self) == ir_text.print_hw_module(other)

    # ---- traversal ---------------------------------------------------------

    def walk(self):
        """Yield ``(node, depth, trail)`` over the control tree, where
        ``trail`` is the tuple of enclosing :class:`HwLoop` nodes."""
        yield from _walk_ctrl(self.ctrl)

    def steps(self) -> List[HwStep]:
        return [n for n, _, _ in self.walk() if isinstance(n, HwStep)]

    def loops(self) -> List[HwLoop]:
        return [n for n, _, _ in self.walk() if isinstance(n, HwLoop)]

    # ---- structural accounting (what the Vivado report would count) --------

    def fsm_state_count(self) -> int:
        """Number of states in the flattened control FSM (hierarchical
        total: every sub-module definition owns its own controller,
        counted once however many instances reference it).

        Every :class:`HwStep` is one state; an :class:`HwInstance` is one
        call state in the parent.  ``fsm``/``stream`` loops add one
        header state (test + counter increment); ``unroll``/``simd``
        bodies are spatial, so their body contributes its states once and
        no header exists.  An idle/done state closes each machine.
        """

        def go(nodes) -> int:
            n = 0
            for node in nodes:
                if isinstance(node, (HwStep, HwInstance)):
                    n += 1
                elif node.kind in ("fsm", "stream"):
                    n += 1 + go(node.body)
                else:                       # unroll / simd: spatial
                    n += go(node.body)
            return n

        return (1 + go(self.ctrl)           # + idle/done
                + sum(s.fsm_state_count() for s in self.submodules))

    def state_bits(self) -> int:
        return max(1, math.ceil(math.log2(max(2, self.fsm_state_count()))))

    def register_bits(self) -> int:
        """Total architectural register bits: declared register banks plus
        the loop counters implied by sequenced loops plus the FSM state
        register (the FF part of the FF/LUT report); sub-module
        definitions contribute their own bits once."""
        bits = sum(r.elems * r.width_bits for r in self.regs)
        bits += sum(l.counter_bits for l in self.loops()
                    if l.kind in ("fsm", "stream"))
        return (bits + self.state_bits()
                + sum(s.register_bits() for s in self.submodules))

    def mem_bytes(self) -> int:
        return (sum(mm.bytes for mm in self.mems)
                + sum(s.mem_bytes() for s in self.submodules))

    def lane_count(self) -> int:
        """Peak spatial compute lanes (the DSP column of Fig. 3)."""
        return max([u.lanes * u.copies for u in self.units]
                   + [s.lane_count() for s in self.submodules] or [0])

    def total_lanes(self) -> int:
        """Summed spatial compute lanes over every declared unit plus
        every sub-module definition counted once — the quantity resource
        sharing actually shrinks (a shared physical unit is one decl,
        however many virtual names bind to it)."""
        return (sum(u.lanes * u.copies for u in self.units)
                + sum(s.total_lanes() for s in self.submodules))

    def _unit_users(self) -> Dict[str, int]:
        """Physical unit name -> number of distinct users (direct step
        references + binding-table rows) competing for its ports."""
        unit_names = {u.name for u in self.units}
        users = {n: 0 for n in unit_names}
        for name in {s.unit for s in self.steps() if s.unit in unit_names}:
            users[name] += 1
        for b in self.bindings:
            if b.unit in users:
                users[b.unit] += 1
        return users

    def mux_bits(self) -> int:
        """Input-select overhead of time-multiplexing: every user of a
        physical unit beyond the first needs a lanes-wide 2:1 mux on each
        of the unit's two operand buses.  Zero for unshared modules."""
        users = self._unit_users()
        bits = 0
        for u in self.units:
            bits += max(0, users[u.name] - 1) * u.lanes * u.copies * 2
        return bits + sum(s.mux_bits() for s in self.submodules)

    def shared_unit_count(self) -> int:
        """Number of physical units that are time-multiplexed (referenced
        through at least one binding-table row), hierarchy-wide."""
        bound = {b.unit for b in self.bindings}
        return (sum(1 for u in self.units if u.name in bound)
                + sum(s.shared_unit_count() for s in self.submodules))

    # ---- verification ------------------------------------------------------

    def verify(self) -> None:
        # ports/regs/mems share one storage namespace; name the duplicate
        seen: set = set()
        for d in self.ports + self.regs + self.mems:
            if d.name in seen:
                raise ValueError(
                    f"duplicate storage name {d.name!r} in module "
                    f"{self.name} (ports, regs and mems share a namespace)")
            seen.add(d.name)
        unit_seen: set = set()
        for u in self.units:
            if u.name in unit_seen:
                raise ValueError(f"duplicate unit name {u.name!r} in module "
                                 f"{self.name}")
            unit_seen.add(u.name)
        sub_seen: set = set()
        for s in self.submodules:
            if s.name in sub_seen:
                raise ValueError(f"duplicate submodule name {s.name!r} in "
                                 f"module {self.name}")
            sub_seen.add(s.name)
            s.verify()
        bind_seen: set = set()
        for b in self.bindings:
            if b.virtual in bind_seen:
                raise ValueError(f"duplicate binding for virtual unit "
                                 f"{b.virtual!r} in module {self.name}")
            if b.virtual in unit_seen:
                raise ValueError(
                    f"binding {b.virtual!r} shadows a unit declaration in "
                    f"module {self.name} (virtual and physical names are "
                    f"disjoint namespaces)")
            bind_seen.add(b.virtual)
            if b.unit not in unit_seen:
                raise ValueError(
                    f"binding {b.virtual} -> {b.unit}: no unit named "
                    f"{b.unit!r} declared in module {self.name}")
        def check_operand(opnd, scope):
            d = self.storage(opnd.target)       # raises on unknown name
            rank = len(d.shape)
            if len(opnd.tile) != rank or len(opnd.index) != rank:
                raise ValueError(
                    f"operand {opnd.target}: index/tile rank "
                    f"({len(opnd.index)}/{len(opnd.tile)}) does not "
                    f"match storage rank {rank}")
            for e in opnd.index:
                for v, _ in e.coeffs:
                    if v not in scope:
                        raise ValueError(
                            f"operand {opnd.target}: index uses "
                            f"counter %{v} not bound by an "
                            f"enclosing loop")
            # bounds over the whole iteration box, sign-aware per
            # coefficient (a mixed-sign index like i1+-1*k3 takes
            # its extrema at different corners per term)
            for e, t, dim in zip(opnd.index, opnd.tile, d.shape):
                lo = hi = e.const
                for v, s in e.coeffs:
                    ext = scope[v] - 1
                    lo += min(0, s * ext)
                    hi += max(0, s * ext)
                if lo * t < 0 or hi * t + t > dim:
                    raise ValueError(
                        f"operand {opnd.target}: tile range "
                        f"[{lo * t}:{hi * t + t}] out of bounds "
                        f"(dim {dim})")
            return d

        counters = set()
        for node, _, trail in self.walk():
            if isinstance(node, HwLoop):
                if node.trips <= 0:
                    raise ValueError(f"loop %{node.counter} has no trips")
                if node.counter in counters:
                    raise ValueError(f"shadowed counter %{node.counter}")
                if node.counter in seen:
                    raise ValueError(f"loop counter %{node.counter} shadows "
                                     f"a storage name")
                counters.add(node.counter)
            elif isinstance(node, HwInstance):
                if node.module not in sub_seen:
                    raise ValueError(
                        f"instance references unknown submodule "
                        f"@{node.module} in module {self.name}")
                sub = self.submodule(node.module)
                if len(node.portmap) != len(sub.ports):
                    raise ValueError(
                        f"instance @{node.module}: port map has "
                        f"{len(node.portmap)} operands but the module "
                        f"declares {len(sub.ports)} ports")
                scope = {l.counter: l.trips for l in trail}
                for opnd, port in zip(node.portmap, sub.ports):
                    want = {"in": "read", "out": "write",
                            "inout": "acc"}[port.direction]
                    if opnd.role != want:
                        raise ValueError(
                            f"instance @{node.module} port {port.name} "
                            f"({port.direction}) needs a {want} operand, "
                            f"got {opnd.role}")
                    d = check_operand(opnd, scope)
                    if tuple(opnd.tile) != tuple(port.shape):
                        raise ValueError(
                            f"instance @{node.module} port {port.name}: "
                            f"bound tile {tuple(opnd.tile)} does not match "
                            f"port shape {tuple(port.shape)}")
                    if d.dtype != port.dtype:
                        raise ValueError(
                            f"instance @{node.module} port {port.name}: "
                            f"dtype {d.dtype} does not match port dtype "
                            f"{port.dtype}")
                    if self.space_of(opnd.target).value != port.space:
                        raise ValueError(
                            f"instance @{node.module} port {port.name}: "
                            f"bound storage {opnd.target} lives in "
                            f"{self.space_of(opnd.target).value}, port "
                            f"declares {port.space}")
            elif isinstance(node, HwStep):
                u = self.unit(node.unit)
                if node.op in _MATMUL_OPS:
                    if u.kind == "vpu":
                        raise ValueError(
                            f"step {node.op} cannot run on vpu unit {u.name}")
                    if len(node.operands) != 3:
                        raise ValueError(
                            f"step {node.op} needs (dst, lhs, rhs) operands, "
                            f"got {len(node.operands)}")
                    for opnd in node.operands[1:]:
                        if len(opnd.tile) < 2:
                            raise ValueError(
                                f"matmul operand {opnd.target} must be a "
                                f"rank>=2 tile")
                if not node.operands:
                    raise ValueError(f"step {node.op} has no operands")
                scope = {l.counter: l.trips for l in trail}
                for opnd in node.operands:
                    check_operand(opnd, scope)

    def __str__(self):
        from . import ir_text
        return ir_text.print_hw_module(self)


# --------------------------------------------------------------------------
# LoopIR -> HwIR lowering (the CIRCT "calyx-to-hw" role)
# --------------------------------------------------------------------------


class _HwLowerer:
    """Structural translation of a scheduled kernel:

      * HBM params        -> ports (outputs drive write channels)
      * VMEM scratch      -> mems
      * VREG scratch      -> regs
      * leaf statements   -> one datapath unit + one control step each;
        a unit under unrolled/vector loops is replicated ``copies`` times
      * loops             -> control nodes per ``CTRL_OF_LOOPKIND``
    """

    def __init__(self, kernel: Kernel, mxu_min_dim: int = 8,
                 max_unit_lanes: int = 1024):
        kernel.verify()
        self.k = kernel
        self.mxu_min_dim = mxu_min_dim
        self.max_unit_lanes = max_unit_lanes
        self.units: List[HwUnit] = []
        self._uid = 0

    def uid(self, hint: str) -> str:
        self._uid += 1
        return f"{hint}{self._uid}"

    # ---- pieces ------------------------------------------------------------

    def _operand(self, role: str, ref: TileRef) -> HwOperand:
        # the TileRef's affine block index becomes the operand's address
        # generator; HwLoop counters keep the LoopIR variable names, so
        # the expressions stay valid at the hardware level.
        return HwOperand(role, ref.buffer.name, tuple(ref.tile),
                         tuple(ref.index))

    def _new_unit(self, kind: str, geometry: Tuple[int, ...],
                  copies: int) -> HwUnit:
        u = HwUnit(self.uid(kind), kind, geometry, copies)
        self.units.append(u)
        return u

    def _lower_stmt(self, s: Stmt, copies: int) -> HwStep:
        if isinstance(s, MatmulTile):
            mt, kt = s.lhs.tile[-2], s.lhs.tile[-1]
            nt = s.rhs.tile[-1]
            kind = "mxu" if min(mt, nt, kt) >= self.mxu_min_dim else "mac"
            # geometry clamps to the physical array edge (128 for the MXU
            # stand-in); the machine model prices partial tiles itself.
            geometry = (min(mt, 128), min(nt, 128))
            u = self._new_unit(kind, geometry, copies)
            role = "acc" if s.accumulate else "write"
            return HwStep("matmul", u.name,
                          [self._operand(role, s.dst),
                           self._operand("read", s.lhs),
                           self._operand("read", s.rhs)])
        if isinstance(s, ZeroTile):
            u = self._new_unit(
                "vpu", (min(s.dst.tile_elems, self.max_unit_lanes),), copies)
            return HwStep("zero", u.name, [self._operand("write", s.dst)])
        if isinstance(s, EwiseTile):
            u = self._new_unit(
                "vpu", (min(s.dst.tile_elems, self.max_unit_lanes),), copies)
            return HwStep(s.op, u.name,
                          [self._operand("write", s.dst)] +
                          [self._operand("read", r) for r in s.srcs])
        if isinstance(s, FillTile):
            # only the two fill constants lowering emits have a hardware
            # spelling: 0.0 reuses the zero broadcast, the reduce-max
            # identity gets its own op (a constant ROM would be overkill)
            if s.value == 0.0:
                op = "zero"
            elif s.value == -1e30:
                op = "fill_min"
            else:
                raise TypeError(
                    f"no HwIR lowering for fill constant {s.value!r}")
            u = self._new_unit(
                "vpu", (min(s.dst.tile_elems, self.max_unit_lanes),), copies)
            return HwStep(op, u.name, [self._operand("write", s.dst)])
        if isinstance(s, ReduceTile):
            u = self._new_unit(
                "vpu", (min(s.src.tile_elems, self.max_unit_lanes),), copies)
            role = "acc" if s.accumulate else "write"
            return HwStep(f"reduce_{s.kind}", u.name,
                          [self._operand(role, s.dst),
                           self._operand("read", s.src)])
        if isinstance(s, ScanTile):
            u = self._new_unit(
                "vpu", (min(s.dst.tile_elems, self.max_unit_lanes),), copies)
            return HwStep(f"scan_{s.kind}", u.name,
                          [self._operand("write", s.dst),
                           self._operand("acc", s.carry)] +
                          [self._operand("read", r) for r in s.srcs])
        raise TypeError(f"no HwIR lowering for statement {type(s).__name__}")

    def _lower_block(self, stmts: Sequence[Stmt], copies: int) -> List[HwCtrl]:
        out: List[HwCtrl] = []
        for s in stmts:
            if isinstance(s, Loop):
                rep = copies
                if s.kind in (LoopKind.UNROLLED, LoopKind.VECTOR):
                    rep *= s.var.extent
                out.append(HwLoop(s.var.name, s.var.extent,
                                  CTRL_OF_LOOPKIND[s.kind],
                                  self._lower_block(s.body, rep)))
            else:
                out.append(self._lower_stmt(s, copies))
        return out

    # ---- driver ------------------------------------------------------------

    def run(self) -> HwModule:
        ctrl = self._lower_block(self.k.body, 1)
        # port direction follows actual channel usage: HBM intermediates
        # are written by one nest and read by the next (inout), kernel
        # outputs drive a write channel, pure inputs a read channel.
        read, written = set(), set()
        for node, _, _ in _walk_ctrl(ctrl):
            if isinstance(node, HwStep):
                for o in node.operands:
                    (read if o.role == "read" else written).add(o.target)
                    if o.role == "acc":
                        read.add(o.target)
        written |= {b.name for b in self.k.outputs}

        def direction(name: str) -> str:
            if name in written:
                return "inout" if name in read else "out"
            return "in"

        ports = [HwPort(b.name, direction(b.name), b.type.dtype,
                        tuple(b.type.shape))
                 for b in self.k.params]
        regs = [HwReg(b.name, b.type.dtype, tuple(b.type.shape))
                for b in self.k.scratch if b.space == MemSpace.VREG]
        mems = [HwMem(b.name, b.type.dtype, tuple(b.type.shape))
                for b in self.k.scratch if b.space == MemSpace.VMEM]
        mod = HwModule(name=self.k.name, ports=ports, regs=regs, mems=mems,
                       units=self.units, ctrl=ctrl)
        mod.verify()
        return mod


def lower_to_hw(kernel: Kernel, mxu_min_dim: int = 8) -> HwModule:
    """Lower a scheduled LoopIR kernel to an FSM + datapath HwModule.

    The produced module is always verified before being returned
    (:meth:`HwModule.verify` — storage/unit name uniqueness, counter
    scoping, operand rank and bounds), so no caller ever holds an
    unchecked hardware module.
    """
    return _HwLowerer(kernel, mxu_min_dim=mxu_min_dim).run()


def set_sequencer(mod: HwModule, counter: str, kind: str) -> HwModule:
    """Re-sequence loop ``%counter`` between ``fsm`` and ``stream``.

    This is the HwIR-level scheduling knob the DSE drives: an ``fsm``
    loop re-sequenced as ``stream`` gains the grid sequencer's
    double-buffered DMA (memory traffic overlaps compute across steps,
    at the price of the ping-pong buffers), and vice versa.  Only the
    two *temporal* sequencer kinds are interconvertible — rewriting a
    loop to/from the spatial kinds (``unroll``/``simd``) would change
    the datapath replication the module was lowered with, so that stays
    a LoopIR-level decision (``unroll``/``vectorize`` passes).
    """
    if kind not in ("fsm", "stream"):
        raise ValueError(
            f"set-sequencer: kind must be 'fsm' or 'stream', got {kind!r} "
            f"(spatial sequencers are fixed at lower-to-hw time)")
    # lazy import: rewrite.py imports this module for its pattern classes
    from .rewrite import RewriteDriver, SetSequencer

    pat = SetSequencer(counter, kind)
    RewriteDriver([pat], max_iterations=2).run(mod)
    if not pat.applied:
        raise KeyError(f"no loop counter %{counter} in module {mod.name}")
    mod.verify()
    return mod


# --------------------------------------------------------------------------
# Verilog-style emission (the paper's "RTL generation" stage)
# --------------------------------------------------------------------------


def _flat_states(mod: HwModule) -> List[Tuple[str, str]]:
    """Enumerate FSM states as ``(name, comment)`` in execution order,
    matching :meth:`HwModule.fsm_state_count`."""
    states: List[Tuple[str, str]] = [("S_IDLE", "wait for start")]

    def go(nodes, prefix):
        for i, n in enumerate(nodes):
            if isinstance(n, HwStep):
                opnds = ", ".join(o.target for o in n.operands)
                states.append((f"S_{prefix}{i}_{n.op.upper()}",
                               f"invoke {n.unit}.{n.op}({opnds})"))
            elif isinstance(n, HwInstance):
                opnds = ", ".join(o.target for o in n.portmap)
                safe = "".join(c if c.isalnum() else "_" for c in n.module)
                states.append((f"S_{prefix}{i}_CALL_{safe.upper()}",
                               f"invoke submodule {n.module}({opnds}); "
                               f"wait for its done"))
            elif n.kind in ("fsm", "stream"):
                states.append((f"S_{prefix}{i}_{n.counter.upper()}",
                               f"{n.kind} loop %{n.counter}: test/increment "
                               f"({n.trips} trips)"))
                go(n.body, f"{prefix}{i}_")
            else:
                # spatial: body hardware replicated, single control step set
                go(n.body, f"{prefix}{i}_")

    go(mod.ctrl, "")
    return states


def emit_verilog(mod: HwModule) -> str:
    """Pretty-print ``mod`` as a Verilog-style module.

    The output is a readable structural description (FSM state encoding,
    counters, register banks, RAMs, generate-replicated units), not a
    synthesis-clean netlist — it is the textual artifact the paper's
    pipeline hands to Vivado, emitted so cycle/resource numbers can be
    audited against real structure.

    Sub-module definitions are emitted as real Verilog modules of their
    own (named ``{parent}_{sub}``) after the parent, each instantiated
    once in the parent's datapath section — instead of the pre-sharing
    form's N inlined copies.  Plain modules (no submodules, no bindings)
    emit byte-identically to the flat form.
    """
    mod.verify()
    texts = []

    def collect(m: HwModule, name: str):
        texts.append(_emit_one(m, name))
        for sub in m.submodules:
            collect(sub, f"{name}_{sub.name}")

    collect(mod, mod.name)
    return "\n\n".join(texts)


def _emit_one(mod: HwModule, modname: str) -> str:
    states = _flat_states(mod)
    sbits = mod.state_bits()
    lines: List[str] = []
    w = lines.append

    w(f"// stagecc HwIR — module {modname}")
    w(f"// fsm: {mod.fsm_state_count()} states, "
      f"{mod.register_bits()} register bits, "
      f"{mod.mem_bytes()} RAM bytes, "
      f"{mod.lane_count()} datapath lanes")
    w(f"module {modname} (")
    w("  input  wire clk,")
    w("  input  wire rst,")
    w("  input  wire start,")
    port_lines = ["  output reg  done"]
    for p in mod.ports:
        shape = "x".join(str(d) for d in p.shape) or "1"
        addr_bits = max(1, (max(p.elems, 1) - 1).bit_length())
        addr = f"[{addr_bits - 1}:0]"
        port_lines.append(f"  // {p.name}: {p.dtype}[{shape}] @{p.space} "
                          f"({p.direction})")
        if p.direction in ("in", "inout"):
            port_lines.append(f"  output reg  {addr} {p.name}_raddr")
            port_lines.append(f"  input  wire [{p.width_bits-1}:0] "
                              f"{p.name}_rdata")
        if p.direction in ("out", "inout"):
            port_lines.append(f"  output reg  {addr} {p.name}_waddr")
            port_lines.append(f"  output reg  [{p.width_bits-1}:0] "
                              f"{p.name}_wdata")
            port_lines.append(f"  output reg  {p.name}_wen")
    for i, pl in enumerate(port_lines):
        sep = "" if i == len(port_lines) - 1 else ","
        w(pl if pl.lstrip().startswith("//") else pl + sep)
    w(");")
    w("")
    w(f"  // ---- control FSM: {len(states)} states ----")
    for i, (name, _) in enumerate(states):
        w(f"  localparam {name} = {sbits}'d{i};")
    w(f"  reg [{sbits-1}:0] state;")
    fsm_loops = [l for l in mod.loops() if l.kind in ("fsm", "stream")]
    if fsm_loops:
        w("")
        w("  // ---- loop counters ----")
        for l in fsm_loops:
            w(f"  reg [{l.counter_bits-1}:0] {l.counter};"
              f"  // {l.kind} loop, {l.trips} trips")
    if mod.regs:
        w("")
        w("  // ---- register banks (VREG tiles) ----")
        for r in mod.regs:
            shape = "x".join(str(d) for d in r.shape) or "1"
            w(f"  reg [{r.width_bits-1}:0] {r.name} [0:{max(r.elems-1, 0)}];"
              f"  // {r.dtype}[{shape}]")
    if mod.mems:
        w("")
        w("  // ---- on-chip RAMs (VMEM) ----")
        for mm in mod.mems:
            shape = "x".join(str(d) for d in mm.shape) or "1"
            w(f"  reg [{8*dtype_bytes(mm.dtype)-1}:0] "
              f"{mm.name} [0:{max(mm.elems-1, 0)}];"
              f"  // {mm.dtype}[{shape}], {mm.bytes} bytes")
    w("")
    w("  // ---- datapath units ----")
    for u in mod.units:
        geo = "x".join(str(g) for g in u.geometry) or "1"
        bound = [b for b in mod.bindings if b.unit == u.name]
        if bound:
            shared = ", ".join(
                b.virtual + (f" (serial={b.serial})" if b.serial > 1 else "")
                for b in bound)
            w(f"  // shared across FSM states — input mux selects among: "
              f"{shared}")
        if u.copies > 1:
            w(f"  genvar {u.name}_g;")
            w(f"  generate for ({u.name}_g = 0; {u.name}_g < {u.copies}; "
              f"{u.name}_g = {u.name}_g + 1) begin : {u.name}_lanes")
            w(f"    stagecc_{u.kind} #(.GEOMETRY(\"{geo}\")) {u.name} ();")
            w("  end endgenerate")
        else:
            w(f"  stagecc_{u.kind} #(.GEOMETRY(\"{geo}\")) {u.name} ();")
    if mod.submodules:
        w("")
        w("  // ---- submodule instances (one def, N call-site states) ----")
        for sub in mod.submodules:
            calls = sum(1 for n, _, _ in mod.walk()
                        if isinstance(n, HwInstance) and n.module == sub.name)
            w(f"  {modname}_{sub.name} {sub.name}_i (.clk(clk), .rst(rst), "
              f".start({sub.name}_start), .done({sub.name}_done));"
              f"  // {calls} call site(s)")
    w("")
    w("  // ---- schedule ----")
    w("  always @(posedge clk) begin")
    w("    if (rst) begin")
    w("      state <= S_IDLE;")
    w("      done  <= 1'b0;")
    w("    end else begin")
    w("      case (state)")
    for i, (name, comment) in enumerate(states):
        nxt = states[i + 1][0] if i + 1 < len(states) else "S_IDLE"
        w(f"        {name}: begin  // {comment}")
        if i == 0:
            w(f"          if (start) state <= "
              f"{nxt if len(states) > 1 else 'S_IDLE'};")
            w("          done <= 1'b0;" if len(states) > 1
              else "          done <= 1'b1;")
        else:
            w(f"          state <= {nxt};")
            if i == len(states) - 1:
                w("          done  <= 1'b1;")
        w("        end")
    w("        default: state <= S_IDLE;")
    w("      endcase")
    w("    end")
    w("  end")
    w("")
    w("endmodule")
    return "\n".join(lines)
