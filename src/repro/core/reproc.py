"""``reproc`` — command-line pipeline driver, the stack's ``mlir-opt``.

Run a pass pipeline over textual IR (or a built-in GEMM) and inspect the
IR after every stage::

    python -m repro.core.reproc --pipeline "lower;flatten" --dump-after-each
    python -m repro.core.reproc --input kernel.ir --pipeline "grid{vars=2}"
    python -m repro.core.reproc --gemm 256x128x64 --epilogue bias_relu \
        --pipeline "lower{tile_m=32,tile_n=32,tile_k=32},fuse-epilogue" --timing
    python -m repro.core.reproc --emit=verilog        # built-in GEMM -> RTL
    python -m repro.core.reproc --gemm 4x4x4 --emit=hw
    python -m repro.core.reproc --gemm 4x4x4 --pipeline lower --simulate
    python -m repro.core.reproc --gemm 8x8x8 --pipeline lower \
        --simulate host --trace --vcd /tmp/gemm.vcd   # full transaction
    python -m repro.core.reproc --gemm 32x32x32 --epilogue none \
        --dse --pareto-csv pareto.csv   # design-space exploration
    python -m repro.core.reproc --raise qwen2_7b          # raisability report
    python -m repro.core.reproc --raise qwen2_7b:mlp      # raised TensorIR
    python -m repro.core.reproc --list-passes --markdown

Pipeline stages separate on ``;`` or ``,``; stage arguments go in braces
(``lower{tile_m=128}``).  Without ``--input``, the driver traces the
quickstart GEMM (``relu(a @ b + bias)``, 64x32x16) as its input module.
``--emit=LEVEL`` lowers the final artifact to the requested level
(``tensor`` | ``loop`` | ``hw`` | ``verilog``) with default passes
before printing, so ``--emit=verilog`` alone walks the whole stack.
``--list-passes --markdown`` regenerates ``docs/PASSES.md``.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from . import frontend as fe
from . import host_bridge, hw_ir, hw_sim, ir_text, lowering, machine_model
from .frontend import spec, trace
from .hw_ir import HwModule
from .loop_ir import Kernel
from .passes import (LEVELS, PASS_ALIASES, PASS_REGISTRY, PassError,
                     PassManager)
from .tensor_ir import Graph


def quickstart_gemm(m: int = 64, k: int = 32, n: int = 16,
                    epilogue: str = "bias_relu") -> Graph:
    """The quickstart's traced GEMM, the driver's default input module."""
    if epilogue == "bias_relu":
        def f(a, b, bias):
            return fe.relu(fe.matmul(a, b) + bias)
        specs = [spec((m, k)), spec((k, n)), spec((n,))]
    elif epilogue == "relu":
        def f(a, b):
            return fe.relu(fe.matmul(a, b))
        specs = [spec((m, k)), spec((k, n))]
    elif epilogue == "none":
        def f(a, b):
            return fe.matmul(a, b)
        specs = [spec((m, k)), spec((k, n))]
    else:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    return trace(f, specs, name=f"gemm_{m}x{n}x{k}_{epilogue}")


def passes_markdown() -> str:
    """The generated pass reference (``docs/PASSES.md``)."""
    lines = [
        "# Pass reference",
        "",
        "<!-- GENERATED FILE — do not edit by hand. -->",
        "<!-- Regenerate with:",
        "       PYTHONPATH=src python -m repro.core.reproc"
        " --list-passes --markdown > docs/PASSES.md",
        "     CI fails if this file is out of sync with the registry. -->",
        "",
        "Passes registered in `repro.core.passes.PASS_REGISTRY`, grouped by",
        "the IR level they operate on.  Invoke them through a pipeline spec",
        "(`PassManager.parse(\"lower{tile_m=128},flatten-inner\")` or",
        "`python -m repro.core.reproc --pipeline ...`) or programmatically",
        "(`PassManager().add(\"lower\", tile_m=128)`).",
        "",
    ]
    level_blurb = {
        "tensor": "Consume **TensorIR** (`Graph`); `lower` produces LoopIR.",
        "loop": "Transform **LoopIR** (`Kernel`) in place; each re-verifies. "
                "`lower-to-hw` produces HwIR.",
        "hw": "Consume **HwIR** (`HwModule`); `emit-verilog` prints RTL text.",
        "backend": "Terminal: turn a scheduled `Kernel` into a callable.",
    }

    def pattern_cell(pd, level):
        # a multi-level pass lists only the patterns of the section's level
        names = [p for p in pd.pattern_names
                 if ":" not in p or p.startswith(f"{level}:")]
        names = [p.split(":", 1)[-1] for p in names]
        return ", ".join(f"`{n}`" for n in names) if names else "—"

    for level in LEVELS:
        defs = sorted((pd for pd in PASS_REGISTRY.values()
                       if level in pd.levels), key=lambda pd: pd.name)
        if not defs:
            continue
        lines.append(f"## {level}-level passes")
        lines.append("")
        lines.append(level_blurb[level])
        lines.append("")
        lines.append("| pass | rewrite patterns | description |")
        lines.append("|------|------------------|-------------|")
        for pd in defs:
            note = (" *(runs at every IR level)*"
                    if len(pd.levels) > 1 else "")
            lines.append(f"| `{pd.name}` | {pattern_cell(pd, level)} | "
                         f"{pd.doc}{note} |")
        lines.append("")
    lines.append("Passes built on the unified rewrite core "
                 "(`repro/core/rewrite.py`) list their pattern set; see "
                 "[REWRITE.md](REWRITE.md) for the pattern reference and "
                 "per-pattern hit statistics.")
    lines.append("")
    if PASS_ALIASES:
        lines.append("## Aliases")
        lines.append("")
        lines.append("| alias | pass |")
        lines.append("|-------|------|")
        for alias in sorted(PASS_ALIASES):
            lines.append(f"| `{alias}` | `{PASS_ALIASES[alias]}` |")
        lines.append("")
    return "\n".join(lines)


def _list_passes_text() -> str:
    rows = [f"{'PASS':18s} {'LEVEL':15s} {'PATTERNS':9s} DESCRIPTION"]
    order = {lv: i for i, lv in enumerate(LEVELS)}
    for pd in sorted(PASS_REGISTRY.values(),
                     key=lambda pd: (order[pd.levels[0]], pd.name)):
        npat = str(len(pd.pattern_names)) if pd.pattern_names else "-"
        rows.append(f"{pd.name:18s} {pd.level_str:15s} {npat:9s} {pd.doc}")
    for alias in sorted(PASS_ALIASES):
        rows.append(f"{alias:18s} {'alias':15s} {'':9s} "
                    f"-> {PASS_ALIASES[alias]}")
    return "\n".join(rows)


_EMIT_LEVELS = ("tensor", "loop", "hw", "verilog")


def coerce_to_level(art, target: str):
    """Lower ``art`` with default passes until it reaches ``target``.

    ``--emit=verilog`` is ``hw`` plus the Verilog pretty-printer, so the
    bare driver (no ``--pipeline``) still walks the whole stack:
    TensorIR -> LoopIR (scalar nested) -> HwIR -> RTL text.
    """
    if target == "verilog":
        if isinstance(art, str):        # pipeline already ended in emit-verilog
            return art
        return hw_ir.emit_verilog(coerce_to_level(art, "hw"))
    rank = {"tensor": 0, "loop": 1, "hw": 2}[target]
    if isinstance(art, Graph) and rank >= 1:
        art = lowering.lower_graph(art)
    if isinstance(art, Kernel) and rank >= 2:
        art = hw_ir.lower_to_hw(art)
    have = {Graph: 0, Kernel: 1, HwModule: 2}.get(type(art), -1)
    if have != rank:
        raise ValueError(
            f"cannot emit {target!r} from a {type(art).__name__} artifact "
            f"(the pipeline already lowered past that level)")
    return art


def simulate_report(args, art) -> str:
    """The ``--simulate`` section: co-simulate the artifact's hardware.

    A Graph/Kernel artifact is lowered to hardware first (keeping the
    LoopIR stage as the numeric oracle); an HwModule artifact simulates
    directly (no oracle — the numeric check is skipped with a note).
    """
    if not isinstance(art, (Graph, Kernel, HwModule)):
        raise ValueError(
            "cannot simulate emitted text; end the pipeline at or before "
            "lower-to-hw (or drop --emit=verilog)")
    kernel = None
    if isinstance(art, Graph):
        art = lowering.lower_graph(art)
    if isinstance(art, Kernel):
        kernel = art
        hw = hw_ir.lower_to_hw(kernel)
    else:
        hw = art

    inputs = hw_sim.random_inputs(hw, seed=args.seed)
    want_trace = args.trace or bool(args.vcd)
    rep = hw_sim.cosim(hw, kernel, inputs, trace=want_trace)
    lines = [f"// {rep.summary()}"]
    lines.append(f"//   observed: {rep.sim.cycles}")
    lines.append(f"//   modeled:  {machine_model.cycles(hw)}")
    if kernel is None:
        lines.append("//   (no LoopIR stage in scope: numeric check "
                     "against the numpy oracle skipped)")
    if args.simulate == "host":
        xbar = _crossbar_from(args)
        # reuse the co-sim's device run rather than simulating twice
        tr = host_bridge.run_transaction(hw, inputs, crossbar=xbar,
                                         sim=rep.sim)
        lines.extend("// " + ln for ln in tr.summary().splitlines())
    if args.simulate == "fabric":
        lines.extend("// " + ln for ln in fabric_report(args, hw, kernel))
    if args.trace:
        lines.append(rep.sim.format_trace())
    if args.vcd:
        with open(args.vcd, "w") as f:
            f.write(rep.sim.vcd())
        lines.append(f"// vcd dump written to {args.vcd}")
    return "\n".join(lines)


def _crossbar_from(args) -> host_bridge.Crossbar:
    """The crossbar the --simulate host/fabric sections price over:
    a named preset (--crossbar) or the latency/width flag pair."""
    if args.crossbar:
        return host_bridge.crossbar_preset(args.crossbar)
    return host_bridge.Crossbar(
        "axi4", data_width_bits=args.crossbar_width,
        latency_cycles=args.crossbar_latency)


def fabric_report(args, hw, kernel) -> List[str]:
    """The ``--simulate fabric`` section: schedule a saturating request
    stream over N copies of the module behind one shared crossbar and
    print serialized-baseline vs contention-aware-overlap pricing, from
    both the fabric machine model and the fabric event simulator."""
    import dataclasses as _dc

    from . import fabric as fabric_mod

    xbar = _crossbar_from(args)
    fab = fabric_mod.make_fleet(
        {hw.name: (hw, kernel)}, copies={hw.name: args.fabric_slots},
        crossbar=xbar, policy=args.fabric_policy)
    base = fabric_mod.transaction_cost(
        hw, xbar, machine_model.cycles(hw).total).total
    mix = fabric_mod.TrafficMix(
        "cli", ((hw.name, 1.0),), num_requests=args.fabric_requests,
        rate=1.0, seed=args.seed)
    # offer ~2x the whole fleet's capacity so contention is visible
    mix = _dc.replace(mix, cycles_per_unit=fabric_mod.
                      saturating_cycles_per_unit(
                          mix, base, load_factor=2.0 * args.fabric_slots))
    stream = fabric_mod.fabric_stream(mix)
    ser = fab.model(stream, overlap=False)
    ovl = fab.model(stream, overlap=True)
    sim = fab.simulate(stream, overlap=True, seed=args.seed)
    dev = (100.0 * abs(sim.requests_per_s - ovl.requests_per_s)
           / max(ovl.requests_per_s, 1e-12))
    lines = [f"fabric: {args.fabric_slots}x {hw.name} over {xbar.name} "
             f"({xbar.data_width_bits}b), policy={args.fabric_policy}, "
             f"{len(stream)} requests"]
    lines += ser.summary().splitlines()
    lines += ovl.summary().splitlines()
    lines += sim.summary().splitlines()
    lines.append(f"overlap speedup {ovl.requests_per_s / ser.requests_per_s:.2f}x "
                 f"over serialized dispatch; "
                 f"event sim deviates {dev:.2f}% from the machine model")
    return lines


_KERNEL_GRAPHS = {
    # name -> (builder, default dims) — the serving kernels as TensorIR
    "flash": (fe.flash_attention_graph, (8, 16, 4)),
    "decode": (fe.decode_attention_graph, (4, 16, 4)),
    "ssd": (fe.ssd_scan_graph, (16, 2, 4)),
}


def kernel_graph(spec_str: str) -> Graph:
    """Build a serving-kernel input module from ``NAME`` or ``NAME:AxBxC``
    (``flash:8x16x4`` — dims as the builder's positional arguments)."""
    name, _, dims = spec_str.partition(":")
    if name not in _KERNEL_GRAPHS:
        raise ValueError(
            f"--kernel expects one of {', '.join(_KERNEL_GRAPHS)} "
            f"(optionally NAME:AxBxC), got {spec_str!r}")
    builder, default = _KERNEL_GRAPHS[name]
    if dims:
        try:
            args = tuple(int(d) for d in dims.lower().split("x"))
        except ValueError:
            raise ValueError(f"--kernel dims must be AxBxC, got {dims!r}")
        if len(args) != len(default):
            raise ValueError(
                f"--kernel {name} takes {len(default)} dims, got {dims!r}")
    else:
        args = default
    return builder(*args)


def raised_block_graph(spec_str: str) -> Graph:
    """Raise one model block named as ``CONFIG:BLOCK`` (see ``--raise``)
    into its TensorIR graph."""
    import importlib
    raising = importlib.import_module("repro.core.raise")
    config, _, block = spec_str.partition(":")
    reports = raising.raise_model_blocks(config)
    by_name = {r.block: r for r in reports}
    if block not in by_name:
        raise ValueError(
            f"--raise: config {config!r} has no block {block!r}; "
            f"available: {', '.join(sorted(by_name))}")
    rep = by_name[block]
    if rep.raised is None:
        raise ValueError(
            f"--raise: block {config}:{block} is not raisable:\n"
            f"{rep.error}")
    return rep.raised.graph


def _load_input(args) -> "ir_text.IR":
    if args.input:
        with open(args.input) as f:
            return ir_text.parse_ir(f.read())
    if args.kernel:
        return kernel_graph(args.kernel)
    if args.raise_spec:
        return raised_block_graph(args.raise_spec)
    m, n, k = 64, 16, 32
    if args.gemm:
        try:
            m, n, k = (int(d) for d in args.gemm.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--gemm expects MxNxK, got {args.gemm!r}")
    return quickstart_gemm(m=m, k=k, n=n, epilogue=args.epilogue)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.reproc",
        description="stagecc pipeline driver (mlir-opt analogue): run a "
                    "pass pipeline over textual TensorIR/LoopIR/HwIR and "
                    "dump the IR at any stage.")
    p.add_argument("--pipeline", metavar="SPEC", default="",
                   help="pipeline spec, e.g. 'lower{tile_m=32};flatten' "
                        "(stages separate on ';' or ',')")
    p.add_argument("--input", metavar="FILE",
                   help="textual IR module to start from (stagecc.func or "
                        "stagecc.kernel); default: the quickstart GEMM")
    p.add_argument("--gemm", metavar="MxNxK",
                   help="use an MxNxK GEMM as the input module (default "
                        "64x16x32, the quickstart shape)")
    p.add_argument("--epilogue", choices=("none", "relu", "bias_relu"),
                   default="bias_relu",
                   help="epilogue for the built-in GEMM input")
    p.add_argument("--kernel", metavar="NAME[:AxBxC]",
                   help="use a serving kernel as the input module: "
                        "flash (SQxSKxD), decode (REPxSMAXxHD), or "
                        "ssd (SxPxN), e.g. 'flash:8x16x4'; dims default "
                        "to a small smoke shape")
    p.add_argument("--raise", dest="raise_spec", metavar="CONFIG[:BLOCK]",
                   help="raise a (reduced) model config's forward-pass "
                        "block from traced JAX into TensorIR and use it as "
                        "the input module, e.g. 'qwen2_7b:mlp'; without "
                        ":BLOCK, print the per-block raisability report "
                        "(raised graphs + unraisable-primitive "
                        "diagnostics) and exit")
    p.add_argument("--emit", metavar="LEVEL",
                   help="lower the final artifact to LEVEL (tensor|loop|"
                        "hw|verilog) with default passes before printing")
    p.add_argument("--dse", nargs="?", const=4, type=int, metavar="N",
                   help="design-space exploration: search schedule "
                        "programs x HwIR knobs over the input module, "
                        "print the cycles x area Pareto frontier, and "
                        "co-simulate the N fastest frontier points "
                        "against the numpy oracle (default N=4; N=0 "
                        "skips validation)")
    p.add_argument("--pareto-csv", metavar="FILE",
                   help="with --dse: write every priced candidate "
                        "(plus frontier/validation flags) to FILE as CSV")
    p.add_argument("--simulate", nargs="?", const="kernel",
                   choices=("kernel", "host", "fabric"),
                   metavar="{kernel,host,fabric}",
                   help="cycle-accurately simulate the final artifact's "
                        "hardware module on seeded random inputs and print "
                        "a co-sim report (observed vs modeled cycles, "
                        "numeric check against the numpy oracle); 'host' "
                        "additionally runs the full crossbar transaction "
                        "(DMA in -> CSR start -> poll -> DMA out); "
                        "'fabric' schedules a saturating request stream "
                        "over --fabric-slots copies of the module behind "
                        "one shared crossbar (serialized baseline vs "
                        "contention-aware overlap, model vs event sim)")
    p.add_argument("--trace", action="store_true",
                   help="with --simulate: print the per-state retired-"
                        "event trace")
    p.add_argument("--vcd", metavar="FILE",
                   help="with --simulate: write a VCD-style dump of the "
                        "schedule to FILE")
    p.add_argument("--seed", type=int, default=0,
                   help="RNG seed for --simulate / --dse validation "
                        "inputs (default 0)")
    p.add_argument("--crossbar-latency", type=int, default=24,
                   help="with --simulate host/fabric: DMA handshake "
                        "latency in cycles (default 24)")
    p.add_argument("--crossbar-width", type=int, default=128,
                   help="with --simulate host/fabric: crossbar data width "
                        "in bits (default 128)")
    p.add_argument("--crossbar", metavar="PRESET",
                   help="with --simulate host/fabric: use a named crossbar "
                        "preset (axi4, axi4_lite) instead of the "
                        "--crossbar-latency/--crossbar-width pair")
    p.add_argument("--fabric-slots", type=int, default=2,
                   help="with --simulate fabric: accelerator copies "
                        "behind the shared crossbar (default 2)")
    p.add_argument("--fabric-requests", type=int, default=12,
                   help="with --simulate fabric: request-stream length "
                        "(default 12)")
    p.add_argument("--fabric-policy", default="round_robin",
                   choices=("round_robin", "priority"),
                   help="with --simulate fabric: crossbar arbitration "
                        "policy (default round_robin)")
    p.add_argument("--dump-after-each", action="store_true",
                   help="print the IR (with wall time and size delta) "
                        "after every pass")
    p.add_argument("--no-verify", action="store_true",
                   help="skip inter-pass IR verification")
    p.add_argument("--timing", action="store_true",
                   help="print the per-pass timing table")
    p.add_argument("-o", "--output", metavar="FILE",
                   help="write the final IR to FILE instead of stdout")
    p.add_argument("--list-passes", action="store_true",
                   help="list registered passes and exit")
    p.add_argument("--markdown", action="store_true",
                   help="with --list-passes: emit docs/PASSES.md markdown")
    return p


def main(argv: Optional[List[str]] = None, out=None) -> int:
    args = build_parser().parse_args(argv)
    close_out = False
    if out is None:
        if args.output:
            out = open(args.output, "w")
            close_out = True
        else:
            out = sys.stdout
    try:
        return _run(args, out)
    except BrokenPipeError:
        # routine when dump output is piped into head/less; exit quietly
        # (redirect stdout to devnull so the interpreter's final flush
        # doesn't print its own traceback)
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
        return 0
    finally:
        if close_out:
            out.close()


def _run(args, out) -> int:

    if args.markdown and not args.list_passes:
        print("error: --markdown requires --list-passes", file=sys.stderr)
        return 2
    if args.emit and args.emit not in _EMIT_LEVELS:
        import difflib
        close = difflib.get_close_matches(args.emit, _EMIT_LEVELS, n=1,
                                          cutoff=0.5)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        print(f"error: --emit: invalid choice {args.emit!r}{hint} "
              f"(choose from {', '.join(_EMIT_LEVELS)})", file=sys.stderr)
        return 2
    if args.kernel:
        kname = args.kernel.partition(":")[0]
        if kname not in _KERNEL_GRAPHS:
            import difflib
            close = difflib.get_close_matches(kname, _KERNEL_GRAPHS, n=1,
                                              cutoff=0.5)
            hint = f"; did you mean {close[0]!r}?" if close else ""
            print(f"error: --kernel: unknown kernel {kname!r}{hint} "
                  f"(choose from {', '.join(_KERNEL_GRAPHS)})",
                  file=sys.stderr)
            return 2
    if args.kernel and (args.gemm or args.input):
        other = "--gemm" if args.gemm else "--input"
        print(f"error: --kernel and {other} both name an input module; "
              f"pick one", file=sys.stderr)
        return 2
    if args.raise_spec and (args.kernel or args.gemm or args.input):
        other = ("--kernel" if args.kernel
                 else "--gemm" if args.gemm else "--input")
        print(f"error: --raise and {other} both name an input module; "
              f"pick one", file=sys.stderr)
        return 2
    if args.raise_spec and ":" not in args.raise_spec:
        if args.pipeline or args.emit or args.simulate \
                or args.dse is not None:
            print("error: '--raise CONFIG' prints the raisability report "
                  "and takes no pipeline; name a block as CONFIG:BLOCK to "
                  "get an input module", file=sys.stderr)
            return 2
        import importlib
        raising = importlib.import_module("repro.core.raise")
        try:
            print(raising.raising_report(args.raise_spec), file=out, end="")
        except (KeyError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        return 0
    if (args.trace or args.vcd) and not args.simulate:
        flag = "--trace" if args.trace else "--vcd"
        print(f"error: {flag} requires --simulate", file=sys.stderr)
        return 2
    if args.crossbar is not None:
        key = args.crossbar.strip().lower()
        if key not in host_bridge.CROSSBAR_PRESETS:
            import difflib
            close = difflib.get_close_matches(
                key, host_bridge.CROSSBAR_PRESETS, n=1, cutoff=0.5)
            hint = f"; did you mean {close[0]!r}?" if close else ""
            print(f"error: --crossbar: unknown preset "
                  f"{args.crossbar!r}{hint} (choose from "
                  f"{', '.join(host_bridge.CROSSBAR_PRESETS)})",
                  file=sys.stderr)
            return 2
        if args.simulate not in ("host", "fabric"):
            print("error: --crossbar requires --simulate host or "
                  "--simulate fabric", file=sys.stderr)
            return 2
    if args.pareto_csv and args.dse is None:
        print("error: --pareto-csv requires --dse", file=sys.stderr)
        return 2
    if args.dse is not None:
        for flag, given in (("--pipeline", args.pipeline),
                            ("--simulate", args.simulate),
                            ("--emit", args.emit)):
            if given:
                print(f"error: --dse explores/validates pipelines itself "
                      f"and cannot be combined with {flag}",
                      file=sys.stderr)
                return 2
    if args.list_passes:
        print(passes_markdown() if args.markdown else _list_passes_text(),
              file=out)
        return 0

    try:
        art = _load_input(args)
    except (OSError, TypeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1

    if args.dse is not None:
        from . import dse

        if not isinstance(art, Graph):
            print("error: --dse needs a TensorIR module as input "
                  f"(got {type(art).__name__}); start from --gemm or a "
                  "stagecc.func --input", file=sys.stderr)
            return 1
        try:
            res = dse.explore(art, validate_top=args.dse, seed=args.seed)
        except (PassError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print(res.table(), file=out)
        if args.pareto_csv:
            with open(args.pareto_csv, "w") as f:
                f.write(res.to_csv())
            print(f"// pareto csv written to {args.pareto_csv}", file=out)
        bad = [v for v in res.validations if not v.ok]
        return 1 if bad else 0

    def render(final) -> str:
        if args.emit:
            final = coerce_to_level(final, args.emit)
        if isinstance(final, str):
            return final
        if isinstance(final, (Graph, Kernel, HwModule)):
            return ir_text.print_ir(final)
        return f"// backend artifact: {final!r}"

    if not args.pipeline:
        # no pipeline: round-trip printer (mlir-opt with no passes), plus
        # any default lowering --emit asks for
        try:
            print(render(art), file=out)
            if args.simulate:
                print(simulate_report(args, art), file=out)
        except (PassError, ValueError, hw_sim.SimError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        return 0

    try:
        pm = PassManager.parse(args.pipeline, verify=not args.no_verify,
                               dump_after_each=args.dump_after_each)
        result = pm.run(art)
    except (KeyError, ValueError) as e:
        print(f"error: {e.args[0] if isinstance(e, KeyError) else e}",
              file=sys.stderr)
        return 1

    if args.dump_after_each:
        print(f"// ===== input ({type(art).__name__}, "
              f"size {ir_text.ir_size(art)}) =====", file=out)
        print(ir_text.print_ir(art), file=out)
        for r in result.records:
            delta = ("" if r.size_after is None or r.size_before is None
                     else f", size {r.size_before} -> {r.size_after}")
            pats = ("" if not r.pattern_stats else ", patterns: "
                    + ir_text.format_pattern_stats(r.pattern_stats))
            print(f"// ===== after {r.name} ({r.level}, "
                  f"{r.wall_ms:.3f} ms{delta}{pats}) =====", file=out)
            print(r.dump_after, file=out)
        if args.emit:
            try:
                print(f"// ===== emitted ({args.emit}) =====", file=out)
                print(render(result.artifact), file=out)
            except ValueError as e:
                print(f"error: {e}", file=sys.stderr)
                return 1
    else:
        try:
            print(render(result.artifact), file=out)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 1

    if args.simulate:
        try:
            print(simulate_report(args, result.artifact), file=out)
        except (ValueError, hw_sim.SimError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1

    if args.timing:
        print("// per-pass timing", file=out)
        for line in result.timing_table().splitlines():
            print(f"//   {line}", file=out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
