"""Automatic schedule selection — the "automatic" of the paper's title.

The paper's pipeline fixes its schedule by hand (nested vs
inner-flattened).  Going beyond: ``autotune_gemm`` enumerates the
schedule space (schedule family x tile sizes), prices every candidate
with the machine model (cycles + resource feasibility against VMEM), and
returns the winner — i.e. the Vivado-simulation feedback loop folded
into the compiler as a cost-model search, which is exactly how a
production TPU kernel compiler chooses BlockSpecs.

The search is pure cost-model evaluation (no execution), so it is fast
enough to run at trace time; ``compile_gemm_autotuned`` caches per
problem shape.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Tuple

from .machine_model import TPU_V5E, MachineModel
from .pipeline import CompiledKernel, compile_gemm

# candidate tile edges (MXU-aligned first, small fallbacks for odd shapes)
_TILES = (256, 128, 64, 32, 16, 8)
_SCHEDULES = ("tpu_mxu_kgrid", "tpu_mxu")


@dataclasses.dataclass
class Candidate:
    schedule: str
    tile: Dict[str, int]
    cycles: int
    vmem_bytes: int
    feasible: bool

    def key(self):
        return (not self.feasible, self.cycles)


def _fits(t: int, dim: int) -> bool:
    return t <= dim and dim % t == 0


def family_points(m: int, n: int, k: int) -> Dict[str, List[Tuple[int, int, int]]]:
    """Unique design points per schedule family (canonical signatures).

    ``tpu_mxu`` keeps the whole K reduction resident in one grid block:
    its working set — the VMEM claim below — is ``(tm*k + k*tn)``
    regardless of ``tk``, and its modeled cycles are monotone
    non-increasing in ``tk`` (larger K steps mean fewer FSM trips at
    identical port traffic).  So the per-``tk`` variants enumerated
    before PR 4 were cost-dominated spellings of the same ``(tm, tn)``
    working set, burning up to ``len(_TILES)``× budget per point; the
    canonical representative is ``(tm, tn)`` with ``tk = K``.
    ``tpu_mxu_kgrid`` time-multiplexes K over the grid, so ``tk`` is a
    real knob there and stays in the signature.
    """
    pts: Dict[str, List[Tuple[int, int, int]]] = {s: [] for s in _SCHEDULES}
    for tm, tn in itertools.product(_TILES, _TILES):
        if not (_fits(tm, m) and _fits(tn, n)):
            continue
        pts["tpu_mxu"].append((tm, tn, k))
        for tk in _TILES:
            if _fits(tk, k):
                pts["tpu_mxu_kgrid"].append((tm, tn, tk))
    return pts


def enumerate_candidates(m: int, n: int, k: int,
                         machine: MachineModel = TPU_V5E,
                         max_candidates: int = 64) -> List[Candidate]:
    pts = family_points(m, n, k)
    # interleave families round-robin under the budget so one family's
    # points can never evict another's (pre-canonicalization, tpu_mxu
    # duplicates and kgrid's cubic tile grid crowded each other out)
    picked: List[Tuple[str, Tuple[int, int, int]]] = []
    for row in itertools.zip_longest(*(pts[s] for s in _SCHEDULES)):
        for sched, tile in zip(_SCHEDULES, row):
            if tile is not None and len(picked) < max_candidates:
                picked.append((sched, tile))
    out: List[Candidate] = []
    for sched, (tm, tn, tk) in picked:
        ck = compile_gemm(m, n, k, schedule=sched,
                          tile={"m": tm, "n": tn, "k": tk},
                          machine=machine, want_jax=False,
                          want_pallas=False)
        # working set while one grid step is resident: operand tiles +
        # accumulator (the BlockSpec VMEM claim)
        if sched == "tpu_mxu":
            vmem = (tm * k + k * tn) * 4 + tm * tn * 4
        else:
            vmem = (tm * tk + tk * tn) * 4 + tm * tn * 4
        out.append(Candidate(
            schedule=sched, tile={"m": tm, "n": tn, "k": tk},
            cycles=ck.cycles.total, vmem_bytes=vmem,
            feasible=vmem <= machine.vmem_capacity_bytes))
    return sorted(out, key=Candidate.key)


@functools.lru_cache(maxsize=128)
def best_schedule(m: int, n: int, k: int,
                  machine: MachineModel = TPU_V5E
                  ) -> Tuple[str, Tuple[int, int, int]]:
    """Winner of the cost-model search for one problem shape *on one
    machine* — ``machine`` (a frozen, hashable dataclass) is part of the
    memoization key, so machines with different VMEM capacities or unit
    costs tune independently instead of silently reusing each other's
    schedules."""
    cands = enumerate_candidates(m, n, k, machine=machine)
    if not cands:
        return ("tpu_mxu_kgrid", (1, 1, 1))
    b = cands[0]
    return (b.schedule, (b.tile["m"], b.tile["n"], b.tile["k"]))


def compile_gemm_autotuned(m: int, n: int, k: int, *, dtype: str = "float32",
                           interpret: bool = True,
                           machine: MachineModel = TPU_V5E) -> CompiledKernel:
    sched, (tm, tn, tk) = best_schedule(m, n, k, machine=machine)
    return compile_gemm(m, n, k, schedule=sched,
                        tile={"m": tm, "n": tn, "k": tk}, dtype=dtype,
                        machine=machine, interpret=interpret)
