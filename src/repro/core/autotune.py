"""Automatic schedule selection — the "automatic" of the paper's title.

The paper's pipeline fixes its schedule by hand (nested vs
inner-flattened).  Going beyond: ``autotune_gemm`` enumerates the
schedule space (schedule family x tile sizes), prices every candidate
with the machine model (cycles + resource feasibility against VMEM), and
returns the winner — i.e. the Vivado-simulation feedback loop folded
into the compiler as a cost-model search, which is exactly how a
production TPU kernel compiler chooses BlockSpecs.

The search is pure cost-model evaluation (no execution), so it is fast
enough to run at trace time; ``compile_gemm_autotuned`` caches per
problem shape.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Tuple

from .machine_model import TPU_V5E, MachineModel
from .pipeline import CompiledKernel, compile_gemm

# candidate tile edges (MXU-aligned first, small fallbacks for odd shapes)
_TILES = (256, 128, 64, 32, 16, 8)
_SCHEDULES = ("tpu_mxu_kgrid", "tpu_mxu")


@dataclasses.dataclass
class Candidate:
    schedule: str
    tile: Dict[str, int]
    cycles: int
    vmem_bytes: int
    feasible: bool

    def key(self):
        return (not self.feasible, self.cycles)


def _fits(t: int, dim: int) -> bool:
    return t <= dim and dim % t == 0


def enumerate_candidates(m: int, n: int, k: int,
                         machine: MachineModel = TPU_V5E,
                         max_candidates: int = 64) -> List[Candidate]:
    out: List[Candidate] = []
    seen = set()
    for sched, tm, tn, tk in itertools.product(
            _SCHEDULES, _TILES, _TILES, _TILES):
        if not (_fits(tm, m) and _fits(tn, n) and _fits(tk, k)):
            continue
        sig = (sched, tm, tn, tk)
        if sig in seen or len(out) >= max_candidates:
            continue
        seen.add(sig)
        ck = compile_gemm(m, n, k, schedule=sched,
                          tile={"m": tm, "n": tn, "k": tk},
                          machine=machine, want_jax=False,
                          want_pallas=False)
        # working set while one grid step is resident: operand tiles +
        # accumulator (the BlockSpec VMEM claim)
        if sched == "tpu_mxu":
            vmem = (tm * k + k * tn) * 4 + tm * tn * 4
        else:
            vmem = (tm * tk + tk * tn) * 4 + tm * tn * 4
        out.append(Candidate(
            schedule=sched, tile={"m": tm, "n": tn, "k": tk},
            cycles=ck.cycles.total, vmem_bytes=vmem,
            feasible=vmem <= machine.vmem_capacity_bytes))
    return sorted(out, key=Candidate.key)


@functools.lru_cache(maxsize=128)
def best_schedule(m: int, n: int, k: int) -> Tuple[str, Tuple[int, int, int]]:
    cands = enumerate_candidates(m, n, k)
    if not cands:
        return ("tpu_mxu_kgrid", (1, 1, 1))
    b = cands[0]
    return (b.schedule, (b.tile["m"], b.tile["n"], b.tile["k"]))


def compile_gemm_autotuned(m: int, n: int, k: int, *, dtype: str = "float32",
                           interpret: bool = True,
                           machine: MachineModel = TPU_V5E) -> CompiledKernel:
    sched, (tm, tn, tk) = best_schedule(m, n, k)
    return compile_gemm(m, n, k, schedule=sched,
                        tile={"m": tm, "n": tn, "k": tk}, dtype=dtype,
                        machine=machine, interpret=interpret)
