"""Textual IR round-trip for the stagecc stack (the `mlir-opt` property).

MLIR's reusability story rests on every level of IR having a canonical
textual form that parses back to an identical module — pipelines can then
be debugged, diffed, golden-tested, and driven from the command line at
any stage.  This module gives TensorIR (``Graph``), LoopIR (``Kernel``)
and HwIR (``HwModule``) that property:

    print_ir(parse_ir(print_ir(x))) == print_ir(x)

``print_graph``/``print_kernel``/``print_hw_module`` are the single
source of truth for the textual form; the ``__str__`` of each IR class
delegates here.

Grammar (by example)::

    stagecc.func @gemm(%arg0: tensor<64x32xfloat32>, %arg1: tensor<32x16xfloat32>) {
      %matmul1 = stagecc.matmul(%arg0, %arg1) : tensor<64x16xfloat32>
      %cast2 = stagecc.cast(%matmul1) {dtype='bfloat16'} : tensor<64x16xbfloat16>
      return %cast2
    }

    stagecc.kernel @gemm(arg0: tensor<64x32xfloat32> @hbm, ...) -> (matmul1) {
      alloc acc1: tensor<16x16xfloat32> @vreg
      for %i1 in [0,4) @grid {
        zero acc1[0, 0 : 16x16]
        for %k3 in [0,2) @seq {
          acc1[0, 0 : 16x16] += mxu.matmul(arg0[i1, k3 : 16x16], arg1[k3, j2 : 16x16])
        }
        matmul1[i1, j2 : 16x16] = vpu.copy(acc1[0, 0 : 16x16])
      }
    }

    stagecc.hw @gemm {
      port in arg0: float32[64x32] @hbm
      reg acc1: float32[16x16]
      unit mxu1: mxu<16x16> x1
      ctrl {
        loop %i1 [4] @fsm {
          step matmul mxu1(acc acc1[0, 0 : 16x16], read arg0[i1, k3 : 16x16], read arg1[k3, j2 : 16x16])
        }
      }
    }

The parser re-runs type inference on every TensorIR op and ``verify()``
on every parsed artifact, so a hand-edited IR file gets the same
diagnostics a pass-produced one would.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Tuple, Union

from .hw_ir import (HwBinding, HwCtrl, HwInstance, HwLoop, HwMem, HwModule,
                    HwOperand, HwPort, HwReg, HwStep, HwUnit, LOOP_CTRL_KINDS)
from .loop_ir import (AffineExpr, Buffer, EwiseTile, FillTile, Kernel, Loop,
                      LoopKind, LoopVar, MatmulTile, MemSpace, ReduceTile,
                      ScanTile, Stmt, TileRef, ZeroTile)
from .tensor_ir import Graph, TensorType

IR = Union[Graph, Kernel, HwModule]


class IRParseError(ValueError):
    """Raised with a line number + message when textual IR is malformed."""

    def __init__(self, lineno: int, line: str, msg: str):
        super().__init__(f"line {lineno}: {msg}\n    {line.strip()}")
        self.lineno = lineno


# --------------------------------------------------------------------------
# printing
# --------------------------------------------------------------------------


def print_type(t: TensorType) -> str:
    # single impl lives on the dataclass; this alias keeps the printer
    # namespace complete
    return str(t)


def print_op(op) -> str:
    ins = ", ".join(f"%{v.name}" for v in op.inputs)
    attrs = ""
    if op.attrs:
        kv = ", ".join(f"{k}={v!r}" for k, v in sorted(op.attrs.items()))
        attrs = " {" + kv + "}"
    return (f"%{op.result.name} = stagecc.{op.opname}({ins}){attrs}"
            f" : {print_type(op.result.type)}")


def print_graph(g: Graph) -> str:
    args = ", ".join(f"%{v.name}: {print_type(v.type)}" for v in g.inputs)
    lines = [f"stagecc.func @{g.name}({args}) {{"]
    for op in g.ops:
        lines.append(f"  {print_op(op)}")
    rets = ", ".join(f"%{v.name}" for v in g.outputs)
    lines.append(f"  return {rets}")
    lines.append("}")
    return "\n".join(lines)


def print_kernel(k: Kernel) -> str:
    # Buffer.__str__ is "name: type @space" — the parseable form
    ps = ", ".join(str(b) for b in k.params)
    outs = ", ".join(b.name for b in k.outputs)
    lines = [f"stagecc.kernel @{k.name}({ps}) -> ({outs}) {{"]
    for b in k.scratch:
        lines.append(f"  alloc {b}")
    for s in k.body:
        lines.extend("  " + line for line in print_stmt(s))
    lines.append("}")
    return "\n".join(lines)


def print_affine(e: AffineExpr) -> str:
    parts = [f"{s}*{v}" if s != 1 else v for v, s in e.coeffs]
    if e.const or not parts:
        parts.append(str(e.const))
    return "+".join(parts)


def print_tileref(r: TileRef) -> str:
    idx = ", ".join(print_affine(e) for e in r.index)
    t = "x".join(str(t) for t in r.tile)
    return f"{r.buffer.name}[{idx} : {t}]"


def print_stmt(s: Stmt) -> List[str]:
    if isinstance(s, ZeroTile):
        return [f"zero {print_tileref(s.dst)}"]
    if isinstance(s, FillTile):
        return [f"fill {print_tileref(s.dst)}, {s.value!r}"]
    if isinstance(s, ReduceTile):
        kind = f"{s.kind},acc" if s.accumulate else s.kind
        return [f"reduce<{kind}> {print_tileref(s.dst)}, "
                f"{print_tileref(s.src)}"]
    if isinstance(s, ScanTile):
        refs = ", ".join(print_tileref(r) for r in [s.carry, *s.srcs])
        return [f"scan<{s.kind}> {print_tileref(s.dst)}, {refs}"]
    if isinstance(s, MatmulTile):
        op = "+=" if s.accumulate else "="
        return [f"{print_tileref(s.dst)} {op} mxu.matmul("
                f"{print_tileref(s.lhs)}, {print_tileref(s.rhs)})"]
    if isinstance(s, EwiseTile):
        srcs = ", ".join(print_tileref(r) for r in s.srcs)
        return [f"{print_tileref(s.dst)} = vpu.{s.op}({srcs})"]
    if isinstance(s, Loop):
        lines = [f"for %{s.var.name} in [0,{s.var.extent}) @{s.kind.value} {{"]
        for inner in s.body:
            lines.extend("  " + line for line in print_stmt(inner))
        lines.append("}")
        return lines
    raise TypeError(f"unknown stmt {type(s).__name__}")


# ---- HwIR printing ---------------------------------------------------------


def _print_shape(shape) -> str:
    return "x".join(str(d) for d in shape)


def print_hw_operand(o: HwOperand) -> str:
    # tileref-shaped: "role target[affine-index : tile]" — the index is
    # the operand's address generator over the enclosing loop counters
    idx = ", ".join(print_affine(e) for e in o.index)
    return f"{o.role} {o.target}[{idx} : {_print_shape(o.tile)}]"


def print_hw_ctrl(node: HwCtrl) -> List[str]:
    if isinstance(node, HwStep):
        opnds = ", ".join(print_hw_operand(o) for o in node.operands)
        return [f"step {node.op} {node.unit}({opnds})"]
    if isinstance(node, HwInstance):
        opnds = ", ".join(print_hw_operand(o) for o in node.portmap)
        return [f"inst @{node.module}({opnds})"]
    if isinstance(node, HwLoop):
        lines = [f"loop %{node.counter} [{node.trips}] @{node.kind} {{"]
        for inner in node.body:
            lines.extend("  " + line for line in print_hw_ctrl(inner))
        lines.append("}")
        return lines
    raise TypeError(f"unknown control node {type(node).__name__}")


def _hw_body_lines(m: HwModule) -> List[str]:
    """Declaration + ctrl lines of a module body, unindented — canonical
    order: submodules, ports, regs, mems, units, binds, ctrl."""
    lines: List[str] = []
    for sub in m.submodules:
        lines.append(f"module @{sub.name} {{")
        lines.extend("  " + line for line in _hw_body_lines(sub))
        lines.append("}")
    for p in m.ports:
        lines.append(f"port {p.direction} {p.name}: "
                     f"{p.dtype}[{_print_shape(p.shape)}] @{p.space}")
    for r in m.regs:
        lines.append(f"reg {r.name}: {r.dtype}[{_print_shape(r.shape)}]")
    for mm in m.mems:
        lines.append(f"mem {mm.name}: "
                     f"{mm.dtype}[{_print_shape(mm.shape)}] @vmem")
    for u in m.units:
        lines.append(f"unit {u.name}: {u.kind}<{_print_shape(u.geometry)}>"
                     f" x{u.copies}")
    for b in m.bindings:
        lines.append(f"bind {b.virtual} -> {b.unit} "
                     f"serial={b.serial} copies={b.copies}")
    lines.append("ctrl {")
    for node in m.ctrl:
        lines.extend("  " + line for line in print_hw_ctrl(node))
    lines.append("}")
    return lines


def print_hw_module(m: HwModule) -> str:
    lines = [f"stagecc.hw @{m.name} {{"]
    lines.extend("  " + line for line in _hw_body_lines(m))
    lines.append("}")
    return "\n".join(lines)


def print_ir(x: IR) -> str:
    if isinstance(x, Graph):
        return print_graph(x)
    if isinstance(x, HwModule):
        return print_hw_module(x)
    return print_kernel(x)


def ir_size(x) -> Optional[int]:
    """IR size metric for instrumentation: ops (Graph) / stmts (Kernel) /
    control nodes (HwModule)."""
    if isinstance(x, Graph):
        return len(x.ops)
    if isinstance(x, (Kernel, HwModule)):
        return sum(1 for _ in x.walk())
    return None


def format_pattern_stats(hits: Dict[str, int]) -> str:
    """Canonical rendering of rewrite-pattern hit counts for IR dumps and
    timing tables: ``"drop-unit-loop x3, dedupe-units x1"`` (most-hit
    first, name-sorted on ties; empty string when nothing fired)."""
    return ", ".join(f"{name} x{n}" for name, n in
                     sorted(hits.items(), key=lambda kv: (-kv[1], kv[0])))


# --------------------------------------------------------------------------
# parsing helpers
# --------------------------------------------------------------------------


def _split_top(s: str, sep: str = ",") -> List[str]:
    """Split on ``sep`` at bracket/paren/quote depth 0."""
    parts, depth, token, quote = [], 0, "", None
    for ch in s:
        if quote:
            token += ch
            if ch == quote:
                quote = None
            continue
        if ch in "'\"":
            quote = ch
        elif ch in "([{<":
            depth += 1
        elif ch in ")]}>":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append(token)
            token = ""
        else:
            token += ch
    if token.strip():
        parts.append(token)
    return [p.strip() for p in parts]


def parse_type(s: str) -> TensorType:
    m = re.fullmatch(r"tensor<(.+)>", s.strip())
    if not m:
        raise ValueError(f"bad tensor type {s!r}")
    parts = m.group(1).split("x")
    dims, dtype = parts[:-1], parts[-1]
    if any(not re.fullmatch(r"\d+", d) for d in dims):
        raise ValueError(f"bad dims in tensor type {s!r}")
    return TensorType(tuple(int(d) for d in dims), dtype)


def _parse_affine(s: str) -> AffineExpr:
    s = s.strip()
    coeffs: List[Tuple[str, int]] = []
    const = 0
    for term in s.split("+"):
        term = term.strip()
        if not term:
            raise ValueError(f"empty term in affine expr {s!r}")
        if "*" in term:
            c, _, v = term.partition("*")
            coeffs.append((v.strip(), int(c)))
        elif re.fullmatch(r"-?\d+", term):
            const += int(term)
        else:
            coeffs.append((term, 1))
    return AffineExpr(tuple(coeffs), const)


# tile group may be empty: rank-0 buffers print as "buf[ : ]"
_TILEREF_RE = re.compile(r"^(\w+)\[(.*) : ([\dx]*)\]$")


def _parse_tileref(s: str, buffers: Dict[str, Buffer]) -> TileRef:
    m = _TILEREF_RE.match(s.strip())
    if not m:
        raise ValueError(f"bad tile ref {s!r}")
    name, idx, tile = m.groups()
    if name not in buffers:
        raise ValueError(f"tile ref names unknown buffer {name!r}")
    index = tuple(_parse_affine(e) for e in _split_top(idx))
    return TileRef(buffers[name], index,
                   tuple(int(t) for t in tile.split("x") if t))


# --------------------------------------------------------------------------
# TensorIR parser
# --------------------------------------------------------------------------

_FUNC_RE = re.compile(r"^stagecc\.func @([\w.\-]+)\((.*)\) \{$")
_OP_RE = re.compile(r"^%([\w.]+) = stagecc\.([\w.\-]+)\((.*?)\)"
                    r"(?: \{(.*)\})? : (.+)$")
_RET_RE = re.compile(r"^return\s*(.*)$")


def parse_graph(text: str) -> Graph:
    lines = [(i + 1, ln.strip()) for i, ln in enumerate(text.splitlines())
             if ln.strip()]
    if not lines:
        raise ValueError("empty TensorIR module")
    lineno, head = lines[0]
    m = _FUNC_RE.match(head)
    if not m:
        raise IRParseError(lineno, head, "expected 'stagecc.func @name(...) {'")
    g = Graph(m.group(1))
    env: Dict[str, "Value"] = {}  # type: ignore[name-defined]
    for arg in _split_top(m.group(2)):
        if not arg:
            continue
        name, _, ty = arg.partition(":")
        name = name.strip().lstrip("%")
        env[name] = g.add_input(name, parse_type(ty))
    saw_return = False
    for lineno, ln in lines[1:]:
        if ln == "}":
            break
        r = _RET_RE.match(ln)
        if r:
            saw_return = True
            for nm in _split_top(r.group(1)):
                nm = nm.lstrip("%")
                if nm not in env:
                    raise IRParseError(lineno, ln, f"return of undefined %{nm}")
            g.set_outputs(*[env[nm.lstrip("%")]
                            for nm in _split_top(r.group(1))])
            continue
        o = _OP_RE.match(ln)
        if not o:
            raise IRParseError(lineno, ln, "expected op, return, or '}'")
        res_name, opname, ins, attrstr, ty = o.groups()
        if res_name in env:
            raise IRParseError(lineno, ln,
                               f"redefinition of %{res_name} (SSA values "
                               f"must be defined once)")
        try:
            inputs = [env[nm.lstrip("%")] for nm in _split_top(ins)]
        except KeyError as e:
            raise IRParseError(lineno, ln, f"use of undefined %{e.args[0]}")
        attrs = {}
        for kv in _split_top(attrstr or ""):
            key, _, val = kv.partition("=")
            try:
                attrs[key.strip()] = ast.literal_eval(val.strip())
            except (ValueError, SyntaxError):
                raise IRParseError(lineno, ln, f"bad attribute {kv!r}")
        try:
            res = g.emit(opname, inputs, **attrs)
        except (KeyError, TypeError) as e:
            raise IRParseError(lineno, ln, str(e))
        declared = parse_type(ty)
        if res.type != declared:
            raise IRParseError(lineno, ln,
                               f"declared type {declared} but op infers {res.type}")
        res.name = res_name
        env[res_name] = res
    if not saw_return:
        raise ValueError(f"func @{g.name} has no return")
    g.verify()
    return g


# --------------------------------------------------------------------------
# LoopIR parser
# --------------------------------------------------------------------------

_KERNEL_RE = re.compile(r"^stagecc\.kernel @([\w.\-]+)\((.*)\)"
                        r" -> \(([^)]*)\) \{$")
_ALLOC_RE = re.compile(r"^alloc (\w+): (tensor<[^>]+>) @(\w+)$")
_FOR_RE = re.compile(r"^for %(\w+) in \[0,(\d+)\) @([\w\-]+) \{$")
_MATMUL_RE = re.compile(r"^(.*?) (\+?=) mxu\.matmul\((.*)\)$")
_EWISE_RE = re.compile(r"^(.*?) = vpu\.(\w+)\((.*)\)$")
_FILL_RE = re.compile(r"^fill (.+)$")
_REDUCE_RE = re.compile(r"^reduce<(\w+)(,acc)?> (.+)$")
_SCAN_RE = re.compile(r"^scan<(\w+)> (.+)$")


def _parse_buffer(decl: str) -> Buffer:
    m = re.fullmatch(r"(\w+): (tensor<[^>]+>) @(\w+)", decl.strip())
    if not m:
        raise ValueError(f"bad buffer declaration {decl!r}")
    name, ty, space = m.groups()
    return Buffer(name, parse_type(ty), MemSpace(space))


def parse_kernel(text: str) -> Kernel:
    lines = [(i + 1, ln.strip()) for i, ln in enumerate(text.splitlines())
             if ln.strip()]
    if not lines:
        raise ValueError("empty LoopIR module")
    lineno, head = lines[0]
    m = _KERNEL_RE.match(head)
    if not m:
        raise IRParseError(lineno, head,
                           "expected 'stagecc.kernel @name(...) -> (...) {'")
    name, params_str, outs_str = m.groups()
    params = [_parse_buffer(p) for p in _split_top(params_str)]
    by_name = {b.name: b for b in params}
    out_names = [o for o in _split_top(outs_str) if o]
    missing = [o for o in out_names if o not in by_name]
    if missing:
        raise IRParseError(lineno, head, f"outputs {missing} are not params")
    outputs = [by_name[o] for o in out_names]
    scratch: List[Buffer] = []

    pos = 1

    def parse_stmt_line(lineno: int, ln: str) -> Stmt:
        mm = _MATMUL_RE.match(ln)
        if mm and " mxu.matmul(" in ln:
            dst, eq, args = mm.groups()
            refs = _split_top(args)
            if len(refs) != 2:
                raise IRParseError(lineno, ln, "mxu.matmul takes 2 operands")
            try:
                return MatmulTile(_parse_tileref(dst, by_name),
                                  _parse_tileref(refs[0], by_name),
                                  _parse_tileref(refs[1], by_name),
                                  accumulate=(eq == "+="))
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
        me = _EWISE_RE.match(ln)
        if me:
            dst, op, args = me.groups()
            try:
                return EwiseTile(op, _parse_tileref(dst, by_name),
                                 [_parse_tileref(r, by_name)
                                  for r in _split_top(args)])
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
        if ln.startswith("zero "):
            try:
                return ZeroTile(_parse_tileref(ln[len("zero "):], by_name))
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
        if (mf := _FILL_RE.match(ln)):
            parts = _split_top(mf.group(1))
            if len(parts) != 2:
                raise IRParseError(lineno, ln, "fill takes 'dst, value'")
            try:
                return FillTile(_parse_tileref(parts[0], by_name),
                                float(parts[1]))
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
        if (mr := _REDUCE_RE.match(ln)):
            kind, acc, rest = mr.groups()
            parts = _split_top(rest)
            if len(parts) != 2:
                raise IRParseError(lineno, ln, "reduce takes 'dst, src'")
            try:
                return ReduceTile(kind, _parse_tileref(parts[0], by_name),
                                  _parse_tileref(parts[1], by_name),
                                  accumulate=bool(acc))
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
        if (ms := _SCAN_RE.match(ln)):
            kind, rest = ms.groups()
            parts = _split_top(rest)
            if len(parts) < 3:
                raise IRParseError(lineno, ln,
                                   "scan takes 'dst, carry, srcs...'")
            try:
                refs = [_parse_tileref(p, by_name) for p in parts]
                return ScanTile(kind, refs[0], refs[2:], refs[1])
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
        raise IRParseError(lineno, ln, "expected statement")

    def parse_block() -> List[Stmt]:
        nonlocal pos
        stmts: List[Stmt] = []
        while pos < len(lines):
            lineno, ln = lines[pos]
            if ln == "}":
                pos += 1
                return stmts
            a = _ALLOC_RE.match(ln)
            if a:
                bname, ty, space = a.groups()
                buf = Buffer(bname, parse_type(ty), MemSpace(space))
                scratch.append(buf)
                by_name[bname] = buf
                pos += 1
                continue
            f = _FOR_RE.match(ln)
            if f:
                var, extent, kind = f.groups()
                try:
                    lk = LoopKind(kind)
                except ValueError:
                    raise IRParseError(lineno, ln, f"unknown loop kind @{kind}")
                pos += 1
                body = parse_block()
                stmts.append(Loop(LoopVar(var, int(extent)), lk, body))
                continue
            stmts.append(parse_stmt_line(lineno, ln))
            pos += 1
        raise IRParseError(lines[-1][0], lines[-1][1], "unclosed block")

    body = parse_block()
    if pos < len(lines):
        lineno, ln = lines[pos]
        raise IRParseError(lineno, ln, "trailing input after kernel body")
    k = Kernel(name=name, params=params, outputs=outputs, scratch=scratch,
               body=body)
    k.verify()
    return k


# --------------------------------------------------------------------------
# HwIR parser
# --------------------------------------------------------------------------

_HW_RE = re.compile(r"^stagecc\.hw @([\w.\-]+) \{$")
_HW_SUBMODULE_RE = re.compile(r"^module @([\w.\-]+) \{$")
_HW_PORT_RE = re.compile(r"^port (inout|in|out) (\w+): (\w+)\[([\dx]*)\]"
                         r" @(hbm|vmem|vreg)$")
_HW_REG_RE = re.compile(r"^reg (\w+): (\w+)\[([\dx]*)\]$")
_HW_MEM_RE = re.compile(r"^mem (\w+): (\w+)\[([\dx]*)\] @vmem$")
_HW_UNIT_RE = re.compile(r"^unit (\w+): (\w+)<([\dx]*)> x(\d+)$")
_HW_BIND_RE = re.compile(r"^bind (\w+) -> (\w+) serial=(\d+) copies=(\d+)$")
_HW_LOOP_RE = re.compile(r"^loop %(\w+) \[(\d+)\] @(\w+) \{$")
_HW_STEP_RE = re.compile(r"^step ([\w.]+) (\w+)\((.*)\)$")
_HW_INST_RE = re.compile(r"^inst @([\w.\-]+)\((.*)\)$")
_HW_OPERAND_RE = re.compile(r"^(read|write|acc) (\w+)\[(.*) : ([\dx]*)\]$")


def _parse_shape(s: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in s.split("x") if d)


def parse_hw_module(text: str) -> HwModule:
    lines = [(i + 1, ln.strip()) for i, ln in enumerate(text.splitlines())
             if ln.strip()]
    if not lines:
        raise ValueError("empty HwIR module")
    lineno, head = lines[0]
    m = _HW_RE.match(head)
    if not m:
        raise IRParseError(lineno, head, "expected 'stagecc.hw @name {'")
    pos = 1

    def parse_operands(lineno: int, ln: str, args: str) -> List[HwOperand]:
        operands = []
        for part in _split_top(args):
            o = _HW_OPERAND_RE.match(part)
            if not o:
                raise IRParseError(lineno, ln, f"bad operand {part!r}")
            role, target, idx, tile = o.groups()
            try:
                index = tuple(_parse_affine(e) for e in _split_top(idx))
            except ValueError as e:
                raise IRParseError(lineno, ln, str(e))
            operands.append(HwOperand(role, target, _parse_shape(tile),
                                      index))
        return operands

    def parse_step(lineno: int, ln: str) -> HwStep:
        s = _HW_STEP_RE.match(ln)
        if not s:
            raise IRParseError(lineno, ln,
                               "expected 'step', 'inst', 'loop', or '}'")
        op, unit, args = s.groups()
        return HwStep(op, unit, parse_operands(lineno, ln, args))

    def parse_block(mod: HwModule) -> List[HwCtrl]:
        nonlocal pos
        nodes: List[HwCtrl] = []
        while pos < len(lines):
            lineno, ln = lines[pos]
            if ln == "}":
                pos += 1
                return nodes
            f = _HW_LOOP_RE.match(ln)
            if f:
                counter, trips, kind = f.groups()
                if kind not in LOOP_CTRL_KINDS:
                    raise IRParseError(lineno, ln,
                                       f"unknown loop kind @{kind}")
                pos += 1
                nodes.append(HwLoop(counter, int(trips), kind,
                                    parse_block(mod)))
                continue
            inst = _HW_INST_RE.match(ln)
            if inst:
                sub_name, args = inst.groups()
                subs = {s.name: s for s in mod.submodules}
                if sub_name not in subs:
                    declared = ", ".join(sorted(subs)) or "none"
                    raise IRParseError(
                        lineno, ln,
                        f"inst references unknown submodule @{sub_name} "
                        f"(declared submodules: {declared})")
                operands = parse_operands(lineno, ln, args)
                want = len(subs[sub_name].ports)
                if len(operands) != want:
                    raise IRParseError(
                        lineno, ln,
                        f"inst @{sub_name}: port map has {len(operands)} "
                        f"operands but module @{sub_name} declares "
                        f"{want} ports")
                nodes.append(HwInstance(sub_name, operands))
                pos += 1
                continue
            nodes.append(parse_step(lineno, ln))
            pos += 1
        raise IRParseError(lines[-1][0], lines[-1][1], "unclosed block")

    def parse_module_body(name: str) -> HwModule:
        """Parse declarations (submodules, ports, regs, mems, units,
        binds), then ``ctrl { ... }``, then the module's closing brace."""
        nonlocal pos
        mod = HwModule(name=name, ports=[], regs=[], mems=[], units=[],
                       ctrl=[])
        while pos < len(lines):
            lineno, ln = lines[pos]
            if (sm := _HW_SUBMODULE_RE.match(ln)):
                pos += 1
                mod.submodules.append(parse_module_body(sm.group(1)))
                continue
            if (p := _HW_PORT_RE.match(ln)):
                direction, pname, dtype, shape, space = p.groups()
                mod.ports.append(HwPort(pname, direction, dtype,
                                        _parse_shape(shape), space))
            elif (r := _HW_REG_RE.match(ln)):
                rname, dtype, shape = r.groups()
                mod.regs.append(HwReg(rname, dtype, _parse_shape(shape)))
            elif (mm := _HW_MEM_RE.match(ln)):
                mname, dtype, shape = mm.groups()
                mod.mems.append(HwMem(mname, dtype, _parse_shape(shape)))
            elif (u := _HW_UNIT_RE.match(ln)):
                uname, kind, geo, copies = u.groups()
                try:
                    mod.units.append(HwUnit(uname, kind, _parse_shape(geo),
                                            int(copies)))
                except ValueError as e:
                    raise IRParseError(lineno, ln, str(e))
            elif (b := _HW_BIND_RE.match(ln)):
                virt, phys, serial, copies = b.groups()
                if not any(un.name == phys for un in mod.units):
                    declared = ", ".join(un.name for un in mod.units) or "none"
                    raise IRParseError(
                        lineno, ln,
                        f"bind {virt} -> {phys}: no unit named {phys!r} "
                        f"declared (units: {declared})")
                try:
                    mod.bindings.append(HwBinding(virt, phys, int(serial),
                                                  int(copies)))
                except ValueError as e:
                    raise IRParseError(lineno, ln, str(e))
            else:
                break
            pos += 1
        if pos >= len(lines) or lines[pos][1] != "ctrl {":
            lineno, ln = lines[min(pos, len(lines) - 1)]
            raise IRParseError(lineno, ln, "expected declaration or 'ctrl {'")
        pos += 1
        mod.ctrl = parse_block(mod)
        if pos >= len(lines) or lines[pos][1] != "}":
            lineno, ln = lines[min(pos, len(lines) - 1)]
            raise IRParseError(lineno, ln, "expected closing '}' of module")
        pos += 1
        return mod

    mod = parse_module_body(m.group(1))
    if pos < len(lines):
        lineno, ln = lines[pos]
        raise IRParseError(lineno, ln, "trailing input after module")
    try:
        mod.verify()
    except KeyError as e:
        raise ValueError(f"module @{mod.name} does not verify: {e.args[0]}")
    return mod


def parse_ir(text: str) -> IR:
    """Parse a textual module, dispatching on ``stagecc.func`` vs
    ``stagecc.kernel`` vs ``stagecc.hw``."""
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln:
            continue
        if ln.startswith("stagecc.func"):
            return parse_graph(text)
        if ln.startswith("stagecc.kernel"):
            return parse_kernel(text)
        if ln.startswith("stagecc.hw"):
            return parse_hw_module(text)
        raise ValueError(f"unrecognised module header: {ln!r}")
    raise ValueError("empty IR module")
