"""Frontend tracer: restricted Python -> TensorIR.

Plays the SYCL/DPC++ role in the paper's Fig. 1: the user writes a kernel
in the host language (here: Python over ``stagecc`` proxy arrays) and the
frontend produces the level-1 IR automatically — no hand-written IR.

Example::

    import repro.core.frontend as fe

    def f(a, b, bias):
        return fe.relu(fe.matmul(a, b) + bias)

    graph = fe.trace(f, [fe.spec((64, 32)), fe.spec((32, 16)),
                         fe.spec((16,))])
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, List, Sequence

from .tensor_ir import Graph, TensorType, Value


@dataclasses.dataclass(frozen=True)
class spec:
    shape: tuple
    dtype: str = "float32"


class Tracer:
    """Proxy value recording ops into the active graph."""

    __slots__ = ("value", "graph")

    def __init__(self, value: Value, graph: Graph):
        self.value = value
        self.graph = graph

    def _emit(self, opname, others=(), **attrs):
        ins = [self.value] + [o.value for o in others]
        res = self.graph.emit(opname, ins, **attrs)
        return Tracer(res, self.graph)

    def __matmul__(self, other):
        return self._emit("matmul", [other])

    def __add__(self, other):
        if other.value.type.rank == 1 and self.value.type.rank > 1:
            return self._emit("bias_add", [other])
        return self._emit("add", [other])

    def __sub__(self, other):
        return self._emit("sub", [other])

    def __mul__(self, other):
        return self._emit("mul", [other])

    def __neg__(self):
        return self._emit("neg")

    @property
    def shape(self):
        return self.value.type.shape

    @property
    def dtype(self):
        return self.value.type.dtype


# free-function forms mirroring the op set
def matmul(a: Tracer, b: Tracer) -> Tracer:
    return a._emit("matmul", [b])


def relu(a: Tracer) -> Tracer:
    return a._emit("relu")


def gelu(a: Tracer) -> Tracer:
    return a._emit("gelu")


def exp(a: Tracer) -> Tracer:
    return a._emit("exp")


def maximum(a: Tracer, b: Tracer) -> Tracer:
    return a._emit("maximum", [b])


def div(a: Tracer, b: Tracer) -> Tracer:
    return a._emit("div", [b])


def reduce(a: Tracer, kind: str, axis: int, keepdims: bool = True) -> Tracer:
    """Carried reduction (``max`` or ``sum``) along ``axis``."""
    return a._emit("reduce", kind=kind, axis=axis, keepdims=keepdims)


def scan(a: Tracer, x: Tracer, axis: int = 0) -> Tracer:
    """Linear recurrence h_t = a_t * h_{t-1} + x_t along ``axis``."""
    return a._emit("scan", [x], kind="linear", axis=axis)


def cumsum(x: Tracer, axis: int = 0) -> Tracer:
    return x._emit("scan", kind="cumsum", axis=axis)


def transpose(a: Tracer, perm) -> Tracer:
    return a._emit("transpose", perm=tuple(perm))


def cast(a: Tracer, dtype: str) -> Tracer:
    return a._emit("cast", dtype=dtype)


# --------------------------------------------------------------------------
# serving-kernel graph builders — the production shapes expressed as
# TensorIR so the whole pipeline (schedules, DSE, backends) applies to
# them instead of only to hand-written pallas (ROADMAP open item #1)
# --------------------------------------------------------------------------


def flash_attention_graph(sq: int, sk: int, d: int,
                          name: str = None) -> Graph:
    """Softmax attention for one (batch*head) slice as TensorIR.

    Inputs: ``q`` (sq, d) — pre-scaled by 1/sqrt(d); ``kt`` (d, sk) —
    keys pre-transposed; ``v`` (sk, d); ``mask`` (sq, sk) — additive,
    0 where attendable and -1e30 where masked (causal/window/valid
    masking is data, so one graph covers every masking policy).

    The online-softmax statistics of the hand kernel appear here as
    carried ``reduce`` ops; tiling their reduction axis threads the
    running max/sum through the carry (see ``lowering.lower_reduce``).
    """
    def f(q, kt, v, mask):
        s = matmul(q, kt) + mask
        m = reduce(s, kind="max", axis=1)
        p = exp(s - m)
        l = reduce(p, kind="sum", axis=1)
        return div(matmul(p, v), l)
    return trace(f, [spec((sq, d)), spec((d, sk)), spec((sk, d)),
                     spec((sq, sk))],
                 name=name or f"flash_{sq}x{sk}x{d}")


def decode_attention_graph(rep: int, smax: int, hd: int,
                           name: str = None) -> Graph:
    """Decode attention for one (batch, kv-group) slice: the same
    online-softmax dataflow as flash at the (rep, smax) decode shape;
    the KV-cache validity mask arrives as the additive ``mask`` input."""
    return flash_attention_graph(rep, smax, hd,
                                 name=name or f"decode_{rep}x{smax}x{hd}")


def ssd_scan_graph(s: int, p: int, n: int, name: str = None) -> Graph:
    """Mamba-2 SSD recurrence for one head as TensorIR.

    The (P, N) state is flattened to PN columns so the recurrence
    h_t = a_t ⊙ h_{t-1} + u_t is a rank-2 associative ``scan`` over the
    sequence axis.  Inputs: ``a`` (s, p*n) per-step decay exp(dt*A);
    ``u`` (s, p*n) the dt*x*B outer-product updates; ``ct`` (s, p*n)
    C broadcast along P; ``g`` (p*n, p) the 0/1 group-sum matrix that
    contracts the state dim back to head width (an MXU op, matching the
    chunked-scan formulation's matmuls).
    """
    pn = p * n

    def f(a, u, ct, g):
        h = scan(a, u, axis=0)
        return matmul(h * ct, g)
    return trace(f, [spec((s, pn)), spec((s, pn)), spec((s, pn)),
                     spec((pn, p))],
                 name=name or f"ssd_{s}x{p}x{n}")


def trace(fn: Callable, in_specs: Sequence[spec], name: str = None) -> Graph:
    # sanitise so the graph name is legal in textual IR (`<lambda>` etc.
    # would make str(graph) unparseable by ir_text)
    g = Graph(re.sub(r"[^\w.\-]", "_", name or fn.__name__))
    tracers = []
    for i, sp in enumerate(in_specs):
        v = g.add_input(f"arg{i}", TensorType(tuple(sp.shape), sp.dtype))
        tracers.append(Tracer(v, g))
    out = fn(*tracers)
    outs = out if isinstance(out, (tuple, list)) else [out]
    g.set_outputs(*[t.value for t in outs])
    g.verify()
    return g


# --------------------------------------------------------------------------
# raising: traced JAX -> TensorIR (the other way into this frontend)
# --------------------------------------------------------------------------
# ``raise`` is a Python keyword, so ``core/raise.py`` cannot be imported with
# ordinary syntax; these delegators give raising a home in the frontend
# namespace next to trace()/the hand-written kernel graphs.


def raise_jaxpr(fn, *in_specs, **kw):
    """Trace ``fn`` at ``in_specs`` and raise the jaxpr into TensorIR.

    Returns a ``RaisedGraph`` (see ``core/raise.py``): the graph plus the
    captured-constant bindings, runnable via ``run_ref``/``compile``."""
    import importlib
    return importlib.import_module("repro.core.raise").raise_jaxpr(
        fn, *in_specs, **kw)


def raise_model_blocks(config_name, **kw):
    """Raise every fused forward-pass block of one model config; returns
    per-block ``BlockReport``s (raised graph or diagnostic)."""
    import importlib
    return importlib.import_module("repro.core.raise").raise_model_blocks(
        config_name, **kw)
