"""Frontend tracer: restricted Python -> TensorIR.

Plays the SYCL/DPC++ role in the paper's Fig. 1: the user writes a kernel
in the host language (here: Python over ``stagecc`` proxy arrays) and the
frontend produces the level-1 IR automatically — no hand-written IR.

Example::

    import repro.core.frontend as fe

    def f(a, b, bias):
        return fe.relu(fe.matmul(a, b) + bias)

    graph = fe.trace(f, [fe.spec((64, 32)), fe.spec((32, 16)),
                         fe.spec((16,))])
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, List, Sequence

from .tensor_ir import Graph, TensorType, Value


@dataclasses.dataclass(frozen=True)
class spec:
    shape: tuple
    dtype: str = "float32"


class Tracer:
    """Proxy value recording ops into the active graph."""

    __slots__ = ("value", "graph")

    def __init__(self, value: Value, graph: Graph):
        self.value = value
        self.graph = graph

    def _emit(self, opname, others=(), **attrs):
        ins = [self.value] + [o.value for o in others]
        res = self.graph.emit(opname, ins, **attrs)
        return Tracer(res, self.graph)

    def __matmul__(self, other):
        return self._emit("matmul", [other])

    def __add__(self, other):
        if other.value.type.rank == 1 and self.value.type.rank > 1:
            return self._emit("bias_add", [other])
        return self._emit("add", [other])

    def __sub__(self, other):
        return self._emit("sub", [other])

    def __mul__(self, other):
        return self._emit("mul", [other])

    def __neg__(self):
        return self._emit("neg")

    @property
    def shape(self):
        return self.value.type.shape

    @property
    def dtype(self):
        return self.value.type.dtype


# free-function forms mirroring the op set
def matmul(a: Tracer, b: Tracer) -> Tracer:
    return a._emit("matmul", [b])


def relu(a: Tracer) -> Tracer:
    return a._emit("relu")


def gelu(a: Tracer) -> Tracer:
    return a._emit("gelu")


def exp(a: Tracer) -> Tracer:
    return a._emit("exp")


def maximum(a: Tracer, b: Tracer) -> Tracer:
    return a._emit("maximum", [b])


def transpose(a: Tracer, perm) -> Tracer:
    return a._emit("transpose", perm=tuple(perm))


def cast(a: Tracer, dtype: str) -> Tracer:
    return a._emit("cast", dtype=dtype)


def trace(fn: Callable, in_specs: Sequence[spec], name: str = None) -> Graph:
    # sanitise so the graph name is legal in textual IR (`<lambda>` etc.
    # would make str(graph) unparseable by ir_text)
    g = Graph(re.sub(r"[^\w.\-]", "_", name or fn.__name__))
    tracers = []
    for i, sp in enumerate(in_specs):
        v = g.add_input(f"arg{i}", TensorType(tuple(sp.shape), sp.dtype))
        tracers.append(Tracer(v, g))
    out = fn(*tracers)
    outs = out if isinstance(out, (tuple, list)) else [out]
    g.set_outputs(*[t.value for t in outs])
    g.verify()
    return g
