"""Host ↔ device coupling model — the paper's vendor-crossbar integration.

The paper's end-to-end flow does not stop at RTL: the generated module is
packaged as an IP core and "coupled with the host CPU using
vendor-specific crossbars" (Fig. 1's AXI interconnect).  This module
models that last hop so a *complete* transaction can be simulated:

    host writes input buffers over DMA  →  host kicks the CSR start bit
        →  device FSM runs (hw_sim)  →  host polls the done bit
        →  host reads output buffers back over DMA

Three pieces:

  * :class:`Crossbar` — the interconnect: data-beat width, a fixed
    per-transaction handshake latency, and a CSR access cost.  Presets
    model an AXI4 burst port (wide) and an AXI4-Lite port (narrow).
  * :func:`csr_map` — the module's memory-mapped control/status register
    block, generated from its ports exactly like the paper's IP-core
    wrapper: CTRL/STATUS/CYCLES plus an address+length pair per port.
  * :func:`run_transaction` — the full transaction simulator.  Device
    cycles come from :func:`repro.core.hw_sim.simulate` (observed, not
    analytic); host-side cycles are charged per DMA beat, per CSR
    access, and per polling round-trip, all in the same device-clock
    domain, so crossbar latency and width visibly move the end-to-end
    cycle count.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import hw_sim
from .hw_ir import HwModule, HwPort
from .machine_model import TPU_V5E, MachineModel
from .tensor_ir import dtype_bytes


@dataclasses.dataclass(frozen=True)
class Crossbar:
    """One host↔device interconnect port (the vendor crossbar).

    ``data_width_bits`` is the beat width of the DMA channel;
    ``latency_cycles`` the fixed address/handshake cost paid once per
    DMA transfer; ``csr_access_cycles`` the cost of one memory-mapped
    register read or write (CSRs ride the narrow control path).
    """

    name: str = "axi4"
    data_width_bits: int = 128
    latency_cycles: int = 24
    csr_access_cycles: int = 4

    def __post_init__(self):
        if self.data_width_bits <= 0 or self.data_width_bits % 8:
            raise ValueError(f"crossbar {self.name}: data width must be a "
                             f"positive multiple of 8 bits")

    def dma_cycles(self, nbytes: int) -> int:
        """Cycles to move ``nbytes`` in one burst transfer."""
        beats = math.ceil(8 * nbytes / self.data_width_bits)
        return self.latency_cycles + beats


#: a wide burst-capable memory port and the narrow control-plane port
AXI4 = Crossbar("axi4", data_width_bits=128, latency_cycles=24)
AXI4_LITE = Crossbar("axi4_lite", data_width_bits=32, latency_cycles=8,
                     csr_access_cycles=8)

#: named crossbar configurations the CLI/fabric accept by name
CROSSBAR_PRESETS: Dict[str, Crossbar] = {
    "axi4": AXI4,
    "axi4_lite": AXI4_LITE,
}


def crossbar_preset(name: str) -> Crossbar:
    """Look up a crossbar preset (case-insensitive).  Raises ``KeyError``
    naming the valid presets on a miss; the CLI adds its did-you-mean
    hint on top."""
    key = name.strip().lower()
    if key not in CROSSBAR_PRESETS:
        raise KeyError(f"unknown crossbar preset {name!r} "
                       f"(choose from {', '.join(CROSSBAR_PRESETS)})")
    return CROSSBAR_PRESETS[key]


class PollTimeout(RuntimeError):
    """The host gave up polling STATUS before the device reported done."""


# --------------------------------------------------------------------------
# CSR block
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CsrField:
    offset: int
    name: str
    doc: str


def csr_map(mod: HwModule) -> List[CsrField]:
    """The module's memory-mapped register block, IP-core-wrapper style:
    CTRL (bit0 = start), STATUS (bit0 = done), CYCLES (observed cycle
    counter), then an address + length register pair per memory port."""
    fields = [
        CsrField(0x00, "CTRL", "bit0: start (write 1 to launch)"),
        CsrField(0x04, "STATUS", "bit0: done (clears on start)"),
        CsrField(0x08, "CYCLES", "device cycle counter of the last run"),
    ]
    off = 0x10
    for p in mod.ports:
        fields.append(CsrField(off, f"{p.name.upper()}_ADDR",
                               f"host buffer address of port {p.name} "
                               f"({p.direction})"))
        fields.append(CsrField(off + 4, f"{p.name.upper()}_LEN",
                               f"transfer length of port {p.name} "
                               f"({port_bytes(p)} bytes)"))
        off += 8
    return fields


def port_bytes(p: HwPort) -> int:
    return p.elems * dtype_bytes(p.dtype)


# --------------------------------------------------------------------------
# transaction simulation
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Phase:
    """One phase of the host transaction, with its cycle cost."""

    name: str                       # "csr_setup" | "dma_in" | "start" | ...
    cycles: int
    detail: str = ""

    def __str__(self):
        return f"{self.name:<10} {self.cycles:>10,} cyc  {self.detail}"


@dataclasses.dataclass
class TransactionReport:
    """A complete host→device→host round trip."""

    module: str
    crossbar: Crossbar
    sim: hw_sim.SimReport           # the device-side run
    phases: List[Phase]
    csr_trace: List[Tuple[int, str, str, int]]   # (cycle, op, reg, value)

    @property
    def total_cycles(self) -> int:
        return sum(p.cycles for p in self.phases)

    @property
    def device_cycles(self) -> int:
        return self.sim.cycles.total

    @property
    def host_overhead_cycles(self) -> int:
        """Cycles the transaction spends outside the device FSM."""
        return self.total_cycles - self.device_cycles

    @property
    def outputs(self) -> List[np.ndarray]:
        return self.sim.outputs

    def summary(self) -> str:
        lines = [f"transaction {self.module} over {self.crossbar.name} "
                 f"(width={self.crossbar.data_width_bits}b, "
                 f"latency={self.crossbar.latency_cycles}cyc): "
                 f"{self.total_cycles:,} cycles total"]
        lines += [f"  {p}" for p in self.phases]
        lines.append(f"  host overhead: {self.host_overhead_cycles:,} "
                     f"cycles over the {self.device_cycles:,}-cycle kernel")
        return "\n".join(lines)


def _validate_inputs(mod: HwModule, inputs: Sequence[np.ndarray]) -> None:
    """Host-side argument checking against the port declarations — the
    crossbar wrapper rejects a malformed DMA descriptor instead of
    silently casting or truncating.  Fewer inputs than ``in`` ports is
    legal (unbound HBM temporaries read zeros, as in ``hw_sim``)."""
    in_ports = [p for p in mod.ports if p.direction == "in"]
    if len(inputs) > len(in_ports):
        raise ValueError(
            f"module {mod.name} has {len(in_ports)} input port(s) but "
            f"{len(inputs)} input buffer(s) were given")
    for p, a in zip(in_ports, inputs):
        a = np.asarray(a)
        if tuple(a.shape) != tuple(p.shape):
            raise ValueError(
                f"module {mod.name}, port {p.name}: input shape "
                f"{tuple(a.shape)} != declared {tuple(p.shape)}")
        # the carried numpy dtype (bfloat16 rides in float32, as in the
        # oracle and the simulator)
        want = np.dtype(hw_sim._np_dtype(p.dtype))
        if a.dtype != want:
            raise ValueError(
                f"module {mod.name}, port {p.name}: input dtype "
                f"{a.dtype} != declared {want} (the DMA engine moves "
                f"raw beats; cast on the host first)")


def run_transaction(mod: HwModule, inputs: Sequence[np.ndarray],
                    machine: MachineModel = TPU_V5E,
                    crossbar: Crossbar = AXI4,
                    poll_interval: int = 64,
                    poll_timeout: Optional[int] = None,
                    trace: bool = False,
                    sim: Optional[hw_sim.SimReport] = None
                    ) -> TransactionReport:
    """Simulate the full host-coupled flow of the paper's Fig. 1.

    Phases, all in device-clock cycles:

    1. **csr_setup** — the host programs every port's ADDR/LEN register
       pair (two CSR writes per port);
    2. **dma_in** — input buffers stream device-ward, one burst per
       ``in`` port (handshake latency + one cycle per data beat);
    3. **start** — one CSR write sets CTRL.start;
    4. **device** — the module FSM runs (:func:`hw_sim.simulate`; the
       *observed* cycle count, not the analytic model);
    5. **poll** — the host reads STATUS every ``poll_interval`` cycles
       until done; completion is only visible at a poll boundary, so the
       phase rounds the device run up and adds one CSR read per poll;
    6. **dma_out** — every write-channel (``out``/``inout``) port
       streams back to the host.

    ``poll_timeout`` caps the number of STATUS polls the host issues:
    if the device run would need more, the transaction raises
    :class:`PollTimeout` instead of spinning — the watchdog every real
    host driver arms against a wedged device.

    Pass ``sim`` to reuse an already-computed device run (e.g. from a
    preceding co-simulation of the same module and inputs) instead of
    simulating a second time.
    """
    _validate_inputs(mod, inputs)
    if poll_timeout is not None and poll_timeout < 1:
        raise ValueError(f"poll_timeout must be >= 1, got {poll_timeout}")
    fields = {f.name: f for f in csr_map(mod)}
    csr_trace: List[Tuple[int, str, str, int]] = []
    phases: List[Phase] = []
    now = 0

    def csr(op: str, reg: str, value: int = 0) -> int:
        """One CSR access: stamped at issue time, advancing the clock."""
        nonlocal now
        if reg not in fields:
            raise KeyError(f"no CSR named {reg!r} on module {mod.name}")
        csr_trace.append((now, op, reg, value))
        now += crossbar.csr_access_cycles
        return crossbar.csr_access_cycles

    # 1. program the address map
    cost = 0
    for i, p in enumerate(mod.ports):
        cost += csr("write", f"{p.name.upper()}_ADDR", 0x1000_0000 + i * 0x100000)
        cost += csr("write", f"{p.name.upper()}_LEN", port_bytes(p))
    phases.append(Phase("csr_setup", cost,
                        f"{2 * len(mod.ports)} CSR writes (ADDR/LEN per port)"))

    # 2. DMA inputs device-ward
    cost = 0
    n_in = 0
    for p in mod.ports:
        if p.direction == "in":
            cost += crossbar.dma_cycles(port_bytes(p))
            n_in += 1
    now += cost
    phases.append(Phase("dma_in", cost,
                        f"{n_in} burst(s), {crossbar.latency_cycles} cyc "
                        f"handshake + 1 cyc/beat @{crossbar.data_width_bits}b"))

    # 3. kick
    cost = csr("write", "CTRL", 1)
    phases.append(Phase("start", cost, "CTRL.start <= 1"))

    # 4. the device runs (observed cycles)
    rep = sim if sim is not None else hw_sim.simulate(mod, inputs,
                                                      machine=machine,
                                                      trace=trace)
    device_start = now
    now += rep.cycles.total
    phases.append(Phase("device", rep.cycles.total,
                        f"module FSM: {rep.steps_retired:,} steps, "
                        f"{rep.fsm_transitions:,} transitions"))

    # 5. poll STATUS until done — completion visible only at poll edges.
    # The polls themselves land *during* the device run, spaced one
    # interval apart (trace-stamped at their real issue cycles); their
    # access cost is charged serially to the host here.
    polls = max(1, math.ceil(rep.cycles.total / max(1, poll_interval)))
    if poll_timeout is not None and polls > poll_timeout:
        raise PollTimeout(
            f"module {mod.name}: device needs {rep.cycles.total:,} cycles "
            f"(≥ {polls} polls at interval {poll_interval}) but the host "
            f"gives up after {poll_timeout} poll(s); raise poll_timeout "
            f"or poll_interval")
    wait = polls * poll_interval - rep.cycles.total   # residual quantisation
    for i in range(min(polls, 4)):                    # keep the trace short
        csr_trace.append((device_start + (i + 1) * poll_interval,
                          "read", "STATUS", 0))
    if polls > 4:
        csr_trace.append((device_start + polls * poll_interval,
                          "read", "STATUS(xN)", polls - 4))
    now += wait + polls * crossbar.csr_access_cycles
    cost = wait + polls * crossbar.csr_access_cycles
    cost += csr("read", "CYCLES", rep.cycles.total)
    phases.append(Phase("poll", cost,
                        f"{polls} STATUS read(s) every {poll_interval} cyc "
                        f"+ CYCLES readback"))

    # 6. DMA results host-ward
    cost = 0
    n_out = 0
    for p in mod.ports:
        if p.direction in ("out", "inout"):
            cost += crossbar.dma_cycles(port_bytes(p))
            n_out += 1
    now += cost
    phases.append(Phase("dma_out", cost, f"{n_out} burst(s) back to host"))

    report = TransactionReport(module=mod.name, crossbar=crossbar, sim=rep,
                               phases=phases, csr_trace=csr_trace)
    assert report.total_cycles == now   # phase costs account every cycle
    return report
