"""JAX/XLA backend: generate a jittable jnp function from LoopIR.

This is the "standalone platform" of the paper's future-work (2): the same
scheduled IR that emits a pallas kernel can instead target plain XLA,
making the stack runnable on any JAX backend (CPU of this container, GPU,
TPU) with no code change.

Codegen strategy: structural recursion over the statement tree, building
jnp expressions with functional updates.  Loop extents are static, so
SEQUENTIAL loops become ``lax.fori_loop`` when profitable and UNROLLED /
GRID / VECTOR loops become python-level unrolling at trace time.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp

from .loop_ir import (EwiseTile, FillTile, Kernel, Loop, LoopKind, MatmulTile,
                      MemSpace, ReduceTile, ScanTile, Stmt, TileRef, ZeroTile,
                      _stmt_written_refs)

_EWISE_JNP = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "maximum": jnp.maximum,
    "relu": lambda a: jnp.maximum(a, 0),
    "gelu": jax.nn.gelu,
    "exp": jnp.exp,
    "neg": lambda a: -a,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "sqrt": jnp.sqrt,
    "rsqrt": jax.lax.rsqrt,
    "log1p": jnp.log1p,
    "abs": jnp.abs,
    "copy": lambda a: a,
}

_JNP_DTYPE = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
              "float16": jnp.float16, "int32": jnp.int32, "int8": jnp.int8}

# unroll python-side below this trip count; lax.fori_loop above
_FORI_THRESHOLD = 8


def emit(kernel: Kernel) -> Callable[..., List[jax.Array]]:
    """Return ``f(*inputs) -> [outputs]`` implementing the kernel."""
    kernel.verify()
    out_names = {b.name for b in kernel.outputs}
    in_params = [b for b in kernel.params if b.name not in out_names]

    def fn(*inputs):
        if len(inputs) > len(in_params):
            raise ValueError(f"{kernel.name}: expected <= {len(in_params)} inputs")
        mem: Dict[str, jax.Array] = {}
        it = iter(inputs)
        for b in in_params:
            try:
                a = next(it)
            except StopIteration:
                mem[b.name] = jnp.zeros(b.shape, _JNP_DTYPE[b.type.dtype])
                continue
            mem[b.name] = jnp.asarray(a, _JNP_DTYPE[b.type.dtype])
        for b in kernel.outputs:
            mem[b.name] = jnp.zeros(b.shape, _JNP_DTYPE[b.type.dtype])
        for b in kernel.scratch:
            mem[b.name] = jnp.zeros(b.shape, _JNP_DTYPE[b.type.dtype])

        def read(ref: TileRef, env):
            starts = [e.evaluate(env) * t for e, t in zip(ref.index, ref.tile)]
            return jax.lax.dynamic_slice(mem[ref.buffer.name], starts, ref.tile)

        def write(ref: TileRef, env, val):
            starts = [e.evaluate(env) * t for e, t in zip(ref.index, ref.tile)]
            mem[ref.buffer.name] = jax.lax.dynamic_update_slice(
                mem[ref.buffer.name], val.astype(mem[ref.buffer.name].dtype),
                starts)

        def exec_stmt(s: Stmt, env):
            if isinstance(s, ZeroTile):
                write(s.dst, env, jnp.zeros(s.dst.tile, jnp.float32))
            elif isinstance(s, MatmulTile):
                a = read(s.lhs, env)
                b = read(s.rhs, env)
                c = jnp.dot(a, b, preferred_element_type=jnp.float32)
                if s.accumulate:
                    c = read(s.dst, env).astype(jnp.float32) + c
                write(s.dst, env, c)
            elif isinstance(s, FillTile):
                write(s.dst, env, jnp.full(s.dst.tile, s.value, jnp.float32))
            elif isinstance(s, ReduceTile):
                src = read(s.src, env)
                r = (jnp.max if s.kind == "max" else jnp.sum)(
                    src, axis=-1, keepdims=True)
                if s.accumulate:
                    d = read(s.dst, env)
                    r = jnp.maximum(d, r) if s.kind == "max" else d + r
                write(s.dst, env, r)
            elif isinstance(s, ScanTile):
                srcs = [read(r, env) for r in s.srcs]
                x = srcs[-1]

                def step(c, row):
                    if s.kind == "linear":
                        a_row, x_row = row
                        c = a_row * c + x_row
                    else:
                        c = c + row[0]
                    return c, c

                rows = (srcs[0], x) if s.kind == "linear" else (x,)
                carry0 = read(s.carry, env)[0]
                last, out = jax.lax.scan(step, carry0, rows)
                write(s.dst, env, out)
                write(s.carry, env, last[None])
            elif isinstance(s, EwiseTile):
                if s.op == "ones":
                    write(s.dst, env, jnp.ones(s.dst.tile, jnp.float32))
                elif s.op == "copy1":
                    src = read(s.srcs[0], env)
                    write(s.dst, env, src.reshape(s.dst.tile))
                else:
                    srcs = [read(r, env) for r in s.srcs]
                    if len(srcs) == 2 and srcs[1].ndim < srcs[0].ndim:
                        srcs[1] = srcs[1][(None,) * (srcs[0].ndim
                                                     - srcs[1].ndim)]
                    write(s.dst, env, _EWISE_JNP[s.op](*srcs))
            else:
                raise TypeError(type(s))

        def go(stmts: List[Stmt], env):
            for s in stmts:
                if isinstance(s, Loop):
                    # Loop-var-dependent starts are traced; extents static.
                    if (s.kind == LoopKind.SEQUENTIAL
                            and s.var.extent > _FORI_THRESHOLD):
                        touched = _buffers_written(s.body)

                        def body_fn(t, carry):
                            for name, arr in zip(touched, carry):
                                mem[name] = arr
                            go(s.body, {**env, s.var.name: t})
                            return tuple(mem[n] for n in touched)

                        init = tuple(mem[n] for n in touched)
                        final = jax.lax.fori_loop(0, s.var.extent, body_fn, init)
                        for name, arr in zip(touched, final):
                            mem[name] = arr
                    else:
                        for t in range(s.var.extent):
                            go(s.body, {**env, s.var.name: t})
                else:
                    exec_stmt(s, env)

        go(kernel.body, {})
        return [mem[b.name] for b in kernel.outputs]

    fn.__name__ = f"stagecc_jax_{kernel.name}"
    return fn


def _buffers_written(stmts: Sequence[Stmt]) -> List[str]:
    out: List[str] = []

    def go(ss):
        for s in ss:
            if isinstance(s, Loop):
                go(s.body)
            elif isinstance(s, (ZeroTile, MatmulTile, EwiseTile, FillTile,
                                ReduceTile, ScanTile)):
                for r in _stmt_written_refs(s):
                    if r.buffer.name not in out:
                        out.append(r.buffer.name)

    go(stmts)
    return out


def emit_jit(kernel: Kernel) -> Callable[..., List[jax.Array]]:
    return jax.jit(emit(kernel))
