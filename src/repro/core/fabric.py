"""Multi-kernel fabric — N accelerators behind one shared crossbar.

The paper's end state couples *one* generated hardware module to the
host CPU over a vendor crossbar; ``host_bridge.run_transaction`` models
exactly that single transaction.  This module generalizes the coupling
to a **fabric**: N :class:`~repro.core.hw_ir.HwModule` instances (the
*slots*) share one :class:`~repro.core.host_bridge.Crossbar`, each with
its own command/DMA queue, and a host-side scheduler dispatches a
request stream across them — overlapping one kernel's DMA with
another's compute, with the shared crossbar arbitrated **per beat** so
overlapping bursts are serialized honestly instead of priced
independently.

Pricing symmetry (the PR-9 rule, fabric-scale): there is exactly ONE
scheduling core, :func:`Fabric._schedule` — an event-driven simulation
of slots + crossbar + host queues.  The **fabric machine model**
(:meth:`Fabric.model`) feeds it analytic per-kernel device cycles from
``machine_model.cycles``; the **fabric event simulator**
(:meth:`Fabric.simulate`) feeds it *observed* device cycles from
``hw_sim.simulate`` (each distinct module executed once, outputs
checked against the numpy oracle when a LoopIR kernel is attached).
Both sides price DMA, CSR and arbitration with the same arithmetic as
``host_bridge.run_transaction`` — a one-slot, one-request fabric
reproduces that transaction's cycle count exactly (pinned by test).

Arbitration policies:

  * ``round_robin`` — per-beat round-robin over the active bursts:
    with n bursts in flight each progresses at 1/n beats per cycle
    (deterministic processor sharing — the limit of per-beat RR);
  * ``priority``    — strict preemptive priority (lower slot
    ``priority`` value wins the crossbar; equal priorities fall back
    to round-robin among themselves).

The **serialized baseline** (``overlap=False``) runs the same core with
a global one-transaction-at-a-time lock — exactly back-to-back
``run_transaction`` calls, the seed behaviour every BENCH_fabric entry
must beat.

Fleet-level DSE: :func:`explore_fleet` composes per-kernel
``dse.explore`` frontiers into fleet candidates (which schedule each
kernel gets, how many copies) under a total
:class:`~repro.core.dse.ResourceBudget`, prices each fleet with the
fabric machine model against a :class:`TrafficMix`, ranks candidates on
a throughput-under-contention × total-area Pareto frontier, and
validates the top points with the fabric event simulator (model vs
simulated requests/s within a tolerance — the same modeled-vs-observed
gate ``dse.validate_point`` applies per kernel).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import dse as dse_mod
from . import hw_sim, machine_model
from .host_bridge import AXI4, Crossbar, port_bytes
from .hw_ir import HwModule
from .loop_ir import Kernel
from .machine_model import TPU_V5E, MachineModel
from .tensor_ir import Graph

ARBITRATION_POLICIES = ("round_robin", "priority")

_EPS = 1e-6


# --------------------------------------------------------------------------
# per-transaction cost breakdown (host_bridge arithmetic, reused)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransactionCost:
    """Phase costs of one request on one slot, in device-clock cycles.

    Mirrors ``host_bridge.run_transaction`` exactly: ``csr_setup`` two
    CSR writes per port, ``dma_in``/``dma_out`` one burst per port
    (handshake latency + one cycle per data beat), ``start`` one CSR
    write, ``poll`` the done-bit quantisation + per-poll CSR reads +
    the CYCLES readback.  The DMA phases are the *contended* ones: on
    the fabric their cycles are crossbar beats that arbitrate against
    other slots' bursts.
    """

    csr_setup: int
    dma_in: int
    start: int
    device: int
    poll: int
    dma_out: int

    @property
    def total(self) -> int:
        return (self.csr_setup + self.dma_in + self.start + self.device
                + self.poll + self.dma_out)


def transaction_cost(mod: HwModule, crossbar: Crossbar, device_cycles: int,
                     poll_interval: int = 64) -> TransactionCost:
    """The fabric's pricing of one request — term-for-term the phase
    arithmetic of ``host_bridge.run_transaction`` (pinned by test)."""
    csr = crossbar.csr_access_cycles
    setup = 2 * len(mod.ports) * csr
    dma_in = sum(crossbar.dma_cycles(port_bytes(p)) for p in mod.ports
                 if p.direction == "in")
    dma_out = sum(crossbar.dma_cycles(port_bytes(p)) for p in mod.ports
                  if p.direction in ("out", "inout"))
    polls = max(1, math.ceil(device_cycles / max(1, poll_interval)))
    wait = polls * poll_interval - device_cycles
    poll = wait + polls * csr + csr          # + the CYCLES readback
    return TransactionCost(csr_setup=setup, dma_in=dma_in, start=csr,
                           device=device_cycles, poll=poll, dma_out=dma_out)


# --------------------------------------------------------------------------
# requests and traffic mixes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FabricRequest:
    """One dispatchable request: run kernel ``kernel`` once, arriving at
    ``arrival`` device-clock cycles after stream start."""

    rid: int
    kernel: str
    arrival: float


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """A fabric workload: arrival process (``serve.loadgen`` reuse) ×
    per-kernel dispatch weights.

    Arrival times come from :func:`repro.serve.loadgen.generate_stream`
    (Poisson / bursty / uniform, replayable seed) in abstract time
    units; ``cycles_per_unit`` converts them to device-clock cycles.
    Each request's target kernel is drawn from ``weights`` by the same
    seeded generator, so the whole stream is a pure function of the mix.
    """

    name: str
    weights: Tuple[Tuple[str, float], ...]    # (kernel name, weight)
    num_requests: int = 32
    process: str = "poisson"                  # poisson | bursty | uniform
    rate: float = 1.0                         # arrivals per time unit
    cycles_per_unit: float = 1.0
    seed: int = 0

    def describe(self) -> Dict:
        return {"name": self.name, "weights": dict(self.weights),
                "num_requests": self.num_requests, "process": self.process,
                "rate": self.rate, "cycles_per_unit": self.cycles_per_unit,
                "seed": self.seed}


def fabric_stream(mix: TrafficMix) -> List[FabricRequest]:
    """The deterministic request stream of ``mix`` (loadgen arrivals,
    seeded kernel draws, arrival units scaled to cycles)."""
    from repro.serve import loadgen

    load = loadgen.LoadConfig(num_requests=mix.num_requests, seed=mix.seed,
                              process=mix.process, rate=mix.rate)
    arrivals = [r.arrival for r in loadgen.generate_stream(load)]
    names = [k for k, _ in mix.weights]
    w = np.asarray([w for _, w in mix.weights], dtype=np.float64)
    if not len(names) or np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"mix {mix.name!r}: weights must be non-empty "
                         f"and non-negative with positive sum")
    rng = np.random.default_rng(mix.seed + 0x5EED)
    picks = rng.choice(len(names), size=mix.num_requests, p=w / w.sum())
    return [FabricRequest(rid=i, kernel=names[int(picks[i])],
                          arrival=float(a * mix.cycles_per_unit))
            for i, a in enumerate(arrivals)]


def saturating_cycles_per_unit(mix: TrafficMix, mean_service_cycles: float,
                               load_factor: float = 2.0) -> float:
    """``cycles_per_unit`` that offers ``load_factor`` × one device's
    capacity: offered rate (req/cycle) = rate / cycles_per_unit, one
    serialized device serves 1/mean_service_cycles — a fabric only shows
    its contention behaviour when the stream actually queues."""
    if mean_service_cycles <= 0 or load_factor <= 0:
        raise ValueError("mean_service_cycles and load_factor must be > 0")
    return mix.rate * mean_service_cycles / load_factor


# --------------------------------------------------------------------------
# the fabric
# --------------------------------------------------------------------------


@dataclasses.dataclass
class FabricSlot:
    """One accelerator instance on the fabric."""

    name: str                         # instance name, e.g. "gemm8#0"
    kernel_name: str                  # dispatch key requests name
    module: HwModule
    kernel: Optional[Kernel] = None   # LoopIR stage: numeric oracle for sim
    priority: int = 0                 # lower wins under the priority policy


class FabricError(RuntimeError):
    """Fabric construction or scheduling failed."""


@dataclasses.dataclass
class _SlotState:
    """Mutable per-slot scheduling state (one request in flight max)."""

    queue: List[FabricRequest] = dataclasses.field(default_factory=list)
    current: Optional[FabricRequest] = None
    phase: int = -1                   # index into _PHASES
    phase_end: float = 0.0            # fixed-duration phases
    dma_remaining: float = 0.0        # crossbar phases
    busy_cycles: float = 0.0
    completed: int = 0


#: phase order of one request; "xbar" phases contend on the crossbar,
#: "slot" phases occupy only the slot's own command channel / datapath
_PHASES = (("csr_setup", "slot"), ("dma_in", "xbar"), ("start", "slot"),
           ("device", "slot"), ("poll", "slot"), ("dma_out", "xbar"))


@dataclasses.dataclass
class FabricReport:
    """One scheduled run of a request stream over the fabric."""

    mode: str                         # "overlap" | "serialized"
    policy: str
    device_source: str                # "model" | "sim"
    crossbar: Crossbar
    requests: int
    completed: int
    total_cycles: int                 # makespan: last completion
    requests_per_s: float
    crossbar_busy_cycles: int
    crossbar_utilization: float
    latency_cycles: Dict[str, float]  # StreamingHistogram summary
    slots: List[Dict]                 # per-slot accounting
    device_cycles: Dict[str, int]     # per-slot device cycles fed in
    checked: bool = False             # sim outputs compared to the oracle
    max_abs_err: float = float("nan")
    transcript: List[str] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict:
        return {
            "mode": self.mode, "policy": self.policy,
            "device_source": self.device_source,
            "crossbar": {"name": self.crossbar.name,
                         "data_width_bits": self.crossbar.data_width_bits,
                         "latency_cycles": self.crossbar.latency_cycles},
            "requests": self.requests, "completed": self.completed,
            "total_cycles": self.total_cycles,
            "requests_per_s": round(self.requests_per_s, 3),
            "crossbar_busy_cycles": self.crossbar_busy_cycles,
            "crossbar_utilization": round(self.crossbar_utilization, 4),
            "latency_cycles": self.latency_cycles,
            "slots": self.slots,
            "device_cycles": self.device_cycles,
        }

    def summary(self) -> str:
        lines = [f"fabric [{self.mode}/{self.policy}] "
                 f"({self.device_source} device cycles): "
                 f"{self.completed}/{self.requests} requests in "
                 f"{self.total_cycles:,} cycles "
                 f"-> {self.requests_per_s:,.1f} req/s, "
                 f"crossbar util {self.crossbar_utilization:.1%}"]
        for s in self.slots:
            q = s["queue_depth"]
            lines.append(
                f"  {s['name']:<14} {s['kernel']:<10} "
                f"served={s['completed']:<4} "
                f"busy={s['busy_cycles']:>10,} cyc "
                f"({s['utilization']:.1%})  "
                f"queue p50/p99={q['p50']:.0f}/{q['p99']:.0f}")
        if self.checked:
            lines.append(f"  numeric check vs numpy oracle: "
                         f"max|err|={self.max_abs_err:.1e}")
        return "\n".join(lines)


@dataclasses.dataclass
class Fabric:
    """N accelerator slots behind one shared crossbar."""

    slots: List[FabricSlot]
    crossbar: Crossbar = AXI4
    policy: str = "round_robin"
    poll_interval: int = 64

    def __post_init__(self):
        if not self.slots:
            raise FabricError("a fabric needs at least one slot")
        if self.policy not in ARBITRATION_POLICIES:
            raise FabricError(
                f"unknown arbitration policy {self.policy!r}; choose from "
                f"{', '.join(ARBITRATION_POLICIES)}")
        names = [s.name for s in self.slots]
        if len(set(names)) != len(names):
            raise FabricError(f"duplicate slot names: {names}")

    # ---- the two symmetric entry points -----------------------------------

    def model(self, stream: Sequence[FabricRequest],
              machine: MachineModel = TPU_V5E, overlap: bool = True,
              transcript: bool = False) -> FabricReport:
        """Fabric machine model: schedule ``stream`` with *analytic*
        per-kernel device cycles (``machine_model.cycles``)."""
        dev = {s.name: machine_model.cycles(s.module, machine).total
               for s in self.slots}
        return self._schedule(stream, dev, machine, overlap=overlap,
                              source="model", transcript=transcript)

    def simulate(self, stream: Sequence[FabricRequest],
                 machine: MachineModel = TPU_V5E, overlap: bool = True,
                 seed: int = 0, check: bool = True,
                 atol: float = 1e-5,
                 transcript: bool = False) -> FabricReport:
        """Fabric event simulator: schedule ``stream`` with *observed*
        device cycles from ``hw_sim.simulate`` — each distinct module
        executed once on seeded inputs, outputs checked against the
        numpy oracle when the slot carries its LoopIR kernel.  The
        scheduling core is byte-identical to :meth:`model`; only the
        device-cycle source differs (the PR-9 symmetry, fabric-scale).
        """
        from . import backend_ref

        dev: Dict[str, int] = {}
        cache: Dict[int, Tuple[int, float, bool]] = {}
        max_err, checked_any = 0.0, False
        for s in self.slots:
            key = id(s.module)
            if key not in cache:
                inputs = hw_sim.random_inputs(s.module, seed=seed)
                rep = hw_sim.simulate(s.module, inputs, machine=machine)
                err, did = float("nan"), False
                if check and s.kernel is not None:
                    refs = backend_ref.run(s.kernel, inputs)
                    err = 0.0
                    for buf, want in zip(s.kernel.outputs, refs):
                        got = rep.storage[buf.name]
                        err = max(err, float(np.max(np.abs(
                            np.asarray(got, np.float64)
                            - np.asarray(want, np.float64)))))
                    if err > atol:
                        raise hw_sim.SimMismatch(
                            f"fabric slot {s.name}: simulated outputs "
                            f"deviate from the numpy oracle by {err:.3e} "
                            f"(> atol={atol:g})")
                    did = True
                cache[key] = (rep.cycles.total, err, did)
            cyc, err, did = cache[key]
            dev[s.name] = cyc
            if did:
                checked_any = True
                max_err = max(max_err, err)
        out = self._schedule(stream, dev, machine, overlap=overlap,
                             source="sim", transcript=transcript)
        out.checked = checked_any
        out.max_abs_err = max_err if checked_any else float("nan")
        return out

    # ---- the one scheduling core ------------------------------------------

    def _costs(self, dev: Dict[str, int]) -> List[TransactionCost]:
        return [transaction_cost(s.module, self.crossbar, dev[s.name],
                                 self.poll_interval) for s in self.slots]

    def _schedule(self, stream: Sequence[FabricRequest],
                  device_cycles: Dict[str, int], machine: MachineModel,
                  overlap: bool, source: str,
                  transcript: bool = False) -> FabricReport:
        """Event-driven schedule of ``stream`` over the slots.

        Deterministic: events process in (time, slot index) order; the
        crossbar arbitrates active DMA bursts per beat (round-robin =
        processor sharing at rate 1/n; priority = strict preemption).
        With ``overlap=False`` a global lock admits one request at a
        time — the serialized single-kernel baseline, identical to
        back-to-back ``host_bridge.run_transaction`` calls.
        """
        from repro.serve.metrics import StreamingHistogram

        stream = sorted(stream, key=lambda r: (r.arrival, r.rid))
        by_kernel: Dict[str, List[int]] = {}
        for i, s in enumerate(self.slots):
            by_kernel.setdefault(s.kernel_name, []).append(i)
        for r in stream:
            if r.kernel not in by_kernel:
                raise FabricError(
                    f"request {r.rid} names kernel {r.kernel!r} but no "
                    f"slot serves it (slots: "
                    f"{', '.join(sorted(by_kernel))})")

        costs = self._costs(device_cycles)
        st = [_SlotState() for _ in self.slots]
        qdepth = [StreamingHistogram(lo=0.5, hi=1e6, growth=1.05)
                  for _ in self.slots]
        latency = StreamingHistogram(lo=1.0, hi=1e12, growth=1.02)
        lines: List[str] = []
        t = 0.0
        xbar_busy = 0.0
        in_flight = 0
        completed = 0
        next_arrival = 0

        def say(msg: str) -> None:
            if transcript and len(lines) < 400:
                lines.append(f"t={int(round(t)):>10,}  {msg}")

        def phase_cost(i: int, ph: int) -> int:
            c = costs[i]
            return (c.csr_setup, c.dma_in, c.start, c.device, c.poll,
                    c.dma_out)[ph]

        def enter_phase(i: int, ph: int) -> None:
            s = st[i]
            s.phase = ph
            name, kind = _PHASES[ph]
            dur = phase_cost(i, ph)
            if kind == "xbar":
                s.dma_remaining = float(dur)
                s.phase_end = math.inf
                say(f"{self.slots[i].name}: {name} "
                    f"({dur} beats on the crossbar)")
            else:
                s.phase_end = t + dur
                say(f"{self.slots[i].name}: {name} ({dur} cyc)")

        def try_start(i: int) -> None:
            nonlocal in_flight
            s = st[i]
            if s.current is not None or not s.queue:
                return
            if not overlap and in_flight > 0:
                return
            s.current = s.queue.pop(0)
            in_flight += 1
            say(f"{self.slots[i].name}: start request "
                f"#{s.current.rid} ({s.current.kernel})")
            enter_phase(i, 0)

        def active_dma() -> List[int]:
            return [i for i, s in enumerate(st)
                    if s.current is not None
                    and _PHASES[s.phase][1] == "xbar"]

        def dma_winners(act: List[int]) -> List[int]:
            """Slots whose bursts progress right now (arbitration)."""
            if self.policy == "priority":
                best = min(self.slots[i].priority for i in act)
                return [i for i in act if self.slots[i].priority == best]
            return act                      # round-robin: all share

        def finish_phase(i: int) -> None:
            nonlocal in_flight, completed
            s = st[i]
            if s.phase + 1 < len(_PHASES):
                enter_phase(i, s.phase + 1)
                return
            req = s.current
            s.current = None
            s.phase = -1
            s.completed += 1
            in_flight -= 1
            completed += 1
            latency.record(max(t - req.arrival, 1.0))
            say(f"{self.slots[i].name}: request #{req.rid} done "
                f"(latency {int(round(t - req.arrival)):,} cyc)")
            if overlap:
                for j in range(len(st)):
                    try_start(j)
            else:
                # the global lock frees: admit the oldest waiting request
                # (global FIFO — the honest serialized baseline)
                waiting = [(st[j].queue[0].arrival, st[j].queue[0].rid, j)
                           for j in range(len(st))
                           if st[j].queue and st[j].current is None]
                if waiting:
                    try_start(min(waiting)[2])

        while next_arrival < len(stream) or in_flight > 0 \
                or any(s.queue for s in st):
            act = active_dma()
            winners = dma_winners(act) if act else []
            # -- next event time ------------------------------------------
            t_next = math.inf
            if next_arrival < len(stream):
                t_next = min(t_next, stream[next_arrival].arrival)
            for i, s in enumerate(st):
                if s.current is not None and _PHASES[s.phase][1] == "slot":
                    t_next = min(t_next, s.phase_end)
            if winners:
                rate = 1.0 / len(winners)   # beats/cycle each
                t_next = min(t_next, t + min(st[i].dma_remaining
                                             for i in winners) / rate)
            if t_next is math.inf:
                raise FabricError("fabric scheduler deadlocked "
                                  "(no runnable event)")      # pragma: no cover
            # -- advance shared-crossbar progress over [t, t_next] ---------
            dt = t_next - t
            if dt > 0:
                if act:
                    xbar_busy += dt
                if winners:
                    rate = 1.0 / len(winners)
                    for i in winners:
                        st[i].dma_remaining -= dt * rate
                for i, s in enumerate(st):
                    if s.current is not None:
                        s.busy_cycles += dt
            t = t_next
            # -- retire events at t (slot order: deterministic) ------------
            for i, s in enumerate(st):
                if s.current is not None and _PHASES[s.phase][1] == "xbar" \
                        and s.dma_remaining <= _EPS:
                    s.dma_remaining = 0.0
                    finish_phase(i)
            for i, s in enumerate(st):
                if s.current is not None and _PHASES[s.phase][1] == "slot" \
                        and s.phase_end <= t + _EPS:
                    finish_phase(i)
            while next_arrival < len(stream) \
                    and stream[next_arrival].arrival <= t + _EPS:
                r = stream[next_arrival]
                next_arrival += 1
                cands = by_kernel[r.kernel]
                tgt = min(cands, key=lambda i: (
                    len(st[i].queue) + (st[i].current is not None), i))
                st[tgt].queue.append(r)
                depth = len(st[tgt].queue) \
                    + (st[tgt].current is not None)
                qdepth[tgt].record(depth)
                say(f"host: dispatch #{r.rid} ({r.kernel}) -> "
                    f"{self.slots[tgt].name} (queue depth {depth})")
                try_start(tgt)

        makespan = int(round(t))
        seconds = makespan / (machine.clock_ghz * 1e9) if makespan else 0.0
        slot_rows = []
        for i, s in enumerate(st):
            slot_rows.append({
                "name": self.slots[i].name,
                "kernel": self.slots[i].kernel_name,
                "priority": self.slots[i].priority,
                "completed": s.completed,
                "busy_cycles": int(round(s.busy_cycles)),
                "utilization": round(s.busy_cycles / makespan, 4)
                               if makespan else 0.0,
                "queue_depth": {k: round(v, 3) for k, v in
                                qdepth[i].summary().items()},
            })
        return FabricReport(
            mode="overlap" if overlap else "serialized",
            policy=self.policy, device_source=source,
            crossbar=self.crossbar,
            requests=len(stream), completed=completed,
            total_cycles=makespan,
            requests_per_s=completed / seconds if seconds else 0.0,
            crossbar_busy_cycles=int(round(xbar_busy)),
            crossbar_utilization=round(xbar_busy / makespan, 6)
                                 if makespan else 0.0,
            latency_cycles={k: round(v, 3)
                            for k, v in latency.summary().items()},
            slots=slot_rows, device_cycles=dict(device_cycles),
            transcript=lines)


def make_fleet(kernels: Dict[str, Tuple[HwModule, Optional[Kernel]]],
               copies: Optional[Dict[str, int]] = None,
               crossbar: Crossbar = AXI4, policy: str = "round_robin",
               poll_interval: int = 64) -> Fabric:
    """Convenience constructor: ``{kernel name: (HwModule, Kernel?)}``
    (+ optional per-kernel copy counts) → a :class:`Fabric`.  Copies
    share the module object, so the event simulator executes each
    distinct module once.  Slot priority is declaration order."""
    slots = []
    for prio, (name, (mod, kernel)) in enumerate(kernels.items()):
        for c in range((copies or {}).get(name, 1)):
            slots.append(FabricSlot(name=f"{name}#{c}", kernel_name=name,
                                    module=mod, kernel=kernel,
                                    priority=prio))
    return Fabric(slots=slots, crossbar=crossbar, policy=policy,
                  poll_interval=poll_interval)


# --------------------------------------------------------------------------
# fleet-level DSE — throughput-under-contention × total-area frontier
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetChoice:
    """One kernel's slice of a fleet: which frontier schedule, how many
    copies."""

    kernel: str
    point: dse_mod.DsePoint
    copies: int


@dataclasses.dataclass
class FleetCandidate:
    """A priced fleet: total area vs modeled throughput under the mix."""

    choices: Tuple[FleetChoice, ...]
    area: int
    model_rps: float
    serialized_rps: float
    feasible: bool
    on_frontier: bool = False

    @property
    def speedup(self) -> float:
        return self.model_rps / self.serialized_rps \
            if self.serialized_rps else 0.0

    @property
    def key(self) -> Tuple[int, float]:
        return (self.area, -self.model_rps)

    def spec(self) -> str:
        return " + ".join(f"{c.kernel}:{c.point.family}x{c.copies}"
                          for c in self.choices)


@dataclasses.dataclass
class FleetValidation:
    """Event-simulator check of one frontier fleet (pricing symmetry)."""

    candidate: FleetCandidate
    sim_rps: float
    model_rps: float
    ok: bool
    max_abs_err: float = float("nan")

    @property
    def deviation_pct(self) -> float:
        if self.model_rps <= 0:
            return 0.0
        return 100.0 * abs(self.sim_rps - self.model_rps) / self.model_rps


@dataclasses.dataclass
class FleetResult:
    """Outcome of one :func:`explore_fleet` run."""

    mix: TrafficMix
    machine: MachineModel
    budget: dse_mod.ResourceBudget
    candidates: List[FleetCandidate]
    validations: List[FleetValidation]
    errors: List[Tuple[str, str]]

    @property
    def frontier(self) -> List[FleetCandidate]:
        return sorted((c for c in self.candidates if c.on_frontier),
                      key=lambda c: c.key)

    def best(self) -> Optional[FleetCandidate]:
        front = self.frontier
        return max(front, key=lambda c: c.model_rps) if front else None

    def table(self) -> str:
        rows = [f"// fleet dse under mix {self.mix.name!r}: "
                f"{len(self.candidates)} fleets priced, "
                f"{len(self.frontier)} on the req/s x area frontier"]
        rows.append(f"{'':2s}{'REQ/S':>12s} {'AREA':>10s} {'SPEEDUP':>8s}  "
                    f"FLEET")
        for c in sorted(self.candidates, key=lambda c: c.key):
            mark = "* " if c.on_frontier else ("  " if c.feasible else "! ")
            rows.append(f"{mark}{c.model_rps:>12,.1f} {c.area:>10,} "
                        f"{c.speedup:>7.2f}x  {c.spec()}")
        rows.append("// '*' = frontier (max req/s, min area), "
                    "'!' = infeasible under the resource budget; speedup "
                    "is overlap vs serialized dispatch of the same stream")
        for v in self.validations:
            status = "ok" if v.ok else "FAIL"
            rows.append(f"// sim-validate [{status}] {v.candidate.spec()}: "
                        f"simulated={v.sim_rps:,.1f} req/s vs "
                        f"modeled={v.model_rps:,.1f} "
                        f"(dev {v.deviation_pct:.2f}%)")
        for kernel, msg in self.errors:
            rows.append(f"// error {kernel}: {msg}")
        return "\n".join(rows)


def fleet_dominates(a: FleetCandidate, b: FleetCandidate) -> bool:
    """Strict Pareto domination on (requests/s ↑, area ↓)."""
    return (a.model_rps >= b.model_rps and a.area <= b.area
            and (a.model_rps > b.model_rps or a.area < b.area))


def _fleet_feasible(parts: Sequence[Tuple[dse_mod.DseCandidate, int]],
                    budget: dse_mod.ResourceBudget) -> bool:
    lanes = sum(c.resources.compute_lanes * n for c, n in parts)
    vmem = sum((c.resources.vmem_bytes + c.dbuf_bytes) * n
               for c, n in parts)
    regs = sum(c.resources.reg_bits * n for c, n in parts)
    return (lanes <= budget.max_lanes and vmem <= budget.max_vmem_bytes
            and regs <= budget.max_reg_bits)


def explore_fleet(graphs: Dict[str, Graph], mix: TrafficMix,
                  machine: MachineModel = TPU_V5E,
                  budget: Optional[dse_mod.ResourceBudget] = None,
                  crossbar: Crossbar = AXI4,
                  policy: str = "round_robin",
                  max_copies: int = 2,
                  per_kernel: int = 3,
                  validate_top: int = 2,
                  rps_tol_pct: float = 10.0,
                  seed: int = 0,
                  **dse_kwargs) -> FleetResult:
    """Optimize the *fleet* against ``mix`` under one total budget.

    Per kernel, ``dse.explore`` supplies the single-kernel cycles × area
    frontier; fleets are the cross product of (frontier point × copy
    count ≤ ``max_copies``) over the kernels ``mix`` names.  Each
    feasible fleet is priced by the fabric machine model (overlap vs
    serialized dispatch of the identical stream) and ranked on a
    requests/s × total-area Pareto frontier; the ``validate_top``
    highest-throughput frontier fleets are re-run through the fabric
    event simulator, which must agree with the model within
    ``rps_tol_pct`` percent (pricing symmetry, fabric-scale).
    """
    budget = budget or dse_mod.ResourceBudget.from_machine(machine)
    names = [k for k, _ in mix.weights]
    missing = [n for n in names if n not in graphs]
    if missing:
        raise FabricError(f"mix {mix.name!r} names kernels with no graph: "
                          f"{', '.join(missing)}")
    stream = fabric_stream(mix)

    errors: List[Tuple[str, str]] = []
    menu: Dict[str, List[Tuple[dse_mod.DseCandidate, HwModule,
                               Optional[Kernel]]]] = {}
    for name in names:
        res = dse_mod.explore(graphs[name], machine=machine, budget=budget,
                              validate_top=0, **dse_kwargs)
        for pt, msg in res.errors:
            errors.append((name, f"{pt.spec}: {msg}"))
        picks = res.frontier[:per_kernel] or res.candidates[:1]
        if not picks:
            raise FabricError(f"kernel {name!r}: no design point survived "
                              f"DSE (all candidates failed)")
        built = []
        for cand in picks:
            kernel, hw = dse_mod.build_point(graphs[name], cand.point,
                                             machine)
            built.append((cand, hw, kernel))
        menu[name] = built

    candidates: List[FleetCandidate] = []
    options = [[(name, cand, hw, kernel, n)
                for (cand, hw, kernel) in menu[name]
                for n in range(1, max_copies + 1)]
               for name in names]
    for combo in itertools.product(*options):
        parts = [(cand, n) for _, cand, _, _, n in combo]
        area = sum(cand.area * n for cand, n in parts)
        feasible = _fleet_feasible(parts, budget)
        choices = tuple(FleetChoice(kernel=name, point=cand.point, copies=n)
                        for name, cand, _, _, n in combo)
        if not feasible:
            candidates.append(FleetCandidate(
                choices=choices, area=area, model_rps=0.0,
                serialized_rps=0.0, feasible=False))
            continue
        fabric = make_fleet(
            {name: (hw, kernel) for name, _, hw, kernel, _ in combo},
            copies={name: n for name, _, _, _, n in combo},
            crossbar=crossbar, policy=policy)
        rps = fabric.model(stream, machine, overlap=True).requests_per_s
        ser = fabric.model(stream, machine, overlap=False).requests_per_s
        candidates.append(FleetCandidate(
            choices=choices, area=area, model_rps=rps,
            serialized_rps=ser, feasible=True))

    feas = [c for c in candidates if c.feasible]
    for c in feas:
        if not any(fleet_dominates(o, c) for o in feas):
            c.on_frontier = True

    validations: List[FleetValidation] = []
    if validate_top:
        combo_of = {id(c): combo for c, combo in
                    zip(candidates, itertools.product(*options))}
        front = sorted((c for c in candidates if c.on_frontier),
                       key=lambda c: -c.model_rps)
        for cand in front[:validate_top]:
            combo = combo_of[id(cand)]
            fabric = make_fleet(
                {name: (hw, kernel) for name, _, hw, kernel, _ in combo},
                copies={name: n for name, _, _, _, n in combo},
                crossbar=crossbar, policy=policy)
            rep = fabric.simulate(stream, machine, overlap=True, seed=seed)
            v = FleetValidation(candidate=cand, sim_rps=rep.requests_per_s,
                                model_rps=cand.model_rps, ok=True,
                                max_abs_err=rep.max_abs_err)
            v.ok = v.deviation_pct <= rps_tol_pct
            validations.append(v)
    return FleetResult(mix=mix, machine=machine, budget=budget,
                       candidates=candidates, validations=validations,
                       errors=errors)
