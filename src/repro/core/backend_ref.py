"""Reference backend: interpret LoopIR with numpy (the simulation oracle).

Every other backend (jax codegen, pallas emission) is validated against
this interpreter, the same way the paper validates generated RTL against
the expected output matrices ("accurate output matrices from MLIR").
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from .loop_ir import (EwiseTile, FillTile, Kernel, Loop, MatmulTile, MemSpace,
                      ReduceTile, ScanTile, Stmt, TileRef, ZeroTile)

_EWISE_NP = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "maximum": np.maximum,
    "relu": lambda a: np.maximum(a, 0),
    "gelu": lambda a: 0.5 * a * (1.0 + np.tanh(np.sqrt(2.0 / np.pi)
                                               * (a + 0.044715 * a ** 3))),
    "exp": np.exp,
    "neg": lambda a: -a,
    "tanh": np.tanh,
    "sigmoid": lambda a: 1.0 / (1.0 + np.exp(-a)),
    "sqrt": np.sqrt,
    "rsqrt": lambda a: 1.0 / np.sqrt(a),
    "log1p": np.log1p,
    "abs": np.abs,
    "copy": lambda a: a,
}


def reduce_tile_np(kind: str, dst: np.ndarray, src: np.ndarray,
                   accumulate: bool) -> np.ndarray:
    """Last-axis keepdims reduction of ``src`` combined into ``dst``.

    Shared by the reference interpreter and the HwIR simulator so cosim
    is bitwise for carried reductions."""
    r = (np.max if kind == "max" else np.sum)(src, axis=-1, keepdims=True)
    if accumulate:
        r = np.maximum(dst, r) if kind == "max" else dst + r
    return r


def scan_tile_np(kind: str, srcs: List[np.ndarray],
                 carry: np.ndarray) -> np.ndarray:
    """Row-sequential scan over a (T, C) tile seeded by the (1, C) carry;
    returns the (T, C) output (its last row is the new carry).  Shared
    with the HwIR simulator for bitwise cosim."""
    x = srcs[-1]
    out = np.empty_like(x)
    c = carry[0]
    if kind == "linear":
        a = srcs[0]
        for t in range(x.shape[0]):
            c = a[t] * c + x[t]
            out[t] = c
    else:
        for t in range(x.shape[0]):
            c = c + x[t]
            out[t] = c
    return out


def _np_dtype(dtype: str):
    # bfloat16 arithmetic is carried in float32 in the oracle
    return {"float32": np.float32, "bfloat16": np.float32,
            "float16": np.float16, "int32": np.int32, "int8": np.int8}[dtype]


def run(kernel: Kernel, inputs: Sequence[np.ndarray]) -> List[np.ndarray]:
    """Execute the kernel; ``inputs`` bind the *read-only* HBM params in
    order.  Returns the output buffers' final contents."""
    kernel.verify()
    out_names = {b.name for b in kernel.outputs}
    mem: Dict[str, np.ndarray] = {}
    it = iter(inputs)
    for b in kernel.params:
        if b.name in out_names:
            mem[b.name] = np.zeros(b.shape, _np_dtype(b.type.dtype))
        else:
            try:
                a = next(it)
            except StopIteration:
                # HBM temporary introduced by lowering — allocate
                mem[b.name] = np.zeros(b.shape, _np_dtype(b.type.dtype))
                continue
            if tuple(a.shape) != b.shape:
                raise ValueError(f"param {b.name}: shape {a.shape} != {b.shape}")
            mem[b.name] = np.array(a, dtype=_np_dtype(b.type.dtype))
    for b in kernel.scratch:
        mem[b.name] = np.zeros(b.shape, _np_dtype(b.type.dtype))

    def read(ref: TileRef, env: Dict[str, int]) -> np.ndarray:
        return mem[ref.buffer.name][ref.slices(env)]

    def write(ref: TileRef, env: Dict[str, int], val: np.ndarray) -> None:
        mem[ref.buffer.name][ref.slices(env)] = val

    def go(stmts: List[Stmt], env: Dict[str, int]) -> None:
        for s in stmts:
            if isinstance(s, Loop):
                # all loop kinds share sequential *semantics*; kinds differ
                # only in schedule/cost.  (Verified: GRID/UNROLLED bodies in
                # our IR have no cross-iteration ordering hazards by
                # construction of the lowering.)
                for t in range(s.var.extent):
                    go(s.body, {**env, s.var.name: t})
            elif isinstance(s, ZeroTile):
                write(s.dst, env, 0.0)
            elif isinstance(s, FillTile):
                write(s.dst, env, s.value)
            elif isinstance(s, ReduceTile):
                write(s.dst, env,
                      reduce_tile_np(s.kind, read(s.dst, env),
                                     read(s.src, env), s.accumulate))
            elif isinstance(s, ScanTile):
                out = scan_tile_np(s.kind, [read(r, env) for r in s.srcs],
                                   read(s.carry, env))
                write(s.dst, env, out)
                write(s.carry, env, out[-1:])
            elif isinstance(s, MatmulTile):
                a = read(s.lhs, env).astype(np.float32)
                b = read(s.rhs, env).astype(np.float32)
                c = a @ b
                if s.accumulate:
                    c = read(s.dst, env) + c
                write(s.dst, env, c)
            elif isinstance(s, EwiseTile):
                if s.op == "ones":
                    write(s.dst, env, 1.0)
                    continue
                srcs = [read(r, env) for r in s.srcs]
                if s.op == "copy1":
                    sl = s.dst.slices(env)
                    shape = mem[s.dst.buffer.name][sl].shape
                    write(s.dst, env, srcs[0].reshape(shape))
                    continue
                if s.op == "cast":
                    val = srcs[0]
                else:
                    # broadcast rank-1 bias against rank-n tiles
                    if len(srcs) == 2 and srcs[1].ndim < srcs[0].ndim:
                        srcs[1] = srcs[1][(None,) * (srcs[0].ndim - srcs[1].ndim)]
                    val = _EWISE_NP[s.op](*srcs)
                write(s.dst, env, val)
            else:
                raise TypeError(f"unknown stmt {type(s)}")

    go(kernel.body, {})
    return [mem[b.name] for b in kernel.outputs]
