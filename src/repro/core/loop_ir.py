"""LoopIR — level-2 (hardware-shaped) dialect of the stagecc stack.

This plays the role Calyx plays in the paper's pipeline: explicit control
(loop nests with sequential / unrolled / grid-parallel semantics) over
explicit storage (buffers with a memory space: HBM, VMEM, VREG).

LoopIR is *tile-structured*: statements operate on rectangular tiles of
buffers addressed by affine functions of the loop variables.  This matches
the TPU execution model (the MXU consumes 128x128 tiles; the VPU consumes
8x128 vectors) the same way Calyx's cells match FPGA primitives.

The scheduling decisions the paper studies — nested (time-multiplexed)
versus inner-flattened (spatially unrolled) loops — are expressed here as
``LoopKind`` annotations, placed by passes in ``schedule.py`` and consumed
by the cycle/resource models and the three backends.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .tensor_ir import TensorType, dtype_bytes


class MemSpace(enum.Enum):
    HBM = "hbm"      # off-chip: kernel arguments live here
    VMEM = "vmem"    # on-chip scratch (the BRAM analogue)
    VREG = "vreg"    # register tile (the FF/LUT-register analogue)


class LoopKind(enum.Enum):
    SEQUENTIAL = "seq"        # time-multiplexed: one datapath, re-used each iter
    UNROLLED = "unrolled"     # spatially flattened: paper's "inner-flattened"
    GRID = "grid"             # mapped to the pallas grid (outer parallel dim)
    VECTOR = "vector"         # mapped to VPU lanes


@dataclasses.dataclass(frozen=True)
class Buffer:
    name: str
    type: TensorType
    space: MemSpace = MemSpace.HBM

    @property
    def shape(self):
        return self.type.shape

    def __str__(self):
        return f"{self.name}: {self.type} @{self.space.value}"


@dataclasses.dataclass(frozen=True)
class LoopVar:
    name: str
    extent: int

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class AffineExpr:
    """sum_i coeff[var_i] * var_i + const   (strides in *tile* units)."""

    coeffs: Tuple[Tuple[str, int], ...] = ()
    const: int = 0

    @staticmethod
    def of(var: Optional[LoopVar], stride: int = 1, const: int = 0) -> "AffineExpr":
        if var is None:
            return AffineExpr((), const)
        return AffineExpr(((var.name, stride),), const)

    def evaluate(self, env: Dict[str, int]) -> int:
        return self.const + sum(env[v] * s for v, s in self.coeffs)

    def __str__(self):
        from . import ir_text
        return ir_text.print_affine(self)


@dataclasses.dataclass(frozen=True)
class TileRef:
    """A rectangular window of ``buffer``: start = idx * tile, size = tile.

    ``index`` has one AffineExpr per buffer dimension, in units of the tile
    size for that dimension (block-index addressing, exactly like a pallas
    BlockSpec index_map).
    """

    buffer: Buffer
    index: Tuple[AffineExpr, ...]
    tile: Tuple[int, ...]

    def __post_init__(self):
        if len(self.index) != len(self.buffer.shape) or \
           len(self.tile) != len(self.buffer.shape):
            raise ValueError(f"rank mismatch in TileRef on {self.buffer.name}")
        for t, d in zip(self.tile, self.buffer.shape):
            if t <= 0 or t > d:
                raise ValueError(
                    f"tile {self.tile} does not fit buffer {self.buffer}")

    @property
    def tile_elems(self) -> int:
        return int(np.prod(self.tile))

    @property
    def tile_bytes(self) -> int:
        return self.tile_elems * dtype_bytes(self.buffer.type.dtype)

    def slices(self, env: Dict[str, int]) -> Tuple[slice, ...]:
        out = []
        for e, t, d in zip(self.index, self.tile, self.buffer.shape):
            start = e.evaluate(env) * t
            if start < 0 or start + t > d:
                raise IndexError(
                    f"tile [{start}:{start+t}] out of bounds on {self.buffer.name} "
                    f"(dim {d})")
            out.append(slice(start, start + t))
        return tuple(out)

    def __str__(self):
        from . import ir_text
        return ir_text.print_tileref(self)


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Stmt:
    def __str__(self):
        # canonical (parseable) statement text lives in ir_text
        from . import ir_text
        return "\n".join(ir_text.print_stmt(self))

    # ---- rewrite-core structural protocol (see core/rewrite.py) -----------

    def children(self) -> List["Stmt"]:
        return []

    def rebuild(self, children: Sequence["Stmt"]) -> "Stmt":
        assert not children
        return dataclasses.replace(self)

    def is_equivalent(self, other) -> bool:
        from . import ir_text
        return isinstance(other, Stmt) and \
            ir_text.print_stmt(self) == ir_text.print_stmt(other)


@dataclasses.dataclass
class ZeroTile(Stmt):
    """dst <- 0  (accumulator initialisation)."""

    dst: TileRef


@dataclasses.dataclass
class MatmulTile(Stmt):
    """dst (+)= lhs @ rhs on the MXU.  dst: (m,n), lhs: (m,k), rhs: (k,n)."""

    dst: TileRef
    lhs: TileRef
    rhs: TileRef
    accumulate: bool = True

    def __post_init__(self):
        m, k = self.lhs.tile[-2], self.lhs.tile[-1]
        k2, n = self.rhs.tile[-2], self.rhs.tile[-1]
        m2, n2 = self.dst.tile[-2], self.dst.tile[-1]
        if (m, n) != (m2, n2) or k != k2:
            raise ValueError(
                f"matmul tile mismatch: {self.lhs.tile} @ {self.rhs.tile} "
                f"-> {self.dst.tile}")

    @property
    def macs(self) -> int:
        m, k = self.lhs.tile[-2:]
        n = self.rhs.tile[-1]
        return m * n * k


@dataclasses.dataclass
class EwiseTile(Stmt):
    """dst = op(srcs...) elementwise on the VPU."""

    op: str  # add | mul | sub | maximum | div | relu | gelu | exp | neg
    # | tanh | sigmoid | sqrt | rsqrt | log1p | abs | copy | cast
    dst: TileRef
    srcs: List[TileRef]


@dataclasses.dataclass
class FillTile(Stmt):
    """dst <- value  (carry initialisation to a reduction identity)."""

    dst: TileRef
    value: float = 0.0


@dataclasses.dataclass
class ReduceTile(Stmt):
    """dst (⊕)= reduce(src, last axis, keepdims) on the VPU.

    ``kind`` is ``max`` or ``sum``; with ``accumulate`` the freshly
    reduced tile combines (same ⊕) into ``dst`` — the carried running
    max/sum of online softmax.  ``dst`` tile is ``src`` tile with its
    last dimension collapsed to 1.
    """

    kind: str
    dst: TileRef
    src: TileRef
    accumulate: bool = True

    def __post_init__(self):
        if self.kind not in ("max", "sum"):
            raise ValueError(f"reduce tile: bad kind {self.kind!r}")
        want = self.src.tile[:-1] + (1,)
        if self.dst.tile != want:
            raise ValueError(
                f"reduce tile mismatch: src {self.src.tile} reduces to "
                f"{want}, dst is {self.dst.tile}")


@dataclasses.dataclass
class ScanTile(Stmt):
    """dst <- scan of the tile's rows, threading ``carry`` across tiles.

    ``linear``: h_r = a_r ⊙ h_{r-1} + x_r with h_{-1} read from
    ``carry`` (srcs = [a, x]); ``cumsum`` is the a == 1 case
    (srcs = [x]).  After the tile, ``carry`` holds the last row — the
    inter-tile state of the chunked SSD scan.  ``carry``'s tile is one
    row of ``dst``'s.
    """

    kind: str
    dst: TileRef
    srcs: List[TileRef]
    carry: TileRef

    def __post_init__(self):
        if self.kind not in ("linear", "cumsum"):
            raise ValueError(f"scan tile: bad kind {self.kind!r}")
        if len(self.srcs) != (2 if self.kind == "linear" else 1):
            raise ValueError(
                f"scan<{self.kind}> tile takes "
                f"{2 if self.kind == 'linear' else 1} sources, "
                f"got {len(self.srcs)}")
        want = (1,) + self.dst.tile[1:]
        if self.carry.tile != want:
            raise ValueError(
                f"scan tile carry mismatch: dst {self.dst.tile} carries "
                f"{want}, carry is {self.carry.tile}")
        for s in self.srcs:
            if s.tile != self.dst.tile:
                raise ValueError(
                    f"scan tile mismatch: src {s.tile} vs dst "
                    f"{self.dst.tile}")


@dataclasses.dataclass
class Loop(Stmt):
    var: LoopVar
    kind: LoopKind
    body: List[Stmt]

    def children(self) -> List[Stmt]:
        return self.body

    def rebuild(self, children: Sequence[Stmt]) -> "Loop":
        return Loop(self.var, self.kind, list(children))


@dataclasses.dataclass
class Kernel:
    """A LoopIR function: buffers (params + scratch) and a statement list."""

    name: str
    params: List[Buffer]            # HBM-resident kernel arguments (in order)
    outputs: List[Buffer]           # subset of params that are written
    scratch: List[Buffer]           # VMEM/VREG temporaries
    body: List[Stmt]

    # ---- verification ------------------------------------------------------

    def verify(self) -> None:
        names = [b.name for b in self.params + self.scratch]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate buffer names in kernel {self.name}")
        known = set(names)
        for out in self.outputs:
            if out.name not in {b.name for b in self.params}:
                raise ValueError(f"output {out.name} is not a param")
        for b in self.scratch:
            if b.space == MemSpace.HBM:
                raise ValueError(f"scratch buffer {b.name} cannot live in HBM")

        def check(stmts: Sequence[Stmt], loop_env: Dict[str, int]):
            for s in stmts:
                if isinstance(s, Loop):
                    if s.var.name in loop_env:
                        raise ValueError(f"shadowed loop var {s.var.name}")
                    if s.var.extent <= 0:
                        raise ValueError(f"empty loop {s.var.name}")
                    check(s.body, {**loop_env, s.var.name: s.var.extent})
                else:
                    for ref in _stmt_refs(s):
                        if ref.buffer.name not in known:
                            raise ValueError(
                                f"unknown buffer {ref.buffer.name} in {s}")
                        for e in ref.index:
                            for v, _ in e.coeffs:
                                if v not in loop_env:
                                    raise ValueError(
                                        f"index uses unbound loop var {v} in {s}")
                        # bounds check at the loop extremes (affine, so the
                        # max index occurs at max of each var).
                        hi = {v: ext - 1 for v, ext in loop_env.items()}
                        ref.slices(hi)
                        ref.slices({v: 0 for v in loop_env})

        check(self.body, {})

    # ---- rewrite-core structural protocol (see core/rewrite.py) -----------

    def children(self) -> List[Stmt]:
        """The kernel's mutable top-level statement list."""
        return self.body

    def rebuild(self, children: Sequence[Stmt]) -> "Kernel":
        return Kernel(self.name, list(self.params), list(self.outputs),
                      list(self.scratch), list(children))

    def is_equivalent(self, other) -> bool:
        """Structural equivalence: identical canonical textual form."""
        from . import ir_text
        return isinstance(other, Kernel) and \
            ir_text.print_kernel(self) == ir_text.print_kernel(other)

    # ---- traversal helpers ---------------------------------------------------

    def walk(self):
        def go(stmts, depth, trail):
            for s in stmts:
                yield s, depth, tuple(trail)
                if isinstance(s, Loop):
                    yield from go(s.body, depth + 1, trail + [s])
        yield from go(self.body, 0, [])

    def loops(self) -> List[Loop]:
        return [s for s, _, _ in self.walk() if isinstance(s, Loop)]

    def find_loop(self, name: str) -> Loop:
        for l in self.loops():
            if l.var.name == name:
                return l
        raise KeyError(f"no loop named {name} in kernel {self.name}")

    def vmem_bytes(self) -> int:
        return sum(b.type.nbytes for b in self.scratch if b.space == MemSpace.VMEM)

    def __str__(self):
        # canonical textual form lives in ir_text (it round-trips through
        # ir_text.parse_kernel); delegate so str() and the parser can't drift.
        from . import ir_text
        return ir_text.print_kernel(self)


def _stmt_refs(s: Stmt) -> List[TileRef]:
    """All tile refs of a statement, written destination FIRST (passes
    and the DSE legality checks rely on refs[0] being the dst).  A
    ScanTile's carry is read AND written; it is listed last — callers
    that care about write sets must treat it as written too (see
    ``_stmt_written_refs``)."""
    if isinstance(s, ZeroTile):
        return [s.dst]
    if isinstance(s, FillTile):
        return [s.dst]
    if isinstance(s, MatmulTile):
        return [s.dst, s.lhs, s.rhs]
    if isinstance(s, EwiseTile):
        return [s.dst, *s.srcs]
    if isinstance(s, ReduceTile):
        return [s.dst, s.src]
    if isinstance(s, ScanTile):
        return [s.dst, *s.srcs, s.carry]
    if isinstance(s, Loop):
        return []
    raise TypeError(f"unknown stmt {type(s)}")


def _stmt_written_refs(s: Stmt) -> List[TileRef]:
    """Tile refs a statement writes (dst, plus a ScanTile's carry)."""
    if isinstance(s, ScanTile):
        return [s.dst, s.carry]
    refs = _stmt_refs(s)
    return refs[:1]
