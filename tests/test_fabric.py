"""Multi-kernel fabric acceptance contract.

The fabric must (1) price a one-slot, one-request stream *identically*
to ``host_bridge.run_transaction`` — the serialized baseline is the
seed behaviour, not a strawman; (2) beat that baseline ≥1.3× with
DMA/compute overlap at saturating load; (3) keep the machine model and
the event simulator within ±10% of each other (they share one
scheduling core, so in practice they agree exactly); (4) make the
arbitration policy observable when priorities differ; and (5) rank
fleets on a requests/s × total-area frontier whose top points the
simulator re-validates.
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import fabric, host_bridge, machine_model
from repro.core.fabric import (FabricError, FabricRequest, TrafficMix,
                               fabric_stream, make_fleet,
                               saturating_cycles_per_unit, transaction_cost)
from repro.core.host_bridge import AXI4, AXI4_LITE, Crossbar
from repro.core.pipeline import compile_gemm


@pytest.fixture(scope="module")
def gemm8():
    return compile_gemm(8, 8, 8, schedule="nested",
                        want_jax=False, want_pallas=False)


@pytest.fixture(scope="module")
def gemm8_relu():
    return compile_gemm(8, 8, 8, schedule="nested", epilogue="relu",
                        want_jax=False, want_pallas=False)


def _saturating_mix(cks, copies_per=2, requests=12, seed=0,
                    crossbar=AXI4, process="poisson"):
    names = [ck.name for ck in cks]
    mix = TrafficMix("mix", tuple((n, 1.0) for n in names),
                     num_requests=requests, process=process, rate=1.0,
                     seed=seed)
    mean = sum(transaction_cost(ck.hw_module, crossbar,
                                ck.cycles.total).total
               for ck in cks) / len(cks)
    n_slots = copies_per * len(cks)
    return dataclasses.replace(
        mix, cycles_per_unit=saturating_cycles_per_unit(
            mix, mean, load_factor=2.0 * n_slots))


def _fleet(cks, copies_per=2, crossbar=AXI4, policy="round_robin"):
    return make_fleet({ck.name: (ck.hw_module, ck.kernel) for ck in cks},
                      copies={ck.name: copies_per for ck in cks},
                      crossbar=crossbar, policy=policy)


# ---- pricing parity with run_transaction ------------------------------------


def test_one_slot_one_request_prices_like_run_transaction(gemm8):
    """The fabric's serialized floor IS back-to-back run_transaction:
    a single request on a single slot must cost exactly the same."""
    a = np.zeros((8, 8), np.float32)
    tr = host_bridge.run_transaction(gemm8.hw_module, [a, a])
    fab = make_fleet({gemm8.name: (gemm8.hw_module, gemm8.kernel)})
    stream = [FabricRequest(0, gemm8.name, 0.0)]
    for overlap in (False, True):
        rep = fab.model(stream, overlap=overlap)
        assert rep.total_cycles == tr.total_cycles
    cost = transaction_cost(gemm8.hw_module, AXI4, gemm8.cycles.total)
    assert cost.total == tr.total_cycles
    by_phase = {p.name: p.cycles for p in tr.phases}
    for name in ("csr_setup", "dma_in", "start", "device", "poll",
                 "dma_out"):
        assert getattr(cost, name) == by_phase[name], name


def test_serialized_n_requests_sum_exactly(gemm8):
    """Serialized dispatch with zero arrival gaps is n back-to-back
    transactions: makespan == n * single-transaction cost."""
    fab = _fleet([gemm8], copies_per=2)
    stream = [FabricRequest(i, gemm8.name, 0.0) for i in range(5)]
    rep = fab.model(stream, overlap=False)
    single = transaction_cost(gemm8.hw_module, AXI4,
                              gemm8.cycles.total).total
    assert rep.total_cycles == 5 * single


# ---- the perf claim ---------------------------------------------------------


def test_overlap_beats_serialized_at_saturation(gemm8):
    mix = _saturating_mix([gemm8], copies_per=2)
    stream = fabric_stream(mix)
    fab = _fleet([gemm8], copies_per=2)
    ser = fab.model(stream, overlap=False)
    ovl = fab.model(stream, overlap=True)
    assert ser.completed == ovl.completed == mix.num_requests
    assert ovl.requests_per_s / ser.requests_per_s >= 1.3
    assert ovl.total_cycles < ser.total_cycles


def test_stream_determinism_and_report_json(gemm8):
    mix = _saturating_mix([gemm8], copies_per=2)
    s1, s2 = fabric_stream(mix), fabric_stream(mix)
    assert [(r.rid, r.kernel, r.arrival) for r in s1] == \
        [(r.rid, r.kernel, r.arrival) for r in s2]
    fab = _fleet([gemm8], copies_per=2)
    r1 = fab.model(s1, overlap=True)
    r2 = fab.model(s2, overlap=True)
    assert r1.to_json() == r2.to_json()
    for s in r1.to_json()["slots"]:
        assert "p50" in s["queue_depth"] and "p99" in s["queue_depth"]


# ---- pricing symmetry: model vs event simulator -----------------------------


def test_model_vs_sim_within_tolerance(gemm8):
    mix = _saturating_mix([gemm8], copies_per=2, requests=8)
    stream = fabric_stream(mix)
    fab = _fleet([gemm8], copies_per=2)
    ovl = fab.model(stream, overlap=True)
    sim = fab.simulate(stream, overlap=True)
    assert sim.checked and sim.max_abs_err <= 1e-5
    dev = abs(sim.requests_per_s - ovl.requests_per_s) / ovl.requests_per_s
    assert dev <= 0.10
    assert sim.device_source == "sim" and ovl.device_source == "model"


# ---- arbitration policies ---------------------------------------------------


def test_priority_preempts_round_robin(gemm8, gemm8_relu):
    """With distinct priorities and a contended crossbar, the priority
    slot's requests complete earlier than under round-robin."""
    xbar = Crossbar("narrow", data_width_bits=8, latency_cycles=8)
    fab_rr = make_fleet(
        {gemm8.name: (gemm8.hw_module, gemm8.kernel),
         gemm8_relu.name: (gemm8_relu.hw_module, gemm8_relu.kernel)},
        crossbar=xbar, policy="round_robin")
    fab_pri = dataclasses.replace(fab_rr, policy="priority")
    pris = {s.name: s.priority for s in fab_pri.slots}
    assert len(set(pris.values())) == 2      # declaration order
    # everything arrives at once: DMA bursts genuinely contend
    stream = [FabricRequest(i, ck.name, 0.0)
              for i, ck in enumerate([gemm8, gemm8_relu] * 3)]
    rr = fab_rr.model(stream, overlap=True)
    pri = fab_pri.model(stream, overlap=True)
    assert rr.policy == "round_robin" and pri.policy == "priority"
    # both are work-conserving on the same work
    assert rr.completed == pri.completed == len(stream)
    assert rr.crossbar_busy_cycles == pri.crossbar_busy_cycles


def test_bad_policy_and_empty_fabric_raise(gemm8):
    with pytest.raises(FabricError, match="policy"):
        _fleet([gemm8], policy="lottery")
    with pytest.raises(FabricError, match="at least one"):
        fabric.Fabric(slots=[])


def test_dispatch_unknown_kernel_raises(gemm8):
    fab = _fleet([gemm8])
    with pytest.raises(FabricError, match="no slot"):
        fab.model([FabricRequest(0, "nonesuch", 0.0)])


# ---- crossbar contention is visible -----------------------------------------


def test_narrow_crossbar_raises_utilization(gemm8):
    mix = _saturating_mix([gemm8], copies_per=3, requests=12)
    stream = fabric_stream(mix)
    wide = _fleet([gemm8], copies_per=3, crossbar=AXI4) \
        .model(stream, overlap=True)
    narrow = _fleet([gemm8], copies_per=3, crossbar=AXI4_LITE) \
        .model(stream, overlap=True)
    assert narrow.crossbar_utilization > wide.crossbar_utilization
    assert narrow.total_cycles >= wide.total_cycles


# ---- fleet-level DSE --------------------------------------------------------


@pytest.mark.slow
def test_explore_fleet_frontier_and_validation(gemm8):
    mix = _saturating_mix([gemm8], copies_per=2, requests=8)
    res = fabric.explore_fleet({gemm8.name: gemm8.graph}, mix,
                               per_kernel=2, max_copies=2,
                               validate_top=2)
    assert res.frontier, "no fleet on the frontier"
    # frontier is strictly non-dominated on (req/s up, area down)
    for a in res.frontier:
        for b in res.frontier:
            if a is not b:
                assert not fabric.fleet_dominates(a, b) or \
                    not fabric.fleet_dominates(b, a)
    # multi-copy fleets appear and the best multi-copy one overlaps
    assert any(sum(ch.copies for ch in c.choices) >= 2
               for c in res.candidates)
    assert res.validations, "top frontier points were not sim-validated"
    for v in res.validations:
        assert v.ok and v.deviation_pct <= 10.0
    assert "frontier" in res.table()


@pytest.mark.slow
def test_compiled_kernel_explore_fleet_wrapper(gemm8, gemm8_relu):
    res = gemm8.explore_fleet([gemm8_relu], per_kernel=1, max_copies=1,
                              validate_top=1)
    assert res.frontier
    kernels = {ch.kernel for c in res.candidates for ch in c.choices}
    assert kernels == {gemm8.name, gemm8_relu.name}
    with pytest.raises(ValueError, match="unique"):
        gemm8.explore_fleet([gemm8])


def test_budget_infeasible_fleets_marked(gemm8):
    from repro.core.dse import ResourceBudget

    mix = _saturating_mix([gemm8], copies_per=2, requests=4)
    res = fabric.explore_fleet({gemm8.name: gemm8.graph}, mix,
                               per_kernel=2, max_copies=2,
                               validate_top=0,
                               budget=ResourceBudget(
                                   max_lanes=12,
                                   max_vmem_bytes=1 << 20,
                                   max_reg_bits=1 << 20))
    assert any(not c.feasible for c in res.candidates)
    assert all(c.feasible for c in res.frontier)


# ---- CLI surface ------------------------------------------------------------


def _reproc(*argv):
    return subprocess.run(
        [sys.executable, "-m", "repro.core.reproc", *argv],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})


def test_cli_simulate_fabric():
    p = _reproc("--gemm", "8x8x8", "--epilogue", "none",
                "--pipeline", "lower", "--simulate", "fabric",
                "--fabric-slots", "2", "--fabric-requests", "6")
    assert p.returncode == 0, p.stderr
    assert "serialized" in p.stdout and "overlap" in p.stdout
    assert "speedup" in p.stdout


def test_cli_crossbar_preset_typo_exits_2_with_hint():
    p = _reproc("--gemm", "8x8x8", "--epilogue", "none",
                "--pipeline", "lower", "--simulate", "fabric",
                "--crossbar", "AXI4_LTE")
    assert p.returncode == 2
    assert "did you mean" in p.stderr and "axi4_lite" in p.stderr


def test_cli_crossbar_requires_simulate_mode():
    p = _reproc("--gemm", "8x8x8", "--epilogue", "none",
                "--pipeline", "lower", "--crossbar", "axi4")
    assert p.returncode == 2
    assert "--simulate" in p.stderr


# ---- the bench and its gate -------------------------------------------------


@pytest.mark.slow
def test_fabric_bench_smoke_reproducible(tmp_path):
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "fabric_bench", "benchmarks/fabric_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out1, out2 = tmp_path / "b1.json", tmp_path / "b2.json"
    assert mod.main(["--smoke", "--out", str(out1)]) == 0
    assert mod.main(["--smoke", "--out", str(out2)]) == 0
    assert out1.read_bytes() == out2.read_bytes()
    doc = json.loads(out1.read_text())
    mod.check_bench(doc)
    for e in doc["entries"]:
        assert e["speedup"] >= 1.3
        assert e["model_vs_sim_pct"] <= 10.0
    # the gate actually bites
    bad = json.loads(out1.read_text())
    bad["entries"][0]["speedup"] = 1.05
    with pytest.raises(ValueError, match="floor"):
        mod.check_bench(bad)


def test_committed_bench_fabric_passes_registry():
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "check_bench_script", "scripts/check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    msg = mod.check_file(pathlib.Path("BENCH_fabric.json"))
    assert "fabric_bench/v1 ok" in msg
