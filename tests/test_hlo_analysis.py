"""HLO analyzer: trip-count-correct flops/bytes/collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (analyze_hlo_module, collective_bytes,
                                       roofline_terms)


def test_plain_dot_matches_xla():
    f = jax.jit(lambda a, b: a @ b)
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = f.lower(s, s).compile()
    st = analyze_hlo_module(c.as_text())
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    np.testing.assert_allclose(st.flops, ca["flops"], rtol=1e-6)


def test_scan_trip_count_multiplied():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        c, _ = jax.lax.scan(body, x, w)
        return c
    xs = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((13, 32, 32), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    st = analyze_hlo_module(c.as_text())
    np.testing.assert_allclose(st.flops, 13 * 2 * 32 ** 3, rtol=1e-6)
    assert 13 in st.while_trips.values()


def test_nested_scan_trips():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, w)
        return c
    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 16, 16), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    st = analyze_hlo_module(c.as_text())
    np.testing.assert_allclose(st.flops, 7 * 3 * 2 * 16 ** 3, rtol=1e-6)


def test_collective_regex_on_synthetic_hlo():
    text = """
  %ar = f32[1024,16]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[2048]{0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
"""
    st = collective_bytes(text)
    assert st.counts == {"all-reduce": 1, "all-gather": 1,
                         "reduce-scatter": 1}
    # all-reduce: 2*(3/4)*1024*16*4
    np.testing.assert_allclose(st.bytes_by_kind["all-reduce"],
                               2 * 0.75 * 1024 * 16 * 4)
    # all-gather over groups of 8: (7/8)*2048*2
    np.testing.assert_allclose(st.bytes_by_kind["all-gather"],
                               (7 / 8) * 2048 * 2)
    # reduce-scatter groups of 2: (2-1)*128*4
    np.testing.assert_allclose(st.bytes_by_kind["reduce-scatter"], 128 * 4)


def test_roofline_bottleneck_selection():
    r = roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0,
                       model_flops_total=197e12, n_devices=1)
    assert r.bottleneck == "compute"
    np.testing.assert_allclose(r.compute_s, 1.0)
    np.testing.assert_allclose(r.useful_ratio, 1.0)
    r2 = roofline_terms(flops=1, hbm_bytes=819e9 * 2, coll_bytes=0)
    assert r2.bottleneck == "memory"
    np.testing.assert_allclose(r2.memory_s, 2.0)
    r3 = roofline_terms(flops=1, hbm_bytes=1, coll_bytes=50e9 * 3)
    assert r3.bottleneck == "collective"
    np.testing.assert_allclose(r3.collective_s, 3.0)


def test_sharded_module_collectives_detected():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >=2 devices")
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((n,), ("data",))
    f = jax.jit(lambda a: a.sum(),
                in_shardings=NamedSharding(mesh, P("data")),
                out_shardings=NamedSharding(mesh, P()))
    c = f.lower(jax.ShapeDtypeStruct((n * 8,), jnp.float32)).compile()
    st = analyze_hlo_module(c.as_text())
    assert sum(st.collectives.counts.values()) >= 1


def test_cache_threading_scan_not_overcounted():
    """Decode pattern: per-layer cache DUS inside scan must charge the
    update region, not the full stacked cache, per iteration."""
    import os
    L, B, S, D = 8, 2, 1024, 64

    def f(x, cache):
        def body(c, layer_cache):
            new = jax.lax.dynamic_update_slice(layer_cache, c[:, None, :],
                                               (0, 5, 0))
            return jnp.tanh(c), new
        return jax.lax.scan(body, x, cache)

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    cs = jax.ShapeDtypeStruct((L, B, S, D), jnp.float32)
    comp = jax.jit(f, donate_argnums=(1,)).lower(xs, cs).compile()
    st = analyze_hlo_module(comp.as_text())
    full_cache = L * B * S * D * 4
    # L x full-cache-per-iteration (the bug) would be ~2x this bound;
    # one-time donation copies/initialisation stay well under it.
    assert st.bytes < 7.5 * full_cache, st.bytes


# --------------------------------------------------------------------------
# synthetic-HLO regressions: the parser paths that real jax traces only
# exercise incidentally (trip-count recovery, iota replica_groups inside
# a multiplied body, fusion multiplicity vs fused-internal bytes)
# --------------------------------------------------------------------------

_SYNTH_WHILE = """
HloModule synth_while

%cond (p: (f32[4,4], s32[])) -> pred[] {
  %p = (f32[4,4], s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=1
  %lim = s32[] constant(11)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

%body (q: (f32[4,4], s32[])) -> (f32[4,4], s32[]) {
  %q = (f32[4,4], s32[]) parameter(0)
  %x = f32[4,4]{1,0} get-tuple-element(%q), index=0
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %j = s32[] get-tuple-element(%q), index=1
  %one = s32[] constant(1)
  %n = s32[] add(%j, %one)
  ROOT %t = (f32[4,4], s32[]) tuple(%d, %n)
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (f32[4,4], s32[]) tuple(%a, %z)
  %w = (f32[4,4], s32[]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[4,4]{1,0} get-tuple-element(%w), index=0
}
"""


def test_synthetic_while_trip_recovery():
    """Trip count comes from the loop-condition constant (11), NOT the
    body's own constant(1) — and multiplies the body's dot flops."""
    st = analyze_hlo_module(_SYNTH_WHILE)
    assert st.while_trips == {"body": 11}
    np.testing.assert_allclose(st.flops, 11 * 2 * 4 * 4 * 4)


_SYNTH_COLL_WHILE = """
HloModule synth_coll

%ccond (p: (f32[2048], s32[])) -> pred[] {
  %p = (f32[2048], s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=1
  %lim = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

%cbody (q: (f32[2048], s32[])) -> (f32[2048], s32[]) {
  %q = (f32[2048], s32[]) parameter(0)
  %x = f32[2048]{0} get-tuple-element(%q), index=0
  %ar = f32[2048]{0} all-reduce(%x), replica_groups=[4,8]<=[32], to_apply=%sum
  %j = s32[] get-tuple-element(%q), index=1
  %one = s32[] constant(1)
  %n = s32[] add(%j, %one)
  ROOT %t = (f32[2048], s32[]) tuple(%ar, %n)
}

ENTRY %cmain (a: f32[2048]) -> f32[2048] {
  %a = f32[2048]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (f32[2048], s32[]) tuple(%a, %z)
  %w = (f32[2048], s32[]) while(%t0), condition=%ccond, body=%cbody
  ROOT %r = f32[2048]{0} get-tuple-element(%w), index=0
}
"""


def test_synthetic_iota_replica_groups_in_while():
    """Iota-form replica_groups=[4,8]<=[32] means groups of EIGHT (the
    second factor), and a collective in a trip-3 body is charged 3x."""
    st = analyze_hlo_module(_SYNTH_COLL_WHILE)
    assert st.collectives.counts == {"all-reduce": 3}
    per_call = 2.0 * (8 - 1) / 8 * 2048 * 4       # ring all-reduce, G=8
    np.testing.assert_allclose(st.collectives.bytes_by_kind["all-reduce"],
                               3 * per_call)


_SYNTH_FUSION_WHILE = """
HloModule synth_fusion

%fcomp (fp: f32[4,4]) -> f32[4,4] {
  %fp = f32[4,4]{1,0} parameter(0)
  ROOT %fd = f32[4,4]{1,0} dot(%fp, %fp), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%fcond (p: (f32[4,4], s32[])) -> pred[] {
  %p = (f32[4,4], s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=1
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

%fbody (q: (f32[4,4], s32[])) -> (f32[4,4], s32[]) {
  %q = (f32[4,4], s32[]) parameter(0)
  %x = f32[4,4]{1,0} get-tuple-element(%q), index=0
  %f = f32[4,4]{1,0} fusion(%x), kind=kLoop, calls=%fcomp
  %j = s32[] get-tuple-element(%q), index=1
  %one = s32[] constant(1)
  %n = s32[] add(%j, %one)
  ROOT %t = (f32[4,4], s32[]) tuple(%f, %n)
}

ENTRY %fmain (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (f32[4,4], s32[]) tuple(%a, %z)
  %w = (f32[4,4], s32[]) while(%t0), condition=%fcond, body=%fbody
  ROOT %r = f32[4,4]{1,0} get-tuple-element(%w), index=0
}
"""


def test_synthetic_fusion_multiplicity_and_fused_bytes():
    """A dot reached through fusion -> calls= inside a trip-5 while is
    charged 5x flops, while the fusion INTERNAL ops contribute no HBM
    bytes (register traffic) — only the fusion's own result + params."""
    st = analyze_hlo_module(_SYNTH_FUSION_WHILE)
    assert st.while_trips == {"fbody": 5}
    np.testing.assert_allclose(st.flops, 5 * 2 * 4 * 4 * 4)
    # bytes: fusion charges result(64) + param(64) per call = 128/call;
    # the s32 add is 12/call; the cond compare (1+4+4)=9 runs trips+1
    # times.  If fused internals leaked in, the dot would add >= 192/call.
    expected = 5 * (128 + 12) + 6 * 9
    np.testing.assert_allclose(st.bytes, expected)
