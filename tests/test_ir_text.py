"""Textual IR round-trip: print -> parse -> print must be a fixpoint at
both IR levels, and parsing must preserve semantics (the mlir-opt
property the PassManager and reproc driver build on)."""

import numpy as np
import pytest

from repro.core import SCHEDULES, backend_ref, compile_gemm
from repro.core import ir_text
from repro.core.frontend import spec, trace
from repro.core.loop_ir import Kernel
from repro.core.tensor_ir import Graph, TensorType
import repro.core.frontend as fe


def _gemm_graph(m=8, n=8, k=8, epilogue=True):
    if epilogue:
        def f(a, b, c):
            return fe.relu(fe.matmul(a, b) + c)
        return trace(f, [spec((m, k)), spec((k, n)), spec((n,))])
    def f(a, b):
        return fe.matmul(a, b)
    return trace(f, [spec((m, k)), spec((k, n))])


# ---- fixpoint property -----------------------------------------------------


@pytest.mark.parametrize("epilogue", [False, True])
def test_graph_roundtrip_fixpoint(epilogue):
    g = _gemm_graph(epilogue=epilogue)
    text = ir_text.print_graph(g)
    g2 = ir_text.parse_graph(text)
    assert ir_text.print_graph(g2) == text
    # and str() is the same canonical form
    assert str(g) == text


@pytest.mark.parametrize("sched", SCHEDULES)
@pytest.mark.parametrize("epilogue", ["none", "bias_relu"])
def test_kernel_roundtrip_fixpoint_all_schedules(sched, epilogue):
    ck = compile_gemm(16, 16, 16, schedule=sched, epilogue=epilogue,
                      want_jax=False, want_pallas=False)
    text = ir_text.print_kernel(ck.kernel)
    k2 = ir_text.parse_kernel(text)
    assert ir_text.print_kernel(k2) == text
    assert str(ck.kernel) == text


@pytest.mark.parametrize("sched", SCHEDULES)
def test_parsed_kernel_preserves_semantics(sched):
    ck = compile_gemm(8, 8, 8, schedule=sched, epilogue="bias_relu",
                      want_jax=False, want_pallas=False)
    k2 = ir_text.parse_kernel(ir_text.print_kernel(ck.kernel))
    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    c = rng.standard_normal((8,)).astype(np.float32)
    want = np.asarray(ck.run_ref(a, b, c)[-1])
    got = np.asarray(backend_ref.run(k2, [a, b, c])[-1])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attr_ops_roundtrip():
    g = Graph("attrs")
    a = g.add_input("a", TensorType((4, 8)))
    t = g.emit("transpose", [a], perm=(1, 0))
    c = g.emit("cast", [t], dtype="bfloat16")
    g.set_outputs(c)
    text = ir_text.print_graph(g)
    assert "{perm=(1, 0)}" in text
    assert "{dtype='bfloat16'}" in text
    assert ir_text.print_graph(ir_text.parse_graph(text)) == text


def test_split_pass_affine_exprs_roundtrip():
    """split introduces multi-term affine indices (stride*var+var)."""
    from repro.core import PassManager
    k = PassManager.parse("lower{tile_m=2,tile_n=2,tile_k=2},split{var=i1,factor=2}") \
        .run(_gemm_graph(epilogue=False)).artifact
    text = ir_text.print_kernel(k)
    assert "2*i1_o+i1_i" in text
    assert ir_text.print_kernel(ir_text.parse_kernel(text)) == text


def test_rank0_scalar_kernel_roundtrip():
    """Rank-0 buffers print as 'buf[ : ]' (empty index/tile) and must
    still round-trip."""
    from repro.core import lower_graph
    g = trace(lambda s: fe.relu(s), [spec(())])
    k = lower_graph(g)
    text = ir_text.print_kernel(k)
    assert "[ : ]" in text
    assert ir_text.print_kernel(ir_text.parse_kernel(text)) == text


def test_parse_rejects_ssa_redefinition():
    text = ("stagecc.func @f(%a: tensor<4x4xfloat32>) {\n"
            "  %x = stagecc.relu(%a) : tensor<4x4xfloat32>\n"
            "  %x = stagecc.neg(%a) : tensor<4x4xfloat32>\n"
            "  return %x\n}")
    with pytest.raises(ir_text.IRParseError, match="redefinition"):
        ir_text.parse_graph(text)


def test_parse_ir_dispatch():
    g = _gemm_graph(epilogue=False)
    assert isinstance(ir_text.parse_ir(str(g)), Graph)
    ck = compile_gemm(8, 8, 8, want_jax=False, want_pallas=False)
    assert isinstance(ir_text.parse_ir(str(ck.kernel)), Kernel)
    with pytest.raises(ValueError):
        ir_text.parse_ir("not an ir module")
    with pytest.raises(ValueError):
        ir_text.parse_ir("")


# ---- parser diagnostics ----------------------------------------------------


def test_parse_rejects_bad_header():
    with pytest.raises(ir_text.IRParseError):
        ir_text.parse_graph("stagecc.func gemm() {\n return \n}")


def test_parse_rejects_undefined_value():
    text = ("stagecc.func @f(%a: tensor<4x4xfloat32>) {\n"
            "  %r = stagecc.relu(%missing) : tensor<4x4xfloat32>\n"
            "  return %r\n}")
    with pytest.raises(ir_text.IRParseError, match="undefined"):
        ir_text.parse_graph(text)


def test_parse_rejects_type_mismatch():
    text = ("stagecc.func @f(%a: tensor<4x4xfloat32>) {\n"
            "  %r = stagecc.relu(%a) : tensor<2x2xfloat32>\n"
            "  return %r\n}")
    with pytest.raises(ir_text.IRParseError, match="declared type"):
        ir_text.parse_graph(text)


def test_parse_rejects_unknown_op():
    text = ("stagecc.func @f(%a: tensor<4x4xfloat32>) {\n"
            "  %r = stagecc.frobnicate(%a) : tensor<4x4xfloat32>\n"
            "  return %r\n}")
    with pytest.raises(ir_text.IRParseError, match="frobnicate"):
        ir_text.parse_graph(text)


def test_parse_rejects_unknown_buffer_and_unclosed_block():
    ck = compile_gemm(8, 8, 8, want_jax=False, want_pallas=False)
    text = str(ck.kernel)
    with pytest.raises(ir_text.IRParseError, match="unknown buffer"):
        ir_text.parse_kernel(text.replace("arg0[", "ghost["))
    with pytest.raises(ir_text.IRParseError, match="unclosed"):
        ir_text.parse_kernel(text.rstrip().rstrip("}"))


def test_parse_rejects_malformed_headers_naming_the_line():
    """Malformed module headers at every level: the diagnostic must carry
    the 1-based line number and echo the offending line."""
    cases = [
        (ir_text.parse_graph, "\nstagecc.func gemm() {\n return\n}",
         "stagecc.func gemm"),                      # missing @
        (ir_text.parse_kernel,
         "\n\nstagecc.kernel @k(a: tensor<4xfloat32> @hbm) {\n}",
         "stagecc.kernel @k"),                      # missing -> (outs)
        (ir_text.parse_hw_module, "stagecc.hw gemm {\n}", "stagecc.hw gemm"),
    ]
    for parse, text, needle in cases:
        lineno = next(i + 1 for i, ln in enumerate(text.splitlines())
                      if ln.strip())
        with pytest.raises(ir_text.IRParseError) as ei:
            parse(text)
        assert f"line {lineno}:" in str(ei.value)
        assert needle in str(ei.value)              # echoes the bad line
        assert ei.value.lineno == lineno


def test_parse_ir_rejects_unknown_level():
    with pytest.raises(ValueError, match="unrecognised module header"):
        ir_text.parse_ir("stagecc.netlist @gemm {\n}")


def test_hw_parse_truncated_control_tree_names_last_line():
    ck = compile_gemm(4, 4, 4, epilogue="none",
                      want_jax=False, want_pallas=False)
    text = str(ck.hw_module)
    # drop the closing braces of the ctrl tree: the parser must point at
    # the last line it saw, not raise a bare IndexError
    truncated = "\n".join(ln for ln in text.splitlines()
                          if ln.strip() != "}")
    with pytest.raises(ir_text.IRParseError, match="unclosed") as ei:
        ir_text.parse_hw_module(truncated)
    assert ei.value.lineno == len(truncated.splitlines())


def test_hw_parse_bad_operand_names_line():
    ck = compile_gemm(4, 4, 4, epilogue="none",
                      want_jax=False, want_pallas=False)
    text = str(ck.hw_module)
    bad = text.replace("read arg0[", "read arg0{", 1)
    lineno = next(i + 1 for i, ln in enumerate(bad.splitlines())
                  if "read arg0{" in ln)
    with pytest.raises(ir_text.IRParseError, match="bad operand") as ei:
        ir_text.parse_hw_module(bad)
    assert f"line {lineno}:" in str(ei.value)


def test_hw_parse_operand_index_roundtrips_semantics():
    """The hw operand's affine address generator survives the text form
    (split introduces multi-term indices even at the hardware level)."""
    from repro.core import PassManager
    hw = PassManager.parse(
        "lower{tile_m=2,tile_n=2,tile_k=2},split{var=i1,factor=2},"
        "lower-to-hw").run(_gemm_graph(epilogue=False)).artifact
    text = ir_text.print_hw_module(hw)
    assert "2*i1_o+i1_i" in text
    assert ir_text.print_hw_module(ir_text.parse_hw_module(text)) == text


def test_parse_type():
    assert ir_text.parse_type("tensor<64x32xfloat32>") == TensorType((64, 32))
    assert ir_text.parse_type("tensor<8xbfloat16>") == TensorType((8,), "bfloat16")
    assert ir_text.parse_type("tensor<float32>") == TensorType(())
    with pytest.raises(ValueError):
        ir_text.parse_type("tensor<axbxfloat32>")
    with pytest.raises(ValueError):
        ir_text.parse_type("vector<4xfloat32>")


def test_ir_size_metric():
    g = _gemm_graph()
    assert ir_text.ir_size(g) == len(g.ops) == 3
    ck = compile_gemm(8, 8, 8, want_jax=False, want_pallas=False)
    assert ir_text.ir_size(ck.kernel) == sum(1 for _ in ck.kernel.walk())
    assert ir_text.ir_size(lambda: None) is None
