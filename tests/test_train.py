"""Training substrate: optimizer math, schedules, grad accumulation,
loss-decreases integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import Model, RunConfig
from repro.optim import schedule as sched
from repro.optim.optimizer import adamw, clip_by_global_norm, global_norm
from repro.train.step import TrainConfig, init_state, make_train_step


def test_adamw_matches_reference_math():
    """One AdamW step against a hand-computed update."""
    lr = lambda s: 0.1
    opt = adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                clip_norm=None)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    state = opt.init(p)
    new_p, new_state, _ = opt.update(g, state, p)
    m_hat = 0.1 * 0.5 / (1 - 0.9)        # (1-b1)*g / bias-corr
    v_hat = 0.001 * 0.25 / (1 - 0.999)
    want = 1.0 - 0.1 * m_hat / (np.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"])[0], want, rtol=1e-5)


def test_adamw_weight_decay_direction():
    opt = adamw(lambda s: 0.1, weight_decay=0.5, clip_norm=None)
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}
    state = opt.init(p)
    new_p, _, _ = opt.update(g, state, p)
    assert float(new_p["w"][0]) < 10.0


def test_factored_second_moment_shapes():
    opt = adamw(lambda s: 1e-3, factored=True)
    p = {"w": jnp.zeros((16, 32)), "b": jnp.zeros((8,))}
    st = opt.init(p)
    assert st.v["w"]["row"].shape == (16,)
    assert st.v["w"]["col"].shape == (32,)
    assert st.v["b"]["full"].shape == (8,)
    g = {"w": jnp.ones((16, 32)), "b": jnp.ones((8,))}
    new_p, st2, _ = opt.update(g, st, p)
    assert bool(jnp.isfinite(new_p["w"]).all())


def test_grad_clipping():
    tree = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_wsd_schedule_shape():
    fn = sched.make("wsd", peak=1.0, warmup_steps=10, total_steps=100,
                    decay_frac=0.2)
    assert float(fn(0)) < 0.2                      # warming up
    np.testing.assert_allclose(float(fn(10)), 1.0, rtol=1e-5)   # plateau
    np.testing.assert_allclose(float(fn(79)), 1.0, rtol=1e-5)   # still stable
    assert float(fn(95)) < 0.5                     # decaying
    assert float(fn(100)) <= 0.02                  # decayed


def test_cosine_schedule_shape():
    fn = sched.make("cosine", peak=1.0, warmup_steps=10, total_steps=100)
    assert float(fn(5)) < 1.0
    np.testing.assert_allclose(float(fn(10)), 1.0, rtol=1e-4)
    assert float(fn(99)) < 0.2


def test_grad_accum_equals_full_batch():
    """K microbatches must produce the same update as the full batch."""
    cfg = reduced(get_config("minicpm_2b"))
    model = Model(cfg, RunConfig(max_seq=32))
    opt = adamw(lambda s: 1e-2, clip_norm=None, weight_decay=0.0)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (4, 17)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:]),
             "mask": jnp.ones((4, 16), jnp.float32)}

    s1 = init_state(model, opt, jax.random.PRNGKey(0))
    s2 = init_state(model, opt, jax.random.PRNGKey(0))
    step_full = jax.jit(make_train_step(model, opt, TrainConfig(1)))
    step_acc = jax.jit(make_train_step(model, opt, TrainConfig(2)))
    s1, m1 = step_full(s1, batch)
    s2, m2 = step_acc(s2, batch)
    # each microbatch has the same token count -> mean-of-means == mean
    for l1, l2 in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_loss_decreases_integration():
    """Tiny LM on structured synthetic data: loss must drop materially."""
    cfg = reduced(get_config("minicpm_2b"), layers=2, d_model=64, vocab=128)
    model = Model(cfg, RunConfig(max_seq=64))
    opt = adamw(sched.make("cosine", peak=5e-3, warmup_steps=5,
                           total_steps=60), weight_decay=0.0)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=48,
                               global_batch=8, seed=3))
    step = jax.jit(make_train_step(model, opt, TrainConfig()),
                   donate_argnums=(0,))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    losses = []
    for i in range(60):
        state, metrics = step(state, pipe.jax_batch(i))
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.25, f"loss did not decrease: {first} -> {last}"
