"""Checkpointing: atomic commit, GC, resume, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck
from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import Model, RunConfig
from repro.optim.optimizer import adamw
from repro.train.step import TrainConfig, init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                   jnp.float32)},
            "b": jnp.asarray(rng.integers(0, 10, (3,)), jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, extra={"note": "x"})
    got, extra = ck.restore(str(tmp_path), target=t)
    np.testing.assert_array_equal(np.asarray(got["a"]["w"]),
                                  np.asarray(t["a"]["w"]))
    assert extra["step"] == 7 and extra["note"] == "x"


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep=3)
    assert ck.latest_step(str(tmp_path)) == 5
    assert sorted(ck.all_steps(str(tmp_path))) == [3, 4, 5]


def test_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 1, _tree())
    bad = {"a": {"w": jnp.zeros((5, 8))}, "b": jnp.zeros((3,), jnp.int32)}
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), target=bad)


def test_tmp_dir_never_visible(tmp_path):
    ck.save(str(tmp_path), 1, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_resume_continues_identically(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical."""
    cfg = reduced(get_config("minicpm_2b"), layers=2, d_model=32, vocab=64)
    model = Model(cfg, RunConfig(max_seq=32))
    opt = adamw(lambda s: 1e-3, weight_decay=0.0)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4, seed=1))
    step = jax.jit(make_train_step(model, opt, TrainConfig()))

    s_straight = init_state(model, opt, jax.random.PRNGKey(0))
    for i in range(6):
        s_straight, _ = step(s_straight, pipe.jax_batch(i))

    s_a = init_state(model, opt, jax.random.PRNGKey(0))
    for i in range(3):
        s_a, _ = step(s_a, pipe.jax_batch(i))
    ck.save(str(tmp_path), 3, s_a)
    s_b, extra = ck.restore(str(tmp_path), target=s_a)
    for i in range(extra["step"], 6):
        s_b, _ = step(s_b, pipe.jax_batch(i))

    for l1, l2 in zip(jax.tree.leaves(s_straight.params),
                      jax.tree.leaves(s_b.params)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-6, atol=1e-7)


def test_trainer_auto_resume(tmp_path):
    cfg = reduced(get_config("minicpm_2b"), layers=2, d_model=32, vocab=64)
    model = Model(cfg, RunConfig(max_seq=32))
    opt = adamw(lambda s: 1e-3)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4, seed=1))
    step = jax.jit(make_train_step(model, opt, TrainConfig()))
    logs = []
    tc = TrainerConfig(total_steps=4, checkpoint_every=2,
                       checkpoint_dir=str(tmp_path), log_every=100)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    Trainer(tc, step, pipe, log_fn=logs.append).run(state)
    assert ck.latest_step(str(tmp_path)) == 4
    # a second run resumes at 4 and does nothing more
    logs2 = []
    t2 = Trainer(tc, step, pipe, log_fn=logs2.append)
    t2.run(init_state(model, opt, jax.random.PRNGKey(0)))
    assert any("resumed from step 4" in l for l in logs2)


def test_elastic_restore_nested_dict(tmp_path):
    """Restore without a target rebuilds the nested structure — the
    elastic path (new mesh shardings applied on device_put)."""
    t = _tree()
    ck.save(str(tmp_path), 2, t)
    got, _ = ck.restore(str(tmp_path))
    assert set(got) == {"a", "b"}
    np.testing.assert_array_equal(got["a"]["w"], np.asarray(t["a"]["w"]))
