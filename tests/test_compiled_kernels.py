"""The serving kernels compiled through the stack — differential matrix.

Flash attention, decode attention, and the Mamba SSD scan expressed as
TensorIR (``frontend.flash_attention_graph`` & friends), lowered through
the PassManager pipeline under every legal schedule, and executed through
``backend_ref`` / ``backend_jax`` / the general pallas emitter.  Every
cell of the matrix is checked three ways:

  * against a closed-form numpy oracle (softmax attention / the scan
    recurrence written directly), and
  * against the hand-written pallas kernels in ``repro/kernels/`` on the
    corresponding input slice, within 1e-4 in fp32.

Also here: the property-based reduce/scan printer/parser/verifier tests
(print→parse→print fixpoint, line-numbered diagnostics on malformed
carry shapes, canonicalize idempotence) and the DSE acceptance check
(non-empty Pareto frontier on flash and ssd whose top points cosim).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.frontend as fe
from repro.core import backend_ref, ir_text, pipeline, schedule
from repro.core.ir_text import IRParseError
from repro.core.lowering import LoweringOptions, lower_graph

NEG = -1e30
TOL = dict(rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# oracles + graph-input builders
# --------------------------------------------------------------------------


def _attn_mask(sq, sk, causal=True, window=None, valid=None):
    """The additive mask input (0 attendable / -1e30 masked) matching the
    hand kernels' positioning: query t sits at cache position t+(sk-sq)."""
    qpos = np.arange(sq)[:, None] + (sk - sq)
    kpos = np.arange(sk)[None, :]
    keep = np.ones((sq, sk), bool)
    if causal:
        keep &= kpos <= qpos
    if window is not None:
        keep &= kpos > qpos - window
    if valid is not None:
        keep &= kpos < valid
    return np.where(keep, 0.0, NEG).astype(np.float32)


def _softmax_oracle(qs, kt, v, mask):
    """Closed-form softmax attention on the graph's own inputs."""
    s = qs.astype(np.float64) @ kt + mask
    m = s.max(axis=1, keepdims=True)
    p = np.exp(s - m)
    return ((p @ v) / p.sum(axis=1, keepdims=True)).astype(np.float32)


def _scan_oracle(a, u, ct, g):
    """Sequential h_t = a_t*h_{t-1} + u_t, then (h*ct) @ g."""
    h = np.zeros_like(u[0], dtype=np.float64)
    hs = np.empty(u.shape, np.float64)
    for t in range(u.shape[0]):
        h = a[t] * h + u[t]
        hs[t] = h
    return ((hs * ct) @ g).astype(np.float32)


def _flash_case(sq, sk, d, seed=0, window=None):
    """Graph + inputs + the hand flash kernel's answer on the same data."""
    from repro.kernels.flash_attention import flash_attention

    rng = np.random.default_rng(seed)
    q = rng.standard_normal((1, sq, d)).astype(np.float32)
    k = rng.standard_normal((1, sk, d)).astype(np.float32)
    v = rng.standard_normal((1, sk, d)).astype(np.float32)
    graph = fe.flash_attention_graph(sq, sk, d)
    inputs = [q[0] / np.sqrt(d).astype(np.float32), k[0].T.copy(), v[0],
              _attn_mask(sq, sk, causal=True, window=window)]
    hand = np.asarray(flash_attention(q, k, v, causal=True, window=window,
                                      interpret=True))[0]
    return graph, inputs, hand


def _ssd_case(s, p, n, head, chunk, seed=0):
    """Graph + per-head inputs + the hand SSD kernel's answer."""
    from repro.kernels.ssd_scan import ssd_scan

    H = head + 1
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((s, H, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, (s, H)).astype(np.float32)
    A = rng.uniform(-1.0, -0.1, (H,)).astype(np.float32)
    B = rng.standard_normal((s, n)).astype(np.float32)
    C = rng.standard_normal((s, n)).astype(np.float32)
    graph = fe.ssd_scan_graph(s, p, n)
    a = np.repeat(np.exp(dt[:, head] * A[head])[:, None], p * n, axis=1)
    u = ((dt[:, head, None] * x[:, head, :])[:, :, None]
         * B[:, None, :]).reshape(s, p * n)
    ct = np.broadcast_to(C[:, None, :], (s, p, n)).reshape(s, p * n).copy()
    g = np.kron(np.eye(p), np.ones((n, 1))).astype(np.float32)
    inputs = [a.astype(np.float32), u.astype(np.float32), ct, g]
    hand = np.asarray(ssd_scan(x, dt, A, B, C, None, chunk=chunk,
                               interpret=True))[:, head, :]
    return graph, inputs, hand


def _compile_and_check(graph, inputs, hand, pipe):
    """One matrix cell: compile under ``pipe`` and check every backend
    against the numpy oracle and the hand kernel."""
    oracle = (_softmax_oracle(*inputs) if graph.name.startswith(("flash",
                                                                 "decode"))
              else _scan_oracle(*inputs))
    np.testing.assert_allclose(hand, oracle, **TOL)

    ck = pipeline.compile_traced(graph, pipeline=pipe)
    (ref,) = ck.run_ref(*inputs)
    np.testing.assert_allclose(ref, oracle, **TOL)
    (jx,) = ck.run_jax(*inputs)
    np.testing.assert_allclose(np.asarray(jx), oracle, **TOL)
    assert ck.run_pallas is not None, \
        f"pallas emitter refused legal schedule {pipe!r}"
    pal = np.asarray(ck.run_pallas(*inputs))
    np.testing.assert_allclose(pal, oracle, **TOL)
    np.testing.assert_allclose(pal, hand, **TOL)
    return ck


def _pipe(template, tile):
    tm, tn, tk = tile
    return template.format(t=f"tile_m={tm},tile_n={tn},tile_k={tk}")


# every legal schedule family for a carried-reduction kernel; the ssd
# list stops before grid{vars=2}, which would grid the scan's time axis
# (pinned as a diagnostic in tests/test_loop_ir_passes.py)
ATTN_PIPES = [
    "lower{{{t}}}",
    "lower{{{t}}},fuse-epilogue",
    "lower{{{t}}},fuse-epilogue,grid{{vars=1}}",
    "lower{{{t}}},fuse-epilogue,grid{{vars=2}}",
]
SSD_PIPES = ATTN_PIPES[:3]

FLASH_SIZES = [
    pytest.param((8, 16, 4), (4, 4, 4), id="small"),
    pytest.param((16, 32, 8), (8, 8, 4), id="medium",
                 marks=pytest.mark.slow),
]
SSD_SIZES = [
    pytest.param((8, 2, 2), (4, 4, 4), id="small"),
    pytest.param((16, 2, 4), (8, 8, 8), id="medium",
                 marks=pytest.mark.slow),
]


# --------------------------------------------------------------------------
# the differential matrix
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dims,tile", FLASH_SIZES)
@pytest.mark.parametrize("sched", ATTN_PIPES)
def test_flash_matrix(dims, tile, sched):
    graph, inputs, hand = _flash_case(*dims)
    _compile_and_check(graph, inputs, hand, _pipe(sched, tile))


def test_flash_window_mask_is_data():
    """A local-window mask is just different mask *data* — the compiled
    artifact is bit-for-bit the same pipeline."""
    graph, inputs, hand = _flash_case(8, 16, 4, window=4)
    _compile_and_check(graph, inputs, hand, _pipe(ATTN_PIPES[1], (4, 4, 4)))


@pytest.mark.parametrize("sched", [ATTN_PIPES[0], ATTN_PIPES[3]])
def test_decode_matrix(sched):
    """Decode attention: per-(batch, kv-group) slice of the hand kernel
    vs the compiled graph, KV-cache validity arriving as mask data."""
    from repro.kernels.decode_attention import decode_attention

    B, KV, rep, smax, hd = 2, 2, 4, 16, 4
    rng = np.random.default_rng(3)
    q = rng.standard_normal((B, KV, rep, hd)).astype(np.float32)
    k = rng.standard_normal((B, KV, smax, hd)).astype(np.float32)
    v = rng.standard_normal((B, KV, smax, hd)).astype(np.float32)
    valid = np.array([smax, smax // 2 + 1], np.int32)
    hand = np.asarray(decode_attention(q, k, v, valid, interpret=True))

    for b, g in ((0, 0), (1, 1)):
        graph = fe.decode_attention_graph(rep, smax, hd)
        inputs = [q[b, g] / np.sqrt(hd).astype(np.float32),
                  k[b, g].T.copy(), v[b, g],
                  _attn_mask(rep, smax, causal=False, valid=valid[b])]
        _compile_and_check(graph, inputs, hand[b, g],
                           _pipe(sched, (4, 4, 4)))


@pytest.mark.parametrize("dims,tile", SSD_SIZES)
@pytest.mark.parametrize("sched", SSD_PIPES)
def test_ssd_matrix(dims, tile, sched):
    graph, inputs, hand = _ssd_case(*dims, head=1, chunk=dims[0] // 2)
    _compile_and_check(graph, inputs, hand, _pipe(sched, tile))


# --------------------------------------------------------------------------
# reproc: the driver exposes the kernels, and --emit=loop round-trips
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kspec", ["flash:8x16x4", "decode:4x8x4",
                                   "ssd:8x2x4"])
def test_reproc_kernel_emit_loop_fixpoint(kspec):
    import io

    from repro.core import reproc

    buf = io.StringIO()
    assert reproc.main(["--kernel", kspec, "--emit", "loop"], out=buf) == 0
    text = buf.getvalue()
    kern = ir_text.parse_ir(text)
    assert ir_text.print_ir(kern) + "\n" == text


def test_reproc_kernel_flag_conflicts_and_typos():
    import io

    from repro.core import reproc

    assert reproc.main(["--kernel", "flash", "--gemm", "4x4x4"],
                       out=io.StringIO()) == 2
    # unknown kernel names are a usage diagnostic (exit 2, with a
    # did-you-mean hint — see test_sharing.py); bad dims stay exit 1
    assert reproc.main(["--kernel", "mamba"], out=io.StringIO()) == 2
    assert reproc.main(["--kernel", "ssd:2x2"], out=io.StringIO()) == 1


# --------------------------------------------------------------------------
# property-based: printer/parser/verifier on the new carried ops
# --------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(sq=st.sampled_from([2, 4, 8]), sk=st.sampled_from([4, 8, 16]),
       d=st.sampled_from([2, 4]), tile=st.sampled_from([1, 2, 3, 4, 8]))
def test_reduce_print_parse_fixpoint(sq, sk, d, tile):
    kern = lower_graph(fe.flash_attention_graph(sq, sk, d),
                       LoweringOptions(tile_m=tile, tile_n=tile, tile_k=tile))
    text = ir_text.print_ir(kern)
    assert ir_text.print_ir(ir_text.parse_ir(text)) == text


@settings(max_examples=12, deadline=None)
@given(s=st.sampled_from([2, 4, 8, 16]), p=st.sampled_from([1, 2]),
       n=st.sampled_from([2, 4]), tile=st.sampled_from([1, 2, 3, 4, 8]))
def test_scan_print_parse_fixpoint(s, p, n, tile):
    kern = lower_graph(fe.ssd_scan_graph(s, p, n),
                       LoweringOptions(tile_m=tile, tile_n=tile, tile_k=tile))
    text = ir_text.print_ir(kern)
    assert ir_text.print_ir(ir_text.parse_ir(text)) == text


def _corrupt_line(text, needle, old, new):
    """Rewrite ``old``→``new`` on the first line containing ``needle``."""
    lines = text.splitlines()
    for i, ln in enumerate(lines):
        if needle in ln:
            assert old in ln, f"expected {old!r} in {ln!r}"
            lines[i] = ln.replace(old, new, 1)
            return "\n".join(lines)
    raise AssertionError(f"no line contains {needle!r}")


def test_parse_rejects_scan_carry_shape_mismatch_with_line_number():
    kern = lower_graph(fe.ssd_scan_graph(8, 2, 4),
                       LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    text = ir_text.print_ir(kern)
    bad = _corrupt_line(text, "scan<linear>", "1x4]", "1x2]")
    with pytest.raises(IRParseError, match="carry mismatch") as ei:
        ir_text.parse_ir(bad)
    assert "line " in str(ei.value)


def test_parse_rejects_reduce_rank_mismatch_with_line_number():
    kern = lower_graph(fe.flash_attention_graph(8, 16, 4),
                       LoweringOptions(tile_m=2, tile_n=2, tile_k=2))
    text = ir_text.print_ir(kern)
    bad = _corrupt_line(text, "reduce<max,acc>", "2x2]", "1x2]")
    with pytest.raises(IRParseError, match="reduce tile mismatch") as ei:
        ir_text.parse_ir(bad)
    assert "line " in str(ei.value)


def test_parse_rejects_bad_reduce_and_scan_kinds():
    kern = lower_graph(fe.ssd_scan_graph(8, 2, 4),
                       LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    text = ir_text.print_ir(kern)
    with pytest.raises(IRParseError, match="bad kind"):
        ir_text.parse_ir(_corrupt_line(text, "scan<linear>",
                                       "scan<linear>", "scan<median>"))


@settings(max_examples=8, deadline=None)
@given(kernel=st.sampled_from(["flash", "ssd"]),
       tile=st.sampled_from([1, 2, 4]))
def test_canonicalize_idempotent_on_carry_kernels(kernel, tile):
    from repro.core.passes import PassManager

    graph = (fe.flash_attention_graph(4, 8, 2) if kernel == "flash"
             else fe.ssd_scan_graph(4, 2, 2))
    opts = LoweringOptions(tile_m=tile, tile_n=tile, tile_k=tile)
    k1 = PassManager().add("canonicalize").run(lower_graph(graph, opts)) \
                      .artifact
    once = ir_text.print_ir(k1)
    k2 = PassManager().add("canonicalize").run(k1).artifact
    assert ir_text.print_ir(k2) == once


def test_canonicalize_preserves_carry_semantics():
    graph, inputs, hand = _ssd_case(8, 2, 2, head=0, chunk=4)
    ck = pipeline.compile_traced(graph, pipeline="lower{tile_m=2,tile_n=2,"
                                                 "tile_k=2}",
                                 canonicalize=True)
    (out,) = ck.run_ref(*inputs)
    np.testing.assert_allclose(out, hand, **TOL)


# --------------------------------------------------------------------------
# DSE: the explorer prices and validates the carried kernels
# --------------------------------------------------------------------------


@pytest.mark.parametrize("graph", [fe.flash_attention_graph(8, 16, 4),
                                   fe.ssd_scan_graph(16, 2, 4)],
                         ids=["flash", "ssd"])
def test_dse_explore_serving_kernels(graph):
    from repro.core import dse

    res = dse.explore(graph, validate_top=2, tiles=(8, 4), use_cache=False)
    assert res.frontier, "empty Pareto frontier"
    assert res.validations, "no frontier point was validated"
    bad = [v for v in res.validations if not v.ok]
    assert not bad, f"frontier points failed cosim: {bad}"
