"""Deterministic fallback for the ``hypothesis`` package.

The container this repo develops in does not ship ``hypothesis`` and new
dependencies cannot be installed, so ``tests/conftest.py`` installs this
stub into ``sys.modules`` *only when the real package is missing*.  It
implements the tiny slice of the API the test-suite uses — ``@given``
with keyword strategies, ``@settings(max_examples=..., deadline=...)``,
and the ``integers`` / ``floats`` / ``sampled_from`` / ``booleans``
strategies — by exhaustively-seeded *deterministic* sampling: every run
draws the same examples, so failures reproduce.

When real hypothesis is available it is always preferred (the stub does
no shrinking and no coverage-guided generation).
"""

from __future__ import annotations

import random
import sys
import types

DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return _Strategy(lambda rnd: fn(self._draw(rnd)))

    def filter(self, pred):
        def draw(rnd, _pred=pred):
            for _ in range(1000):
                v = self._draw(rnd)
                if _pred(v):
                    return v
            raise ValueError("filter predicate too strict for stub hypothesis")
        return _Strategy(draw)


def integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rnd: rnd.choice(elements))


def booleans():
    return _Strategy(lambda rnd: rnd.choice([False, True]))


def just(value):
    return _Strategy(lambda rnd: value)


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rnd: [elements.example_from(rnd)
                                  for _ in range(rnd.randint(min_size,
                                                             max_size))])


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError("stub hypothesis supports keyword strategies only")

    def deco(fn):
        # Zero-arg wrapper: pytest must not mistake the strategy names for
        # fixtures, so we deliberately do NOT set __wrapped__ (pytest
        # follows it when computing the signature).
        def wrapper():
            n = getattr(wrapper, "_hyp_max_examples", DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(_SEED)
            for i in range(n):
                kwargs = {k: s.example_from(rnd)
                          for k, s in kw_strategies.items()}
                try:
                    fn(**kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (stub hypothesis, "
                        f"example {i + 1}/{n}): {kwargs!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hyp_max_examples = getattr(fn, "_hyp_max_examples",
                                            DEFAULT_MAX_EXAMPLES)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register stub ``hypothesis`` + ``hypothesis.strategies`` modules."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just",
                 "lists"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
