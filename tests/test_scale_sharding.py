"""Scale sanity: FULL-config state trees resolve to coherent shardings on
the production mesh shape — no compilation, pure metadata, so the 1T-param
tree is checked in milliseconds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config
from repro.distributed.sharding import tree_shardings
from repro.models.model import Model, RunConfig
from repro.optim.optimizer import adamw
from repro.train.step import state_axes, state_shapes


class _FakeMesh:
    """Duck-typed stand-in for the (16,16) production mesh: pspec_for only
    reads ``.shape``; NamedSharding construction needs real devices, so we
    resolve pspecs only."""
    shape = {"data": 16, "model": 16}


def _pspecs(axes_tree, shapes_tree):
    from repro.distributed.sharding import parse_axes, pspec_for
    mesh = _FakeMesh()
    out = []
    for ax, sds in zip(
            jax.tree.leaves(axes_tree,
                            is_leaf=lambda x: isinstance(x, str)),
            jax.tree.leaves(shapes_tree)):
        out.append((pspec_for(parse_axes(ax), sds.shape, mesh), sds))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_shardings_resolve(arch):
    cfg = get_config(arch)
    model = Model(cfg, RunConfig(param_dtype="bfloat16", max_seq=4096))
    opt = adamw(lambda s: 1e-4, factored=cfg.param_count() > 5e10,
                state_dtype=jnp.bfloat16)
    shapes = state_shapes(model, opt)
    axes = state_axes(model, opt)
    pairs = _pspecs(axes, shapes)
    assert len(pairs) > 5
    total, sharded = 0, 0
    for spec, sds in pairs:
        n = int(np.prod(sds.shape)) if sds.shape else 1
        total += n * sds.dtype.itemsize
        shard_n = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                shard_n *= _FakeMesh.shape[a]
        sharded += n * sds.dtype.itemsize // shard_n
        # every sharded dim must divide
        for entry, dim in zip(spec, sds.shape):
            if entry is None:
                continue
            k = 1
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                k *= _FakeMesh.shape[a]
            assert dim % k == 0, (arch, spec, sds.shape)
    # large configs must actually shard: per-device state <= 1/8 of total
    if total > 1e9:
        assert sharded <= total / 8, (arch, total, sharded)


def test_kimi_state_needs_two_pods():
    """Quantified scale finding (EXPERIMENTS.md §Dry-run): 1T params +
    bf16 momentum = ~4 TB of state; at 256 chips that is 16.1 GB/dev —
    AT the v5e HBM line before activations — while the 512-chip 2-pod
    mesh brings it to ~8 GB/dev.  kimi-k2 training requires >=2 pods."""
    cfg = get_config("kimi_k2_1t")
    model = Model(cfg, RunConfig(param_dtype="bfloat16", max_seq=4096))
    opt = adamw(lambda s: 1e-4, factored=True, state_dtype=jnp.bfloat16)
    shapes = state_shapes(model, opt)
    axes = state_axes(model, opt)

    per_dev_1pod = sum(
        int(np.prod(s.shape)) * s.dtype.itemsize
        // max(_shards(spec, _FakeMesh.shape), 1)
        for spec, s in _pspecs(axes, shapes))
    assert 15e9 < per_dev_1pod < 17.5e9, per_dev_1pod

    # on the 2-pod mesh fsdp spans (pod, data): x2 more shards
    from repro.distributed.sharding import parse_axes, pspec_for

    class Pod2:
        shape = {"pod": 2, "data": 16, "model": 16}

    per_dev_2pod = 0
    for ax, sds in zip(
            jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, str)),
            jax.tree.leaves(shapes)):
        spec = pspec_for(parse_axes(ax), sds.shape, Pod2())
        n = int(np.prod(sds.shape)) if sds.shape else 1
        per_dev_2pod += n * sds.dtype.itemsize \
            // max(_shards(spec, Pod2.shape), 1)
    assert per_dev_2pod < 10e9, per_dev_2pod


def _shards(spec, mesh_shape):
    n = 1
    for entry in spec:
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            n *= mesh_shape[a]
    return n
