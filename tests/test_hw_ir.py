"""HwIR tests: textual round-trip at the hardware level, structural
TABLE I / Fig. 3 accounting from the module, Verilog golden output, and
the pass-manager/driver wiring of the third IR level."""

import io
import os

import pytest

from repro.core import (PassManager, SCHEDULES, compile_gemm, ir_text,
                        machine_model)
from repro.core.hw_ir import (HwModule, HwStep, emit_verilog, lower_to_hw)
from repro.core.passes import PassError
from repro.core.reproc import main as reproc_main

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

PAPER_TABLE1 = {4: (1_498, 1_114), 8: (10_762, 7_946)}


def _hw(size, sched, epilogue="none"):
    ck = compile_gemm(size, size, size, schedule=sched, epilogue=epilogue,
                      want_jax=False, want_pallas=False)
    return ck


# ---- round-trip property at the hw level -----------------------------------


@pytest.mark.parametrize("sched", SCHEDULES)
@pytest.mark.parametrize("epilogue", ["none", "bias_relu"])
def test_hw_roundtrip_fixpoint_all_schedules(sched, epilogue):
    ck = _hw(16, sched, epilogue)
    text = ir_text.print_hw_module(ck.hw_module)
    hw2 = ir_text.parse_hw_module(text)
    assert ir_text.print_hw_module(hw2) == text
    # str() is the same canonical form, and parse_ir dispatches on the
    # stagecc.hw header
    assert str(ck.hw_module) == text
    assert isinstance(ir_text.parse_ir(text), HwModule)


@pytest.mark.parametrize("sched", ["nested", "inner_flattened"])
def test_parsed_hw_preserves_structural_reports(sched):
    """A round-tripped module must price identically: the text carries
    all structure the machine model consumes."""
    ck = _hw(8, sched)
    hw2 = ir_text.parse_hw_module(str(ck.hw_module))
    assert machine_model.cycles(hw2).total == ck.cycles.total
    assert machine_model.resources(hw2) == ck.resources
    assert hw2.fsm_state_count() == ck.hw_module.fsm_state_count()


def test_hw_parser_diagnostics():
    ck = _hw(4, "nested")
    text = str(ck.hw_module)
    with pytest.raises(ValueError, match="does not verify|no storage"):
        ir_text.parse_hw_module(text.replace("read arg0[", "read ghost["))
    with pytest.raises(ir_text.IRParseError,
                       match="unclosed|expected closing"):
        ir_text.parse_hw_module(text.rstrip().rstrip("}"))
    with pytest.raises(ir_text.IRParseError, match="loop kind"):
        ir_text.parse_hw_module(text.replace("@fsm", "@warp"))


# ---- verification -----------------------------------------------------------


def test_verify_rejects_duplicate_storage_names():
    """Ports, regs and mems share one namespace; a duplicate in any
    combination must be rejected *by name*."""
    import dataclasses

    ck = _hw(4, "nested")
    mod = ck.hw_module

    dup_port = dataclasses.replace(mod.ports[1], name=mod.ports[0].name)
    bad = HwModule(mod.name, [mod.ports[0], dup_port], mod.regs, mod.mems,
                   mod.units, mod.ctrl)
    with pytest.raises(ValueError, match=f"duplicate storage name "
                                         f"'{mod.ports[0].name}'"):
        bad.verify()

    dup_reg = dataclasses.replace(mod.regs[0], name=mod.ports[0].name)
    bad = HwModule(mod.name, mod.ports, [dup_reg], mod.mems, mod.units,
                   mod.ctrl)
    with pytest.raises(ValueError, match="duplicate storage name"):
        bad.verify()


def test_verify_rejects_duplicate_unit_names():
    import dataclasses

    mod = _hw(4, "nested").hw_module
    bad = HwModule(mod.name, mod.ports, mod.regs, mod.mems,
                   mod.units + [dataclasses.replace(mod.units[0])], mod.ctrl)
    with pytest.raises(ValueError, match="duplicate unit name"):
        bad.verify()


def test_verify_rejects_unbound_index_counter():
    """Operand address generators may only use enclosing loop counters."""
    mod = _hw(4, "nested").hw_module
    text = str(mod)
    with pytest.raises(ValueError, match="counter %ghost"):
        ir_text.parse_hw_module(text.replace("[i1, k3 :", "[ghost, k3 :", 1))


def test_verify_rejects_mixed_sign_index_out_of_bounds():
    """Bounds must hold over the whole iteration box: a mixed-sign affine
    index (i1 + -1*k3) evaluates in range at both the all-zero and
    all-max corners yet underruns at i1=0, k3=1 — verify has to be
    sign-aware per coefficient, not corner-sampled."""
    mod = _hw(4, "nested").hw_module
    text = str(mod)
    with pytest.raises(ValueError, match="out of bounds"):
        ir_text.parse_hw_module(
            text.replace("read arg0[i1, k3 :", "read arg0[i1+-1*k3, k3 :", 1))


def test_verify_rejects_rank_mismatched_operand():
    mod = _hw(4, "nested").hw_module
    text = str(mod)
    # drop one index dimension from a matmul operand
    with pytest.raises(ValueError, match="rank"):
        ir_text.parse_hw_module(text.replace("[i1, k3 :", "[i1 :", 1))


def test_lower_to_hw_output_always_verifies():
    """lower_to_hw verifies before returning — callers never hold an
    unchecked module (re-verifying here is a no-op, not a crash)."""
    for sched in SCHEDULES:
        _hw(8, sched).hw_module.verify()


# ---- structural lowering ----------------------------------------------------


def test_lowering_maps_loop_kinds_and_storage():
    ck = _hw(8, "inner_flattened", epilogue="bias_relu")
    hw = ck.hw_module
    kinds = {l.kind for l in hw.loops()}
    assert "unroll" in kinds and "fsm" in kinds
    # HBM params became ports; the VREG accumulator a register bank
    assert {p.name for p in hw.ports} == {b.name for b in ck.kernel.params}
    assert [r.name for r in hw.regs] == \
        [b.name for b in ck.kernel.scratch if b.space.value == "vreg"]
    # the unrolled matmul's MAC unit is replicated spatially
    mac = next(u for u in hw.units if u.kind == "mac")
    assert mac.copies == 8


def test_port_directions_follow_usage():
    ck = _hw(8, "nested", epilogue="bias_relu")
    dirs = {p.name: p.direction for p in ck.hw_module.ports}
    assert dirs["arg0"] == "in"
    # HBM intermediates are written by one nest and read by the next
    assert dirs["matmul1"] == "inout"
    assert dirs["relu3"] == "out"


def test_grid_schedule_lowers_to_stream_and_mxu():
    ck = compile_gemm(256, 256, 256, schedule="tpu_mxu",
                      want_jax=False, want_pallas=False)
    hw = ck.hw_module
    assert any(l.kind == "stream" for l in hw.loops())
    assert any(u.kind == "mxu" for u in hw.units)


# ---- TABLE I / Fig. 3 from the hardware -------------------------------------


@pytest.mark.parametrize("size", sorted(PAPER_TABLE1))
def test_structural_cycles_match_paper_table1(size):
    """Regression gate: cycles computed from the HwIR module land within
    15% of the paper's published TABLE I numbers at sizes 4 and 8."""
    pn, pf = PAPER_TABLE1[size]
    n = machine_model.cycles(_hw(size, "nested").hw_module).total
    f = machine_model.cycles(_hw(size, "inner_flattened").hw_module).total
    assert abs(n - pn) / pn < 0.15
    assert abs(f - pf) / pf < 0.15


def test_flattening_trades_fsm_states_for_lanes():
    """The paper's mechanism, read directly off the hardware: flattening
    removes the innermost FSM loop (fewer control states) and replicates
    the datapath (more lanes), leaving compute port-limited."""
    n = _hw(8, "nested").hw_module
    f = _hw(8, "inner_flattened").hw_module
    assert f.fsm_state_count() < n.fsm_state_count()
    assert f.lane_count() == 8 * n.lane_count()
    cn, cf = machine_model.cycles(n), machine_model.cycles(f)
    assert cf.control < cn.control
    assert cf.compute == cn.compute


def test_kernel_input_lowers_before_pricing():
    """cycles()/resources() accept scheduled LoopIR for convenience and
    price its lowered hardware — same numbers as the explicit module."""
    ck = _hw(8, "nested")
    assert machine_model.cycles(ck.kernel).total == ck.cycles.total
    assert machine_model.resources(ck.kernel) == ck.resources


# ---- Verilog emission -------------------------------------------------------


def test_verilog_golden_gemm4x4():
    ck = _hw(4, "nested")
    got = emit_verilog(ck.hw_module) + "\n"
    with open(os.path.join(GOLDEN_DIR, "gemm_4x4x4_nested.v")) as fh:
        want = fh.read()
    assert got == want, (
        "emitted Verilog drifted from tests/golden/gemm_4x4x4_nested.v; "
        "if intentional, regenerate with: PYTHONPATH=src python -m "
        "repro.core.reproc --gemm 4x4x4 --epilogue none --pipeline lower "
        "--emit verilog > tests/golden/gemm_4x4x4_nested.v")


def test_verilog_replicates_unrolled_units():
    v = emit_verilog(_hw(4, "inner_flattened").hw_module)
    assert "generate for" in v and "< 4" in v
    assert v.count("localparam S_") == \
        _hw(4, "inner_flattened").hw_module.fsm_state_count()


# ---- pass manager / driver wiring -------------------------------------------


def test_pipeline_to_verilog_through_passmanager():
    from repro.core.reproc import quickstart_gemm
    g = quickstart_gemm(8, 8, 8, epilogue="none")
    res = PassManager.parse("lower,flatten-inner,lower-to-hw,emit-verilog") \
        .run(g)
    assert isinstance(res.artifact, str)
    assert res.artifact.startswith("// stagecc HwIR")
    levels = [r.level for r in res.records]
    assert levels == ["tensor", "loop", "loop", "hw"]


def test_hw_pass_level_checked():
    from repro.core.reproc import quickstart_gemm
    g = quickstart_gemm(8, 8, 8, epilogue="none")
    with pytest.raises(PassError, match="hw-level pass"):
        PassManager.parse("lower,emit-verilog").run(g)


@pytest.mark.parametrize("emit,needle", [
    ("hw", "stagecc.hw @gemm_"),
    ("verilog", "module gemm_"),
])
def test_reproc_emit_flag(emit, needle):
    out = io.StringIO()
    rc = reproc_main(["--gemm", "4x4x4", "--epilogue", "none",
                      f"--emit={emit}"], out=out)
    assert rc == 0
    assert needle in out.getvalue()


def test_reproc_emit_rejects_uphill():
    out = io.StringIO()
    rc = reproc_main(["--gemm", "4x4x4", "--epilogue", "none",
                      "--pipeline", "lower", "--emit=tensor"], out=out)
    assert rc == 1
