import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import backend_ref, schedule
from repro.core.frontend import spec, trace
from repro.core.loop_ir import LoopKind, MemSpace
from repro.core.lowering import LoweringOptions, lower_graph
from repro.core.passes import parse_pipeline, run_pipeline
import repro.core.frontend as fe


def _gemm_graph(m, n, k, epilogue=False):
    if epilogue:
        def f(a, b, c):
            return fe.relu(fe.matmul(a, b) + c)
        return trace(f, [spec((m, k)), spec((k, n)), spec((n,))])
    def f(a, b):
        return fe.matmul(a, b)
    return trace(f, [spec((m, k)), spec((k, n))])


def test_lowering_structure():
    kern = lower_graph(_gemm_graph(8, 4, 6),
                       LoweringOptions(tile_m=2, tile_n=2, tile_k=2))
    loops = kern.loops()
    assert len(loops) == 3
    assert all(l.kind == LoopKind.SEQUENTIAL for l in loops)
    kern.verify()


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 12), n=st.integers(1, 12), k=st.integers(1, 12),
       tm=st.integers(1, 4), tn=st.integers(1, 4), tk=st.integers(1, 4))
def test_lowering_semantics_hypothesis(m, n, k, tm, tn, tk):
    """Any tiling must preserve GEMM semantics (clamped to divisors)."""
    g = _gemm_graph(m, n, k)
    kern = lower_graph(g, LoweringOptions(tile_m=tm, tile_n=tn, tile_k=tk))
    rng = np.random.default_rng(m * 100 + n * 10 + k)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    (out,) = backend_ref.run(kern, [a, b])
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("sched_name", ["nested", "flattened", "split",
                                        "interchange", "vectorize"])
def test_schedules_preserve_semantics(sched_name):
    g = _gemm_graph(8, 8, 8)
    kern = lower_graph(g, LoweringOptions(tile_m=2, tile_n=2, tile_k=2))
    loops = kern.loops()
    if sched_name == "flattened":
        schedule.flatten_inner(kern)
    elif sched_name == "split":
        schedule.split(kern, loops[0].var.name, 2)
    elif sched_name == "interchange":
        schedule.interchange(kern, loops[0].var.name, loops[1].var.name)
    elif sched_name == "vectorize":
        schedule.vectorize(kern, loops[-1].var.name)
    rng = np.random.default_rng(1)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    (out,) = backend_ref.run(kern, [a, b])
    np.testing.assert_allclose(out, a @ b, rtol=1e-4)


def test_fuse_epilogue_removes_extra_nests():
    g = _gemm_graph(8, 8, 8, epilogue=True)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    n_before = len(kern.body)
    schedule.fuse_epilogue(kern)
    assert len(kern.body) < n_before
    rng = np.random.default_rng(2)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    c = rng.standard_normal((8,)).astype(np.float32)
    (out,) = [x for x in backend_ref.run(kern, [a, b, c])][:1]
    np.testing.assert_allclose(out, np.maximum(a @ b + c, 0), rtol=1e-4)


def test_fuse_epilogue_chained_ewise():
    """relu(a@b + bias) lowers to matmul + TWO ewise nests; fuse must
    fold the whole chain in (bias_add first, then relu consuming the
    fused producer), leaving a single nest."""
    g = _gemm_graph(8, 8, 8, epilogue=True)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    assert len(kern.body) == 3
    schedule.fuse_epilogue(kern)
    assert len(kern.body) == 1, "chained ewise nests must fuse iteratively"
    rng = np.random.default_rng(3)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    c = rng.standard_normal((8,)).astype(np.float32)
    out = backend_ref.run(kern, [a, b, c])[-1]
    np.testing.assert_allclose(out, np.maximum(a @ b + c, 0), rtol=1e-4)


def test_fuse_epilogue_mismatched_tile_grids_refuses():
    """A consumer walking a different tile grid (here: one loop split)
    must NOT be fused — extents no longer line up tile-for-tile."""
    g = _gemm_graph(8, 8, 8, epilogue=True)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    # split the bias_add nest's outer loop: its nest vars become
    # (e_o:1, e_i:2, e:2) against the producer's (i:2, j:2, k:2)
    ewise_outer = [s for s in kern.body][1]
    schedule.split(kern, ewise_outer.var.name, 2)
    n_before = len(kern.body)
    schedule.fuse_epilogue(kern)
    assert len(kern.body) == n_before, \
        "mismatched tile grids must refuse to fuse"
    rng = np.random.default_rng(4)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    c = rng.standard_normal((8,)).astype(np.float32)
    out = backend_ref.run(kern, [a, b, c])[-1]
    np.testing.assert_allclose(out, np.maximum(a @ b + c, 0), rtol=1e-4)


def test_fuse_epilogue_multi_statement_leaf_refuses():
    """A consumer nest whose innermost body holds more than one
    statement is not the canonical tile-for-tile ewise chain; fuse must
    skip it and leave a verifiable kernel."""
    g = _gemm_graph(8, 8, 8, epilogue=True)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    mm_nest, bias_nest, relu_nest = kern.body
    # graft the relu leaf into the bias_add leaf -> two-statement leaf
    bias_leaf_loop = bias_nest.body[0]
    relu_leaf = relu_nest.body[0].body[0]
    # rename the relu leaf's loop vars onto the bias nest's vars
    mapping = {relu_nest.var.name: bias_nest.var.name,
               relu_nest.body[0].var.name: bias_leaf_loop.var.name}
    from repro.core.loop_ir import AffineExpr, TileRef

    def rw(ref):
        idx = tuple(AffineExpr(tuple((mapping.get(v, v), s)
                                     for v, s in e.coeffs), e.const)
                    for e in ref.index)
        return TileRef(ref.buffer, idx, ref.tile)

    relu_leaf.dst = rw(relu_leaf.dst)
    relu_leaf.srcs = [rw(r) for r in relu_leaf.srcs]
    bias_leaf_loop.body.append(relu_leaf)
    kern.body = [mm_nest, bias_nest]
    kern.verify()
    schedule.fuse_epilogue(kern)
    assert len(kern.body) == 2, "multi-statement leaf must refuse to fuse"
    rng = np.random.default_rng(5)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    c = rng.standard_normal((8,)).astype(np.float32)
    out = backend_ref.run(kern, [a, b, c])[-1]
    np.testing.assert_allclose(out, np.maximum(a @ b + c, 0), rtol=1e-4)


def test_fuse_epilogue_unrelated_consumer_untouched():
    """A second nest that does not consume the matmul's output stays
    where it is (no producer/consumer hit -> no fusion)."""
    g = _gemm_graph(8, 8, 8)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    n_before = len(kern.body)
    schedule.fuse_epilogue(kern)          # nothing to fuse: single nest
    assert len(kern.body) == n_before


def test_split_composed_with_interchange():
    """split then interchange of the two freshly-minted loops (and a
    second split on top) must stay verifiable and exact — DSE composes
    these programmatically."""
    g = _gemm_graph(16, 8, 8)
    kern = lower_graph(g, LoweringOptions(tile_m=2, tile_n=2, tile_k=2))
    i, j, k = [l.var.name for l in kern.loops()]
    schedule.split(kern, k, 2)            # k -> k_o x k_i (perfect pair)
    schedule.interchange(kern, f"{k}_o", f"{k}_i")
    schedule.split(kern, f"{k}_i", 2)     # split the (now outer) k_i again
    kern.verify()
    rng = np.random.default_rng(6)
    a = rng.standard_normal((16, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    (out,) = backend_ref.run(kern, [a, b])
    np.testing.assert_allclose(out, a @ b, rtol=1e-4)


def test_interchange_rejects_imperfect_nest():
    g = _gemm_graph(8, 8, 8)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    i, j, k = [l.var.name for l in kern.loops()]
    with pytest.raises(ValueError, match="not perfectly nested"):
        schedule.interchange(kern, j, k)  # j's body: zero, k-loop, copy


def test_split_rejects_non_divisor():
    g = _gemm_graph(8, 8, 8)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    with pytest.raises(ValueError, match="does not divide"):
        schedule.split(kern, kern.loops()[0].var.name, 3)


def test_pipeline_parser():
    stages = parse_pipeline("lower{tile_m=4,tile_n=4,tile_k=2},"
                            "flatten-inner,grid{vars=2}")
    assert [s["name"] for s in stages] == ["lower", "flatten-inner", "grid"]
    assert stages[0]["kwargs"] == {"tile_m": 4, "tile_n": 4, "tile_k": 2}
    with pytest.raises(KeyError):
        run_pipeline(_gemm_graph(4, 4, 4), "nonexistent-pass")


def test_set_space():
    g = _gemm_graph(8, 8, 8)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    acc = kern.scratch[0].name
    schedule.set_space(kern, acc, MemSpace.VMEM)
    assert kern.scratch[0].space == MemSpace.VMEM
    assert kern.vmem_bytes() > 0


def test_reduce_sum_lowering():
    """Row reduction lowers as a GEMM against a ones-vector (the MXU is
    the reduction tree — paper future-work (3) for tensor ops)."""
    from repro.core import backend_jax
    from repro.core.tensor_ir import Graph, TensorType

    g = Graph("rowsum")
    a = g.add_input("a", TensorType((8, 12)))
    r = g.emit("reduce_sum", [a], axis=1)
    g.set_outputs(r)
    kern = lower_graph(g, LoweringOptions(tile_m=4, tile_n=4, tile_k=4))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 12)).astype(np.float32)
    (out,) = backend_ref.run(kern, [x])
    np.testing.assert_allclose(out, x.sum(1), rtol=1e-5)
    (outj,) = backend_jax.emit_jit(kern)(x)
    np.testing.assert_allclose(np.asarray(outj), x.sum(1), rtol=1e-5)


# --------------------------------------------------------------------------
# carry-axis schedule legality: the first non-embarrassingly-tileable
# structure in the pipeline.  Tiling a carried reduction/scan along the
# carry axis without threading the carry must *diagnose*, never silently
# miscompile.
# --------------------------------------------------------------------------


def _flash_kern(tile=4):
    from repro.core.frontend import flash_attention_graph
    return lower_graph(flash_attention_graph(8, 16, 4),
                       LoweringOptions(tile_m=tile, tile_n=tile, tile_k=tile))


def _ssd_kern(tile=4):
    from repro.core.frontend import ssd_scan_graph
    return lower_graph(ssd_scan_graph(8, 2, 4),
                       LoweringOptions(tile_m=tile, tile_n=tile, tile_k=tile))


def _loop_with(kern, stmt_type):
    from repro.core.loop_ir import Loop
    for l in kern.loops():
        if any(isinstance(s, stmt_type) for s in l.body):
            return l
    raise AssertionError(f"no loop carries a {stmt_type.__name__}")


def test_grid_carried_reduce_axis_diagnoses():
    from repro.core.loop_ir import ReduceTile
    kern = _flash_kern()
    kloop = _loop_with(kern, ReduceTile)
    with pytest.raises(ValueError, match="carried reduction axis"):
        schedule.parallelize(kern, kloop.var.name)
    assert kloop.kind == LoopKind.SEQUENTIAL  # diagnosis left IR untouched


def test_vectorize_carried_reduce_axis_diagnoses():
    from repro.core.loop_ir import ReduceTile
    kern = _flash_kern()
    kloop = _loop_with(kern, ReduceTile)
    with pytest.raises(ValueError, match="carried reduction axis"):
        schedule.vectorize(kern, kloop.var.name)


def test_grid_scan_time_axis_diagnoses():
    from repro.core.loop_ir import ScanTile
    kern = _ssd_kern()
    tloop = _loop_with(kern, ScanTile)
    with pytest.raises(ValueError, match="scan axis"):
        schedule.parallelize(kern, tloop.var.name)


def test_grid_pass_pipeline_diagnoses_scan_axis():
    """grid{vars=2} descends into the scan nest's time loop -> the grid
    *pass* (not just the rewrite) surfaces the carry diagnostic."""
    from repro.core.frontend import ssd_scan_graph
    with pytest.raises(ValueError, match="scan axis"):
        run_pipeline(ssd_scan_graph(8, 2, 4),
                     "lower{tile_m=4,tile_n=4,tile_k=4},grid{vars=2}")


def test_grid_row_axis_of_carried_reduce_is_legal():
    """Only the carry axis is restricted: the row (statistic-per-row)
    loop of the same nest grids fine."""
    from repro.core.loop_ir import Loop, ReduceTile
    kern = _flash_kern()
    kloop = _loop_with(kern, ReduceTile)
    row = next(l for l in kern.loops()
               if any(s is kloop for s in l.body))
    schedule.parallelize(kern, row.var.name)
    assert row.kind == LoopKind.GRID
    kern.verify()


def test_split_scan_time_axis_stays_exact():
    """Splitting the time axis keeps iterations in carry order — legal,
    and the recurrence still matches the sequential oracle."""
    from repro.core.loop_ir import ScanTile
    kern = _ssd_kern(tile=4)
    tloop = _loop_with(kern, ScanTile)
    schedule.split(kern, tloop.var.name, 2)
    kern.verify()
    rng = np.random.default_rng(9)
    a = rng.uniform(0.1, 0.9, (8, 8)).astype(np.float32)
    u = rng.standard_normal((8, 8)).astype(np.float32)
    ct = rng.standard_normal((8, 8)).astype(np.float32)
    g = np.kron(np.eye(2), np.ones((4, 1))).astype(np.float32)
    (out,) = backend_ref.run(kern, [a, u, ct, g])
    h = np.zeros(8)
    want = np.empty((8, 8))
    for t in range(8):
        h = a[t] * h + u[t]
        want[t] = h
    np.testing.assert_allclose(out, (want * ct) @ g, rtol=1e-4, atol=1e-5)


def test_unroll_carried_reduce_axis_stays_exact():
    """@unrolled replicates the datapath but retires in order — the carry
    threads, so unrolling the reduction axis is legal and exact."""
    from repro.core.frontend import flash_attention_graph
    from repro.core.loop_ir import ReduceTile
    kern = _flash_kern(tile=2)
    kloop = _loop_with(kern, ReduceTile)
    schedule.unroll(kern, kloop.var.name)
    kern.verify()
    rng = np.random.default_rng(11)
    q = rng.standard_normal((8, 4)).astype(np.float32)
    kt = rng.standard_normal((4, 16)).astype(np.float32)
    v = rng.standard_normal((16, 4)).astype(np.float32)
    mask = np.zeros((8, 16), np.float32)
    (out,) = backend_ref.run(kern, [q, kt, v, mask])
    s = q @ kt + mask
    p = np.exp(s - s.max(1, keepdims=True))
    want = (p @ v) / p.sum(1, keepdims=True)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
