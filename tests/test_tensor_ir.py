import numpy as np
import pytest

from repro.core import tensor_ir as tir
from repro.core.frontend import spec, trace
import repro.core.frontend as fe


def test_graph_build_and_verify():
    g = tir.Graph("f")
    a = g.add_input("a", tir.TensorType((4, 8)))
    b = g.add_input("b", tir.TensorType((8, 2)))
    c = g.emit("matmul", [a, b])
    d = g.emit("relu", [c])
    g.set_outputs(d)
    g.verify()
    assert c.type.shape == (4, 2)
    assert "stagecc.matmul" in str(g)


def test_type_errors():
    g = tir.Graph("f")
    a = g.add_input("a", tir.TensorType((4, 8)))
    b = g.add_input("b", tir.TensorType((4, 8)))
    with pytest.raises(TypeError):
        g.emit("matmul", [a, b])
    with pytest.raises(TypeError):
        tir.TensorType((0, 2))
    with pytest.raises(TypeError):
        tir.TensorType((2,), "float99")


def test_eval_np_matches_numpy():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((4, 8)).astype(np.float32)
    b = rng.standard_normal((8, 2)).astype(np.float32)
    bias = rng.standard_normal((2,)).astype(np.float32)

    def f(x, y, z):
        return fe.relu(fe.matmul(x, y) + z)

    g = trace(f, [spec((4, 8)), spec((8, 2)), spec((2,))])
    (out,) = g.eval_np(a, b, bias)
    np.testing.assert_allclose(out, np.maximum(a @ b + bias, 0), rtol=1e-5)


def test_use_before_def_detected():
    g = tir.Graph("f")
    a = g.add_input("a", tir.TensorType((2, 2)))
    rogue = tir.Value("rogue", tir.TensorType((2, 2)))
    op = tir.Op("relu", [rogue], {}, tir.Value("r", tir.TensorType((2, 2))))
    g.ops.append(op)
    with pytest.raises(ValueError):
        g.verify()


def test_register_custom_op():
    name = "test_double_op"
    if name not in tir.OP_REGISTRY:
        tir.register_op(name, lambda ts, at: ts[0], lambda a, **at: a * 2)
    g = tir.Graph("f")
    a = g.add_input("a", tir.TensorType((3,)))
    r = g.emit(name, [a])
    g.set_outputs(r)
    (out,) = g.eval_np(np.ones(3, np.float32))
    np.testing.assert_allclose(out, 2 * np.ones(3))
    with pytest.raises(ValueError):
        tir.register_op(name, lambda ts, at: ts[0], lambda a, **at: a)


def test_tracer_operators():
    def f(a, b):
        return (a @ b) * (a @ b) - (a @ b)

    g = trace(f, [spec((2, 3)), spec((3, 2))])
    assert len([o for o in g.ops if o.opname == "matmul"]) == 3
    g.verify()
