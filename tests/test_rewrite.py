"""Unified pattern-rewrite core (PR 5 tentpole): structural protocol,
greedy driver, canonicalize at all three levels (idempotence + cosim
equivalence), pattern-ported schedule transforms, and the stats wiring
through PassRecord / docs."""

import os

import numpy as np
import pytest

from repro.core import backend_ref, hw_ir, hw_sim, ir_text, machine_model, \
    rewrite, schedule
from repro.core.frontend import spec, trace
from repro.core.loop_ir import Kernel, Loop
from repro.core.passes import PASS_REGISTRY, PassManager
from repro.core.pipeline import SCHEDULES, compile_gemm
from repro.core.reproc import quickstart_gemm
from repro.core.rewrite import (CANONICAL_PATTERNS, Pattern, RewriteDriver,
                                RewriteError, canonicalize, collect_stats,
                                normalize_affine)
from repro.core.tensor_ir import Graph, TensorType
import repro.core.frontend as fe

DOCS = os.path.join(os.path.dirname(__file__), "..", "docs")


def _gemm(s=8, epilogue="bias_relu"):
    return quickstart_gemm(s, s, s, epilogue=epilogue)


def _lowered(s=8, tile=4, epilogue="bias_relu"):
    return PassManager.parse(
        f"lower{{tile_m={tile},tile_n={tile},tile_k={tile}}}"
    ).run(_gemm(s, epilogue)).artifact


# --------------------------------------------------------------------------
# the structural protocol
# --------------------------------------------------------------------------


def test_protocol_children_are_the_mutable_lists():
    g = _gemm()
    assert g.children() is g.ops
    k = _lowered()
    assert k.children() is k.body
    loop = k.body[0]
    assert loop.children() is loop.body
    assert k.body[0].body[0].body[0].children() == []      # leaf stmt
    mod = hw_ir.lower_to_hw(k)
    assert mod.children() is mod.ctrl
    hw_loop = mod.ctrl[0]
    assert hw_loop.children() is hw_loop.body
    steps = mod.steps()
    assert steps[0].children() == []


def test_protocol_rebuild_round_trips_each_level():
    g = _gemm()
    g2 = g.rebuild(list(g.children()))
    assert ir_text.print_ir(g2) == ir_text.print_ir(g)
    k = _lowered()
    k2 = k.rebuild(list(k.children()))
    assert ir_text.print_ir(k2) == ir_text.print_ir(k)
    loop = k.body[0]
    assert ir_text.print_stmt(loop.rebuild(list(loop.body))) == \
        ir_text.print_stmt(loop)
    mod = hw_ir.lower_to_hw(k)
    m2 = mod.rebuild(list(mod.children()))
    assert ir_text.print_ir(m2) == ir_text.print_ir(mod)


def test_protocol_is_equivalent_is_structural():
    k1, k2 = _lowered(), _lowered()
    assert k1.is_equivalent(k2) and k1 is not k2
    schedule.flatten_inner(k2)
    assert not k1.is_equivalent(k2)
    g1, g2 = _gemm(), _gemm(4)
    assert g1.is_equivalent(_gemm()) and not g1.is_equivalent(g2)
    m1 = hw_ir.lower_to_hw(_lowered())
    m2 = hw_ir.lower_to_hw(_lowered())
    assert m1.is_equivalent(m2)
    hw_ir.set_sequencer(m2, m2.loops()[0].counter, "stream")
    assert not m1.is_equivalent(m2)


# --------------------------------------------------------------------------
# the driver
# --------------------------------------------------------------------------


class _RetagFirstLoop(Pattern):
    """test-only: rename the first loop it sees (once per loop)."""

    name = "retag"

    def match_and_rewrite(self, parent, siblings, i, root):
        from repro.core.loop_ir import LoopVar
        s = siblings[i]
        if not isinstance(s, Loop) or s.var.name.startswith("rt_"):
            return None
        new_name = "rt_" + s.var.name

        def rn(ref):
            from repro.core.loop_ir import AffineExpr, TileRef
            idx = tuple(AffineExpr(tuple(
                (new_name if v == s.var.name else v, c)
                for v, c in e.coeffs), e.const) for e in ref.index)
            return TileRef(ref.buffer, idx, ref.tile)

        rewrite._map_stmt_refs(s.body, rn)
        s.var = LoopVar(new_name, s.var.extent)
        return (1, [s])


def test_driver_reaches_fixpoint_and_counts_hits():
    k = _lowered()
    n_loops = len(k.loops())
    stats = RewriteDriver([_RetagFirstLoop()]).run(k)
    assert stats.converged
    assert stats.hits == {"retag": n_loops}
    assert all(l.var.name.startswith("rt_") for l in k.loops())
    k.verify()
    # second run: already in target form, no hits, one clean sweep
    stats2 = RewriteDriver([_RetagFirstLoop()]).run(k)
    assert stats2.converged and stats2.total == 0


def test_driver_iteration_cap_reports_non_convergence():
    class Flip(Pattern):
        name = "flip"

        def match_and_rewrite(self, parent, siblings, i, root):
            s = siblings[i]
            if not isinstance(s, Loop):
                return None
            return (1, [s])          # claims a rewrite forever

    stats = RewriteDriver([Flip()], max_iterations=3).run(_lowered())
    assert not stats.converged and stats.iterations == 3
    # canonicalize surfaces a missed fixpoint as a hard error: one sweep
    # can never confirm convergence on a kernel that needed rewrites
    with pytest.raises(RewriteError, match="no fixpoint"):
        canonicalize(_lowered(8, 8), max_iterations=1)


def test_driver_benefit_orders_patterns():
    fired = []

    class Lo(Pattern):
        name = "lo"
        benefit = 1

        def match_and_rewrite(self, parent, siblings, i, root):
            fired.append("lo")
            return None

    class Hi(Pattern):
        name = "hi"
        benefit = 9

        def match_and_rewrite(self, parent, siblings, i, root):
            fired.append("hi")
            return None

    RewriteDriver([Lo(), Hi()]).run(_lowered())
    assert fired and fired[0] == "hi"
    assert fired.index("hi") < fired.index("lo")


def test_collect_stats_scopes_nest_and_merge():
    k = _lowered(8, 8)
    with collect_stats() as outer:
        with collect_stats() as inner:
            canonicalize(k)
        assert inner.get("drop-unit-loop", 0) >= 3
    assert outer == inner            # both scopes saw the same driver


# --------------------------------------------------------------------------
# canonicalize: TensorIR
# --------------------------------------------------------------------------


def test_canonicalize_tensor_dead_ops_and_identities():
    g = Graph("junk")
    a = g.add_input("a", TensorType((4, 4)))
    dead = g.emit("exp", [a])                       # never used
    t = g.emit("transpose", [a], perm=[0, 1])       # identity perm
    c = g.emit("cast", [t], dtype="float32")        # identity cast
    r1 = g.emit("relu", [c])
    r2 = g.emit("relu", [r1])                       # relu∘relu
    g.set_outputs(r2)
    g.verify()
    x = np.random.default_rng(0).standard_normal((4, 4)).astype(np.float32)
    (want,) = g.eval_np(x)

    with collect_stats() as hits:
        canonicalize(g)
    g.verify()
    assert hits["dead-op-elim"] >= 1
    assert hits["fold-identity-transpose"] == 1
    assert hits["fold-identity-cast"] == 1
    assert hits["fold-idempotent-ewise"] == 1
    assert [op.opname for op in g.ops] == ["relu"]  # all folded to one
    (got,) = g.eval_np(x)
    np.testing.assert_array_equal(got, want)
    # idempotent
    t1 = ir_text.print_ir(g)
    canonicalize(g)
    assert ir_text.print_ir(g) == t1


def test_canonicalize_tensor_keeps_live_nonidentity_ops():
    g = _gemm()
    before = ir_text.print_ir(g)
    canonicalize(g)
    assert ir_text.print_ir(g) == before


# --------------------------------------------------------------------------
# canonicalize: LoopIR
# --------------------------------------------------------------------------


def test_canonicalize_loop_drops_unit_loops_preserving_semantics():
    k = _lowered(8, 8)               # full-dim tiles -> all extents 1
    rng = np.random.default_rng(1)
    a, b, c = (rng.standard_normal(s).astype(np.float32)
               for s in ((8, 8), (8, 8), (8,)))
    want = backend_ref.run(k, [a, b, c])[-1]
    with collect_stats() as hits:
        canonicalize(k)
    k.verify()
    assert hits["drop-unit-loop"] >= 3
    assert not k.loops(), "every extent-1 loop must be inlined"
    got = backend_ref.run(k, [a, b, c])[-1]
    np.testing.assert_array_equal(got, want)


def test_canonicalize_loop_merges_independent_seq_nests():
    text = """\
stagecc.kernel @two(a: tensor<8x8xfloat32> @hbm, b: tensor<8x8xfloat32> @hbm, c: tensor<8x8xfloat32> @hbm, d: tensor<8x8xfloat32> @hbm) -> (c, d) {
  for %i in [0,2) @seq {
    c[i, 0 : 4x8] = vpu.relu(a[i, 0 : 4x8])
  }
  for %j in [0,2) @seq {
    d[j, 0 : 4x8] = vpu.neg(b[j, 0 : 4x8])
  }
}
"""
    k = ir_text.parse_kernel(text)
    rng = np.random.default_rng(2)
    a, b = (rng.standard_normal((8, 8)).astype(np.float32) for _ in range(2))
    want = backend_ref.run(k, [a, b])
    with collect_stats() as hits:
        canonicalize(k)
    k.verify()
    assert hits["merge-seq-loops"] == 1
    assert len(k.body) == 1 and len(k.body[0].body) == 2
    got = backend_ref.run(k, [a, b])
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_canonicalize_loop_refuses_dependent_nests():
    """The lowered bias_relu chain is producer/consumer at every seam:
    merge-seq-loops must not fire (that is fuse-epilogue's, tile-grid-
    checked, job)."""
    k = _lowered(8, 4)
    assert len(k.body) == 3
    with collect_stats() as hits:
        canonicalize(k)
    assert hits.get("merge-seq-loops", 0) == 0
    assert len(k.body) == 3


def test_canonicalize_loop_normalizes_tile_refs():
    text = """\
stagecc.kernel @n(a: tensor<8x8xfloat32> @hbm, c: tensor<8x8xfloat32> @hbm) -> (c) {
  for %i in [0,2) @seq {
    c[0*i+i, 0 : 4x8] = vpu.relu(a[i+0*i, 0 : 4x8])
  }
}
"""
    k = ir_text.parse_kernel(text)
    with collect_stats() as hits:
        canonicalize(k)
    assert hits["normalize-tileref"] == 1
    assert "c[i, 0 : 4x8] = vpu.relu(a[i, 0 : 4x8])" in ir_text.print_ir(k)


def test_normalize_affine_unit():
    from repro.core.loop_ir import AffineExpr
    e = AffineExpr((("j", 1), ("i", 2), ("j", -1), ("i", 1)), 3)
    n = normalize_affine(e)
    assert n.coeffs == (("i", 3),) and n.const == 3
    env = {"i": 5, "j": 7}
    assert n.evaluate(env) == e.evaluate(env)


# --------------------------------------------------------------------------
# canonicalize: HwIR
# --------------------------------------------------------------------------


def test_canonicalize_hw_collapses_trip1_and_dedupes_units():
    k = _lowered(8, 8)               # unit extents everywhere
    mod = hw_ir.lower_to_hw(k)
    n_units = len(mod.units)
    inputs = hw_sim.random_inputs(mod, seed=0)
    want = hw_sim.simulate(mod, inputs)
    with collect_stats() as hits:
        canonicalize(mod)
    mod.verify()
    assert hits["collapse-trip1-sequencer"] >= 3
    assert hits["dedupe-units"] >= 1
    assert not mod.loops() and len(mod.units) < n_units
    got = hw_sim.simulate(mod, inputs)
    for name in want.out_ports:
        np.testing.assert_array_equal(got.storage[name],
                                      want.storage[name])
    # fewer FSM states, never more
    assert got.cycles.total <= want.cycles.total
    # model and sim stay consistent on the canonical module
    modeled = machine_model.cycles(mod).total
    assert abs(got.cycles.total - modeled) <= max(1, 0.1 * modeled)


def test_canonicalize_hw_normalizes_address_generators():
    k = _lowered(8, 4)
    mod = hw_ir.lower_to_hw(k)
    # denormalize one operand's address generator by hand
    step = mod.steps()[1]
    o = step.operands[1]
    from repro.core.loop_ir import AffineExpr
    dirty = tuple(AffineExpr(e.coeffs + tuple((v, 0) for v, _ in e.coeffs),
                             e.const) for e in o.index)
    object.__setattr__(o, "index", dirty)
    with collect_stats() as hits:
        canonicalize(mod)
    assert hits["normalize-addr-gen"] == 1
    mod.verify()
    t1 = ir_text.print_ir(mod)
    canonicalize(mod)
    assert ir_text.print_ir(mod) == t1


# --------------------------------------------------------------------------
# acceptance: canonicalize across every schedule x size, cosim-checked
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sched", SCHEDULES)
@pytest.mark.parametrize("size", [4, 8, 16])
def test_canonicalize_idempotent_and_cosim_equivalent(sched, size):
    """PR-5 acceptance: with canonicalize wired between lowerings the
    compiled kernel still co-simulates within 1e-5 of the numpy oracle,
    and one canonicalize run is a fixpoint at every level."""
    ck = compile_gemm(size, size, size, schedule=sched,
                      epilogue="bias_relu", want_jax=False,
                      want_pallas=False, canonicalize=True)
    assert any(r.name == "canonicalize" for r in ck.pass_records)
    rng = np.random.default_rng(size)
    a = rng.standard_normal((size, size)).astype(np.float32)
    b = rng.standard_normal((size, size)).astype(np.float32)
    bias = rng.standard_normal((size,)).astype(np.float32)
    rep = ck.simulate(a, b, bias, atol=1e-5)
    (want,) = ck.graph.eval_np(a, b, bias)
    got = rep.outputs[-1] if isinstance(rep.outputs, list) else rep.outputs
    np.testing.assert_allclose(got, want, atol=1e-5)
    # idempotence at every level: a second canonicalize changes nothing
    for art in (ck.graph, ck.kernel, ck.hw_module):
        t1 = ir_text.print_ir(art)
        canonicalize(ir_text.parse_ir(t1))
        assert ir_text.print_ir(canonicalize(ir_text.parse_ir(t1))) == t1


# --------------------------------------------------------------------------
# pattern-ported schedule transforms: round-trip-stable text
# --------------------------------------------------------------------------


@pytest.mark.parametrize("transform", ["split", "interchange", "fuse"])
def test_ported_transforms_round_trip_stable(transform):
    k = _lowered(8, 2)
    if transform == "split":
        schedule.split(k, k.loops()[0].var.name, 2)
    elif transform == "interchange":
        loops = k.loops()
        schedule.interchange(k, loops[0].var.name, loops[1].var.name)
    else:
        schedule.fuse_epilogue(k)
    t1 = ir_text.print_ir(k)
    t2 = ir_text.print_ir(ir_text.parse_ir(t1))
    assert t1 == t2, f"{transform} output must round-trip stably"


def test_ported_transforms_report_pattern_hits():
    r = PassManager.parse(
        "lower{tile_m=4,tile_n=4,tile_k=4},fuse-epilogue,"
        "split{var=i1,factor=2},interchange{outer=i1_o,inner=i1_i},"
        "unroll{var=i1_i}").run(_gemm(8))
    by_name = {rec.name: rec for rec in r.records}
    assert by_name["fuse-epilogue"].pattern_stats == {"fuse-epilogue": 2}
    assert by_name["split"].pattern_stats == {"split-loop": 1}
    assert by_name["interchange"].pattern_stats == \
        {"interchange-loops": 1}
    assert by_name["unroll"].pattern_stats == {"set-loop-kind": 1}
    assert "patterns:" in by_name["split"].summary()


def test_set_sequencer_is_pattern_ported():
    mod = hw_ir.lower_to_hw(_lowered(8, 4))
    r = PassManager.parse(
        f"set-sequencer{{counter={mod.loops()[0].counter},kind=stream}}"
    ).run(mod)
    assert r.records[0].pattern_stats == {"set-sequencer": 1}


# --------------------------------------------------------------------------
# registration / wiring
# --------------------------------------------------------------------------


def test_canonicalize_registered_at_all_three_levels():
    pd = PASS_REGISTRY["canonicalize"]
    assert pd.levels == ("tensor", "loop", "hw")
    assert len(pd.pattern_names) == sum(len(v) for v in
                                        CANONICAL_PATTERNS.values())
    # and it actually runs at each level through the PassManager
    r1 = PassManager.parse("canonicalize").run(_gemm())
    assert r1.records[0].level == "tensor"
    r2 = PassManager.parse("lower,canonicalize").run(_gemm())
    assert r2.records[-1].level == "loop"
    r3 = PassManager.parse("lower,lower-to-hw,canonicalize").run(_gemm())
    assert r3.records[-1].level == "hw"


def test_canonicalize_rejects_backend_artifact():
    from repro.core.passes import PassError
    with pytest.raises(PassError, match="tensor/loop/hw-level pass"):
        PassManager.parse("lower,emit-ref,canonicalize").run(_gemm())


def test_register_canonical_pattern_extends_a_level():
    class Nop(Pattern):
        name = "thirdparty-nop"

        def match_and_rewrite(self, parent, siblings, i, root):
            return None

    if not any(p.name == "thirdparty-nop"
               for p in CANONICAL_PATTERNS["loop"]):
        rewrite.register_canonical_pattern("loop")(Nop)
    assert any(p.name == "thirdparty-nop"
               for p in CANONICAL_PATTERNS["loop"])
    canonicalize(_lowered())         # still converges with the extra rule
    # late registrations show up in the pass metadata (it resolves live,
    # not from an import-time snapshot)
    assert "loop:thirdparty-nop" in \
        PASS_REGISTRY["canonicalize"].pattern_names
    CANONICAL_PATTERNS["loop"] = [
        p for p in CANONICAL_PATTERNS["loop"]
        if p.name != "thirdparty-nop"]
    with pytest.raises(ValueError, match="no canonicalization set"):
        rewrite.register_canonical_pattern("backend")


def test_canonicalize_keeps_grid_loops_for_pallas():
    """Annotation-bearing loop kinds survive canonicalization: the
    @grid nest IS the pallas mapping, so compile(canonicalize=True)
    must not silently lose the pallas backend (found in review)."""
    ck = compile_gemm(8, 8, 8, schedule="tpu_mxu", epilogue="bias_relu",
                      want_jax=False, want_pallas=True, canonicalize=True)
    from repro.core.loop_ir import LoopKind
    kinds = {l.kind for l in ck.kernel.loops()}
    assert LoopKind.GRID in kinds
    assert ck.run_pallas is not None, \
        "canonicalize must not cost tpu_mxu its pallas emission"
    rng = np.random.default_rng(7)
    a, b, bias = (rng.standard_normal(s).astype(np.float32)
                  for s in ((8, 8), (8, 8), (8,)))
    res = ck.run_pallas(a, b, bias)
    out = np.asarray(res[-1] if isinstance(res, (list, tuple)) else res)
    (want,) = ck.graph.eval_np(a, b, bias)
    np.testing.assert_allclose(out, want, atol=1e-5)


def test_docs_rewrite_md_in_sync():
    """docs/REWRITE.md is generated; regenerate with `make docs`."""
    import subprocess
    import sys
    gen = subprocess.run(
        [sys.executable, os.path.join(DOCS, "..", "scripts",
                                      "gen_rewrite_md.py")],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(DOCS, "..", "src")})
    assert gen.returncode == 0, gen.stderr
    with open(os.path.join(DOCS, "REWRITE.md")) as f:
        assert f.read().rstrip("\n") == gen.stdout.rstrip("\n")
