"""Autotuner: cost-model-driven schedule search (beyond-paper feature)."""

import dataclasses

import numpy as np
import pytest

from repro.core.autotune import (best_schedule, compile_gemm_autotuned,
                                 enumerate_candidates, family_points)
from repro.core.machine_model import TPU_V5E
from repro.core.pipeline import compile_gemm


def test_candidates_sorted_and_feasible_first():
    cands = enumerate_candidates(256, 256, 256)
    assert len(cands) > 4
    cyc = [c.cycles for c in cands if c.feasible]
    assert cyc == sorted(cyc)
    assert cands[0].feasible


def test_autotuned_never_worse_than_naive_tiles():
    """The chosen schedule must beat (or match) an arbitrary legal one."""
    for m, n, k in ((256, 256, 256), (512, 128, 64), (128, 384, 256)):
        tuned = compile_gemm_autotuned(m, n, k, interpret=True)
        naive = compile_gemm(m, n, k, schedule="tpu_mxu_kgrid",
                             tile={"m": 8, "n": 8, "k": 8},
                             want_jax=False, want_pallas=False)
        assert tuned.cycles.total <= naive.cycles.total


def test_autotuned_correctness():
    rng = np.random.default_rng(0)
    m, n, k = 128, 96, 64
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    ck = compile_gemm_autotuned(m, n, k)
    np.testing.assert_allclose(ck.run_ref(a, b)[0], a @ b, rtol=1e-4)
    if ck.run_pallas is not None:
        np.testing.assert_allclose(np.asarray(ck.run_pallas(a, b)), a @ b,
                                   rtol=1e-4, atol=1e-4)


def test_mxu_aligned_tiles_preferred_on_big_gemm():
    sched, tile = best_schedule(1024, 1024, 1024)
    assert tile[0] >= 128 and tile[1] >= 128, \
        f"MXU-aligned tiles expected, got {tile}"


def test_odd_shapes_get_legal_tiles():
    sched, (tm, tn, tk) = best_schedule(96, 56, 24)
    assert 96 % tm == 0 and 56 % tn == 0 and 24 % tk == 0


def test_candidate_signatures_unique():
    """Regression (PR 4): tpu_mxu's working set is tk-independent (full
    K resident) and its cycles are monotone in tk, so per-tk
    enumeration spent up to 6x budget on cost-dominated spellings of
    each (tm, tn) point.  Canonical signatures must make every
    enumerated candidate a distinct design point."""
    cands = enumerate_candidates(64, 64, 64)
    sigs = [(c.schedule, c.tile["m"], c.tile["n"], c.tile["k"])
            for c in cands]
    assert len(sigs) == len(set(sigs)), "duplicate candidates enumerated"
    # tpu_mxu's canonical point pins tk to the full reduction
    assert all(c.tile["k"] == 64 for c in cands
               if c.schedule == "tpu_mxu")
    # one point per (tm, tn) for tpu_mxu; (tm, tn, tk) for kgrid
    pts = family_points(64, 64, 64)
    assert len(pts["tpu_mxu"]) == 4 * 4
    assert len(pts["tpu_mxu_kgrid"]) == 4 * 4 * 4


def test_budget_cannot_evict_a_family():
    """64^3 has 64 unique kgrid points — exactly the default budget.
    Pre-fix, enumeration order let one family fill max_candidates and
    evict the other entirely; the round-robin budget keeps both."""
    cands = enumerate_candidates(64, 64, 64)
    assert len(cands) <= 64
    fams = {c.schedule for c in cands}
    assert fams == {"tpu_mxu", "tpu_mxu_kgrid"}
    # every tpu_mxu point fits under the budget next to kgrid's cube
    assert sum(c.schedule == "tpu_mxu" for c in cands) == 16


def test_best_schedule_is_machine_keyed():
    """Regression (PR 4): best_schedule was lru_cached without the
    machine, so a second machine silently reused the first's winner.
    A VMEM-starved machine must pick a different (smaller) schedule."""
    m = n = k = 512
    big = dataclasses.replace(TPU_V5E, name="big_vmem")
    # winner tile on the default machine claims (tm*k + k*tn + tm*tn)*4
    # bytes; starve VMEM below that so the same point turns infeasible
    sched_big, tile_big = best_schedule(m, n, k, machine=big)
    tm, tn, tk = tile_big
    claim = (tm * k + k * tn) * 4 + tm * tn * 4 \
        if sched_big == "tpu_mxu" else (tm * tk + tk * tn) * 4 + tm * tn * 4
    small = dataclasses.replace(TPU_V5E, name="small_vmem",
                                vmem_capacity_bytes=claim // 4)
    sched_small, tile_small = best_schedule(m, n, k, machine=small)
    assert (sched_big, tile_big) != (sched_small, tile_small), \
        "VMEM-starved machine reused the big machine's schedule"
    # and the small machine's winner actually fits its budget
    tm, tn, tk = tile_small
    claim_small = (tm * k + k * tn) * 4 + tm * tn * 4 \
        if sched_small == "tpu_mxu" \
        else (tm * tk + tk * tn) * 4 + tm * tn * 4
    assert claim_small <= small.vmem_capacity_bytes
