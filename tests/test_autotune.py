"""Autotuner: cost-model-driven schedule search (beyond-paper feature)."""

import numpy as np
import pytest

from repro.core.autotune import (best_schedule, compile_gemm_autotuned,
                                 enumerate_candidates)
from repro.core.pipeline import compile_gemm


def test_candidates_sorted_and_feasible_first():
    cands = enumerate_candidates(256, 256, 256)
    assert len(cands) > 4
    cyc = [c.cycles for c in cands if c.feasible]
    assert cyc == sorted(cyc)
    assert cands[0].feasible


def test_autotuned_never_worse_than_naive_tiles():
    """The chosen schedule must beat (or match) an arbitrary legal one."""
    for m, n, k in ((256, 256, 256), (512, 128, 64), (128, 384, 256)):
        tuned = compile_gemm_autotuned(m, n, k, interpret=True)
        naive = compile_gemm(m, n, k, schedule="tpu_mxu_kgrid",
                             tile={"m": 8, "n": 8, "k": 8},
                             want_jax=False, want_pallas=False)
        assert tuned.cycles.total <= naive.cycles.total


def test_autotuned_correctness():
    rng = np.random.default_rng(0)
    m, n, k = 128, 96, 64
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    ck = compile_gemm_autotuned(m, n, k)
    np.testing.assert_allclose(ck.run_ref(a, b)[0], a @ b, rtol=1e-4)
    if ck.run_pallas is not None:
        np.testing.assert_allclose(np.asarray(ck.run_pallas(a, b)), a @ b,
                                   rtol=1e-4, atol=1e-4)


def test_mxu_aligned_tiles_preferred_on_big_gemm():
    sched, tile = best_schedule(1024, 1024, 1024)
    assert tile[0] >= 128 and tile[1] >= 128, \
        f"MXU-aligned tiles expected, got {tile}"


def test_odd_shapes_get_legal_tiles():
    sched, (tm, tn, tk) = best_schedule(96, 56, 24)
    assert 96 % tm == 0 and 56 % tn == 0 and 24 % tk == 0
