"""serve_bench: schema gate, deterministic virtual-clock runs, mesh path."""

import copy
import importlib.util
import json
import os

import pytest

_BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "serve_bench.py")


def _load_bench():
    spec = importlib.util.spec_from_file_location("serve_bench", _BENCH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def bench():
    return _load_bench()


@pytest.fixture(scope="module")
def entry(bench):
    """One tiny virtual-clock run shared across schema tests."""
    return bench.run_config(
        "qwen2_7b", slots=2, requests=6, rate=8.0, process="poisson",
        seed=0, clock_kind="virtual", queue_limit=4, prompt_hi=6,
        out_hi=4, with_plan=False, max_len=32)


def test_entry_has_required_metrics(bench, entry):
    doc = {"schema": "serve_bench/v1", "entries": [entry]}
    bench.check_bench(doc)                   # raises on any violation
    m = entry["metrics"]
    assert m["tokens_per_s"] > 0
    assert entry["requests_completed"] == 6
    for h in ("ttft", "tpot", "e2e"):
        for k in ("p50", "p90", "p99"):
            assert m[h][k] >= 0
    json.dumps(doc)


def test_virtual_clock_runs_are_reproducible(bench, entry):
    again = bench.run_config(
        "qwen2_7b", slots=2, requests=6, rate=8.0, process="poisson",
        seed=0, clock_kind="virtual", queue_limit=4, prompt_hi=6,
        out_hi=4, with_plan=False, max_len=32)
    assert again["stream_digest"] == entry["stream_digest"]
    assert again["metrics"] == entry["metrics"]


def test_check_bench_rejects_malformed(bench, entry):
    with pytest.raises(ValueError, match="bad schema"):
        bench.check_bench({"schema": "nope", "entries": [entry]})
    with pytest.raises(ValueError, match="no entries"):
        bench.check_bench({"schema": "serve_bench/v1", "entries": []})

    broken = copy.deepcopy(entry)
    del broken["metrics"]["tpot"]
    with pytest.raises(ValueError, match="missing metric 'tpot'"):
        bench.check_bench({"schema": "serve_bench/v1", "entries": [broken]})

    broken = copy.deepcopy(entry)
    broken["metrics"]["tokens_per_s"] = 0.0
    with pytest.raises(ValueError, match="tokens_per_s"):
        bench.check_bench({"schema": "serve_bench/v1", "entries": [broken]})

    broken = copy.deepcopy(entry)
    broken["requests_completed"] = 99
    with pytest.raises(ValueError, match="request accounting"):
        bench.check_bench({"schema": "serve_bench/v1", "entries": [broken]})


def test_parse_mesh(bench):
    assert bench.parse_mesh(None) is None
    mesh = bench.parse_mesh("data=1")
    assert dict(mesh.shape) == {"data": 1}
    with pytest.raises(SystemExit, match="devices"):
        bench.parse_mesh("data=4096")


def test_run_config_under_mesh(bench):
    mesh = bench.parse_mesh("data=1")
    e = bench.run_config(
        "qwen2_7b", slots=1, requests=3, rate=8.0, process="uniform",
        seed=1, clock_kind="virtual", queue_limit=None, prompt_hi=5,
        out_hi=3, with_plan=False, mesh=mesh, max_len=32)
    assert e["mesh"] == {"data": 1}
    assert e["requests_completed"] == 3


@pytest.mark.slow
def test_main_smoke_writes_valid_json(bench, tmp_path):
    out = tmp_path / "BENCH_serve.json"
    rc = bench.main(["--smoke", "--clock", "virtual", "--no-plan",
                     "--configs", "qwen2_7b", "--out", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    bench.check_bench(doc)
    assert [e["config"] for e in doc["entries"]] == ["qwen2_7b"]


@pytest.mark.slow
def test_main_with_compile_plan(bench, tmp_path):
    out = tmp_path / "BENCH_serve.json"
    rc = bench.main(["--smoke", "--clock", "virtual",
                     "--configs", "qwen2_7b", "--out", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    e = doc["entries"][0]
    assert e["compiled_count"] >= 1
    assert any(r["status"] == "compiled" for r in e["compiled_blocks"])
