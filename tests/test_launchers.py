"""CLI entrypoint smokes: the train and serve launchers run end to end."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=REPO)


@pytest.mark.slow
def test_train_cli(tmp_path):
    r = _run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
              "--steps", "6", "--seq-len", "32", "--global-batch", "4",
              "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "3"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss first->last" in r.stdout
    assert any(n.startswith("step_") for n in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "mamba2-130m", "--reduced",
              "--batch", "2", "--prompt-len", "8", "--gen", "6"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


@pytest.mark.slow
def test_dryrun_list_cli():
    r = _run(["repro.launch.dryrun", "--list"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.count("SKIP") == 7       # the 7 long_500k skips
    assert r.stdout.count("run") >= 33
