"""Load generator: replayable seeds, arrival processes, length bounds."""

import numpy as np
import pytest

from repro.serve.loadgen import (GenRequest, LengthDist, LoadConfig,
                                 generate_stream, stream_digest)


def _streams_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x.rid == y.rid
        assert x.arrival == y.arrival
        assert x.max_new == y.max_new
        np.testing.assert_array_equal(x.prompt, y.prompt)


def test_same_seed_same_stream():
    cfg = LoadConfig(num_requests=40, seed=7, process="poisson")
    _streams_equal(generate_stream(cfg), generate_stream(cfg))
    assert stream_digest(generate_stream(cfg)) == \
        stream_digest(generate_stream(cfg))


def test_different_seed_different_stream():
    a = generate_stream(LoadConfig(num_requests=40, seed=1))
    b = generate_stream(LoadConfig(num_requests=40, seed=2))
    assert stream_digest(a) != stream_digest(b)


@pytest.mark.parametrize("process", ["poisson", "bursty", "uniform"])
def test_processes_produce_monotone_arrivals(process):
    cfg = LoadConfig(num_requests=64, seed=3, process=process, rate=8.0)
    stream = generate_stream(cfg)
    arr = np.asarray([r.arrival for r in stream])
    assert (np.diff(arr) >= 0).all()
    assert arr[0] > 0


def test_poisson_rate_roughly_matches():
    cfg = LoadConfig(num_requests=2000, seed=0, process="poisson", rate=10.0)
    stream = generate_stream(cfg)
    mean_gap = stream[-1].arrival / len(stream)
    assert 0.08 <= mean_gap <= 0.125          # 1/rate within ~25%


def test_bursty_has_higher_variance_than_poisson():
    kw = dict(num_requests=2000, seed=0, rate=4.0)
    pois = generate_stream(LoadConfig(process="poisson", **kw))
    burst = generate_stream(LoadConfig(process="bursty", burst_rate=64.0,
                                       burst_fraction=0.2, **kw))
    cv = lambda s: np.std(np.diff([0.0] + [r.arrival for r in s])) \
        / np.mean(np.diff([0.0] + [r.arrival for r in s]))
    assert cv(burst) > cv(pois)


def test_lengths_respect_bounds_and_vocab():
    cfg = LoadConfig(num_requests=100, seed=5, vocab_size=17,
                     prompt=LengthDist("lognormal", 2, 9, mu=1.5),
                     output=LengthDist("uniform", 3, 5))
    for r in generate_stream(cfg):
        assert 2 <= len(r.prompt) <= 9
        assert 3 <= r.max_new <= 5
        assert r.prompt.dtype == np.int32
        assert r.prompt.min() >= 0 and r.prompt.max() < 17


def test_fixed_lengths():
    cfg = LoadConfig(num_requests=10, seed=0,
                     prompt=LengthDist("fixed", 6, 6),
                     output=LengthDist("fixed", 4, 4))
    for r in generate_stream(cfg):
        assert len(r.prompt) == 6 and r.max_new == 4


def test_unknown_process_rejected():
    with pytest.raises(ValueError, match="unknown arrival process"):
        generate_stream(LoadConfig(process="fractal"))
    with pytest.raises(ValueError, match="unknown length"):
        LengthDist("zipf").sample(np.random.default_rng(0), 3)
