"""Continuous-batching scheduler: results must match single-request
generation exactly (greedy), regardless of slot scheduling order."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import Model, RunConfig
from repro.serve.engine import (ContinuousEngine, Engine, EngineConfig,
                                Request)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2_7b"))
    model = Model(cfg, RunConfig(max_seq=64))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_matches_sequential(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (4 + i,)).astype(np.int32),
                    max_new=5)
            for i in range(6)]

    ce = ContinuousEngine(model, params, slots=2, max_len=64)
    got = ce.serve(list(reqs))

    eng = Engine(model, params, EngineConfig(max_len=64))
    for r in reqs:
        want = eng.generate(r.prompt[None, :], r.max_new)[0,
                                                          len(r.prompt):]
        np.testing.assert_array_equal(got[r.rid][:r.max_new], want,
                                      err_msg=f"request {r.rid}")


def test_more_requests_than_slots(setup):
    cfg, model, params = setup
    reqs = [Request(rid=i, prompt=np.arange(3, dtype=np.int32),
                    max_new=3) for i in range(7)]
    ce = ContinuousEngine(model, params, slots=3, max_len=32)
    got = ce.serve(reqs)
    assert sorted(got) == list(range(7))
    for v in got.values():
        assert len(v) == 3
