"""Continuous-batching scheduler: results must match single-request
generation exactly (greedy), regardless of slot scheduling order — and
the batched engine (one vmap'd jit'd decode step across all slots) must
be bit-identical to the serial per-slot reference engine."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import Model, RunConfig
from repro.serve.engine import (ContinuousEngine, Engine, EngineConfig,
                                Request, SerialSlotEngine)
from repro.serve.metrics import ServeMetrics, VirtualClock


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2_7b"))
    model = Model(cfg, RunConfig(max_seq=64))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def setup_ssm():
    cfg = reduced(get_config("mamba2_130m"))
    model = Model(cfg, RunConfig(max_seq=64))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mixed_requests(cfg, n=6, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (4 + i,)).astype(np.int32),
                    max_new=int(rng.integers(1, 8)))
            for i in range(n)]


def test_continuous_matches_sequential(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (4 + i,)).astype(np.int32),
                    max_new=5)
            for i in range(6)]

    ce = ContinuousEngine(model, params, slots=2, max_len=64)
    got = ce.serve(list(reqs))

    eng = Engine(model, params, EngineConfig(max_len=64))
    for r in reqs:
        want = eng.generate(r.prompt[None, :], r.max_new)[0,
                                                          len(r.prompt):]
        np.testing.assert_array_equal(got[r.rid][:r.max_new], want,
                                      err_msg=f"request {r.rid}")


def test_more_requests_than_slots(setup):
    cfg, model, params = setup
    reqs = [Request(rid=i, prompt=np.arange(3, dtype=np.int32),
                    max_new=3) for i in range(7)]
    ce = ContinuousEngine(model, params, slots=3, max_len=32)
    got = ce.serve(reqs)
    assert sorted(got) == list(range(7))
    for v in got.values():
        assert len(v) == 3


@pytest.mark.parametrize("fixture", ["setup", "setup_ssm"])
def test_batched_bit_identical_to_serial(fixture, request):
    """Acceptance: the vmap-batched decode step emits bit-identical
    greedy token streams to the old per-slot B=1 engine on a mixed
    request set (different prompt lengths, different max_new incl. 1)."""
    cfg, model, params = request.getfixturevalue(fixture)
    reqs = _mixed_requests(cfg)
    batched = ContinuousEngine(model, params, slots=2, max_len=64).serve(
        [Request(r.rid, r.prompt, r.max_new) for r in reqs])
    serial = SerialSlotEngine(model, params, slots=2, max_len=64).serve(
        [Request(r.rid, r.prompt, r.max_new) for r in reqs])
    assert sorted(batched) == sorted(serial) == [r.rid for r in reqs]
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], serial[r.rid],
                                      err_msg=f"request {r.rid}")
        assert len(batched[r.rid]) == r.max_new


@pytest.mark.parametrize("engine_cls", [ContinuousEngine, SerialSlotEngine])
def test_max_new_one_emits_exactly_one_token(setup, engine_cls):
    """Regression: admit() samples the first token at prefill, so a
    max_new=1 request must finish WITHOUT a decode step (the old
    engine emitted 2 tokens)."""
    cfg, model, params = setup
    reqs = [Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=1),
            Request(rid=1, prompt=np.arange(5, dtype=np.int32), max_new=3)]
    got = engine_cls(model, params, slots=2, max_len=32).serve(reqs)
    assert len(got[0]) == 1
    assert len(got[1]) == 3
    eng = Engine(model, params, EngineConfig(max_len=32))
    want = eng.generate(reqs[0].prompt[None, :], 1)[0, 4:]
    np.testing.assert_array_equal(got[0], want)


def test_submit_step_api_and_backpressure(setup):
    cfg, model, params = setup
    eng = ContinuousEngine(model, params, slots=2, max_len=32,
                           queue_limit=2)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=4)
            for i in range(5)]
    assert eng.submit(reqs[0])
    assert eng.submit(reqs[1])
    assert not eng.submit(reqs[2])       # queue full -> backpressure
    assert eng.queue_depth == 2
    eng.step()                           # admits into both slots + 1 decode
    assert eng.active_slots == 2 and eng.queue_depth == 0
    assert eng.submit(reqs[2]) and eng.submit(reqs[3])
    eng.drain()
    assert not eng.busy
    assert sorted(eng.results) == [0, 1, 2, 3]
    for v in eng.results.values():
        assert len(v) == 4


def test_batched_engine_records_metrics(setup):
    cfg, model, params = setup
    metrics = ServeMetrics(VirtualClock(), slots=2)
    eng = ContinuousEngine(model, params, slots=2, max_len=32,
                           metrics=metrics)
    reqs = [Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32),
                    max_new=3) for i in range(4)]
    eng.serve(reqs)
    snap = metrics.snapshot()
    assert snap["requests"]["submitted"] == 4
    assert snap["requests"]["completed"] == 4
    assert snap["tokens"]["decode"] == 4 * 3
    assert snap["tokens"]["prefill"] == sum(3 + i for i in range(4))
    assert snap["ttft"]["count"] == 4
    assert snap["tpot"]["count"] == 4 * 2     # gaps between 3 tokens
    assert snap["slot_utilization"] > 0


def test_max_len_truncates_generation(setup):
    """A request whose prompt+output would overflow max_len finishes at
    the cache boundary instead of writing past it."""
    cfg, model, params = setup
    req = Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=50)
    got = ContinuousEngine(model, params, slots=1, max_len=16).serve([req])
    ref = SerialSlotEngine(model, params, slots=1, max_len=16).serve(
        [Request(0, req.prompt, 50)])
    np.testing.assert_array_equal(got[0], ref[0])
    assert len(got[0]) < 50


def test_temperature_sampling_stays_in_vocab(setup):
    cfg, model, params = setup
    eng = ContinuousEngine(model, params, slots=2, max_len=32,
                           temperature=1.0, seed=3)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=4)
            for i in range(3)]
    got = eng.serve(reqs)
    for v in got.values():
        assert v.min() >= 0 and v.max() < cfg.vocab_size

    # per-slot keys are folded from (seed, rid): same seed -> same streams
    eng2 = ContinuousEngine(model, params, slots=2, max_len=32,
                            temperature=1.0, seed=3)
    got2 = eng2.serve([Request(i, np.arange(4, dtype=np.int32), 4)
                       for i in range(3)])
    for rid in got:
        np.testing.assert_array_equal(got[rid], got2[rid])
