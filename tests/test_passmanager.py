"""PassManager: level checking, inter-pass verification, instrumentation,
string-spec round-trip, and the reproc CLI driver."""

import io
import os

import numpy as np
import pytest

from repro.core import (PASS_REGISTRY, PassError, PassManager, compile_gemm,
                        register_pass, run_pipeline)
from repro.core import reproc
from repro.core.frontend import spec, trace
from repro.core.loop_ir import (AffineExpr, Buffer, Kernel, Loop, LoopKind,
                                LoopVar, MemSpace, TileRef, ZeroTile)
from repro.core.passes import parse_pipeline, resolve_pass
from repro.core.tensor_ir import TensorType
import repro.core.frontend as fe

DOCS = os.path.join(os.path.dirname(__file__), "..", "docs")

# snapshot at import, before any test-only register_pass() calls below run,
# so docs-sync comparisons see exactly the built-in registry
_CLEAN_MD = reproc.passes_markdown()
_BUILTIN_PASSES = {name: pd.doc for name, pd in PASS_REGISTRY.items()}


def _gemm_graph(m=8, n=8, k=8):
    def f(a, b):
        return fe.matmul(a, b)
    return trace(f, [spec((m, k)), spec((k, n))])


# ---- construction ----------------------------------------------------------


def test_programmatic_equals_string_spec():
    g = _gemm_graph()
    r1 = (PassManager().add("lower", tile_m=2, tile_n=2, tile_k=2)
          .add("flatten-inner").run(g))
    g2 = _gemm_graph()
    r2 = PassManager.parse("lower{tile_m=2,tile_n=2,tile_k=2},flatten-inner") \
        .run(g2)
    assert str(r1.artifact) == str(r2.artifact)


def test_spec_roundtrip():
    s = "lower{tile_m=2,tile_n=2,tile_k=2},flatten-inner"
    pm = PassManager.parse(s)
    assert pm.spec() == s
    assert PassManager.parse(pm.spec()).spec() == s


def test_spec_roundtrip_preserves_bool_kwargs():
    """bool("False") is True — spec() must serialise bools as 0/1 so the
    re-parsed pipeline is semantically identical."""
    pm = PassManager().add("lower", tile_m=4, tile_n=4, tile_k=4,
                           use_accumulator=False)
    k1 = pm.run(_gemm_graph()).artifact
    k2 = PassManager.parse(pm.spec()).run(_gemm_graph()).artifact
    assert [b.name for b in k1.scratch] == [b.name for b in k2.scratch] == []


def test_run_does_not_render_dumps_unless_asked():
    """Textual IR dumps are hot-path overhead; without a dump flag the
    trace stays empty and records carry no dump text."""
    r = PassManager.parse("lower").run(_gemm_graph())
    assert r.trace == []
    assert r.records[0].dump_before is None
    assert r.records[0].dump_after is None


def test_semicolon_separator_and_aliases():
    stages = parse_pipeline("lower{tile_m=4,tile_n=4,tile_k=4};flatten")
    assert [s["name"] for s in stages] == ["lower", "flatten"]
    assert resolve_pass("flatten").name == "flatten-inner"
    assert resolve_pass("fuse").name == "fuse-epilogue"
    r = PassManager.parse("lower{tile_m=4,tile_n=4,tile_k=4};flatten") \
        .run(_gemm_graph())
    assert [rec.name for rec in r.records] == ["lower", "flatten-inner"]


def test_unknown_pass_raises_keyerror():
    with pytest.raises(KeyError):
        run_pipeline(_gemm_graph(4, 4, 4), "nonexistent-pass")
    with pytest.raises(KeyError):
        PassManager().add("nonexistent-pass")


def test_unknown_pass_did_you_mean():
    with pytest.raises(KeyError, match="did you mean 'flatten-inner'"):
        PassManager().add("flatten-iner")
    with pytest.raises(KeyError, match="did you mean 'canonicalize'"):
        PassManager().add("canonicalise")


# ---- pipeline-spec hardening ------------------------------------------------


@pytest.mark.parametrize("spec,fragment", [
    ("lower{tile_m=4", "unclosed '{' at offset 5"),
    ("lower{tile_m={4}}", "nested '{' at offset 13"),
    ("lower}ugh", "unbalanced '}' at offset 5"),
    ("lower,,flatten", "empty pipeline stage before ',' at offset 6"),
    (",lower", "empty pipeline stage before ',' at offset 0"),
    ("lower;;flatten", "empty pipeline stage before ';' at offset 6"),
    ("lower{}", "empty argument braces on 'lower' at offset 0"),
    ("lower{tile_m=}", "bad pass argument 'tile_m='"),
    ("lower{=4}", "bad pass argument '=4'"),
    ("lower{tile_m 4}", "bad pass argument 'tile_m 4'"),
    ("low er", "bad pipeline stage 'low er' at offset 0"),
])
def test_pipeline_parse_errors_name_offset(spec, fragment):
    from repro.core.passes import PipelineParseError
    with pytest.raises(PipelineParseError) as ei:
        parse_pipeline(spec)
    assert fragment in str(ei.value)
    assert repr(spec) in str(ei.value)      # the offending spec is echoed


def test_pipeline_parse_errors_reach_cli_as_diagnostics(capsys):
    rc, _ = _run_cli(["--pipeline", "lower{tile_m=4"])
    assert rc == 1
    assert "unclosed '{'" in capsys.readouterr().err


def test_pipeline_parser_still_accepts_benign_edges():
    assert parse_pipeline("") == []
    assert parse_pipeline("lower,") == [{"name": "lower", "kwargs": {}}]
    stages = parse_pipeline(" lower { tile_m = 4 } ; flatten ".replace(" ", ""))
    assert [s["name"] for s in stages] == ["lower", "flatten"]


# ---- level checking --------------------------------------------------------


def test_loop_pass_rejects_tensor_artifact():
    with pytest.raises(PassError, match="loop-level pass"):
        PassManager.parse("flatten-inner").run(_gemm_graph())


def test_tensor_pass_rejects_loop_artifact():
    with pytest.raises(PassError, match="tensor-level pass"):
        PassManager.parse("lower,lower").run(_gemm_graph())


def test_backend_passes_are_terminal():
    with pytest.raises(PassError, match="terminal"):
        PassManager.parse("lower,emit-ref,flatten-inner").run(_gemm_graph())


# ---- instrumentation -------------------------------------------------------


def test_records_capture_time_and_size():
    r = PassManager.parse("lower{tile_m=2,tile_n=2,tile_k=2},flatten-inner") \
        .run(_gemm_graph())
    assert [rec.name for rec in r.records] == ["lower", "flatten-inner"]
    lower = r.records[0]
    assert lower.level == "tensor"
    assert lower.kwargs == {"tile_m": 2, "tile_n": 2, "tile_k": 2}
    assert lower.wall_ms >= 0
    assert lower.size_before == 1           # one matmul op
    assert lower.size_after > lower.size_before
    assert "lower" in r.timing_table()
    # flatten-inner only re-tags a loop: size is conserved
    assert r.records[1].size_before == r.records[1].size_after


def test_dump_after_each_records_ir_text():
    r = PassManager.parse("lower", dump_after_each=True).run(_gemm_graph())
    assert r.records[0].dump_after.startswith("stagecc.kernel @")
    assert r.records[0].dump_before is None
    r2 = PassManager.parse("lower", dump_before_each=True).run(_gemm_graph())
    assert r2.records[0].dump_before.startswith("stagecc.func @")


def test_compiled_kernel_carries_pass_records():
    ck = compile_gemm(8, 8, 8, schedule="tpu_mxu", want_jax=False,
                      want_pallas=False)
    assert [r.name for r in ck.pass_records] == ["lower", "fuse-epilogue",
                                                 "grid"]


def test_run_pipeline_trace_backward_compat():
    assert run_pipeline(_gemm_graph(), "lower").trace == []
    t = run_pipeline(_gemm_graph(), "lower", dump=True).trace
    assert len(t) == 2 and t[0].startswith("== input ==")
    assert t[1].startswith("== after lower ==")


# ---- verification ----------------------------------------------------------


def _valid_kernel():
    a = Buffer("a", TensorType((4, 4)))
    i = LoopVar("i", 4)
    body = [Loop(i, LoopKind.SEQUENTIAL,
                 [ZeroTile(TileRef(a, (AffineExpr.of(i), AffineExpr.of(None)),
                                   (1, 4)))])]
    return Kernel("k", params=[a], outputs=[a], scratch=[], body=body)


def test_verifier_accepts_wellformed():
    _valid_kernel().verify()


def test_verifier_rejects_duplicate_buffer_names():
    a = Buffer("a", TensorType((4, 4)))
    dup = Kernel("k", params=[a, Buffer("a", TensorType((2, 2)))],
                 outputs=[a], scratch=[], body=[])
    with pytest.raises(ValueError, match="duplicate buffer"):
        dup.verify()


def test_verifier_rejects_unbound_loop_var():
    a = Buffer("a", TensorType((4, 4)))
    ghost = LoopVar("ghost", 4)
    bad = Kernel("k", params=[a], outputs=[a], scratch=[],
                 body=[ZeroTile(TileRef(a, (AffineExpr.of(ghost),
                                            AffineExpr.of(None)), (1, 4)))])
    with pytest.raises(ValueError, match="unbound loop var"):
        bad.verify()


def test_verifier_rejects_hbm_scratch_and_nonparam_output():
    a = Buffer("a", TensorType((4, 4)))
    with pytest.raises(ValueError, match="HBM"):
        Kernel("k", params=[a], outputs=[a],
               scratch=[Buffer("s", TensorType((2, 2)), MemSpace.HBM)],
               body=[]).verify()
    with pytest.raises(ValueError, match="not a param"):
        Kernel("k", params=[a],
               outputs=[Buffer("o", TensorType((4, 4)))], scratch=[],
               body=[]).verify()


def test_passmanager_flags_pass_that_breaks_invariants():
    """A buggy pass whose output kernel fails verification is caught by the
    manager and attributed to the pass."""
    if "break-kernel" not in PASS_REGISTRY:
        @register_pass("break-kernel", "loop", "test-only: corrupt the kernel")
        def _break(k):
            k.scratch.append(Buffer("evil", TensorType((2, 2)), MemSpace.HBM))
            return k

    with pytest.raises(PassError, match="break-kernel"):
        PassManager.parse("lower,break-kernel").run(_gemm_graph())
    # without verification the corruption sails through (mlir-opt's
    # -verify-each=false): same pipeline, no error
    r = PassManager.parse("lower,break-kernel", verify=False).run(_gemm_graph())
    assert any(b.name == "evil" for b in r.artifact.scratch)


def test_register_pass_doc_defaults_to_docstring():
    if "docdemo" not in PASS_REGISTRY:
        @register_pass("docdemo", "loop")
        def _docdemo(k):
            """One-line summary used as the pass doc.

            Longer body that must not leak into the registry doc.
            """
            return k
    assert PASS_REGISTRY["docdemo"].doc == \
        "One-line summary used as the pass doc."
    assert all(pd.doc for pd in PASS_REGISTRY.values())


# ---- reproc CLI ------------------------------------------------------------


def _run_cli(argv):
    out = io.StringIO()
    rc = reproc.main(argv, out=out)
    return rc, out.getvalue()


def test_cli_acceptance_pipeline_dumps():
    """python -m repro.core.reproc --pipeline "lower;flatten"
    --dump-after-each emits per-pass timed IR dumps on the quickstart GEMM."""
    rc, out = _run_cli(["--pipeline", "lower;flatten", "--dump-after-each"])
    assert rc == 0
    assert "// ===== after lower (tensor," in out
    assert "// ===== after flatten-inner (loop," in out
    assert "ms" in out and "stagecc.kernel @" in out


def test_cli_roundtrip_printer_mode(tmp_path):
    rc, printed = _run_cli(["--gemm", "16x16x16", "--epilogue", "none"])
    assert rc == 0
    f = tmp_path / "m.ir"
    f.write_text(printed)
    rc2, reprinted = _run_cli(["--input", str(f)])
    assert rc2 == 0 and reprinted == printed


def test_cli_runs_pipeline_from_ir_file(tmp_path):
    rc, printed = _run_cli(["--gemm", "8x8x8", "--epilogue", "none",
                            "--pipeline", "lower{tile_m=4,tile_n=4,tile_k=4}"])
    assert rc == 0 and printed.startswith("stagecc.kernel @")
    f = tmp_path / "k.ir"
    f.write_text(printed)
    rc2, out = _run_cli(["--input", str(f), "--pipeline", "grid{vars=2}",
                         "--timing"])
    assert rc2 == 0
    assert "@grid" in out and "// per-pass timing" in out


def test_cli_errors_are_diagnosed():
    rc, _ = _run_cli(["--pipeline", "no-such-pass"])
    assert rc == 1
    rc, _ = _run_cli(["--input", "/nonexistent/file.ir"])
    assert rc == 1
    # zero dims raise TypeError inside tracing; must be a diagnostic, not
    # a traceback
    rc, _ = _run_cli(["--gemm", "0x16x32", "--pipeline", "lower"])
    assert rc == 1


def test_cli_unknown_pass_exits_nonzero_with_diagnostic(capsys):
    rc, out = _run_cli(["--pipeline", "frobnicate"])
    assert rc == 1 and out == ""
    err = capsys.readouterr().err
    assert "unknown pass 'frobnicate'" in err
    assert "registered:" in err          # the fix is listed right there


def test_cli_unknown_emit_level_exits_nonzero(capsys):
    # bad --emit levels are a diagnostic (exit code 2), not a traceback
    assert reproc.main(["--emit", "netlist"], out=io.StringIO()) == 2
    assert "invalid choice" in capsys.readouterr().err


def test_cli_unknown_emit_level_suggests(capsys):
    """A close misspelling earns a did-you-mean hint."""
    assert reproc.main(["--emit", "verilogg"], out=io.StringIO()) == 2
    err = capsys.readouterr().err
    assert "did you mean 'verilog'?" in err


def test_cli_unknown_pass_suggests(capsys):
    """Unknown pass diagnostics suggest the closest registered name."""
    rc, _ = _run_cli(["--pipeline", "lower,flaten-inner"])
    assert rc == 1
    err = capsys.readouterr().err
    assert "did you mean 'flatten-inner'?" in err


def test_cli_list_passes_shows_level_and_pattern_count():
    rc, out = _run_cli(["--list-passes"])
    assert rc == 0
    lines = {ln.split()[0]: ln for ln in out.splitlines()[1:] if ln.strip()}
    # canonicalize is level-agnostic and pattern-built
    assert "tensor/loop/hw" in lines["canonicalize"]
    ncanon = len(PASS_REGISTRY["canonicalize"].pattern_names)
    assert ncanon >= 6 and f" {ncanon} " in lines["canonicalize"]
    # ported schedule passes name their pattern count too
    assert " 1 " in lines["split"]
    # non-pattern passes show '-'
    assert " - " in lines["lower"]


def test_cli_output_file_for_emit(tmp_path):
    """-o/--output routes --emit artifacts to a file instead of stdout."""
    dst = tmp_path / "gemm.v"
    rc = reproc.main(["--gemm", "4x4x4", "--epilogue", "none",
                      "--emit", "verilog", "-o", str(dst)])
    assert rc == 0
    assert dst.read_text().startswith("// stagecc HwIR")


def test_cli_output_file_for_simulate_trace(tmp_path):
    """-o also captures the --simulate co-sim report and --trace events."""
    dst = tmp_path / "cosim.txt"
    rc = reproc.main(["--gemm", "4x4x4", "--epilogue", "none",
                      "--pipeline", "lower", "--simulate", "--trace",
                      "-o", str(dst)])
    assert rc == 0
    text = dst.read_text()
    assert "// cosim gemm_4x4x4_none" in text
    assert "observed=" in text and "modeled=" in text
    assert "// trace of gemm_4x4x4_none" in text


def test_cli_simulate_host_and_vcd(tmp_path):
    vcd = tmp_path / "gemm.vcd"
    rc, out = _run_cli(["--gemm", "4x4x4", "--epilogue", "none",
                        "--pipeline", "lower", "--simulate", "host",
                        "--vcd", str(vcd)])
    assert rc == 0
    assert "// transaction gemm_4x4x4_none over axi4" in out
    assert "dma_in" in out and "poll" in out
    assert vcd.read_text().startswith("$date")


def test_cli_trace_and_vcd_require_simulate(capsys):
    rc, _ = _run_cli(["--gemm", "4x4x4", "--trace"])
    assert rc == 2
    assert "--trace requires --simulate" in capsys.readouterr().err
    rc, _ = _run_cli(["--gemm", "4x4x4", "--vcd", "/tmp/x.vcd"])
    assert rc == 2
    assert "--vcd requires --simulate" in capsys.readouterr().err


def test_cli_simulate_rejects_emitted_text(capsys):
    rc, _ = _run_cli(["--gemm", "4x4x4", "--epilogue", "none",
                      "--pipeline", "lower,lower-to-hw,emit-verilog",
                      "--simulate"])
    assert rc == 1
    assert "cannot simulate emitted text" in capsys.readouterr().err


def test_cli_simulate_hw_input_skips_oracle(tmp_path):
    """Simulating a bare HwIR file still runs; the numeric check is
    skipped (no LoopIR stage in scope) and says so."""
    rc, hw_text = _run_cli(["--gemm", "4x4x4", "--epilogue", "none",
                            "--emit", "hw"])
    assert rc == 0
    f = tmp_path / "m.ir"
    f.write_text(hw_text)
    rc2, out = _run_cli(["--input", str(f), "--simulate"])
    assert rc2 == 0
    assert "numeric check" in out and "skipped" in out


def test_cli_list_passes_text():
    rc, out = _run_cli(["--list-passes"])
    assert rc == 0
    for name in ("lower", "flatten-inner", "grid", "emit-pallas"):
        assert name in out
    assert "-> flatten-inner" in out        # alias table


def test_docs_passes_md_in_sync():
    """docs/PASSES.md is generated from the registry; CI and this test fail
    if it goes stale.  Regenerate with:
        PYTHONPATH=src python -m repro.core.reproc --list-passes --markdown \
            > docs/PASSES.md
    """
    with open(os.path.join(DOCS, "PASSES.md")) as f:
        on_disk = f.read()
    assert on_disk.rstrip("\n") == _CLEAN_MD.rstrip("\n")


def test_markdown_reference_covers_all_builtin_passes():
    for name, doc in _BUILTIN_PASSES.items():
        assert f"`{name}`" in _CLEAN_MD
        assert doc in _CLEAN_MD
