"""MoE routing/dispatch unit tests (both execution paths)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import moe as moe_mod
from repro.models.layers import Maker
from repro.models.model import Model, RunConfig


def _cfg(E=4, K=2, cf=8.0):
    cfg = reduced(get_config("deepseek_v2_236b"))
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=E, top_k=K, capacity_factor=cf, num_shared=1))


def test_single_expert_equals_dense_ffn():
    """With E=1, K=1 MoE must equal a plain (gated) FFN + shared expert."""
    cfg = _cfg(E=1, K=1)
    mk = Maker("init", jax.random.PRNGKey(0))
    p = moe_mod.init_moe(cfg, mk)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_mod.apply_moe(p, x, cfg)
    # manual: norm -> expert 0 ffn (gates==1) + shared -> residual
    from repro.models.layers import rmsnorm
    h = rmsnorm(x, p["norm"], cfg.norm_eps).reshape(-1, cfg.d_model)
    act = jax.nn.silu
    hid = act(h @ p["w_gate"][0]) * (h @ p["w_up"][0])
    want = (hid @ p["w_down"][0])
    sh = act(h @ p["shared_gate"]) * (h @ p["shared_up"])
    want = want + sh @ p["shared_down"]
    want = x + want.reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4,
                               atol=2e-5)


def test_gates_normalised_and_topk():
    cfg = _cfg(E=8, K=3)
    model = Model(cfg, RunConfig(max_seq=32))
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, 8), jnp.int32)
    logits, _, aux = model.apply(params, tokens)
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) > 0


def test_capacity_zero_drops_all_routed():
    """cf -> 0 means every routed token drops; output = residual + shared."""
    cfg = _cfg(E=4, K=2, cf=1e-9)
    mk = Maker("init", jax.random.PRNGKey(0))
    p = moe_mod.init_moe(cfg, mk)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y, _ = moe_mod.apply_moe(p, x, cfg)
    # capacity = 1 minimum -> only 1 token per expert survives; most of the
    # routed contribution is gone but shapes/finiteness hold
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_aux_loss_uniform_routing_lower_bound():
    """Balanced routing gives aux ~= weight; concentrated routing higher."""
    cfg = _cfg(E=4, K=1)
    T, E = 1024, 4
    probs_uniform = jnp.full((T, E), 0.25)
    sel = jnp.zeros((T,), jnp.int32)  # all tokens to expert 0
    # direct formula check
    frac_balanced = jnp.full((E,), 0.25)
    aux_b = E * jnp.sum(frac_balanced * probs_uniform.mean(0))
    assert float(aux_b) == pytest.approx(1.0, rel=1e-5)


def test_moe_impl_knob():
    moe_mod.set_moe_impl("gspmd")
    assert moe_mod._MOE_IMPL == "gspmd"
    with pytest.raises(AssertionError):
        moe_mod.set_moe_impl("bogus")
    moe_mod.set_moe_impl("auto")
