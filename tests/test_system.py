"""End-to-end behaviour tests for the whole system.

Covers the paper's full pipeline (Fig. 1) driven through the public API,
plus a fault-injection train/restore cycle — the production story in one
test module.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import compile_gemm, run_pipeline, trace, spec
import repro.core.frontend as fe
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import Model, RunConfig
from repro.optim import schedule as sched
from repro.optim.optimizer import adamw
from repro.serve.engine import Engine, EngineConfig
from repro.train.step import TrainConfig, init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def test_fig1_pipeline_end_to_end():
    """SYCL-role python -> TensorIR -> LoopIR -> pallas kernel -> output
    matrices validated (the paper's §II.B 'accurate output matrices')."""
    def f(a, b):
        return fe.relu(fe.matmul(a, b))

    g = trace(f, [spec((32, 16)), spec((16, 8))])
    result = run_pipeline(
        g, "lower{tile_m=8,tile_n=8,tile_k=8},fuse-epilogue,"
           "grid{vars=3},emit-pallas", dump=True)
    assert len(result.trace) >= 4          # IR visible at each stage
    rng = np.random.default_rng(0)
    a = rng.standard_normal((32, 16)).astype(np.float32)
    b = rng.standard_normal((16, 8)).astype(np.float32)
    out = np.asarray(result.artifact(a, b))
    np.testing.assert_allclose(out, np.maximum(a @ b, 0), rtol=1e-4,
                               atol=1e-4)


def test_schedule_study_reproduces_paper_shape():
    """TABLE I + Fig. 3 in one assertion set."""
    sizes = (8, 32, 128)
    ratios, lanes = [], []
    for s in sizes:
        n = compile_gemm(s, s, s, schedule="nested",
                         want_jax=False, want_pallas=False)
        f = compile_gemm(s, s, s, schedule="inner_flattened",
                         want_jax=False, want_pallas=False)
        ratios.append(n.cycles.total / f.cycles.total)
        lanes.append((n.resources.compute_lanes, f.resources.compute_lanes))
    assert all(1.25 < r < 1.55 for r in ratios)
    assert all(l[0] == 1 for l in lanes)                  # nested: constant
    assert [l[1] for l in lanes] == [8, 32, 128]          # flat: ~ size


@pytest.mark.slow
def test_train_checkpoint_resume_generate(tmp_path):
    """Full lifecycle: train -> checkpoint -> resume -> serve."""
    cfg = reduced(get_config("minicpm_2b"), layers=2, d_model=48, vocab=96)
    model = Model(cfg, RunConfig(max_seq=64))
    opt = adamw(sched.make("wsd", peak=3e-3, warmup_steps=3,
                           total_steps=40), weight_decay=0.0)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=4, seed=0))
    step = jax.jit(make_train_step(model, opt, TrainConfig()))
    ckdir = str(tmp_path / "ck")

    # phase 1: 20 steps then stop (simulated preemption at step budget)
    t1 = Trainer(TrainerConfig(total_steps=20, checkpoint_every=10,
                               checkpoint_dir=ckdir, log_every=100),
                 step, pipe, log_fn=lambda s: None)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    t1.run(state)

    # phase 2: resume and finish
    t2 = Trainer(TrainerConfig(total_steps=40, checkpoint_every=20,
                               checkpoint_dir=ckdir, log_every=100),
                 step, pipe, log_fn=lambda s: None)
    state2 = init_state(model, opt, jax.random.PRNGKey(0))
    state2 = t2.run(state2)
    losses = [m["loss"] for m in t2.metrics_history]
    assert len(losses) == 20               # only the remaining 20 steps ran

    # serve from trained params
    eng = Engine(model, state2.params, EngineConfig(max_len=48))
    out = eng.generate(np.zeros((2, 8), np.int32), 4)
    assert out.shape == (2, 12)


def test_straggler_detection_via_injection():
    cfg = reduced(get_config("minicpm_2b"), layers=2, d_model=32, vocab=64)
    model = Model(cfg, RunConfig(max_seq=32))
    opt = adamw(lambda s: 1e-3)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=2, seed=0))
    base_step = jax.jit(make_train_step(model, opt, TrainConfig()))
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        out = base_step(state, batch)
        if calls["n"] == 6:
            import time
            time.sleep(1.0)               # inject a straggler
        return out

    t = Trainer(TrainerConfig(total_steps=8, straggler_factor=3.0,
                              log_every=100), slow_step, pipe,
                log_fn=lambda s: None)
    t.run(init_state(model, opt, jax.random.PRNGKey(0)))
    assert t.straggler_events >= 1
