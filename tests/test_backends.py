"""Backend agreement: ref (numpy) vs jax (XLA) vs pallas emission."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pipeline import compile_gemm


@pytest.mark.parametrize("sched", ["tpu_mxu", "tpu_mxu_kgrid"])
@pytest.mark.parametrize("epilogue", ["none", "relu", "bias_relu"])
def test_three_backend_agreement(sched, epilogue):
    m, n, k = 16, 8, 12
    ck = compile_gemm(m, n, k, schedule=sched, tile={"m": 4, "n": 4, "k": 4},
                      epilogue=epilogue)
    rng = np.random.default_rng(7)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    args = (a, b)
    if epilogue == "bias_relu":
        args = (a, b, rng.standard_normal((n,)).astype(np.float32))
    ref = ck.run_ref(*args)[-1]
    jx = np.asarray(ck.run_jax(*args)[-1])
    np.testing.assert_allclose(jx, ref, rtol=1e-4, atol=1e-4)
    assert ck.run_pallas is not None, "pallas emission failed"
    pal = np.asarray(ck.run_pallas(*args))
    np.testing.assert_allclose(pal, ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(mt=st.sampled_from([2, 4, 8]), nt=st.sampled_from([2, 4, 8]),
       kt=st.sampled_from([2, 4, 8]),
       mm=st.integers(1, 3), nn=st.integers(1, 3), kk=st.integers(1, 3))
def test_pallas_emission_hypothesis(mt, nt, kt, mm, nn, kk):
    """Sweep tile/problem combinations through the full pipeline."""
    m, n, k = mt * mm, nt * nn, kt * kk
    ck = compile_gemm(m, n, k, schedule="tpu_mxu_kgrid",
                      tile={"m": mt, "n": nt, "k": kt})
    rng = np.random.default_rng(m * 64 + n * 8 + k)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    want = a @ b
    assert ck.run_pallas is not None
    np.testing.assert_allclose(np.asarray(ck.run_pallas(a, b)), want,
                               rtol=1e-4, atol=1e-4)


def test_scalar_schedules_ref_only():
    """nested / inner_flattened are scalar-datapath studies: ref + jax."""
    ck = compile_gemm(6, 6, 6, schedule="inner_flattened")
    rng = np.random.default_rng(3)
    a = rng.standard_normal((6, 6)).astype(np.float32)
    b = rng.standard_normal((6, 6)).astype(np.float32)
    np.testing.assert_allclose(ck.run_ref(a, b)[0], a @ b, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ck.run_jax(a, b)[0]), a @ b,
                               rtol=1e-4)


def test_bf16_gemm():
    ck = compile_gemm(128, 128, 128, schedule="tpu_mxu_kgrid",
                      dtype="bfloat16")
    rng = np.random.default_rng(9)
    a = rng.standard_normal((128, 128)).astype(np.float32)
    b = rng.standard_normal((128, 128)).astype(np.float32)
    pal = np.asarray(ck.run_pallas(a, b)).astype(np.float32)
    np.testing.assert_allclose(pal, a @ b, rtol=5e-2, atol=5e-1)
