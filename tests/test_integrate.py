"""Integration layer (AXI-wrapper analogue): differentiable + shardable
stagecc kernels inside jit/grad/shard_map."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.integrate import gemm_op, sharded_gemm_op


def test_custom_vjp_matches_reference():
    op = gemm_op(8, 8, 8, backend="xla")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)

    def loss_op(a, b):
        return jnp.sum(op(a, b) ** 2)

    def loss_ref(a, b):
        return jnp.sum((a @ b) ** 2)

    ga = jax.grad(loss_op, argnums=(0, 1))(a, b)
    gr = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    for x, y in zip(ga, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4,
                                   atol=1e-4)


def test_pallas_backend_forward():
    op = gemm_op(16, 16, 16, backend="pallas")
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    np.testing.assert_allclose(np.asarray(op(a, b)), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


def test_inside_jit_and_training_step():
    op = gemm_op(4, 4, 4, backend="xla")

    @jax.jit
    def step(w, x):
        def loss(w):
            return jnp.sum(op(x, w))
        g = jax.grad(loss)(w)
        return w - 0.1 * g

    w = jnp.eye(4)
    x = jnp.ones((4, 4))
    w2 = step(w, x)
    assert w2.shape == (4, 4)
    assert not np.allclose(np.asarray(w2), np.eye(4))


def test_sharded_gemm_under_mesh():
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    m = 8 * n
    op = sharded_gemm_op(mesh, m, 8, 8, backend="xla")
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((m, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    with mesh:
        out = jax.jit(op)(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


def test_sharded_gemm_rejects_indivisible():
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    if n == 1:
        pytest.skip("any m divides 1")
    with pytest.raises(ValueError):
        sharded_gemm_op(mesh, n + 1, 8, 8)
