"""Host↔device coupling tests: the paper's crossbar integration.

A full transaction (DMA in → CSR start → poll done → DMA out) must
round-trip a GEMM numerically, and the crossbar's latency/width must be
visible in the end-to-end cycle count.
"""

import numpy as np
import pytest

from repro.core import compile_gemm, host_bridge
from repro.core.host_bridge import AXI4, AXI4_LITE, Crossbar


def _ck(size=8, sched="nested", epilogue="none"):
    return compile_gemm(size, size, size, schedule=sched, epilogue=epilogue,
                        want_jax=False, want_pallas=False)


def _gemm_args(size, epilogue="none", seed=0):
    rng = np.random.default_rng(seed)
    args = [rng.standard_normal((size, size)).astype(np.float32),
            rng.standard_normal((size, size)).astype(np.float32)]
    if epilogue == "bias_relu":
        args.append(rng.standard_normal((size,)).astype(np.float32))
    return args


# ---- acceptance: the full transaction round-trips a GEMM --------------------


def test_transaction_roundtrips_gemm_numerically():
    ck = _ck(8)
    a, b = _gemm_args(8)
    tr = host_bridge.run_transaction(ck.hw_module, [a, b])
    want = np.asarray(ck.run_ref(a, b)[-1])
    np.testing.assert_allclose(tr.outputs[-1], want, atol=1e-5)
    # phase structure is the paper's Fig.-1 flow, in order
    assert [p.name for p in tr.phases] == \
        ["csr_setup", "dma_in", "start", "device", "poll", "dma_out"]
    # the device run is embedded, and the host adds real overhead
    assert tr.device_cycles == tr.sim.cycles.total
    assert tr.total_cycles > tr.device_cycles
    assert tr.host_overhead_cycles == tr.total_cycles - tr.device_cycles


def test_transaction_with_epilogue_kernel():
    ck = _ck(8, sched="tpu_mxu", epilogue="bias_relu")
    args = _gemm_args(8, epilogue="bias_relu")
    tr = host_bridge.run_transaction(ck.hw_module, args)
    want = np.asarray(ck.run_ref(*args)[-1])
    np.testing.assert_allclose(tr.outputs[-1], want, atol=1e-5)


def test_compiled_kernel_simulate_host_wrapper():
    ck = _ck(8)
    a, b = _gemm_args(8)
    tr = ck.simulate_host(a, b)
    want = np.asarray(ck.run_ref(a, b)[-1])
    np.testing.assert_allclose(tr.outputs[-1], want, atol=1e-5)
    assert "transaction" in tr.summary()


# ---- crossbar parameters move the observed cycle count ----------------------


def test_crossbar_latency_reflected_in_cycles():
    ck = _ck(8)
    a, b = _gemm_args(8)
    base = host_bridge.run_transaction(ck.hw_module, [a, b], crossbar=AXI4)
    laggy = host_bridge.run_transaction(
        ck.hw_module, [a, b],
        crossbar=Crossbar("slow", data_width_bits=128, latency_cycles=500))
    assert laggy.total_cycles > base.total_cycles
    # 3 DMA bursts (2 in + 1 out): the latency delta is fully visible
    assert laggy.total_cycles - base.total_cycles == 3 * (500 - 24)


def test_crossbar_width_reflected_in_cycles():
    ck = _ck(16)
    args = _gemm_args(16)
    wide = host_bridge.run_transaction(ck.hw_module, args, crossbar=AXI4)
    narrow = host_bridge.run_transaction(ck.hw_module, args,
                                         crossbar=AXI4_LITE)
    wide_dma = sum(p.cycles for p in wide.phases if p.name.startswith("dma"))
    narrow_dma = sum(p.cycles for p in narrow.phases
                     if p.name.startswith("dma"))
    assert narrow_dma > wide_dma      # 32b beats move 4x less than 128b


def test_poll_interval_quantises_completion():
    ck = _ck(8)
    a, b = _gemm_args(8)
    fine = host_bridge.run_transaction(ck.hw_module, [a, b],
                                       poll_interval=16)
    coarse = host_bridge.run_transaction(ck.hw_module, [a, b],
                                         poll_interval=4096)
    # done is only visible at a poll edge: a coarse interval rounds the
    # device run up towards the next multiple of the interval
    coarse_poll = next(p for p in coarse.phases if p.name == "poll")
    assert coarse_poll.cycles >= 4096 - coarse.device_cycles % 4096


def test_crossbar_validation():
    with pytest.raises(ValueError, match="multiple of 8"):
        Crossbar("bad", data_width_bits=12)


# ---- CSR block --------------------------------------------------------------


def test_csr_map_covers_every_port():
    ck = _ck(8, epilogue="bias_relu")
    fields = host_bridge.csr_map(ck.hw_module)
    names = [f.name for f in fields]
    assert names[:3] == ["CTRL", "STATUS", "CYCLES"]
    for p in ck.hw_module.ports:
        assert f"{p.name.upper()}_ADDR" in names
        assert f"{p.name.upper()}_LEN" in names
    offsets = [f.offset for f in fields]
    assert len(set(offsets)) == len(offsets)        # no overlap
    assert offsets == sorted(offsets)


def test_transaction_csr_trace_records_handshake():
    ck = _ck(8)
    tr = host_bridge.run_transaction(ck.hw_module, _gemm_args(8))
    ops = [(op, reg) for _, op, reg, _ in tr.csr_trace]
    assert ("write", "CTRL") in ops
    assert any(op == "read" and reg.startswith("STATUS") for op, reg in ops)
    assert ("read", "CYCLES") in ops
    # the CYCLES readback reports the observed device cycle count
    cycles_val = [v for _, op, reg, v in tr.csr_trace if reg == "CYCLES"]
    assert cycles_val == [tr.device_cycles]


def test_csr_trace_timestamps_advance_per_access():
    """CSR accesses are stamped at issue time: setup writes advance one
    access apart, STATUS polls land one poll_interval apart during the
    device run, and the whole trace is chronological."""
    ck = _ck(8)
    tr = host_bridge.run_transaction(ck.hw_module, _gemm_args(8),
                                     poll_interval=64)
    stamps = [t for t, _, _, _ in tr.csr_trace]
    assert stamps == sorted(stamps)
    setup = [t for t, op, reg, _ in tr.csr_trace
             if op == "write" and reg != "CTRL"]
    assert len(set(setup)) == len(setup)        # not all at one instant
    assert setup[1] - setup[0] == tr.crossbar.csr_access_cycles
    polls = [t for t, _, reg, _ in tr.csr_trace if reg == "STATUS"]
    assert len(polls) >= 2
    assert polls[1] - polls[0] == 64
    # phase costs account for every cycle of the transaction
    assert tr.total_cycles == sum(p.cycles for p in tr.phases)


# ---- hierarchical modules: only parent ports are host-visible ---------------


def _outlined_mlp():
    """Two identical matmul+relu layers, tiled and outlined — a module
    with sub-module definitions and a binding table."""
    from repro.core import frontend as fe, hw_ir
    from repro.core.passes import PassManager

    def mlp(x, w1, w2):
        return fe.relu(fe.matmul(fe.relu(fe.matmul(x, w1)), w2))

    g = fe.trace(mlp, [fe.spec((8, 8))] * 3, name="mlp2")
    k = PassManager.parse(
        "lower{tile_m=4,tile_n=4,tile_k=4}").run(g).artifact
    hw = PassManager.parse("canonicalize,outline-subcircuits,share-units") \
        .run(hw_ir.lower_to_hw(k)).artifact
    return hw


def test_csr_map_hierarchical_module_only_parent_ports():
    hw = _outlined_mlp()
    assert hw.submodules, "outliner produced no sub-module definitions"
    fields = host_bridge.csr_map(hw)
    names = {f.name for f in fields}
    # every parent port is mapped...
    for p in hw.ports:
        assert f"{p.name.upper()}_ADDR" in names
        assert f"{p.name.upper()}_LEN" in names
    # ...and ONLY parent ports: sub-module ports are internal wiring,
    # not host-addressable DMA targets
    parent = {p.name for p in hw.ports}
    for sub in hw.submodules:
        for p in sub.ports:
            if p.name not in parent:
                assert f"{p.name.upper()}_ADDR" not in names, \
                    f"sub-module port {p.name} leaked into the CSR map"
    addr_len = [f for f in fields
                if f.name.endswith(("_ADDR", "_LEN"))]
    assert len(addr_len) == 2 * len(hw.ports)


def test_run_transaction_roundtrips_outlined_mlp():
    hw = _outlined_mlp()
    rng = np.random.default_rng(7)
    x, w1, w2 = (rng.standard_normal((8, 8)).astype(np.float32)
                 for _ in range(3))
    tr = host_bridge.run_transaction(hw, [x, w1, w2])
    want = np.maximum(np.maximum(x @ w1, 0.0) @ w2, 0.0)
    np.testing.assert_allclose(tr.outputs[-1], want, atol=1e-4)
    # DMA is priced over parent ports only
    assert [p.name for p in tr.phases] == \
        ["csr_setup", "dma_in", "start", "device", "poll", "dma_out"]
    setup = next(p for p in tr.phases if p.name == "csr_setup")
    assert setup.cycles == 2 * len(hw.ports) * tr.crossbar.csr_access_cycles


# ---- error paths: arity, shape, dtype, poll timeout -------------------------


def test_transaction_rejects_wrong_input_arity():
    ck = _ck(8)
    a, b = _gemm_args(8)
    with pytest.raises(ValueError, match="input buffer"):
        host_bridge.run_transaction(ck.hw_module, [a, b, a])


def test_transaction_rejects_shape_mismatch():
    ck = _ck(8)
    a, b = _gemm_args(8)
    with pytest.raises(ValueError, match="shape"):
        host_bridge.run_transaction(ck.hw_module, [a[:4], b])


def test_transaction_rejects_dtype_mismatch():
    ck = _ck(8)
    a, b = _gemm_args(8)
    with pytest.raises(ValueError, match="dtype"):
        host_bridge.run_transaction(ck.hw_module,
                                    [a.astype(np.float64), b])


def test_transaction_poll_timeout_path():
    ck = _ck(8)
    a, b = _gemm_args(8)
    # a tiny interval needs many polls; a budget of 1 poll must trip
    with pytest.raises(host_bridge.PollTimeout, match="poll"):
        host_bridge.run_transaction(ck.hw_module, [a, b],
                                    poll_interval=16, poll_timeout=1)
    # a generous budget passes and the transaction is unchanged
    tr = host_bridge.run_transaction(ck.hw_module, [a, b],
                                     poll_interval=16, poll_timeout=10**6)
    want = np.asarray(ck.run_ref(a, b)[-1])
    np.testing.assert_allclose(tr.outputs[-1], want, atol=1e-5)
    with pytest.raises(ValueError, match="poll_timeout"):
        host_bridge.run_transaction(ck.hw_module, [a, b], poll_timeout=0)


def test_crossbar_preset_lookup():
    assert host_bridge.crossbar_preset("axi4") is AXI4
    assert host_bridge.crossbar_preset("AXI4_Lite") is AXI4_LITE
    with pytest.raises(KeyError, match="unknown crossbar preset"):
        host_bridge.crossbar_preset("AXI4_LTE")
