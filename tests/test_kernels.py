"""Per-kernel allclose sweeps against the pure-jnp oracles in ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_chunked, ssd_scan


# ---- GEMM (stagecc-generated) ---------------------------------------------


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 64),
                                   (64, 192, 256), (96, 96, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(shape, dtype):
    m, n, k = shape
    rng = np.random.default_rng(sum(shape))
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = np.asarray(ops.matmul(a, b, backend="pallas")).astype(np.float32)
    want = np.asarray(ref.gemm_ref(a, b))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


# ---- flash attention ---------------------------------------------------------


@pytest.mark.parametrize("sq,sk,d,causal,window", [
    (128, 128, 64, True, None),
    (128, 128, 64, False, None),
    (64, 128, 32, True, 32),
    (256, 256, 64, True, 128),
    (128, 256, 128, True, None),
])
def test_flash_attention_vs_ref(sq, sk, d, causal, window):
    rng = np.random.default_rng(sq + sk + d)
    q = jnp.asarray(rng.standard_normal((3, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((3, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((3, sk, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    want = jax.vmap(lambda qq, kk, vv: ref.attention_ref(
        qq, kk, vv, causal=causal, window=window))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64, 128]))
def test_flash_attention_block_invariance(bq, bk):
    """Output must not depend on the BlockSpec tiling choice."""
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32)
    a = flash_attention(q, k, v, block_q=bq, block_k=bk)
    b = flash_attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.bfloat16)
    got = np.asarray(flash_attention(q, k, v)).astype(np.float32)
    want = np.asarray(jax.vmap(lambda a, b, c: ref.attention_ref(a, b, c))(
        q, k, v)).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


# ---- SSD ---------------------------------------------------------------------


def _ssd_inputs(S, H, P, N, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((S, H, P)), jnp.float32),
            jnp.asarray(np.abs(rng.standard_normal((S, H))) * 0.1, jnp.float32),
            jnp.asarray(-np.abs(rng.standard_normal(H)), jnp.float32),
            jnp.asarray(rng.standard_normal((S, N)), jnp.float32),
            jnp.asarray(rng.standard_normal((S, N)), jnp.float32),
            jnp.asarray(rng.standard_normal(H), jnp.float32))


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_pallas_vs_naive(chunk):
    x, dt, A, B, C, D = _ssd_inputs(128, 4, 16, 8)
    want = np.asarray(ref.ssd_ref(x, dt, A, B, C, D))
    got = np.asarray(ssd_scan(x, dt, A, B, C, D, chunk=chunk))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(S=st.sampled_from([32, 64, 128]), H=st.sampled_from([1, 2, 4]),
       P=st.sampled_from([8, 16]), N=st.sampled_from([4, 8]))
def test_ssd_chunked_hypothesis(S, H, P, N):
    x, dt, A, B, C, D = _ssd_inputs(S, H, P, N, seed=S + H + P)
    want = np.asarray(ref.ssd_ref(x, dt, A, B, C, D))
    got = np.asarray(ssd_chunked(x, dt, A, B, C, D, chunk=16))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_ssd_chunk_invariance():
    """Chunk size is a schedule choice — results must be identical."""
    x, dt, A, B, C, D = _ssd_inputs(128, 2, 8, 4, seed=11)
    a = np.asarray(ssd_chunked(x, dt, A, B, C, D, chunk=16))
    b = np.asarray(ssd_chunked(x, dt, A, B, C, D, chunk=64))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---- RG-LRU oracle sanity -----------------------------------------------------


def test_rglru_ref_decays():
    S, D = 32, 8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((S, D)), jnp.float32)
    ag = jnp.full((S, D), 10.0)          # strong gate -> a ~ exp(-8*softplus)
    ig = jnp.full((S, D), 10.0)          # input gate ~ 1
    a_param = jnp.full((D,), 5.0)
    h = ref.rglru_ref(x, ag, ig, a_param)
    assert np.isfinite(np.asarray(h)).all()
    # with a ~ 0, h_t ~ x_t (no memory): check correlation
    np.testing.assert_allclose(np.asarray(h[5:]), np.asarray(x[5:]),
                               atol=2e-2)


# ---- decode attention ---------------------------------------------------------


def test_decode_attention_vs_ref():
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    rng = np.random.default_rng(7)
    B, KV, rep, hd, Smax = 3, 2, 4, 32, 512
    q = jnp.asarray(rng.standard_normal((B, KV, rep, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KV, Smax, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KV, Smax, hd)), jnp.float32)
    valid = jnp.asarray([17, 256, 511], jnp.int32)
    got = decode_attention(q, k, v, valid, block_k=128)
    want = decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("valid0", [1, 100, 512])
def test_decode_attention_valid_boundaries(valid0):
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    rng = np.random.default_rng(valid0)
    q = jnp.asarray(rng.standard_normal((1, 1, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 512, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 512, 16)), jnp.float32)
    valid = jnp.asarray([valid0], jnp.int32)
    got = decode_attention(q, k, v, valid, block_k=256)
    want = decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
