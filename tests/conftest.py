import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # The dev container has no hypothesis and cannot install packages;
    # fall back to a deterministic stub (see _hypothesis_stub.py).
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import install
    install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests")
