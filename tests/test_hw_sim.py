"""HwSim co-simulation tests: the hardware level *executes*.

Numerics must match the LoopIR numpy oracle (the paper's "accurate
output matrices" check) and the observed cycle count must track the
analytic machine model (the paper's Vivado-simulation cycle readout).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import SCHEDULES, compile_gemm, hw_sim, ir_text, machine_model
from repro.core.hw_ir import HwLoop, HwStep
from repro.core.passes import PassError, PassManager
from repro.core.reproc import quickstart_gemm


def _gemm_args(size, epilogue="none", seed=0):
    rng = np.random.default_rng(seed)
    args = [rng.standard_normal((size, size)).astype(np.float32),
            rng.standard_normal((size, size)).astype(np.float32)]
    if epilogue == "bias_relu":
        args.append(rng.standard_normal((size,)).astype(np.float32))
    return args


def _ck(size, sched, epilogue="none"):
    return compile_gemm(size, size, size, schedule=sched, epilogue=epilogue,
                        want_jax=False, want_pallas=False)


# ---- acceptance: every schedule, sizes {4, 8, 16} ---------------------------


@pytest.mark.parametrize("sched", SCHEDULES)
@pytest.mark.parametrize("size", [4, 8, 16])
def test_cosim_matches_oracle_and_model(sched, size):
    """CompiledKernel.simulate: outputs within 1e-5 of backend_ref and
    observed cycles within ±10% of machine_model.cycles.

    On deviation: the simulator takes its per-event unit latencies from
    ``machine_model.step_cycles`` (one source of truth) and its @stream
    double-buffer replays the same engine-concurrency assumption the
    analytic model makes, so the observed/modeled gap is float-rounding
    only (~0%) — any real divergence would be a scheduling bug, and the
    ±10% band is the contract that catches it.
    """
    ck = _ck(size, sched)
    rep = ck.simulate(*_gemm_args(size))
    assert rep.checked
    assert rep.max_abs_err <= 1e-5
    assert abs(rep.cycle_ratio - 1.0) <= 0.10
    # fsm-only schedules have no overlap scheduling at all: exact match
    if sched in ("nested", "inner_flattened"):
        assert rep.observed_cycles == rep.modeled_cycles


@pytest.mark.parametrize("sched", ["nested", "tpu_mxu"])
def test_cosim_with_epilogue(sched):
    ck = _ck(8, sched, epilogue="bias_relu")
    rep = ck.simulate(*_gemm_args(8, epilogue="bias_relu"))
    assert rep.checked and rep.max_abs_err <= 1e-5
    assert abs(rep.cycle_ratio - 1.0) <= 0.10


@pytest.mark.slow
def test_cosim_large_gemm():
    """Large-simulation smoke (slow marker): 32³ scalar-MAC events."""
    ck = _ck(32, "nested")
    rep = ck.simulate(*_gemm_args(32))
    assert rep.max_abs_err <= 1e-5
    assert rep.observed_cycles == rep.modeled_cycles
    assert rep.sim.steps_retired > 32 ** 3


# ---- the simulator catches broken hardware ----------------------------------


def test_cosim_detects_numeric_corruption():
    """Dropping the accumulate role on the matmul datapath (acc -> write)
    loses the k-reduction; co-sim must flag it, not bless it."""
    ck = _ck(8, "nested")
    mod = ck.hw_module
    for node, _, _ in mod.walk():
        if isinstance(node, HwStep) and node.op == "matmul":
            node.operands[0] = dataclasses.replace(node.operands[0],
                                                   role="write")
    with pytest.raises(hw_sim.SimMismatch, match="max\\|err\\|"):
        hw_sim.cosim(mod, ck.kernel, _gemm_args(8))


def test_simulate_rejects_bad_inputs():
    ck = _ck(4, "nested")
    a, b = _gemm_args(4)
    with pytest.raises(hw_sim.SimError, match="input ports"):
        hw_sim.simulate(ck.hw_module, [a, b, a])          # too many
    with pytest.raises(hw_sim.SimError, match="shape"):
        hw_sim.simulate(ck.hw_module, [a[:2], b])         # wrong shape


def test_unbound_input_channels_read_zeros():
    """Trailing unbound input ports read zeros — HBM-temporary semantics,
    matching the numpy oracle's allocation rule."""
    ck = _ck(4, "nested")
    rep = hw_sim.simulate(ck.hw_module)
    assert all(np.all(rep.storage[n] == 0) for n in rep.out_ports)


# ---- trace + VCD ------------------------------------------------------------


def test_trace_records_every_retired_step():
    ck = _ck(4, "nested")
    rep = hw_sim.simulate(ck.hw_module, _gemm_args(4), trace=True)
    steps = [ev for ev in rep.trace if ev.kind == "step"]
    assert len(steps) == rep.steps_retired
    # trace cycles are monotone non-decreasing up to stream reclaim
    cycles = [ev.cycle for ev in rep.trace]
    assert cycles == sorted(cycles)
    assert rep.trace[-1].kind == "done"
    text = rep.format_trace()
    assert "mac" in text and "%i1" in text


def test_trace_truncates_at_cap():
    ck = _ck(8, "nested")
    rep = hw_sim.simulate(ck.hw_module, _gemm_args(8), trace=True,
                          max_trace_events=10)
    assert rep.trace_truncated and len(rep.trace) == 10


def test_vcd_dump_shape():
    ck = _ck(4, "nested")
    rep = hw_sim.simulate(ck.hw_module, _gemm_args(4), trace=True)
    vcd = rep.vcd()
    assert vcd.startswith("$date")
    assert "$enddefinitions $end" in vcd
    for counter in rep.counters:
        assert f" {counter} $end" in vcd
    stamps = [int(ln[1:]) for ln in vcd.splitlines() if ln.startswith("#")]
    assert stamps[-1] >= rep.cycles.total


@pytest.mark.parametrize("sched", SCHEDULES)
def test_vcd_timestamps_strictly_ascend(sched):
    """VCD requires ascending simulation times even though @stream
    overlap reclaim can step the raw trace clock backwards."""
    ck = _ck(8, sched)
    rep = hw_sim.simulate(ck.hw_module, _gemm_args(8), trace=True)
    stamps = [int(ln[1:]) for ln in rep.vcd().splitlines()
              if ln.startswith("#")]
    assert all(b > a for a, b in zip(stamps, stamps[1:]))


# ---- parsed modules simulate too (textual IR carries full semantics) --------


def test_parsed_hw_module_simulates_identically():
    """The textual HwIR now carries address generators, so a module that
    round-trips through text must produce bit-identical simulation."""
    ck = _ck(8, "inner_flattened")
    args = _gemm_args(8)
    want = hw_sim.simulate(ck.hw_module, args)
    mod2 = ir_text.parse_hw_module(str(ck.hw_module))
    got = hw_sim.simulate(mod2, args)
    assert got.cycles == want.cycles
    for name in want.out_ports:
        np.testing.assert_array_equal(got.storage[name], want.storage[name])


# ---- the `simulate` verification pass ---------------------------------------


def test_simulate_pass_gates_the_pipeline():
    g = quickstart_gemm(8, 8, 8, epilogue="none")
    res = PassManager.parse("lower,lower-to-hw,simulate,emit-verilog").run(g)
    assert isinstance(res.artifact, str)
    names = [r.name for r in res.records]
    assert names == ["lower", "lower-to-hw", "simulate", "emit-verilog"]
    assert [r.level for r in res.records] == ["tensor", "loop", "hw", "hw"]


def test_simulate_pass_needs_hw_level():
    g = quickstart_gemm(8, 8, 8, epilogue="none")
    with pytest.raises(PassError, match="hw-level pass"):
        PassManager.parse("lower,simulate").run(g)


def test_random_inputs_deterministic():
    ck = _ck(8, "nested")
    a = hw_sim.random_inputs(ck.hw_module, seed=7)
    b = hw_sim.random_inputs(ck.hw_module, seed=7)
    assert len(a) == sum(1 for p in ck.hw_module.ports
                         if p.direction == "in")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
