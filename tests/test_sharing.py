"""Hierarchical HwIR: subcircuit outlining, the binding scheduler, the
serialization cost contract, and the new textual syntax (PR 9)."""

import io

import pytest

from repro.core import dse, frontend as fe, hw_ir, hw_sim, ir_text, \
    machine_model, reproc
from repro.core.hw_ir import (HwBinding, HwInstance, HwModule, HwPort,
                              HwUnit)
from repro.core.machine_model import TPU_V5E
from repro.core.passes import PassManager
from repro.core.pipeline import compile_gemm
from repro.core.rewrite import canonicalize
from repro.core.sharing import (SHARING_MODES, outline_subcircuits,
                                set_sharing, share_units)


def _clone(mod):
    return ir_text.parse_hw_module(ir_text.print_hw_module(mod))


def _mlp():
    """Two identical matmul+relu layers — the canonical outlining
    subject — plus its scheduled kernel (the cosim oracle)."""

    def mlp(x, w1, w2):
        return fe.relu(fe.matmul(fe.relu(fe.matmul(x, w1)), w2))

    g = fe.trace(mlp, [fe.spec((8, 8))] * 3, name="mlp2")
    k = PassManager.parse(
        "lower{tile_m=4,tile_n=4,tile_k=4}").run(g).artifact
    return hw_ir.lower_to_hw(k), k


def _flat_gemm():
    ck = compile_gemm(8, 8, 8, schedule="inner_flattened",
                      want_jax=False, want_pallas=False)
    return ck.hw_module, ck.kernel


# --------------------------------------------------------------------------
# outlining
# --------------------------------------------------------------------------


def test_outline_folds_repeated_layers_into_instanced_submodules():
    mod, _ = _mlp()
    outline_subcircuits(mod)
    # both layers share one matmul-nest def and one relu-nest def
    assert len(mod.submodules) == 2
    insts = [n for n in mod.ctrl if isinstance(n, HwInstance)]
    assert len(insts) == 4
    by_def = {s.name: sum(1 for i in insts if i.module == s.name)
              for s in mod.submodules}
    assert all(n == 2 for n in by_def.values()), by_def
    mod.verify()


def test_outlined_module_cosims_exactly():
    mod, kernel = _mlp()
    set_sharing(mod, "share")
    assert mod.submodules, "outlining found nothing to fold"
    rep = hw_sim.cosim(mod, kernel, hw_sim.random_inputs(mod))
    assert rep.checked and rep.max_abs_err <= 1e-5
    assert abs(rep.cycle_ratio - 1.0) <= 0.10


def test_outline_and_share_reach_fixpoint_in_one_rerun():
    """The CI share-smoke contract: a second run of the full sharing
    pipeline over its own printed output changes nothing (instances are
    not re-outlined, bound units are not re-bound)."""
    mod, _ = _mlp()
    res = PassManager.parse(
        "canonicalize,outline-subcircuits,share-units").run(mod)
    once = ir_text.print_ir(res.artifact)
    again = PassManager.parse(
        "canonicalize,outline-subcircuits,share-units").run(
        ir_text.parse_hw_module(once))
    assert ir_text.print_ir(again.artifact) == once


def test_hierarchical_text_roundtrips_at_fixpoint():
    mod, _ = _mlp()
    set_sharing(mod, "share")
    text = ir_text.print_hw_module(mod)
    assert ir_text.print_hw_module(ir_text.parse_hw_module(text)) == text


def test_outlined_verilog_emits_defs_and_instantiations():
    mod, _ = _mlp()
    set_sharing(mod, "share")
    v = hw_ir.emit_verilog(mod)
    for sub in mod.submodules:
        assert f"module mlp2_{sub.name}" in v      # one real def each
        assert f"mlp2_{sub.name} " in v            # ...and instantiations


# --------------------------------------------------------------------------
# the binding scheduler + serialization pricing
# --------------------------------------------------------------------------


def test_share_folds_duplicate_units_behind_bindings():
    mod, kernel = _flat_gemm()          # un-canonicalized: duplicate vpus
    before = mod.total_lanes()
    share_units(mod)
    assert mod.bindings, "scheduler bound nothing"
    assert mod.total_lanes() < before
    assert mod.shared_unit_count() >= 1
    # share mode is free: serial=1 bindings change zero cycles
    assert all(b.serial == 1 for b in mod.bindings)
    rep = hw_sim.cosim(mod, kernel, hw_sim.random_inputs(mod))
    assert rep.checked and rep.max_abs_err <= 1e-5


def test_serialize_trades_cycles_for_area_symmetrically():
    mod, kernel = _flat_gemm()
    base_cycles = machine_model.cycles(mod, TPU_V5E).total
    base_area = dse.area(_canon_clone(mod))
    set_sharing(mod, "serialize")
    assert any(b.serial > 1 for b in mod.bindings)
    priced = machine_model.cycles(mod, TPU_V5E).total
    assert priced > base_cycles          # serialization is not free
    assert dse.area(mod) < base_area     # ...but it is smaller
    # the simulator charges the identical stall formula: cosim holds
    rep = hw_sim.cosim(mod, kernel, hw_sim.random_inputs(mod))
    assert rep.checked and rep.max_abs_err <= 1e-5
    assert abs(rep.cycle_ratio - 1.0) <= 0.10


def _canon_clone(mod):
    c = _clone(mod)
    canonicalize(c)
    return c


def test_serialize_shrinks_area_at_least_20pct_on_builtin_schedule():
    """The PR's headline acceptance number, pinned."""
    mod, _ = _flat_gemm()
    before = dse.area(_canon_clone(mod))
    after = _canon_clone(mod)
    set_sharing(after, "serialize")
    assert dse.area(after) <= 0.8 * before, \
        (dse.area(after), before)


@pytest.mark.parametrize("kname", ("flash", "decode", "ssd"))
@pytest.mark.parametrize("mode", ("share", "serialize"))
def test_serving_kernels_cosim_with_sharing_enabled(kname, mode):
    g = reproc.kernel_graph(kname)
    kernel = PassManager.parse("lower").run(g).artifact
    mod = hw_ir.lower_to_hw(kernel)
    set_sharing(mod, mode)
    rep = hw_sim.cosim(mod, kernel, hw_sim.random_inputs(mod))
    assert rep.checked and rep.max_abs_err <= 1e-5
    assert abs(rep.cycle_ratio - 1.0) <= 0.10


def test_set_sharing_rejects_unknown_mode_naming_choices():
    mod, _ = _flat_gemm()
    with pytest.raises(ValueError, match="none/share/serialize"):
        set_sharing(mod, "everything")
    assert set(SHARING_MODES) == {"none", "share", "serialize"}


# --------------------------------------------------------------------------
# interplay with canonicalization (regressions)
# --------------------------------------------------------------------------


def test_dedupe_units_refuses_bound_units():
    """Canonicalize after serialize must keep the binding table — folding
    a bound unit into an unbound twin would silently drop the
    serialization accounting."""
    mod, kernel = _flat_gemm()
    set_sharing(mod, "serialize")
    bindings = list(mod.bindings)
    priced = machine_model.cycles(mod, TPU_V5E).total
    canonicalize(mod)
    assert mod.bindings == bindings
    assert machine_model.cycles(mod, TPU_V5E).total == priced
    rep = hw_sim.cosim(mod, kernel, hw_sim.random_inputs(mod))
    assert rep.checked and rep.max_abs_err <= 1e-5


def test_orphan_submodule_pruned_under_its_own_stat():
    """A sub-module def with no remaining instance is dropped by
    canonicalize — and the elimination is visible in the pattern stats,
    never silent."""
    mod, _ = _mlp()
    outline_subcircuits(mod)
    # orphan every instance of the first def
    victim = mod.submodules[0].name
    mod.ctrl = [n for n in mod.ctrl
                if not (isinstance(n, HwInstance) and n.module == victim)]
    res = PassManager.parse("canonicalize").run(mod)
    stats = res.records[0].pattern_stats
    assert stats.get("prune-unused-module", 0) >= 1, stats
    assert victim not in {s.name for s in res.artifact.submodules}
    res.artifact.verify()


def test_prune_keeps_physical_units_reached_only_via_bindings():
    mod, _ = _flat_gemm()
    share_units(mod)
    phys = {b.unit for b in mod.bindings}
    canonicalize(mod)
    assert phys <= {u.name for u in mod.units}
    mod.verify()


# --------------------------------------------------------------------------
# textual diagnostics for the new syntax
# --------------------------------------------------------------------------


def _hw_lines(*body):
    lines = ["stagecc.hw @m {"] + list(body) + ["}"]
    return "\n".join(lines)


def test_parse_inst_unknown_submodule_names_line():
    text = _hw_lines(
        "  port in a: float32[4] @hbm",
        "  ctrl {",
        "    inst @nosuch(read a[0 : 4])",
        "  }")
    with pytest.raises(ir_text.IRParseError) as ei:
        ir_text.parse_hw_module(text)
    assert "unknown submodule @nosuch" in str(ei.value)
    assert ei.value.lineno == 4
    assert "inst @nosuch" in str(ei.value)


def test_parse_bind_to_undeclared_unit_names_line():
    text = _hw_lines(
        "  port in a: float32[4] @hbm",
        "  unit u0: vpu<4> x1",
        "  bind u9 -> phantom serial=2 copies=1",
        "  ctrl {",
        "    step relu u0(write a[0 : 4], read a[0 : 4])",
        "  }")
    with pytest.raises(ir_text.IRParseError) as ei:
        ir_text.parse_hw_module(text)
    assert "no unit named 'phantom'" in str(ei.value)
    assert ei.value.lineno == 4
    assert "bind u9 -> phantom" in str(ei.value)


def test_parse_inst_portmap_arity_mismatch_names_line():
    text = _hw_lines(
        "  module @sub {",
        "    port in p0: float32[4] @hbm",
        "    port out p1: float32[4] @hbm",
        "    unit u0: vpu<4> x1",
        "    ctrl {",
        "      step relu u0(write p1[0 : 4], read p0[0 : 4])",
        "    }",
        "  }",
        "  port in a: float32[4] @hbm",
        "  port out b: float32[4] @hbm",
        "  ctrl {",
        "    inst @sub(read a[0 : 4])",
        "  }")
    with pytest.raises(ir_text.IRParseError) as ei:
        ir_text.parse_hw_module(text)
    assert "port map has 1 operands" in str(ei.value)
    assert "declares 2 ports" in str(ei.value)
    assert ei.value.lineno == 13
    assert "inst @sub" in str(ei.value)


# --------------------------------------------------------------------------
# verifier, pricing surface, DSE + CLI wiring
# --------------------------------------------------------------------------


def test_verify_rejects_binding_to_undeclared_unit():
    mod = HwModule(name="m",
                   ports=[HwPort("a", "in", "float32", (4,))],
                   regs=[], mems=[],
                   units=[HwUnit("u0", "vpu", (4,), 1)], ctrl=[])
    mod.bindings.append(HwBinding("v0", "ghost", 2, 1))
    with pytest.raises(ValueError, match="binding v0 -> ghost"):
        mod.verify()


def test_resource_report_carries_sharing_breakdown():
    mod, _ = _flat_gemm()
    set_sharing(mod, "serialize")
    r = machine_model.resources(mod, TPU_V5E)
    assert r.total_lanes == mod.total_lanes()
    assert r.shared_units == mod.shared_unit_count() >= 1
    assert r.mux_bits == mod.mux_bits()
    # peak lane pressure (the budget/Fig.3 quantity) stays distinct
    assert r.compute_lanes == mod.lane_count()


def test_dse_space_contains_sharing_families_and_csv_breakdown(
        tmp_path, monkeypatch):
    monkeypatch.setenv("STAGECC_DSE_CACHE", str(tmp_path / "cache"))
    g = reproc.quickstart_gemm(8, 8, 8, epilogue="none")
    pts = dse.enumerate_points(g)
    fams = {p.family for p in pts}
    assert {"shared", "flat_serialized"} <= fams
    for p in pts:
        if p.family in ("shared", "flat_serialized"):
            PassManager.parse(p.pipeline)
            PassManager.parse(p.hw_pipeline)
    res = dse.explore(g)
    header = res.to_csv().splitlines()[0]
    assert header.startswith("family,spec,cycles")
    assert header.endswith("total_lanes,mux_bits,shared_units")
    assert any(c.point.family == "flat_serialized" and c.feasible
               for c in res.candidates)


def test_cli_unknown_kernel_suggests_and_exits_2(capsys):
    assert reproc.main(["--kernel", "flsh"], out=io.StringIO()) == 2
    err = capsys.readouterr().err
    assert "did you mean 'flash'?" in err
    assert reproc.main(["--kernel", "zzz"], out=io.StringIO()) == 2
    err = capsys.readouterr().err
    assert "unknown kernel 'zzz'" in err and "flash, decode, ssd" in err
