"""Dry-run plumbing: cell applicability, input specs, and one real
subprocess cell on the production mesh (slow)."""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro.configs.base import ARCHS, get_config
from repro.models.model import SHAPES, cell_applicable, input_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_long_context_skip_policy():
    runs = {a: cell_applicable(get_config(a), "long_500k")[0] for a in ARCHS}
    assert runs["mamba2_130m"] and runs["recurrentgemma_2b"] \
        and runs["gemma3_4b"]
    for a in ("qwen1_5_32b", "qwen2_7b", "minicpm_2b", "deepseek_v2_236b",
              "kimi_k2_1t", "pixtral_12b", "whisper_base"):
        assert not runs[a], f"{a} must skip long_500k"


def test_all_other_cells_applicable():
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_applicable(get_config(a), s)[0]


def test_cell_count_is_33():
    n = sum(cell_applicable(get_config(a), s)[0]
            for a in ARCHS for s in SHAPES)
    assert n == 33


@pytest.mark.parametrize("arch", ["qwen2_7b", "pixtral_12b", "whisper_base"])
def test_input_specs_shapes(arch):
    cfg = get_config(arch)
    sp = input_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096)
    assert sp["labels"].shape == (256, 4096)
    if cfg.frontend == "image_patches":
        assert sp["extra_embeds"].shape == (256, cfg.frontend_len,
                                            cfg.d_model)
    if cfg.frontend == "audio_frames":
        assert sp["extra_embeds"].shape[1] == cfg.encoder.context
    dec = input_specs(cfg, "decode_32k")
    assert dec["tokens"].shape == (128, 1)


def test_input_specs_no_allocation():
    sp = input_specs(get_config("kimi_k2_1t"), "train_4k")
    for v in sp.values():
        assert not hasattr(v, "addressable_shards")   # abstract only


@pytest.mark.slow
def test_real_dryrun_cell_subprocess(tmp_path):
    """Compile one full-config cell on the 256-chip mesh in a subprocess
    (needs its own process for the 512-device XLA flag)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.load(open(tmp_path / "whisper-base__decode_32k__16x16.json"))
    assert out["roofline"]["bottleneck"] in ("compute", "memory",
                                             "collective")
    assert out["hlo_stats"]["flops"] > 0
