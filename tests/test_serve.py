"""Serving engine: generation, determinism, throughput probe."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import Model, RunConfig
from repro.serve.engine import (Engine, EngineConfig, real_token_count,
                                throughput_stats)


def _engine(arch="qwen2_7b", max_len=48, temp=0.0):
    cfg = reduced(get_config(arch))
    model = Model(cfg, RunConfig(max_seq=max_len))
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, EngineConfig(max_len=max_len,
                                              temperature=temp)), cfg


def test_generate_shapes():
    eng, cfg = _engine()
    prompts = np.zeros((3, 8), np.int32)
    out = eng.generate(prompts, 5)
    assert out.shape == (3, 13)
    assert out.min() >= 0 and out.max() < cfg.padded_vocab


def test_greedy_is_deterministic():
    eng, _ = _engine()
    prompts = np.arange(16, dtype=np.int32).reshape(2, 8)
    a = eng.generate(prompts, 6)
    b = eng.generate(prompts, 6)
    np.testing.assert_array_equal(a, b)


def test_sampled_tokens_within_true_vocab():
    """Padded logit columns must never be sampled."""
    eng, cfg = _engine(temp=1.0)
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate(prompts, 8)
    assert out.max() < cfg.vocab_size


def test_eos_early_stop():
    eng, cfg = _engine()
    prompts = np.zeros((1, 4), np.int32)
    # force eos on the first sampled token by learning nothing: just check
    # the loop respects an impossible eos (no early stop) vs eos=argmax
    full = eng.generate(prompts, 4, eos_id=None)
    assert full.shape[1] == 8


def test_recurrent_arch_serving():
    eng, cfg = _engine("recurrentgemma_2b")
    out = eng.generate(np.zeros((2, 6), np.int32), 4)
    assert out.shape == (2, 10)


def test_ssm_arch_serving():
    eng, cfg = _engine("mamba2_130m")
    out = eng.generate(np.zeros((2, 6), np.int32), 4)
    assert out.shape == (2, 10)


def test_throughput_stats():
    eng, _ = _engine()
    stats = throughput_stats(eng, np.zeros((2, 4), np.int32), 3)
    assert stats["tokens"] == 6
    assert stats["tok_per_s"] > 0


def test_eos_freezes_finished_rows():
    """Regression: once a row emits eos_id, every later position in that
    row must be eos_id — not whatever the decoder keeps sampling into
    the finished row."""
    eng, _ = _engine()
    prompts = np.arange(16, dtype=np.int32).reshape(2, 8)
    free = eng.generate(prompts, 6)
    eos = int(free[0, 8])                # row 0's first generated token
    out = eng.generate(prompts, 6, eos_id=eos)
    for row in out[:, 8:]:
        hits = np.flatnonzero(row == eos)
        if hits.size:
            assert (row[hits[0]:] == eos).all()
    # row 0 hits eos immediately, so it is fully frozen
    assert (out[0, 8:] == eos).all()
    # the eos run must agree with the free run up to each row's first eos
    np.testing.assert_array_equal(out[0, :9], free[0, :9])


def test_real_token_count():
    out = np.array([[7, 7, 3, 9, 9, 9],       # eos=9 at gen position 1
                    [7, 7, 4, 5, 6, 8]],      # never hits eos
                   np.int32)
    assert real_token_count(out, prompt_len=2) == 8
    assert real_token_count(out, prompt_len=2, eos_id=9) == 2 + 4
    assert real_token_count(out, prompt_len=2, eos_id=123) == 8


def test_throughput_counts_only_real_tokens():
    eng, _ = _engine()
    prompts = np.arange(16, dtype=np.int32).reshape(2, 8)
    eos = int(eng.generate(prompts, 1)[0, 8])
    stats = throughput_stats(eng, prompts, 6, eos_id=eos)
    full = throughput_stats(eng, prompts, 6)
    assert full["tokens"] == 12
    assert 0 < stats["tokens"] < full["tokens"]
