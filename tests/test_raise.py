"""Differential raising test matrix: traced JAX -> TensorIR.

Covers the PR-7 tentpole end to end:

  * for every config in the registry, every raisable forward-pass block
    must raise into TensorIR and match the traced JAX function on the
    example inputs (graph interpreter, then the compiled ref and jax
    backends through the full PassManager pipeline);
  * the raised flash / decode / ssd mirrors must be structurally
    ``is_equivalent`` to the hand-written ``frontend.*_graph`` builders;
  * scan lengths recovered by raising must agree with the ``while`` trip
    counts ``launch.hlo_analysis`` walks out of the XLA-optimized HLO;
  * a property-based fuzzer round-trips random programs from the
    supported vocabulary (raise -> print/parse fixpoint -> backends);
  * everything outside the vocabulary must fail with a diagnostic naming
    the primitive and the offending equation.

The general pallas emitter's numerics on non-matmul graphs are a known
pre-existing gap (tracked by test_kernels' xfails), so pallas is only
smoke-tested for successful emission here — numeric assertions run on
the ref and jax backends.
"""

import functools
import importlib
import io

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.frontend as fe
from repro.core import ir_text, reproc
from repro.configs.base import ARCHS

raising = importlib.import_module("repro.core.raise")

# big configs ride the slow lane (the reduced() shrink keeps shapes tiny,
# but MoE/MLA tracing is still the long pole of the matrix)
_SLOW = {"qwen1_5_32b", "deepseek_v2_236b", "kimi_k2_1t", "pixtral_12b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW else a
               for a in ARCHS]

_TILE8 = {"m": 8, "n": 8, "k": 8}


@functools.lru_cache(maxsize=None)
def _reports(arch):
    return {r.block: r for r in raising.raise_model_blocks(arch)}


def _expected(rep):
    return np.asarray(rep.fn(*rep.example_inputs), np.float32)


def _tol(exp, rel=1e-4):
    return rel * max(1.0, float(np.max(np.abs(exp))))


# --------------------------------------------------------------------------
# the differential matrix
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_matrix_raises_and_matches_ref(arch):
    """Every raisable block of every config: raised TensorIR executed by
    the graph interpreter matches the traced JAX function at 1e-4."""
    reps = _reports(arch)
    ok = [r for r in reps.values() if r.ok]
    assert ok, f"{arch}: no raisable blocks"
    for rep in ok:
        exp = _expected(rep)
        (got,) = rep.raised.run_ref(*rep.example_inputs)
        assert got.shape == exp.shape, rep.block
        np.testing.assert_allclose(got, exp, atol=_tol(exp), rtol=0,
                                   err_msg=f"{arch}:{rep.block}")
        assert rep.raised.lowerable, \
            f"{arch}:{rep.block} raised ops outside the lowerable set: " \
            f"{rep.raised.unlowerable_ops}"


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_matrix_compiles_through_pipeline(arch):
    """The largest raised block of each config compiles through the full
    PassManager pipeline and both the ref and jax backends match the
    traced JAX function at 1e-4."""
    reps = _reports(arch)
    rep = max((r for r in reps.values() if r.ok),
              key=lambda r: len(r.raised.graph.ops))
    sched = "nested" if rep.raised.scan_lengths else "tpu_mxu"
    ck = rep.raised.compile(tile=_TILE8, schedule=sched, want_pallas=False)
    exp = _expected(rep)
    for backend in ("ref", "jax"):
        (got,) = rep.raised.run_compiled(ck, *rep.example_inputs,
                                         backend=backend)
        np.testing.assert_allclose(
            got, exp, atol=_tol(exp), rtol=0,
            err_msg=f"{arch}:{rep.block} backend={backend}")


def test_expected_block_coverage():
    """Regression-pin which blocks raise per config family so a raiser
    change that silently loses a block fails loudly."""
    assert {b for b, r in _reports("qwen2_7b").items() if r.ok} == \
        {"rmsnorm", "mlp", "head", "attn_softmax"}
    assert {b for b, r in _reports("mamba2_130m").items() if r.ok} == \
        {"rmsnorm", "head", "ssd_core"}
    assert {b for b, r in _reports("recurrentgemma_2b").items() if r.ok} == \
        {"rmsnorm", "mlp", "head", "attn_softmax", "rglru_core"}
    # negatives stay negative, with real diagnostics
    rope = _reports("qwen2_7b")["rope"]
    assert not rope.ok and "slice" in rope.error
    router = _reports("deepseek_v2_236b")["moe_router"]
    assert not router.ok and "top_k" in router.error


def test_pallas_emission_smoke():
    """Raised graphs must at least *emit* a pallas kernel (numerics of the
    general emitter on ewise graphs are a pre-existing, separately
    tracked gap)."""
    rep = _reports("qwen2_7b")["rmsnorm"]
    ck = rep.raised.compile(tile=_TILE8)
    assert ck.run_pallas is not None


# --------------------------------------------------------------------------
# equivalence against the hand-written frontend builders
# --------------------------------------------------------------------------


def _assert_numeric_identical(rg, hand, shapes, rng, atol=1e-5):
    args = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    got = rg.graph.eval_np(*rg.bind(*args))
    want = hand.eval_np(*[a.reshape(v.type.shape)
                          for a, v in zip(args, hand.inputs)])
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=atol, rtol=0)


def test_flash_mirror_is_equivalent():
    rg = raising.reference_flash(8, 16, 4)
    hand = fe.flash_attention_graph(8, 16, 4)
    assert rg.graph.is_equivalent(hand), \
        f"raised:\n{ir_text.print_graph(rg.graph)}\n" \
        f"hand:\n{ir_text.print_graph(hand)}"
    _assert_numeric_identical(rg, hand, rg.arg_shapes,
                              np.random.default_rng(0))


def test_decode_mirror_is_equivalent():
    rg = raising.reference_decode(4, 16, 4)
    hand = fe.decode_attention_graph(4, 16, 4)
    assert rg.graph.is_equivalent(hand)
    _assert_numeric_identical(rg, hand, rg.arg_shapes,
                              np.random.default_rng(1))


def test_ssd_mirror_is_equivalent():
    rg = raising.reference_ssd(8, 2, 4)
    hand = fe.ssd_scan_graph(8, 2, 4)
    assert rg.graph.is_equivalent(hand)
    rng = np.random.default_rng(2)
    # decay in (0, 1) like the real kernel
    a = rng.uniform(0.2, 0.95, rg.arg_shapes[0]).astype(np.float32)
    rest = [rng.standard_normal(s).astype(np.float32)
            for s in rg.arg_shapes[1:]]
    got = rg.graph.eval_np(*rg.bind(a, *rest))
    want = hand.eval_np(a, *rest)
    np.testing.assert_allclose(got[0], want[0], atol=1e-5, rtol=0)


# --------------------------------------------------------------------------
# scan raising + HLO trip-count cross-check
# --------------------------------------------------------------------------


def test_cumsum_raises_to_scan():
    rg = raising.raise_jaxpr(lambda x: jnp.cumsum(x, axis=0), (8, 4))
    ops = {op.opname: op for op in rg.graph.ops}
    assert "scan" in ops
    assert ops["scan"].attrs["kind"] == "cumsum"
    assert rg.scan_lengths == [8]
    x = np.random.default_rng(3).standard_normal((8, 4)).astype(np.float32)
    np.testing.assert_allclose(rg.run_ref(x)[0], np.cumsum(x, axis=0),
                               atol=1e-5, rtol=0)


def test_linear_scan_raises_with_hlo_trip_crosscheck():
    """lax.scan of h = a*h + u raises to a linear scan op AND the recovered
    scan length must appear among the while-loop trip counts that
    launch.hlo_analysis walks out of the XLA-optimized module."""
    def fn(a, u, ct, g):
        return (raising._scan_linear(a, u) * ct) @ g

    rg = raising.raise_jaxpr(fn, (8, 16), (8, 16), (8, 16), (16, 2),
                             check_hlo_trips=True)
    ops = {op.opname: op for op in rg.graph.ops}
    assert ops["scan"].attrs["kind"] == "linear"
    assert rg.scan_lengths == [8]
    assert rg.hlo_trips and 8 in rg.hlo_trips.values()


def test_scan_rejects_nonlinear_body():
    def fn(u):
        def step(h, x):
            h = h * h + x          # quadratic in the carry
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), u)[1]

    with pytest.raises(raising.RaiseError) as ei:
        raising.raise_jaxpr(fn, (8, 4))
    assert "scan" in str(ei.value)


# --------------------------------------------------------------------------
# wiring: frontend delegators, reproc CLI, DSE
# --------------------------------------------------------------------------


def test_frontend_delegators():
    rg = fe.raise_jaxpr(lambda x: jnp.tanh(x) + 1.0, (4, 4))
    assert isinstance(rg, raising.RaisedGraph)
    reps = fe.raise_model_blocks("mamba2_130m")
    assert any(r.ok for r in reps)


def test_const_inputs_are_deduped():
    w = np.random.default_rng(4).standard_normal((4, 4)).astype(np.float32)
    rg = raising.raise_jaxpr(lambda x: (x + w) * w, (4, 4))
    # one user arg + ONE captured const, despite two uses of w
    assert rg.n_args == 1
    assert len(rg.graph.inputs) == 2
    assert set(rg.const_bindings) == {"c0"}


def test_reproc_raise_emits_tensorir():
    buf = io.StringIO()
    assert reproc.main(["--raise", "qwen2_7b:mlp"], out=buf) == 0
    text = buf.getvalue()
    assert "stagecc.func" in text and "matmul" in text


def test_reproc_raise_report_mode():
    buf = io.StringIO()
    assert reproc.main(["--raise", "qwen2_7b"], out=buf) == 0
    text = buf.getvalue()
    assert "RAISED" in text and "NOT RAISABLE" in text


@pytest.mark.filterwarnings("ignore:invalid value encountered")
def test_reproc_raise_pipeline_and_simulate():
    # random CLI inputs can drive rsqrt negative in BOTH cosim legs —
    # the outputs still agree bitwise, so the warning is noise here
    buf = io.StringIO()
    rc = reproc.main(["--raise", "qwen2_7b:rmsnorm",
                      "--pipeline", "lower{tile_m=8,tile_n=8,tile_k=8}",
                      "--simulate"], out=buf)
    assert rc == 0
    assert "cosim" in buf.getvalue()


def test_reproc_raise_cli_errors(capsys):
    # --raise is exclusive with the other graph sources
    assert reproc.main(["--raise", "qwen2_7b:mlp", "--gemm", "4x4x4"],
                       out=io.StringIO()) == 2
    # report mode takes no pipeline
    assert reproc.main(["--raise", "qwen2_7b", "--emit", "loop"],
                       out=io.StringIO()) == 2
    # unknown block names the available ones (diagnostic goes to stderr)
    assert reproc.main(["--raise", "qwen2_7b:nope"], out=io.StringIO()) == 1
    assert "mlp" in capsys.readouterr().err


def test_dse_explores_raised_region():
    rep = _reports("qwen2_7b")["rmsnorm"]
    res = rep.raised.explore(tiles=(8,), validate_top=1)
    assert res.frontier, "no feasible frontier point for the raised graph"
    assert res.validations and all(v.ok for v in res.validations)


# --------------------------------------------------------------------------
# property-based round-trip fuzzer
# --------------------------------------------------------------------------

# every step is shape-preserving over a (rows, cols) value, so random
# programs compose freely; consts are captured numpy arrays (exercising
# the lazy const materialization + dedup path)


def _const(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


_STEP_POOL = [
    ("tanh", lambda rng, r, c: (lambda v: jnp.tanh(v))),
    ("abs", lambda rng, r, c: (lambda v: jnp.abs(v))),
    ("neg", lambda rng, r, c: (lambda v: -v)),
    ("exp", lambda rng, r, c: (lambda v: jnp.exp(-jnp.abs(v)))),
    ("sigmoid", lambda rng, r, c: (lambda v: jax.nn.sigmoid(v))),
    ("sqrt", lambda rng, r, c: (lambda v: jnp.sqrt(jnp.abs(v) + 0.5))),
    ("log1p", lambda rng, r, c: (lambda v: jnp.log1p(jnp.abs(v)))),
    ("add", lambda rng, r, c: (lambda v, w=None: v + w,
                               _const(rng, r, c))),
    ("sub", lambda rng, r, c: (lambda v, w=None: v - w,
                               _const(rng, r, c))),
    ("mul_row", lambda rng, r, c: (lambda v, w=None: v * w,
                                   _const(rng, 1, c))),
    ("maximum", lambda rng, r, c: (lambda v, w=None: jnp.maximum(v, w),
                                   _const(rng, r, c))),
    ("div", lambda rng, r, c: (lambda v, w=None: v / (jnp.abs(w) + 0.7),
                               _const(rng, r, c))),
    ("softmax_shift", lambda rng, r, c:
        (lambda v: v - jnp.max(v, axis=1, keepdims=True))),
    ("l1_norm", lambda rng, r, c:
        (lambda v: v / (jnp.sum(jnp.abs(v), axis=1, keepdims=True) + 1.0))),
    ("matmul", lambda rng, r, c: (lambda v, w=None: v @ w,
                                  _const(rng, c, c))),
    ("cumsum", lambda rng, r, c: (lambda v: jnp.cumsum(v, axis=0))),
    ("scan_linear", lambda rng, r, c:
        (lambda v: raising._scan_linear(jax.nn.sigmoid(v), v))),
]


def _build_program(seed):
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(2, 7))
    cols = int(rng.integers(2, 6))
    n = int(rng.integers(2, 7))
    steps, names = [], []
    for _ in range(n):
        name, build = _STEP_POOL[int(rng.integers(len(_STEP_POOL)))]
        built = build(rng, rows, cols)
        if isinstance(built, tuple):
            f, w = built
            steps.append(functools.partial(lambda v, f, w: f(v, w), f=f, w=w))
        else:
            steps.append(built)
        names.append(name)

    def fn(x):
        v = x
        for s in steps:
            v = s(v)
        return v

    return fn, (rows, cols), names


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_fuzz_roundtrip(seed):
    fn, (rows, cols), names = _build_program(seed)
    rg = raising.raise_jaxpr(fn, (rows, cols), name=f"fuzz{seed}")

    # textual round-trip fixpoint: print(parse(print(g))) == print(g)
    text = ir_text.print_graph(rg.graph)
    assert ir_text.print_graph(ir_text.parse_graph(text)) == text, names

    x = np.random.default_rng(seed ^ 0x5EED).standard_normal(
        (rows, cols)).astype(np.float32)
    exp = np.asarray(fn(jnp.asarray(x)), np.float32)
    tol = _tol(exp)

    (got,) = rg.run_ref(x)
    np.testing.assert_allclose(got, exp, atol=tol, rtol=0, err_msg=str(names))

    # through the pipeline to the LoopIR reference interpreter; the
    # default tpu_mxu schedule (correctly) refuses to grid a scan's time
    # axis, so scan-bearing programs take the nested schedule
    sched = "nested" if rg.scan_lengths else "tpu_mxu"
    ck = rg.compile(tile={"m": 4, "n": 4, "k": 4}, schedule=sched,
                    want_jax=False, want_pallas=False)
    (got,) = rg.run_compiled(ck, x, backend="ref")
    np.testing.assert_allclose(got, exp, atol=tol, rtol=0, err_msg=str(names))


# --------------------------------------------------------------------------
# handler edge cases: the corners of the vocabulary
# --------------------------------------------------------------------------


def _check_fn(fn, *shapes, seed=7, atol=1e-5, **kw):
    rg = raising.raise_jaxpr(fn, *shapes, **kw)
    rng = np.random.default_rng(seed)
    args = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    exp = np.asarray(fn(*map(jnp.asarray, args)), np.float32)
    (got,) = rg.run_ref(*args)
    np.testing.assert_allclose(got, exp, atol=atol * max(1.0, float(
        np.max(np.abs(exp)))), rtol=0)
    return rg


def test_dot_rhs_transposed_emits_transpose():
    # einsum "ij,kj->ik": rhs contracts its LAST axis, so raising must
    # transpose the traced rhs before the matmul
    rg = _check_fn(lambda x, w: jnp.einsum("ij,kj->ik", x, w),
                   (6, 4), (5, 4))
    assert "transpose" in {op.opname for op in rg.graph.ops}


def test_dot_lhs_is_traced_transpose():
    rg = _check_fn(lambda x: x.T @ x, (6, 4))
    assert "transpose" in {op.opname for op in rg.graph.ops}


def test_dot_const_lhs_contraction_moved():
    # const lhs contracting axis 0 is fixed by folding a moveaxis
    w = np.random.default_rng(8).standard_normal((6, 3)).astype(np.float32)
    _check_fn(lambda x: jnp.einsum("ji,jk->ik", w, x), (6, 4))


def test_integer_pow():
    _check_fn(lambda x: x ** 2 + x ** 3, (4, 4))


def test_scalar_and_rank1_inputs():
    rg = _check_fn(lambda s: s * 2.0 + 1.0, ())
    assert rg.arg_shapes == [()]
    _check_fn(lambda v: jnp.exp(v) / 3.0, (5,))


def test_remat_call_is_inlined():
    _check_fn(jax.checkpoint(lambda x: jnp.tanh(x) * 2.0), (4, 4))


def test_nan_guard_select_is_identity():
    rg = _check_fn(
        lambda x: jnp.where(jnp.isnan(x), jnp.zeros_like(x), x), (4, 4))
    # the isnan/where pair folds away entirely — output is the input
    assert not rg.graph.ops


def test_broadcast_of_reduce_output():
    _check_fn(lambda x: jnp.broadcast_to(
        jnp.sum(x, axis=1, keepdims=True), x.shape) + x, (5, 3))


def test_bind_arity_error():
    rg = raising.raise_jaxpr(lambda x: x + 1.0, (4, 4))
    with pytest.raises(ValueError):
        rg.bind(np.zeros((4, 4), np.float32), np.zeros((4, 4), np.float32))


def test_scan_body_vocabulary():
    """The linearity analysis must see through div/neg/max/unaries/outer
    consts in the body as long as the carry enters linearly."""
    def f_div(a, u):
        def step(h, xs):
            at, ut = xs
            h = at * h + ut / (jnp.abs(ut) + 1.5)
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), (a, u))[1]

    def f_neg_max(a, u):
        def step(h, xs):
            at, ut = xs
            h = at * h - jnp.maximum(ut, 0.25)
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), (a, u))[1]

    w = np.random.default_rng(9).standard_normal((4,)).astype(np.float32)

    def f_outer_const(a, u):
        def step(h, xs):
            at, ut = xs
            h = jnp.tanh(at) * h + ut * w
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), (a, u))[1]

    rng = np.random.default_rng(10)
    a = rng.uniform(0.2, 0.95, (6, 4)).astype(np.float32)
    u = rng.standard_normal((6, 4)).astype(np.float32)
    for f in (f_div, f_neg_max, f_outer_const):
        rg = raising.raise_jaxpr(f, a, u)
        ops = {op.opname: op for op in rg.graph.ops}
        assert ops["scan"].attrs["kind"] == "linear", f.__name__
        exp = np.asarray(f(a, u), np.float32)
        np.testing.assert_allclose(rg.run_ref(a, u)[0], exp, atol=1e-5,
                                   rtol=0, err_msg=f.__name__)


def test_scan_body_neg_and_reshape_views():
    def fn(a, u):
        def step(h, xs):
            at, ut = xs
            h = at * h + (-ut).reshape(4)
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), (a, u))[1]

    def fn_neg_carry(a, u):
        def step(h, xs):
            at, ut = xs
            h = (-h) * (-at) + ut          # carry enters through a neg
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), (a, u))[1]

    def fn_jit_in_body(a, u):
        helper = jax.jit(lambda t: t * 2.0)
        def step(h, xs):
            at, ut = xs
            h = at * h + helper(ut)        # pjit call inlined in the body
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), (a, u))[1]

    rng = np.random.default_rng(11)
    a = rng.uniform(0.2, 0.95, (6, 4)).astype(np.float32)
    u = rng.standard_normal((6, 4)).astype(np.float32)
    for f in (fn, fn_neg_carry, fn_jit_in_body):
        rg = raising.raise_jaxpr(f, a, u)
        np.testing.assert_allclose(rg.run_ref(a, u)[0],
                                   np.asarray(f(a, u), np.float32),
                                   atol=1e-5, rtol=0, err_msg=f.__name__)


def test_rank1_reduce_output_orientation():
    # keepdims-free reduce leaves an (N, 1) value for a (N,) result; the
    # output leg must transpose it back to the (1, N) canonical layout
    rg = _check_fn(lambda x: jnp.sum(x, axis=1), (5, 3))
    assert rg.out_shapes == [(5,)]


def test_const_only_output_materialized():
    rg = raising.raise_jaxpr(lambda x: jnp.ones((3, 2), jnp.float32) * 2.0,
                             (4, 4))
    (got,) = rg.run_ref(np.zeros((4, 4), np.float32))
    np.testing.assert_allclose(got, np.full((3, 2), 2.0))


def test_unit_dim_reshape_is_identity():
    _check_fn(lambda x: (x[:, None, :] * 1.0).reshape(4, 4), (4, 4))


def test_scan_final_carry_only_rejected():
    def fn(u):
        return jax.lax.scan(lambda c, xt: (c + xt, c + xt),
                            jnp.zeros(4), u)[0]
    with pytest.raises(raising.RaiseError):
        raising.raise_jaxpr(fn, (6, 4))


_SCAN_REJECTS = [
    ("div_by_carry", lambda h, ut: ut / h),
    ("max_over_carry", lambda h, ut: jnp.maximum(h, ut)),
    ("tanh_of_carry", lambda h, ut: jnp.tanh(h) + ut),
]


@pytest.mark.parametrize("name,upd", _SCAN_REJECTS,
                         ids=[c[0] for c in _SCAN_REJECTS])
def test_scan_rejects_nonlinear_carry_uses(name, upd):
    def fn(u):
        def step(h, ut):
            h = upd(h, ut)
            return h, h
        return jax.lax.scan(step, jnp.zeros((4,)), u)[1]

    with pytest.raises(raising.RaiseError):
        raising.raise_jaxpr(fn, (6, 4))


_EDGE_NEGATIVES = [
    ("double contraction", "dot_general",
     lambda x: jax.lax.dot_general(x, x, (((0, 1), (0, 1)), ((), ())))),
    ("traced lhs contracts axis 0", "dot_general",
     lambda x: jnp.einsum("ji,jk->ik", x, x)),
    ("reduce over rows", "reduce_sum", lambda x: jnp.sum(x, axis=0)),
    ("reduce_min", "reduce_min", lambda x: jnp.min(x, axis=1)),
    ("cumsum along cols", "cumsum", lambda x: jnp.cumsum(x, axis=1)),
    ("reverse cumsum", "cumsum",
     lambda x: jax.lax.cumsum(x, axis=0, reverse=True)),
    ("data-dependent select", "select_n",
     lambda x: jnp.where(x > 0, x, -x)),
    ("integer_pow 4", "integer_pow", lambda x: x ** 4),
    ("comparison consumed as data", "gt", lambda x: (x > 0.0) * 1.0),
    ("int conversion", "convert_element_type",
     lambda x: x.astype(jnp.int32).astype(jnp.float32) * 1.0),
    ("non-unit reshape", "reshape", lambda x: x.reshape(2, 8)),
    ("reverse scan", "scan",
     lambda x: jax.lax.scan(lambda c, xt: (c + xt, c + xt),
                            jnp.zeros(4), x, reverse=True)[1]),
    ("two carries", "scan",
     lambda x: jax.lax.scan(
         lambda c, xt: ((c[0] + xt, c[1] + xt), c[0]),
         (jnp.zeros(4), jnp.zeros(4)), x)[1]),
    ("nonzero init", "scan",
     lambda x: jax.lax.scan(
         lambda c, xt: (c + xt, c + xt), jnp.ones(4), x)[1]),
]


@pytest.mark.parametrize("label,prim,fn", _EDGE_NEGATIVES,
                         ids=[c[0].replace(" ", "-") for c in _EDGE_NEGATIVES])
def test_edge_negatives_name_the_primitive(label, prim, fn):
    with pytest.raises(raising.RaiseError) as ei:
        raising.raise_jaxpr(fn, (4, 4))
    assert prim in str(ei.value), str(ei.value)


def test_rank1_rhs_dot_rejected():
    with pytest.raises(raising.RaiseError) as ei:
        raising.raise_jaxpr(lambda x, v: jnp.dot(x, v), (4, 4), (4,))
    assert "dot_general" in str(ei.value)


_NEGATIVE_CASES = [
    ("sin", lambda x: jnp.sin(x)),
    ("concatenate", lambda x: jnp.concatenate([x, x], axis=0)),
    ("top_k", lambda x: jax.lax.top_k(x, 2)[0]),
    ("sort", lambda x: jnp.sort(x, axis=1)),
    ("slice", lambda x: x[0:1, :]),
]


@pytest.mark.parametrize("prim,fn", _NEGATIVE_CASES,
                         ids=[c[0] for c in _NEGATIVE_CASES])
def test_negative_names_primitive_and_equation(prim, fn):
    with pytest.raises(raising.RaiseError) as ei:
        raising.raise_jaxpr(fn, (4, 4))
    msg = str(ei.value)
    assert prim in msg, msg
    assert "in equation" in msg, msg
