"""Elastic rescale: checkpoints restore onto a different mesh."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import checkpointer as ck
from repro.configs.base import get_config, reduced
from repro.distributed.sharding import axis_rules, tree_shardings
from repro.models.model import Model, RunConfig
from repro.optim.optimizer import adamw
from repro.train.step import init_state, state_axes, state_shapes

cfg = reduced(get_config('qwen2_7b'))
model = Model(cfg, RunConfig(max_seq=32))
opt = adamw(lambda s: 1e-3)

# train-state built and saved on a (4 data x 2 model) mesh
mesh_a = jax.make_mesh((4, 2), ('data', 'model'))
axes = state_axes(model, opt)
shapes = state_shapes(model, opt)
with mesh_a, axis_rules(mesh_a):
    sh_a = tree_shardings(axes, shapes, mesh_a)
    state = jax.jit(lambda k: init_state(model, opt, k),
                    out_shardings=sh_a)(jax.random.PRNGKey(0))
ck.save('{d}', 1, state)

# restore onto a (2 data x 4 model) mesh — the elastic path
mesh_b = jax.make_mesh((2, 4), ('data', 'model'))
with mesh_b, axis_rules(mesh_b):
    sh_b = tree_shardings(axes, shapes, mesh_b)
    restored, extra = ck.restore('{d}', target=state, shardings=sh_b)

for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
leaf = jax.tree.leaves(restored.params)[1]
assert leaf.sharding.mesh.shape == {{'data': 2, 'model': 4}}, leaf.sharding
print('elastic ok')
"""


@pytest.mark.slow
def test_elastic_reshard_across_meshes(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _CODE.format(d=str(tmp_path))],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "elastic ok" in r.stdout
