"""Failure injection: the FailoverRunner must survive step crashes and
produce the exact same final state as an uninterrupted run."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed.fault_tolerance import FailoverConfig, FailoverRunner
from repro.models.model import Model, RunConfig
from repro.optim.optimizer import adamw
from repro.train.step import TrainConfig, init_state, make_train_step


def _setup():
    cfg = reduced(get_config("minicpm_2b"), layers=2, d_model=32, vocab=64)
    model = Model(cfg, RunConfig(max_seq=32))
    opt = adamw(lambda s: 1e-3, weight_decay=0.0)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4, seed=7))
    step = jax.jit(make_train_step(model, opt, TrainConfig()))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    return model, opt, pipe, step, state


def test_failover_replays_to_identical_state(tmp_path):
    model, opt, pipe, step, state0 = _setup()

    # reference: uninterrupted 12 steps
    ref = state0
    for i in range(12):
        ref, _ = step(ref, pipe.jax_batch(i))

    # failure-injected: crash at steps 5 and 9
    crash_at = {5, 9}
    calls = {"n": -1}

    def flaky_step(state, batch):
        calls["n"] += 1
        # the crash happens "mid-step": raise before producing new state
        if calls["n"] in crash_at:
            raise RuntimeError("injected chip failure")
        return step(state, batch)

    runner = FailoverRunner(
        FailoverConfig(checkpoint_dir=str(tmp_path), checkpoint_every=4),
        flaky_step, lambda i: pipe.jax_batch(i), log_fn=lambda s: None)
    final, end_step = runner.run(init_state(
        model, opt, jax.random.PRNGKey(0)), 0, 12)

    assert end_step == 12
    assert runner.failures == 2
    assert runner.replayed_steps > 0
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(final.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_failover_gives_up_after_max_failures(tmp_path):
    model, opt, pipe, step, state0 = _setup()

    def always_fail(state, batch):
        raise RuntimeError("dead host")

    runner = FailoverRunner(
        FailoverConfig(checkpoint_dir=str(tmp_path), checkpoint_every=4,
                       max_failures=2),
        always_fail, lambda i: pipe.jax_batch(i), log_fn=lambda s: None)
    with pytest.raises(RuntimeError, match="exceeded"):
        runner.run(state0, 0, 4)
