// stagecc HwIR — module gemm_4x4x4_none
// fsm: 7 states, 41 register bits, 0 RAM bytes, 1 datapath lanes
module gemm_4x4x4_none (
  input  wire clk,
  input  wire rst,
  input  wire start,
  output reg  done,
  // arg0: float32[4x4] @hbm (in)
  output reg  [3:0] arg0_raddr,
  input  wire [31:0] arg0_rdata,
  // arg1: float32[4x4] @hbm (in)
  output reg  [3:0] arg1_raddr,
  input  wire [31:0] arg1_rdata,
  // matmul1: float32[4x4] @hbm (out)
  output reg  [3:0] matmul1_waddr,
  output reg  [31:0] matmul1_wdata,
  output reg  matmul1_wen
);

  // ---- control FSM: 7 states ----
  localparam S_IDLE = 3'd0;
  localparam S_0_I1 = 3'd1;
  localparam S_0_0_J2 = 3'd2;
  localparam S_0_0_0_ZERO = 3'd3;
  localparam S_0_0_1_K3 = 3'd4;
  localparam S_0_0_1_0_MATMUL = 3'd5;
  localparam S_0_0_2_COPY = 3'd6;
  reg [2:0] state;

  // ---- loop counters ----
  reg [1:0] i1;  // fsm loop, 4 trips
  reg [1:0] j2;  // fsm loop, 4 trips
  reg [1:0] k3;  // fsm loop, 4 trips

  // ---- register banks (VREG tiles) ----
  reg [31:0] acc4 [0:0];  // float32[1x1]

  // ---- datapath units ----
  stagecc_vpu #(.GEOMETRY("1")) vpu1 ();
  stagecc_mac #(.GEOMETRY("1x1")) mac2 ();
  stagecc_vpu #(.GEOMETRY("1")) vpu3 ();

  // ---- schedule ----
  always @(posedge clk) begin
    if (rst) begin
      state <= S_IDLE;
      done  <= 1'b0;
    end else begin
      case (state)
        S_IDLE: begin  // wait for start
          if (start) state <= S_0_I1;
          done <= 1'b0;
        end
        S_0_I1: begin  // fsm loop %i1: test/increment (4 trips)
          state <= S_0_0_J2;
        end
        S_0_0_J2: begin  // fsm loop %j2: test/increment (4 trips)
          state <= S_0_0_0_ZERO;
        end
        S_0_0_0_ZERO: begin  // invoke vpu1.zero(acc4)
          state <= S_0_0_1_K3;
        end
        S_0_0_1_K3: begin  // fsm loop %k3: test/increment (4 trips)
          state <= S_0_0_1_0_MATMUL;
        end
        S_0_0_1_0_MATMUL: begin  // invoke mac2.matmul(acc4, arg0, arg1)
          state <= S_0_0_2_COPY;
        end
        S_0_0_2_COPY: begin  // invoke vpu3.copy(matmul1, acc4)
          state <= S_IDLE;
          done  <= 1'b1;
        end
        default: state <= S_IDLE;
      endcase
    end
  end

endmodule
